"""Logical-axis sharding rules (MaxText-style), applied via ambient context.

Models annotate activations/params with *logical* axis names; a
``ShardingRules`` table maps those to physical mesh axes.  When no rules are
active (CPU smoke tests) every annotation is a no-op, so the same model code
runs single-device and on a 512-chip mesh.

Default logical axes:
  batch      -> ('pod', 'data')   data parallel
  seq        -> None              (or 'model' for sequence parallelism)
  heads/ff/vocab/experts -> 'model'   tensor/expert parallel
  kv_seq     -> 'model'           context-parallel decode (KV cache on seq)
  wt_fsdp    -> 'data'            ZeRO-3 weight shard (gathered per layer)
  layers     -> None              scan-stacked leading dim
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]


class ShardingRules(dict):
    """logical axis name -> mesh axis (str | tuple | None)."""

    def spec(self, logical_axes: Sequence[Optional[str]]) -> P:
        out = []
        for ax in logical_axes:
            v = self.get(ax) if ax is not None else None
            out.append(tuple(v) if isinstance(v, list) else v)
        return P(*out)


def default_rules(multi_pod: bool = False, fsdp_over_pod: bool = False,
                  seq_parallel: bool = False) -> ShardingRules:
    dp = ("pod", "data") if multi_pod else ("data",)
    fsdp = (("pod", "data") if (multi_pod and fsdp_over_pod) else ("data",))
    return ShardingRules(
        batch=dp,
        seq="model" if seq_parallel else None,
        moe_seq="model",
        heads="model",
        kv_heads="model",
        kv_seq="model",
        d_model=None,
        ff="model",
        vocab="model",
        experts="model",
        wt_fsdp=fsdp,
        layers=None,
        stage=None,
    )


class _State(threading.local):
    rules: Optional[ShardingRules] = None
    mesh: Optional[Mesh] = None


_STATE = _State()


@contextlib.contextmanager
def use_sharding_rules(mesh: Mesh, rules: ShardingRules):
    prev = (_STATE.rules, _STATE.mesh)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        with mesh:
            yield
    finally:
        _STATE.rules, _STATE.mesh = prev


def current_rules() -> Tuple[Optional[Mesh], Optional[ShardingRules]]:
    return _STATE.mesh, _STATE.rules


def logical_spec(*logical_axes) -> Optional[P]:
    _, rules = current_rules()
    if rules is None:
        return None
    return rules.spec(logical_axes)


def _axis_size(mesh: Mesh, v) -> int:
    names = (v,) if isinstance(v, str) else tuple(v)
    n = 1
    for name in names:
        n *= mesh.shape[name]
    return n


def resolve_spec(shape, logical_axes, mesh: Mesh, rules: ShardingRules) -> P:
    """Spec with per-dim divisibility fallback: a logical axis whose mesh
    extent does not divide the dim is replicated (e.g. GQA kv=2 heads on a
    16-way 'model' axis). A mesh axis consumed by an earlier dim is not
    reused (first dim wins): two logical axes may share a mesh axis in the
    rules (e.g. kv_seq and kv_heads both -> 'model'), and usually at most one
    survives the divisibility check — when both do, the later is replicated."""
    out = []
    used: set = set()
    for dim, ax in zip(shape, logical_axes):
        v = rules.get(ax) if ax is not None else None
        if isinstance(v, list):
            v = tuple(v)
        if v is not None:
            names = (v,) if isinstance(v, str) else tuple(v)
            if dim % _axis_size(mesh, v) != 0 or used & set(names):
                v = None
            else:
                used |= set(names)
        out.append(v)
    return P(*out)


def logical_shard(x, *logical_axes):
    """Annotate ``x`` with the sharding for these logical axes (no-op when no
    rules are active; non-divisible dims fall back to replication)."""
    mesh, rules = current_rules()
    if rules is None or mesh is None:
        return x
    spec = resolve_spec(x.shape, logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
