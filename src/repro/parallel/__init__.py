from repro.parallel.sharding import (ShardingRules, current_rules,
                                     logical_shard, logical_spec,
                                     use_sharding_rules)

__all__ = ["ShardingRules", "current_rules", "logical_shard", "logical_spec",
           "use_sharding_rules"]
