"""Deterministic synthetic data pipeline, host-sharded, restart-safe.

Design for 1000+ nodes:
  * **Stateless addressing**: batch ``i`` is a pure function of
    ``(seed, step)`` — restart at step N regenerates exactly the stream a
    checkpoint expects, with no data-state to snapshot and no replay log.
  * **Host sharding**: each host materializes only its slice of the global
    batch (``host_id / num_hosts``); arrays are assembled into global
    jax.Arrays via ``jax.make_array_from_process_local_data`` when running
    multi-host (single-host fallback: full batch).
  * **Prefetch**: a background thread keeps ``depth`` batches ahead so host
    data generation overlaps device compute.

The synthetic LM stream is a deterministic mixture (Zipfian unigram +
shift-structured spans) so losses are reproducible across runs and the
pipeline cost is realistic (vocab-range integers, not zeros).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    prefix_len: int = 0          # vlm: patch positions at the front
    d_model: int = 0             # for embeds/frames stubs
    mode: str = "tokens"         # tokens | embeds_prefix | frames


class SyntheticLMDataset:
    """Deterministic per-step synthetic batches (host-sharded)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.host_id]))
        B, S = self.local_batch, cfg.seq_len
        # Zipfian unigrams with shift structure (next-token partially
        # predictable => loss actually decreases when training works).
        zipf = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        base = np.minimum(zipf, cfg.vocab - 2).astype(np.int32)
        shifted = np.roll(base, 1, axis=1)
        use_prev = rng.random((B, S)) < 0.5
        tokens = np.where(use_prev, shifted, base).astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = 0
        out = {"tokens": tokens, "labels": labels,
               "loss_mask": np.ones((B, S), np.float32)}
        if cfg.mode == "embeds_prefix":
            out["embeds"] = rng.standard_normal(
                (B, cfg.prefix_len, cfg.d_model)).astype(np.float32)
            out["loss_mask"][:, :1] = 0.0
        elif cfg.mode == "frames":
            out["frames"] = rng.standard_normal(
                (B, S, cfg.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def prefetch(it: Iterator[dict], depth: int = 2) -> Iterator[dict]:
    """Background-thread prefetch."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item


def make_stencil_inputs(key, dims, has_aux: bool):
    g = jax.random.uniform(key, dims, jnp.float32, 0.5, 2.0)
    aux = None
    if has_aux:
        aux = jax.random.uniform(jax.random.fold_in(key, 1), dims,
                                 jnp.float32, 0.0, 0.1)
    return g, aux
