from repro.data.pipeline import (DataConfig, SyntheticLMDataset,
                                 make_stencil_inputs, prefetch)

__all__ = ["DataConfig", "SyntheticLMDataset", "make_stencil_inputs",
           "prefetch"]
