"""Stencil zoo — the four paper benchmarks (Table 2) plus a generic star stencil.

A stencil is described by:
  * its neighborhood (radius + offsets used),
  * an ``apply`` function written against an abstract neighbor *getter*, so the
    same arithmetic is reused by the unblocked oracle (kernels/ref.py), the
    pure-JAX blocked engine (core/engine.py) and the Pallas kernels
    (kernels/stencil2d.py, stencil3d.py),
  * bookkeeping constants matching the paper's Table 2 (FLOP and bytes per
    cell update, external reads/writes per cell update).

Boundary condition (paper §5.1): "all out-of-bound neighbors of grid cells on
the grid boundaries fall back on the boundary cell itself" — i.e. index clamp
/ edge replication, re-imposed at *every* time-step.  That clamp is only the
*default* here: ``repro.core.boundary`` makes the BC a per-axis parameter
(clamp / periodic / reflect / constant) honored by every backend.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax.numpy as jnp

# Neighbor getter: maps an offset tuple (dy, dx) or (dz, dy, dx) to the
# (shifted) array of that neighbor for every cell being updated.
Getter = Callable[[Sequence[int]], jnp.ndarray]

TEMP_AMB = 80.0  # Hotspot ambient temperature — compile-time constant (paper §5.1)


def _star_offsets(ndim: int, radius: int) -> tuple:
    """Axis-aligned (star) neighborhood: center + ±1..radius on each axis."""
    offs = []
    for axis in range(ndim):
        for d in range(-radius, radius + 1):
            off = [0] * ndim
            off[axis] = d
            offs.append(tuple(off))
    return tuple(dict.fromkeys(offs))  # dedup center


@dataclasses.dataclass(frozen=True)
class Stencil:
    name: str
    ndim: int                     # 1, 2 or 3
    radius: int
    flop_pcu: int                 # FLOPs per cell update      (Table 2)
    num_read: int                 # external reads per update  (Table 2)
    num_write: int                # external writes per update (Table 2)
    has_aux: bool                 # second input stream (Hotspot `power`)
    coeff_names: tuple            # scalar coefficients, passed at run time
    apply: Callable               # (get, coeffs, aux_center) -> updated center
    #: Neighbor offsets ``apply`` actually touches, stored at construction so
    #: non-star shapes (``make_box`` diagonals) report their true footprint.
    #: Defaults to the axis-aligned star — correct for every builtin.
    offsets: tuple = ()
    #: Number of input grids ``apply`` reads.  ``arity == 1`` (every classic
    #: stencil) gets a single neighbor getter; ``arity > 1`` (fan-in combine
    #: stages in a program DAG) gets a *tuple* of getters, one per input.
    arity: int = 1

    def __post_init__(self):
        if self.arity < 1:
            raise ValueError(f"{self.name}: arity must be >= 1")
        offs = self.offsets or _star_offsets(self.ndim, self.radius)
        object.__setattr__(self, "offsets",
                           tuple(tuple(int(d) for d in o) for o in offs))
        if any(len(o) != self.ndim for o in self.offsets):
            raise ValueError(f"{self.name}: offsets must be {self.ndim}-D")
        span = max((abs(d) for o in self.offsets for d in o), default=0)
        if span > self.radius:
            raise ValueError(
                f"{self.name}: offset span {span} exceeds radius "
                f"{self.radius} — halo sizing (rad*par_time) would be wrong")

    @property
    def bytes_pcu(self) -> int:
        """Bytes per cell update with full spatial-locality optimization."""
        return 4 * (self.num_read + self.num_write)

    @property
    def bytes_per_flop(self) -> float:
        return self.bytes_pcu / self.flop_pcu


def _diffusion2d(get: Getter, c: Mapping[str, jnp.ndarray], aux=None):
    # c_c*val_c + c_w*val_w + c_e*val_e + c_s*val_s + c_n*val_n  (9 FLOPs)
    return (c["cc"] * get((0, 0)) + c["cw"] * get((0, -1)) + c["ce"] * get((0, 1))
            + c["cs"] * get((1, 0)) + c["cn"] * get((-1, 0)))


def _diffusion3d(get: Getter, c: Mapping[str, jnp.ndarray], aux=None):
    # 7-point star (13 FLOPs); b(elow)/a(bove) are the z-neighbors.
    return (c["cc"] * get((0, 0, 0))
            + c["cw"] * get((0, 0, -1)) + c["ce"] * get((0, 0, 1))
            + c["cs"] * get((0, 1, 0)) + c["cn"] * get((0, -1, 0))
            + c["cb"] * get((-1, 0, 0)) + c["ca"] * get((1, 0, 0)))


def _hotspot2d(get: Getter, c: Mapping[str, jnp.ndarray], aux=None):
    # val_c + sdc*(power_c + (n+s-2c)*Ry1 + (e+w-2c)*Rx1 + (AMB-c)*Rz1)  (15 FLOPs)
    v = get((0, 0))
    return v + c["sdc"] * (
        aux
        + (get((-1, 0)) + get((1, 0)) - 2.0 * v) * c["ry1"]
        + (get((0, 1)) + get((0, -1)) - 2.0 * v) * c["rx1"]
        + (TEMP_AMB - v) * c["rz1"])


def _hotspot3d(get: Getter, c: Mapping[str, jnp.ndarray], aux=None):
    # val_c*cc + n*cn + s*cs + e*ce + w*cw + a*ca + b*cb + sdc*power + ca*AMB (17 FLOPs)
    return (get((0, 0, 0)) * c["cc"]
            + get((0, -1, 0)) * c["cn"] + get((0, 1, 0)) * c["cs"]
            + get((0, 0, 1)) * c["ce"] + get((0, 0, -1)) * c["cw"]
            + get((1, 0, 0)) * c["ca"] + get((-1, 0, 0)) * c["cb"]
            + c["sdc"] * aux + c["ca"] * TEMP_AMB)


DIFFUSION2D = Stencil("diffusion2d", 2, 1, 9, 1, 1, False,
                      ("cc", "cw", "ce", "cs", "cn"), _diffusion2d)
DIFFUSION3D = Stencil("diffusion3d", 3, 1, 13, 1, 1, False,
                      ("cc", "cw", "ce", "cs", "cn", "cb", "ca"), _diffusion3d)
HOTSPOT2D = Stencil("hotspot2d", 2, 1, 15, 2, 1, True,
                    ("sdc", "rx1", "ry1", "rz1"), _hotspot2d)
HOTSPOT3D = Stencil("hotspot3d", 3, 1, 17, 2, 1, True,
                    ("cc", "cn", "cs", "ce", "cw", "ca", "cb", "sdc"), _hotspot3d)

STENCILS = {s.name: s for s in (DIFFUSION2D, DIFFUSION3D, HOTSPOT2D, HOTSPOT3D)}


def make_combine(ndim: int, arity: int) -> Stencil:
    """Radius-0 elementwise combine — the fan-in node of a program DAG:

        out = w0*x0 + w1*x1 + ... + w_{n-1}*x_{n-1}

    With appropriate weights this expresses residuals (``r = f - A@u`` via
    ``combine(f, Au; w=(1,-1))``), time integrators (the wave equation's
    ``2u - u_prev + c*lap``), damping, and axis splitting — StencilFlow's
    "arithmetic nodes" (arXiv:2010.15218 §3).  ``apply`` receives a tuple of
    neighbor getters, one per input (``arity > 1``)."""
    if arity < 2:
        raise ValueError("make_combine needs arity >= 2 (use make_star(nd, 0)"
                         " for a single-input scale)")
    names = tuple(f"w{i}" for i in range(arity))
    center = tuple([0] * ndim)

    def _apply(gets, c, aux=None):
        out = c["w0"] * gets[0](center)
        for i in range(1, arity):
            out = out + c[f"w{i}"] * gets[i](center)
        return out

    return Stencil(f"combine{ndim}d_x{arity}", ndim, 0, 2 * arity - 1,
                   arity, 1, False, names, _apply, offsets=(center,),
                   arity=arity)


def make_star(ndim: int, radius: int) -> Stencil:
    """Generic star stencil of arbitrary radius (paper §8 future-work: high-order).

    u' = c0*u + sum_{axis,offset!=0} c_{axis,offset} * u[offset on axis]
    Coefficient names: ``c0`` and ``c_{axis}_{offset}``.
    """
    names = ["c0"]
    offs = []
    for axis in range(ndim):
        for d in range(-radius, radius + 1):
            if d == 0:
                continue
            names.append(f"c_{axis}_{d}")
            off = [0] * ndim
            off[axis] = d
            offs.append((f"c_{axis}_{d}", tuple(off)))
    n_neighbors = len(offs)
    flops = 2 * (n_neighbors + 1) - 1

    def _apply(get, c, aux=None, _offs=tuple(offs)):
        out = c["c0"] * get(tuple([0] * ndim))
        for cname, off in _offs:
            out = out + c[cname] * get(off)
        return out

    return Stencil(f"star{ndim}d_r{radius}", ndim, radius, flops, 1, 1, False,
                   tuple(names), _apply,
                   offsets=(tuple([0] * ndim),) + tuple(o for _, o in offs))


# 1D star stencils (stream axis only, no blocked dims) — the 1D kernel entry
# point: registered so `plan()` accepts 1D problems on every backend.
STAR1D_R1 = make_star(1, 1)
STAR1D_R2 = make_star(1, 2)
STENCILS[STAR1D_R1.name] = STAR1D_R1
STENCILS[STAR1D_R2.name] = STAR1D_R2


def make_box(ndim: int, radius: int) -> Stencil:
    """Generic box (dense-neighborhood) stencil: every cell within the
    L-inf ball of ``radius`` contributes (the paper's §6.4 "differently-
    shaped stencils" portability claim — a box is the densest same-order
    shape). (2r+1)^ndim coefficients named ``b_{offsets joined by _}``.
    """
    import itertools
    names = []
    offs = []
    for off in itertools.product(range(-radius, radius + 1), repeat=ndim):
        name = "b_" + "_".join(str(d) for d in off)
        names.append(name)
        offs.append((name, tuple(off)))
    flops = 2 * len(offs) - 1

    def _apply(get, c, aux=None, _offs=tuple(offs)):
        first, rest = _offs[0], _offs[1:]
        out = c[first[0]] * get(first[1])
        for cname, off in rest:
            out = out + c[cname] * get(off)
        return out

    return Stencil(f"box{ndim}d_r{radius}", ndim, radius, flops, 1, 1, False,
                   tuple(names), _apply, offsets=tuple(o for _, o in offs))


def default_coeffs(stencil: Stencil, dtype=jnp.float32) -> dict:
    """Reasonable physically-plausible coefficients (sum-preserving diffusion)."""
    if stencil.name == "diffusion2d":
        k = 0.125
        return {"cc": jnp.asarray(1 - 4 * k, dtype), "cw": jnp.asarray(k, dtype),
                "ce": jnp.asarray(k, dtype), "cs": jnp.asarray(k, dtype),
                "cn": jnp.asarray(k, dtype)}
    if stencil.name == "diffusion3d":
        k = 0.0833
        return {"cc": jnp.asarray(1 - 6 * k, dtype), "cw": jnp.asarray(k, dtype),
                "ce": jnp.asarray(k, dtype), "cs": jnp.asarray(k, dtype),
                "cn": jnp.asarray(k, dtype), "cb": jnp.asarray(k, dtype),
                "ca": jnp.asarray(k, dtype)}
    if stencil.name == "hotspot2d":
        return {"sdc": jnp.asarray(0.054, dtype), "rx1": jnp.asarray(0.1, dtype),
                "ry1": jnp.asarray(0.1, dtype), "rz1": jnp.asarray(0.0137, dtype)}
    if stencil.name == "hotspot3d":
        k = 0.07
        return {"cc": jnp.asarray(1 - 6 * k - 0.01, dtype),
                "cn": jnp.asarray(k, dtype), "cs": jnp.asarray(k, dtype),
                "ce": jnp.asarray(k, dtype), "cw": jnp.asarray(k, dtype),
                "ca": jnp.asarray(k, dtype), "cb": jnp.asarray(k, dtype),
                "sdc": jnp.asarray(0.054, dtype)}
    if stencil.name.startswith("combine"):
        # uniform convex combination (stable: weights sum to 1)
        n = len(stencil.coeff_names)
        return {name: jnp.asarray(1.0 / n, dtype)
                for name in stencil.coeff_names}
    if stencil.name.startswith("box"):
        # uniform averaging kernel (stable: coefficients sum to 1)
        n = len(stencil.coeff_names)
        return {name: jnp.asarray(1.0 / n, dtype)
                for name in stencil.coeff_names}
    # generic star: diffusion-like, stable
    n = len(stencil.coeff_names) - 1
    k = 0.5 / max(n, 1)
    out = {"c0": jnp.asarray(0.5, dtype)}
    for name in stencil.coeff_names[1:]:
        out[name] = jnp.asarray(k, dtype)
    return out
