"""Multi-device spatial distribution of the blocked stencil engine.

This implements the paper's stated future work (§8: "spatial distribution of
large stencils on multiple FPGAs") on a TPU mesh: the grid is domain-
decomposed over mesh axes via ``shard_map``; each device runs the *same*
combined spatial+temporal blocking locally; halos of width
``rad * par_time`` are exchanged with ``lax.ppermute`` **once per
super-step** — temporal blocking divides the number of exchanges (and thus
ICI latency events) by ``par_time``. That communication aggregation is the
distributed-optimization payoff of the paper's technique.

Key correctness points:
  * Received halos make a shard's local run exact up to ``rad*par_time``
    cells from its extended edge — exactly the overlapped-blocking argument
    one level up; the polluted rim is discarded at write-back.
  * Shards at true grid boundaries pass ``bounds`` to the engine so the
    boundary condition is re-imposed at the *global* edge (not the shard
    edge) every fused sub-step (DESIGN.md §2.1, ``core.boundary``): clamp/
    reflect gather from the mapped in-shard coordinate, constant fills the
    scalar.  Edge shards receive zero-filled halos from ``ppermute``
    (non-wrapping) — harmless, as bounds re-imposition makes those
    positions unread.
  * A **periodic** axis has no physical edge: its halo exchange runs on a
    wrap-around ``ppermute`` ring (the last shard's trailing strip is the
    first shard's leading halo and vice versa), every shard's bounds span
    the whole extended shard, and the local engine treats the axis as an
    internal seam (no re-imposition; the wrapped halo is an exact
    translated copy covered by garbage creep).
  * Elasticity: the decomposition is a pure function of (mesh, grid shape);
    restarting on a different mesh re-shards automatically.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.blocking import BlockGeometry
from repro.core.engine import (blocked_superstep, blocked_superstep_chain,
                               blocked_superstep_dag)
from repro.core.stencils import Stencil
from repro.programs import DagSpec, dag_radius
from repro.resilience.faults import fault_point, register_point

#: fires when a halo exchange is *built* — i.e. at trace time, once per
#: compiled program per sharded axis, NOT once per super-step (the exchange
#: itself runs inside jit).  An injected raise here models a mesh/collective
#: setup failure, which is how ICI faults actually surface to the host.
FP_EXCHANGE = register_point(
    "distributed.exchange", "at halo-exchange build (trace) time — models a "
    "collective/mesh setup failure")


def _linear_index(axis_names: Tuple[str, ...]) -> jnp.ndarray:
    """Linearized shard index over (possibly several) mesh axes."""
    idx = jax.lax.axis_index(axis_names[0])
    for name in axis_names[1:]:
        idx = idx * compat.axis_size(name) + jax.lax.axis_index(name)
    return idx


def _axis_total(axis_names: Tuple[str, ...]) -> int:
    n = 1
    for name in axis_names:
        n *= compat.axis_size(name)
    return n


def _exchange_halo(x: jnp.ndarray, grid_axis: int,
                   axis_names: Tuple[str, ...], h: int,
                   periodic: bool = False) -> jnp.ndarray:
    """Extend ``x`` with h-wide neighbor strips along ``grid_axis``.

    Neighbor ``i-1``'s trailing strip becomes our leading halo and vice
    versa.  Non-periodic: the outermost shards receive zeros (cleaned up by
    the bounds re-imposition).  Periodic: the ring wraps around the mesh —
    shard 0's leading halo is shard n-1's trailing strip, which IS the
    global periodic neighbor (no true-edge handling left to do locally).
    """
    fault_point(FP_EXCHANGE, {"axis": grid_axis, "halo": h,
                              "periodic": periodic})
    n = _axis_total(axis_names)
    lead = jax.lax.slice_in_dim(x, 0, h, axis=grid_axis)
    trail = jax.lax.slice_in_dim(x, x.shape[grid_axis] - h,
                                 x.shape[grid_axis], axis=grid_axis)
    perm_lo = [(j, (j + 1) % n) for j in range(n)] if periodic else \
        [(j, j + 1) for j in range(n - 1)]
    perm_hi = [(j, (j - 1) % n) for j in range(n)] if periodic else \
        [(j, j - 1) for j in range(1, n)]
    halo_lo = jax.lax.ppermute(trail, axis_names, perm_lo)
    halo_hi = jax.lax.ppermute(lead, axis_names, perm_hi)
    return jnp.concatenate([halo_lo, x, halo_hi], axis=grid_axis)


def partition_spec(axis_map) -> P:
    return P(*[names if names else None for names in axis_map])


def shard_extents(dims, axis_map, mesh: Mesh):
    """Per-shard local extents; raises unless evenly divisible (the launcher
    pads the grid to make it so)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for ax, (d, names) in enumerate(zip(dims, axis_map)):
        n = math.prod(sizes[a] for a in names) if names else 1
        if d % n:
            raise ValueError(f"grid axis {ax} (extent {d}) not divisible by "
                             f"its {n} mesh shards")
        out.append(d // n)
    return tuple(out)


def _superstep_stub(stencil: Stencil, geom: BlockGeometry, ext, coeffs,
                    steps, aux_ext, bounds, bc=None):
    """Custom-call stand-in for the Pallas streaming kernel (dry-run billing).

    Per-shard (already inside shard_map, so GSPMD sees sharded operands):
    lowers to one opaque custom-call whose operands+result are the kernel's
    HBM DMA footprint — grid in, aux in, grid out. The kernel's true DMA
    schedule adds halo re-reads (+3-8%, `kernels.ops.dma_traffic_bytes`;
    Table 4's traffic-accuracy column quantifies the gap). Executable on
    host via the pure-JAX engine, so tests can run this path end-to-end.
    """
    import numpy as np
    nb = len(bounds)
    ext_arr, keep = ext                  # (extended grid, interior slices)

    def host(ext_h, aux_h, steps_h, bounds_h, *coeff_vals):
        cf = {k: jnp.asarray(v) for k, v in zip(stencil.coeff_names,
                                                coeff_vals)}
        bd = tuple((jnp.asarray(bounds_h[i, 0]), jnp.asarray(bounds_h[i, 1]))
                   for i in range(nb))
        out = blocked_superstep(stencil, geom, jnp.asarray(ext_h), cf,
                                jnp.asarray(steps_h),
                                jnp.asarray(aux_h) if stencil.has_aux
                                else None, bounds=bd, bc=bc)
        return np.asarray(out[keep])

    bounds_arr = jnp.stack([jnp.stack([jnp.asarray(lo, jnp.int32),
                                       jnp.asarray(hi, jnp.int32)])
                            for lo, hi in bounds])
    coeff_vals = [coeffs[k] for k in stencil.coeff_names]
    aux_in = aux_ext if aux_ext is not None else jnp.zeros((), jnp.float32)
    out_shape = tuple(len(range(*k.indices(s)))
                      for k, s in zip(keep, ext_arr.shape))
    return jax.pure_callback(
        host, jax.ShapeDtypeStruct(out_shape, ext_arr.dtype), ext_arr,
        aux_in, steps, bounds_arr, *coeff_vals, vmap_method="sequential")


def build_distributed_fn(stencil: Stencil, dims, iters: Optional[int],
                         par_time: int, bsize, mesh: Mesh,
                         axis_map: Sequence[Optional[Tuple[str, ...]]],
                         kernel_stub: bool = False, *,
                         batch: bool = False, aux_batched: bool = False,
                         trace_hook=None, bc=None, stages=None, dag=None):
    """Build the jitted multi-device runner ``fn(grid, aux, coeffs) -> grid``.

    Used both for real execution (tests/examples) and for the dry-run
    (``fn.lower(ShapeDtypeStruct...)``).  ``axis_map[d]``: mesh axis names
    sharding grid axis ``d`` (or None). 2D on a (pod, data, model) mesh:
    ``axis_map = (("pod", "data"), ("model",))``. ``kernel_stub=True``
    routes each shard's super-step through the Pallas-kernel stand-in
    (billing/dry-run; see ``_superstep_stub``).

    Throughput extensions (the serving path — see ``repro.api.backends``):
      * ``iters=None`` builds a *dynamic-iteration* runner
        ``fn(grid, aux, coeffs, iters)``: the super-step count is computed
        from the traced ``iters`` scalar, so one shard_map program serves
        every iteration count (this generalizes the old per-``iters``
        compiled-program dict).
      * ``batch=True`` expects a leading batch axis on ``grid`` (replicated
        over the mesh, sharded only in the grid axes): each super-step
        exchanges ONE aggregated halo per mesh axis for the whole batch —
        temporal blocking already divides the number of ICI latency events
        by ``par_time``; batching divides the per-problem count by ``B``
        again — then updates all batch members via a vmapped engine
        super-step.  ``aux_batched`` selects whether the aux (power) grid
        carries a matching batch axis or is shared by the whole batch.
      * ``trace_hook`` (if given) is called each time the local program is
        (re)traced — the executable cache's trace counter.
      * ``bc`` (``core.boundary.BoundaryCondition``; None = clamp): per-axis
        boundary condition.  Periodic axes that are mesh-sharded exchange
        halos on a wrap-around ring and are *localized* to no-op bounds (a
        shard never sees a physical edge there); every other kind keeps its
        rule and ``bounds`` distinguishes internal from physical edges.
      * ``stages`` (multi-stage programs — see ``repro.programs``): the
        static ``((stencil, bc), ...)`` chain.  The halo width becomes
        ``sum(stage radii) * par_time`` (one exchange still covers the whole
        fused chain per super-step), each stage's BC is localized per the
        rule above (per-axis periodicity is uniform across stages, so the
        ring topology is well-defined), and each shard runs the fused
        chain super-step locally.  ``coeffs`` then is one dict per stage;
        ``bc`` must be the program's structural (stage-0) BC.
      * ``dag`` (general stage DAGs — see ``repro.programs``): the resolved
        static :class:`~repro.programs.DagSpec`.  The halo width becomes the
        DAG's *critical-path* radius × ``par_time``; per-stage BCs localize
        like ``stages``; a multi-field program's state carries a leading
        ``(F, ...)`` field axis that is never mesh-sharded — ONE halo
        exchange per sharded grid axis still covers all fields (the strips
        stack along the field axis), so temporal blocking's
        latency-aggregation win extends unchanged to multi-field DAGs.
    """
    if isinstance(bsize, int):
        bsize = (bsize,) * (len(dims) - 1)
    axis_map = tuple(tuple(a) if a else None for a in axis_map)
    from repro.core import boundary
    kinds = boundary.kinds_of(bc, len(dims))
    # Localize the BC for the per-shard engine: a sharded periodic axis has
    # no physical edge locally (the wrapped halo arrives by ppermute), so its
    # local kind degrades to clamp under full-extent bounds (a no-op) — a
    # local wrap-pad would wrap the *shard*, not the grid.  Unsharded axes
    # keep their kind: the shard owns the full global extent there.
    local_kinds = tuple(
        "clamp" if (names and kind == "periodic") else kind
        for names, kind in zip(axis_map, kinds))
    bc_local = None if bc is None else dataclasses.replace(
        bc, kinds=local_kinds)
    def localize(bc_s):
        return dataclasses.replace(bc_s, kinds=tuple(
            "clamp" if (names and k == "periodic") else k
            for names, k in zip(axis_map, bc_s.kinds)))

    local_dag = None
    n_fields = 1
    if dag is not None:
        if kernel_stub:
            raise NotImplementedError(
                "kernel_stub supports single-stage problems only")
        # the exchange must cover the DAG's deepest dependency path per
        # iteration, not the sum over stages (branches run in parallel)
        rad = dag_radius(dag)
        has_aux = any(st.has_aux for st, _, _ in dag.stages)
        n_fields = dag.n_fields
        # localize every stage's BC the same way (sharded periodic axes
        # degrade to clamp under no-op bounds — the wrapped halo is exact)
        local_dag = DagSpec(
            stages=tuple((st, localize(bc_s), refs)
                         for st, bc_s, refs in dag.stages),
            n_fields=dag.n_fields, updates=dag.updates, topo=dag.topo)
        local_stages = None
    elif stages is not None:
        if kernel_stub:
            raise NotImplementedError(
                "kernel_stub supports single-stage problems only")
        rad = sum(st.radius for st, _ in stages)
        has_aux = any(st.has_aux for st, _ in stages)
        local_stages = tuple((st, localize(bc_s)) for st, bc_s in stages)
    else:
        rad = stencil.radius
        has_aux = stencil.has_aux
        local_stages = None
    h = rad * par_time
    local_dims = shard_extents(dims, axis_map, mesh)
    ext_dims = tuple(ld + (2 * h if names else 0)
                     for ld, names in zip(local_dims, axis_map))
    geom = BlockGeometry(len(dims), ext_dims, rad, par_time,
                         tuple(bsize))
    spec = partition_spec(axis_map)
    if kernel_stub and batch:
        raise NotImplementedError("kernel_stub has no batched variant")
    # leading batch and/or field axes are never sharded; grid axes shift
    # right by one per leading axis
    off = (1 if batch else 0) + (1 if n_fields > 1 else 0)

    def local_impl(g, aux_l, coeffs_l, iters_l):
        if trace_hook is not None:
            trace_hook()
        n_super = (iters_l + par_time - 1) // par_time
        bounds = []
        for names, ld, kind in zip(axis_map, local_dims, kinds):
            if names is None:
                bounds.append((0, ld - 1))
                continue
            if kind == "periodic":
                # wrap-around ring: every shard edge is internal — bounds
                # span the whole halo-extended shard (re-imposition no-op)
                bounds.append((0, ld + 2 * h - 1))
                continue
            i = _linear_index(names)
            n = _axis_total(names)
            lo = jnp.where(i == 0, h, 0)
            hi = jnp.where(i == n - 1, h + ld - 1, ld + 2 * h - 1)
            bounds.append((lo, hi))
        bounds = tuple(bounds)

        keep = (slice(None),) * off + tuple(
            slice(h, h + ld) if names else slice(None)
            for names, ld in zip(axis_map, local_dims))
        # aux (power) grid is read-only: exchange its halo once, not per
        # super-step (hoisted out of the fori_loop)
        aux_ext = aux_l
        if has_aux:
            aux_off = 1 if (batch and aux_batched) else 0
            for ax, names in enumerate(axis_map):
                if names:
                    aux_ext = _exchange_halo(aux_ext, ax + aux_off, names, h,
                                             periodic=kinds[ax] == "periodic")

        def one_superstep(ext, steps):
            """Per-shard super-step on the halo-extended local grid."""
            if kernel_stub:
                return _superstep_stub(stencil, geom, (ext, keep), coeffs_l,
                                       steps, aux_ext if has_aux else None,
                                       bounds, bc_local)
            if local_dag is not None:
                cf_dag = (coeffs_l if isinstance(coeffs_l, tuple)
                          else (coeffs_l,))

                def step_local(e, a):
                    return blocked_superstep_dag(local_dag, geom, e, cf_dag,
                                                 steps, a, bounds)
            elif local_stages is not None:
                def step_local(e, a):
                    return blocked_superstep_chain(local_stages, geom, e,
                                                   coeffs_l, steps, a, bounds)
            else:
                def step_local(e, a):
                    return blocked_superstep(stencil, geom, e, coeffs_l,
                                             steps, a, bounds, bc_local)
            if batch:
                aux_ax = (0 if aux_batched else None) if has_aux else None
                upd = jax.vmap(step_local, in_axes=(0, aux_ax))(
                    ext, aux_ext if has_aux else None)
            else:
                upd = step_local(ext, aux_ext if has_aux else None)
            return upd[keep]

        def superstep(s, gl):
            steps = jnp.minimum(par_time, iters_l - s * par_time)
            ext = gl
            for ax, names in enumerate(axis_map):
                if names:
                    # one aggregated exchange per axis for the whole batch
                    ext = _exchange_halo(ext, ax + off, names, h,
                                         periodic=kinds[ax] == "periodic")
            return one_superstep(ext, steps)

        return jax.lax.fori_loop(0, n_super, superstep, g)

    aux_spec = P() if not has_aux else (
        P(None, *spec) if (batch and aux_batched) else spec)
    grid_spec = P(*((None,) * off), *spec) if off else spec
    if iters is None:
        # dynamic iters: the runner takes the count as a replicated scalar —
        # fn(grid, aux, coeffs, iters)
        local_run, in_specs = local_impl, (grid_spec, aux_spec, P(), P())
    else:
        # legacy static-iters arity (keeps .lower(grid, aux, coeffs) working
        # for the dry-run/HLO paths)
        def local_run(g, aux_l, coeffs_l):
            return local_impl(g, aux_l, coeffs_l, iters)
        in_specs = (grid_spec, aux_spec, P())
    shmapped = compat.shard_map(local_run, mesh=mesh, in_specs=in_specs,
                                out_specs=grid_spec, check_vma=False)
    return jax.jit(shmapped,
                   in_shardings=(NamedSharding(mesh, grid_spec),
                                 NamedSharding(mesh, aux_spec),
                                 None) + ((None,) if iters is None else ()),
                   out_shardings=NamedSharding(mesh, grid_spec))


def distributed_run(stencil: Stencil, grid: jnp.ndarray, coeffs: dict,
                    iters: int, par_time: int, bsize, mesh: Mesh,
                    axis_map, aux: jnp.ndarray | None = None, *,
                    bc=None) -> jnp.ndarray:
    """Run ``iters`` steps of ``stencil`` on a grid sharded over ``mesh``."""
    fn = build_distributed_fn(stencil, grid.shape, iters, par_time, bsize,
                              mesh, axis_map, bc=bc)
    aux_in = aux if aux is not None else jnp.zeros((), jnp.float32)
    return fn(grid, aux_in, coeffs)
