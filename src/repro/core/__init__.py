"""Core: the paper's contribution — combined spatial + temporal blocking."""
from repro.core.blocking import BlockGeometry
from repro.core.boundary import BoundaryCondition
from repro.core.engine import blocked_superstep, run_blocked
from repro.core.perf_model import Device, Prediction, autotune, predict
from repro.core.stencils import (DIFFUSION2D, DIFFUSION3D, HOTSPOT2D,
                                 HOTSPOT3D, STENCILS, Stencil, default_coeffs,
                                 make_box, make_star)

__all__ = [
    "BlockGeometry", "BoundaryCondition", "blocked_superstep", "run_blocked",
    "Device",
    "Prediction", "autotune", "predict", "DIFFUSION2D", "DIFFUSION3D",
    "HOTSPOT2D", "HOTSPOT3D", "STENCILS", "Stencil", "default_coeffs",
    "make_box", "make_star",
]
