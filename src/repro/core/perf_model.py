"""Performance model — paper §4 (Eqs. 3-9) with TPU hardware constants.

The paper's model assumes the computation is memory-bound and predicts run
time from external-memory traffic alone (Eq. 8).  On TPU the byte/FLOP
balance moves ~10x toward compute (819 GB/s HBM vs. 25-34 GB/s DDR), so we
keep the paper's traffic accounting *exactly* (Eqs. 4-7, via
``core.blocking``) but take ``time = max(t_mem, t_compute, t_halo)`` — the
deep-pipeline overlap assumption carries over (DMA prefetch overlaps VPU
compute; halo exchange overlaps the interior sweep).

Two roles, mirroring the paper:
  1. Predict throughput for a given (bsize, par_time, par_vec) — §4.
  2. Prune the design space: pick the best (bsize, par_time, par_vec) subject
     to the VMEM budget — §5.3's BRAM/DSP pruning, with VMEM as the scarce
     resource.  ``par_vec`` (paper §3.3, Eq. 6-7) is the stream-axis vector
     width: the lane dimension is pinned at the 128-lane VPU row, but V
     rows/planes per tick is a free knob the model prices two ways — 2D
     sublane utilization (a ``(V, bsize)`` tile wastes ``(8-V)/8`` of the
     f32 tile's sublanes below V=8) and per-DMA issue cost (V-row slabs cut
     the descriptor count ~V-fold; thin-row streams are issue-bound, not
     bandwidth-bound).  See DESIGN.md §2.2.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.blocking import (BlockGeometry, bsize_feasible,
                                 choose_bsize_candidates, extended_geometry,
                                 superstep_traffic_bytes)
from repro.core.precision import sublanes_for
from repro.core.stencils import Stencil

#: baseline ``par_vec`` sweep of :func:`autotune` — powers of two around the
#: 8-sublane f32 tile (V=8 fills every sublane; V=16 halves the DMA
#: descriptor count again at 2x the window VMEM).  16-bit dtypes extend to
#: V=32 — see :func:`par_vec_candidates`.
PAR_VEC_CANDIDATES = (1, 2, 4, 8, 16)


def par_vec_candidates(cell_bytes: int = 4):
    """The ``par_vec`` sweep for a given cell width.  Sub-4-byte dtypes get
    taller minimum tiles (16 sublanes for bf16), doubling the V that fills a
    tile's sublanes — the sweep ceiling doubles with it (V=32 for 16-bit
    cells, the bf16 analogue of f32's V=16)."""
    if cell_bytes <= 2:
        return PAR_VEC_CANDIDATES + (32,)
    return PAR_VEC_CANDIDATES


@dataclasses.dataclass(frozen=True)
class Device:
    """Per-chip hardware constants. Defaults: TPU v5e-class (see DESIGN.md §7)."""
    name: str = "tpu_v5e"
    mem_bw: float = 819e9            # HBM bytes/s
    vpu_flops: float = 12.3e12       # f32 vector FLOP/s (assumed MXU_bf16/16)
    mxu_flops_bf16: float = 197e12   # MXU peak (LM roofline uses this)
    vmem_budget: int = 32 * 2 ** 20  # usable VMEM for kernel working set
    ici_bw: float = 50e9             # bytes/s per ICI link
    hbm_bytes: int = 16 * 2 ** 30
    #: amortized cost of issuing one DMA descriptor (the reason a
    #: ``(1, bsize)`` row stream cannot saturate ``mem_bw``: at V=1 the
    #: kernels issue one descriptor per row per block per stream)
    dma_issue_s: float = 2e-8

    def scaled(self, **kw) -> "Device":
        return dataclasses.replace(self, **kw)


# Projection targets (paper §6.3 analogue: model-driven next-gen estimates).
TPU_V5E = Device()
TPU_V5P = Device(name="tpu_v5p", mem_bw=2765e9, vpu_flops=28.7e12,
                 mxu_flops_bf16=459e12, vmem_budget=64 * 2 ** 20,
                 ici_bw=100e9, hbm_bytes=95 * 2 ** 30)
TPU_V6E = Device(name="tpu_v6e", mem_bw=1640e9, vpu_flops=57.4e12,
                 mxu_flops_bf16=918e12, vmem_budget=64 * 2 ** 20,
                 ici_bw=90e9, hbm_bytes=32 * 2 ** 30)

DEVICES = {d.name: d for d in (TPU_V5E, TPU_V5P, TPU_V6E)}


@dataclasses.dataclass(frozen=True)
class Prediction:
    geom: BlockGeometry
    t_mem: float                 # s per super-step (memory term)
    t_compute: float             # s per super-step (compute term)
    t_halo: float                # s per super-step (collective term; 0 if single chip)
    n_super: int
    run_time: float
    gbytes_s: float              # paper Eq. 9 "throughput"
    gcells_s: float
    gflops: float
    vmem_bytes: int
    bound: str                   # "memory" | "compute" | "collective"
    batch: int = 1               # problems advanced per batched super-step

    def describe(self) -> str:
        return (f"bsize={self.geom.bsize} par_time={self.geom.par_time} "
                f"par_vec={self.geom.par_vec} "
                f"-> {self.gflops / 1e9:.1f} GFLOP/s ({self.bound}-bound, "
                f"{self.gcells_s / 1e9:.2f} GCell/s, red={self.geom.redundancy:.2f})")


def predict(stencil: Stencil, dims: Sequence[int], iters: int,
            bsize, par_time: int, device: Device = TPU_V5E,
            cell_bytes: int = 4, n_chips: int = 1,
            chip_grid: Sequence[int] | None = None,
            batch: int = 1, bc=None, par_vec: int = 1) -> Prediction:
    """Paper Eqs. (3)-(9) + compute/collective terms.

    ``par_vec`` (paper Eq. 7's vector width, V): the kernels stream V
    rows/planes per tick, so the idealized bytes are unchanged (up to the
    slab pad of a non-divisible stream) while the tick and DMA-descriptor
    counts shrink ~V-fold — ``t_mem`` gains a per-descriptor issue term that
    V amortizes.  For 2D grids the per-tick compute tile is ``(V, bsize)``
    whose sublane dim is V, so the VPU runs at ``min(V, 8)/8`` utilization
    below the 8-sublane f32 tile; 3D tiles put the blocked y extent on the
    sublanes and V only moves the DMA term.

    ``n_chips``: spatial distribution (core/distributed.py) — the grid is
    split over chips along the streaming axis (+x for 2D), each chip runs
    the same blocking locally and exchanges a halo of width rad*par_time
    per super-step over ICI.

    ``batch``: ``StencilPlan.run_batch`` advances ``batch`` problems per
    super-step through one executable.  Grid traffic, compute, and halo
    bytes scale with the batch; the read-only aux stream (Hotspot's power
    grid, shared by the batch) and the scalar coefficients are loaded once
    — so batched Hotspot moves fewer bytes per problem than ``batch``
    separate runs.  Per-problem metrics (``gcells_s`` etc.) are reported
    for the whole batch.

    ``bc``: the boundary condition prices into the model two ways.  A
    periodic *streaming* axis adds a ``2 * rad * par_time`` stream extension
    per super-step (the kernels materialize the wrap in HBM — extra rows
    both read and traversed).  Periodic *sharded* axes exchange on a full
    wrap-around ring: per-chip halo bytes are unchanged (interior shards
    already sent both strips, which is what ``t_halo`` prices as the
    critical path), so only the memory/compute terms move.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if isinstance(bsize, int):
        bsize = (bsize,) * (len(dims) - 1)
    local_dims = tuple(dims)
    cg = (1,) * len(dims)
    if n_chips > 1:
        cg = tuple(chip_grid) if chip_grid else (n_chips,) + (1,) * (len(dims) - 1)
        local_dims = tuple(math.ceil(d / c) for d, c in zip(dims, cg))
    geom = BlockGeometry(len(dims), local_dims, stencil.radius, par_time,
                         bsize, par_vec)
    # periodic stream BC: the kernels stream 2*size_halo extra rows/planes
    # per super-step (the materialized wrap) — bill traffic/compute on the
    # extended geometry, report the caller-visible one
    geom_t = extended_geometry(geom, bc)

    # --- memory term (paper Eq. 3: th_mem saturates at th_max = HBM bw) ----
    step_bytes = superstep_traffic_bytes(geom_t, stencil.num_read,
                                         stencil.num_write, cell_bytes)
    # per-descriptor issue cost: each block moves ceil(stream/V) slabs per
    # input stream and per output per super-step — at V=1 a thin-row stream
    # is descriptor-bound, which is what par_vec amortizes
    n_dma = (batch * geom_t.num_blocks * geom_t.stream_slabs()
             * (stencil.num_read + stencil.num_write))
    if batch > 1:
        # batched super-steps share the read-only aux stream: bill it once,
        # not `batch` times (coefficients are scalars — free either way)
        aux_bytes = (superstep_traffic_bytes(geom_t, 1, 0, cell_bytes)
                     if stencil.has_aux else 0)
        step_bytes = batch * step_bytes - (batch - 1) * aux_bytes
    t_mem = step_bytes / device.mem_bw + n_dma * device.dma_issue_s

    # --- compute term: every traversed cell is updated par_time times ------
    # sublane utilization of the per-tick compute tile: 1D/2D slabs are
    # (V,)/(V, bsize) — V sublanes of the 8-sublane f32 tile; 3D slabs are
    # (V, bsize_y, bsize_x) — the y extent fills the sublanes
    # the minimum-tile sublane count is dtype-dependent: 8 for 4-byte cells,
    # 16 for bf16 — a (V, bsize) bf16 tile needs V=16 to fill its sublanes
    sublanes = sublanes_for(cell_bytes)
    sub = bsize[0] if len(dims) == 3 else par_vec
    sub_eff = min(sub, sublanes) / sublanes
    cells_per_super = batch * geom_t.stream_dim * math.prod(
        n * b for n, b in zip(geom.bnum, geom.bsize))
    flops_per_super = cells_per_super * par_time * stencil.flop_pcu
    t_compute = flops_per_super / (device.vpu_flops * sub_eff)

    # --- collective term: halo exchange once per super-step ----------------
    # Each grid axis actually sharded by the chip grid exchanges two strips
    # of width size_halo whose face area is the shard's cross-section
    # *perpendicular to that axis* — not always the streaming-axis face the
    # 2D paper setup suggests.  A batch aggregates its members' halos into
    # one exchange (bytes scale with the batch; the per-super-step latency
    # events do not).
    t_halo = 0.0
    if n_chips > 1:
        local_cells = math.prod(local_dims)
        halo_cells = sum(geom.size_halo * local_cells // local_dims[ax]
                         for ax, c in enumerate(cg) if c > 1)
        halo_bytes = 2 * batch * halo_cells * cell_bytes * max(stencil.num_read, 1)
        t_halo = halo_bytes / device.ici_bw

    n_super = math.ceil(iters / par_time)
    t_step = max(t_mem, t_compute, t_halo)
    run_time = n_super * t_step
    total_cells = batch * math.prod(dims) * iters   # all problems, all chips
    bound = ("memory" if t_mem >= max(t_compute, t_halo)
             else "compute" if t_compute >= t_halo else "collective")
    return Prediction(
        geom=geom, t_mem=t_mem, t_compute=t_compute, t_halo=t_halo,
        n_super=n_super, run_time=run_time,
        gbytes_s=n_super * step_bytes / run_time,
        gcells_s=total_cells / run_time,
        gflops=total_cells * stencil.flop_pcu / run_time,
        vmem_bytes=geom.vmem_bytes(
            cell_bytes, stencil.has_aux,
            stage_radii=getattr(stencil, "stage_radii", None),
            dag_info=(stencil.dag_vmem_info(geom.par_time, geom.par_vec)
                      if hasattr(stencil, "dag_vmem_info") else None)),
        bound=bound, batch=batch)


def autotune(stencil: Stencil, dims: Sequence[int], iters: int,
             device: Device = TPU_V5E, cell_bytes: int = 4,
             par_time_max: int = 64, n_chips: int = 1,
             chip_grid: Sequence[int] | None = None, *,
             par_time: int | None = None,
             bsize: Sequence[int] | None = None,
             par_vec: int | None = None,
             par_vecs: Sequence[int] | None = None,
             top_k: int | None = None, bc=None) -> list:
    """Design-space pruning (paper §5.3): enumerate power-of-two bsize ×
    par_time × par_vec, drop configs whose working set exceeds the VMEM
    budget, rank by predicted run time. Returns predictions sorted best-first.

    A pinned ``par_time``, ``bsize`` or ``par_vec`` constrains the sweep to
    exactly that value (the paper's tuned depths, e.g. 36, need not be powers
    of two); only the free dimension(s) are enumerated — ``par_vec`` over
    :func:`par_vec_candidates` for the cell width by default (V<=16 for
    f32, V<=32 for 16-bit cells).  ``top_k`` keeps only the
    best-ranked predictions — the shortlist the measured tuner
    (``repro.api.tuner``) times on real hardware.  May return ``[]`` when
    nothing is feasible — callers must not index blindly."""
    if par_time is not None:
        pts = [par_time]
    else:
        pts, pt = [], 1
        while pt <= par_time_max:
            pts.append(pt)
            pt *= 2
    if par_vecs is None:
        # 16-bit cells sweep up to V=32 (the 16-sublane tile ceiling)
        par_vecs = par_vec_candidates(cell_bytes)
    pvs = [par_vec] if par_vec is not None else list(par_vecs)
    cands = []
    for pt in pts:
        if bsize is not None:
            # feasibility mirrors choose_bsize_candidates' filter
            bss = ([tuple(bsize)]
                   if bsize_feasible(stencil.radius, pt, bsize) else [])
        else:
            bss = choose_bsize_candidates(len(dims), dims, stencil.radius, pt)
        for bs in bss:
            for pv in pvs:
                p = predict(stencil, dims, iters, bs, pt, device,
                            cell_bytes, n_chips, chip_grid, bc=bc,
                            par_vec=pv)
                if p.vmem_bytes <= device.vmem_budget:
                    cands.append(p)
    cands.sort(key=lambda p: p.run_time)
    return cands if top_k is None else cands[:top_k]


def model_accuracy(measured_s: float, predicted: Prediction) -> float:
    """Paper §6.2: measured/estimated performance ratio."""
    return predicted.run_time / measured_s
