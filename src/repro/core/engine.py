"""Combined spatial + temporal blocking executor — pure JAX (the algorithm).

This is the paper's accelerator expressed as data-parallel JAX: overlapped
spatial blocks are materialized as a batch and updated ``par_time`` fused
time-steps by a vmapped per-block pipeline, then the compute blocks are
stitched back (out-of-bound compute is sliced off — the paper's "control only
the flow of writes").  The Pallas kernels in ``repro.kernels`` implement the
same math with explicit VMEM streaming; this module is their semantic spec
and the multi-device distribution's local worker.

Boundary-condition handling across fused steps: see DESIGN.md §2.1 and
``core.boundary`` — local BCs (clamp/reflect/constant) are re-imposed on
out-of-grid positions before every sub-step (``_reclamp``, now a BC-dispatch
table), and the streaming axis uses BC-mode padding re-derived per sub-step
(exact, because it is re-computed from current values).  Periodic axes need
no re-imposition at all: the super-step padding wraps (``mode="wrap"``), and
a wrapped halo is an exact translated copy that stays exact up to the
standard ``rad``-per-sub-step garbage creep — the same argument that makes
interior block seams correct.

PE forwarding (paper §3.2): when ``iters % par_time != 0`` the trailing
sub-steps forward data unchanged — implemented as a ``where(t < steps)``
select, exactly like unused PEs passing data down the chain.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import boundary, precision
from repro.core.blocking import BlockGeometry
from repro.core.stencils import Stencil


def _pad_blocked_dims(grid: jnp.ndarray, geom: BlockGeometry,
                      bc=None) -> jnp.ndarray:
    """BC-pad trailing (blocked) dims: halo on the left, halo + out-of-bound
    overhang on the right, so every block slice is in-bounds.  Periodic axes
    wrap (their only materialization — no per-sub-step re-imposition); other
    kinds pad per their rule and are refreshed by ``_reclamp`` each sub-step.
    """
    h = geom.size_halo
    kinds = boundary.kinds_of(bc, geom.ndim)
    out = grid
    for i, (d, p) in enumerate(zip(geom.blocked_dims, geom.padded_dims)):
        out = boundary.pad_axis(out, i + 1, h, p - d - h, kinds[i + 1],
                                boundary.fill_of(bc))
    return out


def _block_index(geom: BlockGeometry, dim_i: int) -> jnp.ndarray:
    """(bnum, bsize) gather indices into the padded grid for blocked dim i."""
    c, b, n = geom.csize[dim_i], geom.bsize[dim_i], geom.bnum[dim_i]
    return (jnp.arange(n)[:, None] * c + jnp.arange(b)[None, :])


def extract_blocks(grid: jnp.ndarray, geom: BlockGeometry,
                   bc=None) -> jnp.ndarray:
    """-> (num_blocks..., stream_dim, *bsize) overlapped blocks, any rank
    (1D: the whole stream is the single 'block')."""
    gp = _pad_blocked_dims(grid, geom, bc)
    nb = geom.ndim - 1
    for i in range(nb):
        # blocked dim i sits at axis 1 + 2*i once earlier dims are expanded
        gp = jnp.take(gp, _block_index(geom, i), axis=1 + 2 * i)
    # (stream, bn0, bs0, bn1, bs1, ..) -> (bn0, bn1, .., stream, bs0, bs1, ..)
    perm = (tuple(1 + 2 * i for i in range(nb)) + (0,)
            + tuple(2 + 2 * i for i in range(nb)))
    return jnp.transpose(gp, perm)


def stitch_blocks(blocks: jnp.ndarray, geom: BlockGeometry) -> jnp.ndarray:
    """Write-back: keep each block's compute region, discard halos and
    out-of-bound columns (paper's masked writes)."""
    h = geom.size_halo
    nb = geom.ndim - 1
    comp = blocks[(slice(None),) * (nb + 1)
                  + tuple(slice(h, h + c) for c in geom.csize)]
    # (bn0, .., stream, cs0, ..) -> (stream, bn0, cs0, bn1, cs1, ..)
    perm = (nb,) + tuple(x for i in range(nb) for x in (i, nb + 1 + i))
    out = jnp.transpose(comp, perm).reshape(
        (blocks.shape[nb],) + tuple(n * c
                                    for n, c in zip(geom.bnum, geom.csize)))
    return out[(slice(None),) + tuple(slice(0, d) for d in geom.blocked_dims)]


def _mask_fill(arr: jnp.ndarray, mask1d: jnp.ndarray, axis: int,
               value: float) -> jnp.ndarray:
    """Overwrite positions selected by a 1-D mask along ``axis`` with
    ``value`` (the 'constant' BC's re-imposition)."""
    shape = [1] * arr.ndim
    shape[axis] = mask1d.shape[0]
    return jnp.where(mask1d.reshape(shape), jnp.asarray(value, arr.dtype),
                     arr)


def _reclamp(block: jnp.ndarray, bidx, geom: BlockGeometry,
             bounds=None, bc=None) -> jnp.ndarray:
    """Re-impose the (local) BC: overwrite out-of-grid positions per each
    axis' rule — clamp/reflect gather from the mapped in-grid coordinate,
    constant fills the scalar.  No-op for interior blocks; periodic axes are
    skipped entirely (their wrap-padded halos stay exact up to garbage
    creep — see ``core.boundary``).

    ``bounds``: optional (ndim, 2) physical-edge range per grid axis, in
    grid coordinates — used by the multi-device runtime, where a shard's
    local edge may be an *internal* boundary (no re-imposition: bounds cover
    the whole halo-extended shard) or a *true* grid boundary (BC at the halo
    offset). Entries may be traced. None = BC at the grid edges.
    """
    h = geom.size_halo
    kinds = boundary.kinds_of(bc, geom.ndim)
    value = boundary.fill_of(bc)
    if bounds is not None and kinds[0] != "periodic":
        # streaming axis (axis 0 of the block)
        idx = jnp.arange(block.shape[0])
        lo, hi = bounds[0]
        if kinds[0] == "constant":
            block = _mask_fill(block, boundary.out_of_range(idx, lo, hi),
                               0, value)
        else:
            block = jnp.take(block, boundary.map_index(idx, lo, hi, kinds[0]),
                             axis=0)
    for i, (dim, b, c) in enumerate(zip(geom.blocked_dims, geom.bsize,
                                        geom.csize)):
        kind = kinds[i + 1]
        if kind == "periodic":
            continue
        axis = block.ndim - (geom.ndim - 1) + i
        lo, hi = (0, dim - 1) if bounds is None else bounds[i + 1]
        gx = bidx[i] * c + jnp.arange(b) - h
        if kind == "constant":
            block = _mask_fill(block, boundary.out_of_range(gx, lo, hi),
                               axis, value)
        else:
            jc = boundary.map_index(gx, lo, hi, kind) + h - bidx[i] * c
            block = jnp.take(block, jnp.clip(jc, 0, b - 1), axis=axis)
    return block


def _block_getter(block: jnp.ndarray, r: int, bc=None):
    """Neighbor getter on a block: exact BC-mode pad on the streaming axis
    (the block carries the full stream extent, so wrap/reflect/constant
    padding IS the boundary condition there), garbage-tolerant edge-pad on
    blocked axes (halo shrinkage covers it)."""
    p = boundary.pad_axis(block, 0, r, r, boundary.kinds_of(bc, 1)[0],
                          boundary.fill_of(bc))
    p = jnp.pad(p, [(0, 0)] + [(r, r)] * (block.ndim - 1), mode="edge")

    def get(off):
        idx = tuple(slice(r + o, r + o + n) for o, n in zip(off, block.shape))
        return p[idx]

    return get


def _block_substep(stencil: Stencil, block: jnp.ndarray, coeffs: dict,
                   aux_block, bc=None) -> jnp.ndarray:
    """One plain stencil step on a block (see :func:`_block_getter`).

    Storage/accumulation policy (``repro.core.precision``): bf16 blocks
    widen to f32 for the stage arithmetic and round back to storage once
    per application; f32 passes through apply() untouched."""
    get = _block_getter(block, stencil.radius, bc)
    return precision.apply_stage(stencil, get, coeffs, aux_block,
                                 block.dtype)


def _block_substep_dag(stencil: Stencil, blocks, coeffs: dict,
                       aux_block, bc=None) -> jnp.ndarray:
    """One (possibly multi-input) stage application on pre-reclamped input
    blocks: each input is read under this stage's BC; ``arity > 1`` stencils
    receive a tuple of getters."""
    r = stencil.radius
    gets = [_block_getter(b, r, bc) for b in blocks]
    return precision.apply_stage(
        stencil, tuple(gets) if stencil.arity > 1 else gets[0],
        coeffs, aux_block, blocks[0].dtype)


@partial(jax.jit, static_argnames=("stages", "geom"))
def blocked_superstep_chain(stages, geom: BlockGeometry, grid: jnp.ndarray,
                            stage_coeffs, steps,
                            aux: jnp.ndarray | None = None,
                            bounds=None) -> jnp.ndarray:
    """Apply ``steps`` (<= par_time) fused *program iterations* — each one
    the whole stage chain, in order — via one HBM round-trip worth of
    overlapped blocks.

    ``stages`` is the static ``((stencil, bc), ...)`` tuple (S=1 recovers
    :func:`blocked_superstep` exactly); ``stage_coeffs`` one coefficient dict
    per stage.  Block extraction pads under stage 0's BC (the BC the chain's
    first read sees; periodicity is uniform across stages by construction)
    and each stage re-imposes its own BC before it reads.  ``steps`` may be
    a traced scalar; ``bounds`` is the optional per-axis physical-edge range
    (see ``_reclamp``)."""
    bc0 = stages[0][1]
    has_aux = any(st.has_aux for st, _ in stages)
    blocks = extract_blocks(grid, geom, bc0)
    aux_blocks = extract_blocks(aux, geom, bc0) if has_aux else None
    nb = geom.ndim - 1

    def one_block(block, aux_block, *bidx):
        def substep(t, blk):
            cur = blk
            for (st, bc_s), cf in zip(stages, stage_coeffs):
                rec = _reclamp(cur, bidx, geom, bounds, bc_s)
                new = _block_substep(st, rec, cf,
                                     aux_block if st.has_aux else None, bc_s)
                cur = jnp.where(t < steps, new, rec)   # PE forwarding
            return cur
        return jax.lax.fori_loop(0, geom.par_time, substep, block)

    aux_ax = 0 if aux_blocks is not None else None
    fn = one_block
    for i in range(nb - 1, -1, -1):
        fn = jax.vmap(fn, in_axes=(0, aux_ax)
                      + tuple(0 if j == i else None for j in range(nb)))
    upd = fn(blocks, aux_blocks,
             *(jnp.arange(geom.bnum[j]) for j in range(nb)))
    return stitch_blocks(upd, geom)


@partial(jax.jit, static_argnames=("dag", "geom"))
def blocked_superstep_dag(dag, geom: BlockGeometry, state: jnp.ndarray,
                          stage_coeffs, steps,
                          aux: jnp.ndarray | None = None,
                          bounds=None) -> jnp.ndarray:
    """Apply ``steps`` (<= par_time) fused *program iterations* of a stage
    DAG (:class:`repro.programs.DagSpec`) via one HBM round-trip worth of
    overlapped blocks.

    ``state`` is the plain grid for single-field programs, else the
    ``(F, *shape)`` field stack — every field is blocked identically and
    travels through the same vmapped per-block pipeline.  Each iteration
    evaluates the stages in topological order (every input re-reclamped
    under the *consuming* stage's BC), then updates all fields
    simultaneously; partial super-steps forward each field's previous value
    (PE forwarding, generalized per field)."""
    F = dag.n_fields
    fields = [state[k] for k in range(F)] if F > 1 else [state]
    bc0 = dag.stages[0][1]
    has_aux = any(st.has_aux for st, _, _ in dag.stages)
    fblocks = tuple(extract_blocks(g, geom, bc0) for g in fields)
    aux_blocks = extract_blocks(aux, geom, bc0) if has_aux else None
    nb = geom.ndim - 1

    def one_block(blks, aux_block, *bidx):
        def substep(t, cur):
            vals: list = [None] * len(dag.stages)
            for si in dag.topo:
                st, bc_s, refs = dag.stages[si]
                ins = [cur[~r] if r < 0 else vals[r] for r in refs]
                recs = [_reclamp(x, bidx, geom, bounds, bc_s) for x in ins]
                vals[si] = _block_substep_dag(
                    st, recs, stage_coeffs[si],
                    aux_block if st.has_aux else None, bc_s)
            out = []
            for k, u in enumerate(dag.updates):
                if u == ~k:                  # field carried unchanged
                    out.append(cur[k])
                    continue
                tgt = vals[u] if u >= 0 else cur[~u]
                out.append(jnp.where(t < steps, tgt, cur[k]))
            return tuple(out)
        return jax.lax.fori_loop(0, geom.par_time, substep, blks)

    aux_ax = 0 if aux_blocks is not None else None
    fn = one_block
    for i in range(nb - 1, -1, -1):
        fn = jax.vmap(fn, in_axes=(0, aux_ax)
                      + tuple(0 if j == i else None for j in range(nb)))
    upd = fn(fblocks, aux_blocks,
             *(jnp.arange(geom.bnum[j]) for j in range(nb)))
    outs = [stitch_blocks(u, geom) for u in upd]
    return jnp.stack(outs) if F > 1 else outs[0]


def superstep_loop_dag(dag, geom: BlockGeometry, state: jnp.ndarray,
                       stage_coeffs, iters,
                       aux: jnp.ndarray | None = None,
                       bounds=None) -> jnp.ndarray:
    """Fused whole-run driver for a stage DAG — the DAG analogue of
    :func:`superstep_loop_chain` (dynamic ``iters``, PE-forwarded partial
    final super-step)."""
    par_time = geom.par_time
    n_super = (iters + par_time - 1) // par_time

    def body(s, g):
        steps = jnp.minimum(par_time, iters - s * par_time)
        return blocked_superstep_dag(dag, geom, g, stage_coeffs, steps,
                                     aux, bounds)

    return jax.lax.fori_loop(0, n_super, body, state)


def blocked_superstep(stencil: Stencil, geom: BlockGeometry,
                      grid: jnp.ndarray, coeffs: dict, steps,
                      aux: jnp.ndarray | None = None,
                      bounds=None, bc=None) -> jnp.ndarray:
    """Single-operator special case of :func:`blocked_superstep_chain`
    (legacy entry point, semantics unchanged)."""
    return blocked_superstep_chain(((stencil, bc),), geom, grid, (coeffs,),
                                   steps, aux, bounds)


def superstep_loop_chain(stages, geom: BlockGeometry, grid: jnp.ndarray,
                         stage_coeffs, iters, aux: jnp.ndarray | None = None,
                         bounds=None) -> jnp.ndarray:
    """Fused whole-run driver for a stage chain: ``ceil(iters/par_time)``
    super-steps as one traced loop (paper Eq. 8 numerator), so an enclosing
    ``jit`` lowers the entire iteration count to a single dispatch.

    ``iters`` may be a *traced* scalar: the trip count is computed inside the
    trace and the loop lowers to a dynamic ``while``, so one compiled
    executable serves every iteration count — a serving process never
    re-traces because a request asked for a different ``iters``.  Trailing
    iterations of a partial final super-step are PE-forwarded (paper §3.2)
    exactly as in :func:`blocked_superstep_chain`.
    """
    par_time = geom.par_time
    n_super = (iters + par_time - 1) // par_time

    def body(s, g):
        steps = jnp.minimum(par_time, iters - s * par_time)
        return blocked_superstep_chain(stages, geom, g, stage_coeffs, steps,
                                       aux, bounds)

    return jax.lax.fori_loop(0, n_super, body, grid)


def superstep_loop(stencil: Stencil, geom: BlockGeometry, grid: jnp.ndarray,
                   coeffs: dict, iters, aux: jnp.ndarray | None = None,
                   bounds=None, bc=None) -> jnp.ndarray:
    """Single-operator special case of :func:`superstep_loop_chain` (legacy
    entry point, semantics unchanged)."""
    return superstep_loop_chain(((stencil, bc),), geom, grid, (coeffs,),
                                iters, aux, bounds)


@partial(jax.jit, static_argnames=("stencil", "geom", "bc"))
def _run_blocked_jit(stencil, geom, grid, coeffs, iters, aux, bc=None):
    return superstep_loop(stencil, geom, grid, coeffs, iters, aux, bc=bc)


def run_blocked(stencil: Stencil, grid: jnp.ndarray, coeffs: dict, iters: int,
                par_time: int, bsize, aux: jnp.ndarray | None = None, *,
                bc=None) -> jnp.ndarray:
    """Full run: ceil(iters/par_time) super-steps (paper Eq. 8 numerator).

    ``iters`` is passed into the executable as a dynamic scalar, so repeated
    calls with different iteration counts share one compiled program."""
    if isinstance(bsize, int):
        bsize = (bsize,) * (grid.ndim - 1)
    geom = BlockGeometry(grid.ndim, grid.shape, stencil.radius, par_time, bsize)
    return _run_blocked_jit(stencil, geom, grid, coeffs,
                            jnp.asarray(iters, jnp.int32), aux, bc)
