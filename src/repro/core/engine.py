"""Combined spatial + temporal blocking executor — pure JAX (the algorithm).

This is the paper's accelerator expressed as data-parallel JAX: overlapped
spatial blocks are materialized as a batch and updated ``par_time`` fused
time-steps by a vmapped per-block pipeline, then the compute blocks are
stitched back (out-of-bound compute is sliced off — the paper's "control only
the flow of writes").  The Pallas kernels in ``repro.kernels`` implement the
same math with explicit VMEM streaming; this module is their semantic spec
and the multi-device distribution's local worker.

Boundary-condition handling across fused steps: see DESIGN.md §2.1 — the
clamp is re-imposed on out-of-grid positions before every sub-step
(``_reclamp``), and the streaming axis uses edge-mode padding re-derived per
sub-step (exact, because it is re-computed from current values).

PE forwarding (paper §3.2): when ``iters % par_time != 0`` the trailing
sub-steps forward data unchanged — implemented as a ``where(t < steps)``
select, exactly like unused PEs passing data down the chain.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.blocking import BlockGeometry
from repro.core.stencils import Stencil


def _pad_blocked_dims(grid: jnp.ndarray, geom: BlockGeometry) -> jnp.ndarray:
    """Edge-pad trailing (blocked) dims: halo on the left, halo + out-of-bound
    overhang on the right, so every block slice is in-bounds."""
    h = geom.size_halo
    pads = [(0, 0)]
    for d, p in zip(geom.blocked_dims, geom.padded_dims):
        pads.append((h, p - d - h))
    return jnp.pad(grid, pads, mode="edge")


def _block_index(geom: BlockGeometry, dim_i: int) -> jnp.ndarray:
    """(bnum, bsize) gather indices into the padded grid for blocked dim i."""
    c, b, n = geom.csize[dim_i], geom.bsize[dim_i], geom.bnum[dim_i]
    return (jnp.arange(n)[:, None] * c + jnp.arange(b)[None, :])


def extract_blocks(grid: jnp.ndarray, geom: BlockGeometry) -> jnp.ndarray:
    """-> (num_blocks..., stream_dim, *bsize) overlapped blocks."""
    gp = _pad_blocked_dims(grid, geom)
    if geom.ndim == 2:
        blk = jnp.take(gp, _block_index(geom, 0), axis=1)   # (ny, bnx, bsx)
        return jnp.moveaxis(blk, 1, 0)                      # (bnx, ny, bsx)
    blk = jnp.take(gp, _block_index(geom, 0), axis=1)       # (nz, bny, bsy, nxp)
    blk = jnp.take(blk, _block_index(geom, 1), axis=3)      # (nz, bny, bsy, bnx, bsx)
    return jnp.transpose(blk, (1, 3, 0, 2, 4))              # (bny, bnx, nz, bsy, bsx)


def stitch_blocks(blocks: jnp.ndarray, geom: BlockGeometry) -> jnp.ndarray:
    """Write-back: keep each block's compute region, discard halos and
    out-of-bound columns (paper's masked writes)."""
    h = geom.size_halo
    if geom.ndim == 2:
        comp = blocks[:, :, h:h + geom.csize[0]]             # (bnx, ny, csx)
        out = jnp.moveaxis(comp, 0, 1).reshape(blocks.shape[1], -1)
        return out[:, :geom.blocked_dims[0]]
    csy, csx = geom.csize
    comp = blocks[:, :, :, h:h + csy, h:h + csx]             # (bny,bnx,nz,csy,csx)
    bny, bnx, nz = comp.shape[:3]
    out = jnp.transpose(comp, (2, 0, 3, 1, 4)).reshape(nz, bny * csy, bnx * csx)
    return out[:, :geom.blocked_dims[0], :geom.blocked_dims[1]]


def _reclamp(block: jnp.ndarray, bidx, geom: BlockGeometry,
             bounds=None) -> jnp.ndarray:
    """Re-impose the clamp BC: overwrite out-of-grid positions with the value
    at the clamped global coordinate. No-op for interior blocks.

    ``bounds``: optional (ndim, 2) clamp range per grid axis, in grid
    coordinates — used by the multi-device runtime, where a shard's local
    edge may be an *internal* boundary (no clamp: bounds cover the whole
    halo-extended shard) or a *true* grid boundary (clamp at the halo
    offset). Entries may be traced. None = clamp at the grid edges.
    """
    h = geom.size_halo
    if bounds is not None:
        # streaming axis (axis 0 of the block)
        idx = jnp.clip(jnp.arange(block.shape[0]), bounds[0][0], bounds[0][1])
        block = jnp.take(block, idx, axis=0)
    for i, (dim, b, c) in enumerate(zip(geom.blocked_dims, geom.bsize,
                                        geom.csize)):
        axis = block.ndim - (geom.ndim - 1) + i
        lo, hi = (0, dim - 1) if bounds is None else bounds[i + 1]
        gx = bidx[i] * c + jnp.arange(b) - h
        jc = jnp.clip(gx, lo, hi) + h - bidx[i] * c
        block = jnp.take(block, jnp.clip(jc, 0, b - 1), axis=axis)
    return block


def _block_substep(stencil: Stencil, block: jnp.ndarray, coeffs: dict,
                   aux_block) -> jnp.ndarray:
    """One plain stencil step on a block: exact edge-pad BC on the streaming
    axis, garbage-tolerant edge-pad on blocked axes (halo shrinkage covers
    it)."""
    r = stencil.radius
    p = jnp.pad(block, r, mode="edge")

    def get(off):
        idx = tuple(slice(r + o, r + o + n) for o, n in zip(off, block.shape))
        return p[idx]

    return stencil.apply(get, coeffs, aux_block)


@partial(jax.jit, static_argnames=("stencil", "geom"))
def blocked_superstep(stencil: Stencil, geom: BlockGeometry,
                      grid: jnp.ndarray, coeffs: dict, steps,
                      aux: jnp.ndarray | None = None,
                      bounds=None) -> jnp.ndarray:
    """Apply ``steps`` (<= par_time) fused time-steps via one HBM round-trip
    worth of overlapped blocks. ``steps`` may be a traced scalar; ``bounds``
    is the optional per-axis clamp range (see ``_reclamp``)."""
    blocks = extract_blocks(grid, geom)
    aux_blocks = extract_blocks(aux, geom) if stencil.has_aux else None

    def one_block(block, aux_block, *bidx):
        def substep(t, blk):
            blk = _reclamp(blk, bidx, geom, bounds)
            new = _block_substep(stencil, blk, coeffs, aux_block)
            return jnp.where(t < steps, new, blk)   # PE forwarding
        return jax.lax.fori_loop(0, geom.par_time, substep, block)

    aux_ax = 0 if aux_blocks is not None else None
    if geom.ndim == 2:
        upd = jax.vmap(one_block, in_axes=(0, aux_ax, 0))(
            blocks, aux_blocks, jnp.arange(geom.bnum[0]))
    else:
        inner = jax.vmap(one_block, in_axes=(0, aux_ax, None, 0))
        upd = jax.vmap(inner, in_axes=(0, aux_ax, 0, None))(
            blocks, aux_blocks, jnp.arange(geom.bnum[0]),
            jnp.arange(geom.bnum[1]))
    return stitch_blocks(upd, geom)


def superstep_loop(stencil: Stencil, geom: BlockGeometry, grid: jnp.ndarray,
                   coeffs: dict, iters, aux: jnp.ndarray | None = None,
                   bounds=None) -> jnp.ndarray:
    """Fused whole-run driver: ``ceil(iters/par_time)`` super-steps as one
    traced loop (paper Eq. 8 numerator), so an enclosing ``jit`` lowers the
    entire iteration count to a single dispatch.

    ``iters`` may be a *traced* scalar: the trip count is computed inside the
    trace and the loop lowers to a dynamic ``while``, so one compiled
    executable serves every iteration count — a serving process never
    re-traces because a request asked for a different ``iters``.  Trailing
    sub-steps of a partial final super-step are PE-forwarded (paper §3.2)
    exactly as in :func:`blocked_superstep`.
    """
    par_time = geom.par_time
    n_super = (iters + par_time - 1) // par_time

    def body(s, g):
        steps = jnp.minimum(par_time, iters - s * par_time)
        return blocked_superstep(stencil, geom, g, coeffs, steps, aux, bounds)

    return jax.lax.fori_loop(0, n_super, body, grid)


@partial(jax.jit, static_argnames=("stencil", "geom"))
def _run_blocked_jit(stencil, geom, grid, coeffs, iters, aux):
    return superstep_loop(stencil, geom, grid, coeffs, iters, aux)


def run_blocked(stencil: Stencil, grid: jnp.ndarray, coeffs: dict, iters: int,
                par_time: int, bsize, aux: jnp.ndarray | None = None
                ) -> jnp.ndarray:
    """Full run: ceil(iters/par_time) super-steps (paper Eq. 8 numerator).

    ``iters`` is passed into the executable as a dynamic scalar, so repeated
    calls with different iteration counts share one compiled program."""
    if isinstance(bsize, int):
        bsize = (bsize,) * (grid.ndim - 1)
    geom = BlockGeometry(grid.ndim, grid.shape, stencil.radius, par_time, bsize)
    return _run_blocked_jit(stencil, geom, grid, coeffs,
                            jnp.asarray(iters, jnp.int32), aux)
