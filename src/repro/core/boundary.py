"""Pluggable boundary conditions — clamp / periodic / reflect / constant.

The paper fixes one boundary condition: every out-of-bound neighbor falls
back on the boundary cell itself (§5.1 — index clamp / edge replication).
Real stencil workloads (PDE solvers, wave propagation, periodic physics
domains) need more, so the BC is a first-class per-axis parameter of
:class:`~repro.api.problem.StencilProblem` rather than a baked-in constant:

  ``clamp``      out-of-grid index i -> clip(i, 0, n-1)          (paper §5.1)
  ``periodic``   i -> i mod n (torus topology; no physical edge)
  ``reflect``    i -> mirror about the edge cells, edge NOT repeated
                 (numpy ``mode="reflect"``: -1 -> 1, n -> n-2)
  ``constant``   out-of-grid neighbors read a fixed scalar fill value

Axes may mix kinds (e.g. periodic in x, clamp in y).  Mixed-BC corner
semantics: each axis' rule is applied to its own coordinate independently —
index-map kinds commute, and a ``constant`` axis absorbs (any out-of-range
constant-axis coordinate yields the fill value).  This is exactly what
sequential per-axis ``jnp.pad`` produces, which is how the oracle
(``kernels/ref.py``) defines the ground truth every backend is checked
against.

Execution-strategy notes (why each backend can honor these exactly):
  * clamp / reflect / constant are *local*: the ghost value at depth ``k``
    derives from cells within ``k`` of the same edge, so a block (or shard)
    containing that edge can re-impose the BC on its own data every fused
    sub-step — the generalization of the paper's per-step re-clamp.
  * periodic is *non-local* (the ghost source is the far side of the grid)
    but needs **no** re-imposition at all: a wrapped halo is an exact
    translated copy whose neighborhood is the same translated copy, so the
    standard overlapped-blocking staleness argument (garbage creeps ``rad``
    cells per sub-step, halo width ``rad*par_time`` covers it) applies
    verbatim.  Backends therefore materialize the wrap once per super-step
    (wrap-mode padding, or a wrap-around ``ppermute`` ring on a mesh) and
    treat it as an interior seam.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple, Union

import jax.numpy as jnp

#: Supported per-axis boundary kinds.
KINDS = ("clamp", "periodic", "reflect", "constant")

#: Spec forms accepted by :meth:`BoundaryCondition.make` / StencilProblem.
BCSpec = Union[str, Sequence[str], "BoundaryCondition"]


@dataclasses.dataclass(frozen=True)
class BoundaryCondition:
    """Per-axis boundary condition (streaming axis first, like grid shapes).

    ``kinds`` has one entry per grid axis; ``value`` is the shared scalar
    fill for ``constant`` axes.  Frozen + hashable: the BC participates in
    jit static arguments and in the schedule/executable cache keys.
    """
    kinds: Tuple[str, ...]
    value: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "kinds", tuple(self.kinds))
        for k in self.kinds:
            if k not in KINDS:
                raise ValueError(f"unknown boundary kind {k!r}; "
                                 f"supported: {KINDS}")
        try:
            v = float(self.value)
        except (TypeError, ValueError):
            raise ValueError(
                f"constant boundary fill must be a scalar, got "
                f"{self.value!r} ({type(self.value).__name__})") from None
        object.__setattr__(self, "value", v)

    # --- construction -------------------------------------------------------
    @classmethod
    def make(cls, spec: BCSpec, ndim: int) -> "BoundaryCondition":
        """Normalize a user spec to a per-axis BC.

        Accepts a single kind name (applied to every axis), a per-axis
        sequence of kind names, or an already-built ``BoundaryCondition``.
        A ``"constant:VALUE"`` token sets the fill value inline, e.g.
        ``("periodic", "constant:80.0")``.
        """
        if isinstance(spec, BoundaryCondition):
            if len(spec.kinds) != ndim:
                raise ValueError(f"boundary has {len(spec.kinds)} axis kinds "
                                 f"but the grid is {ndim}D")
            return spec
        if isinstance(spec, str):
            entries = (spec,) * ndim
        else:
            entries = tuple(spec)
            if len(entries) != ndim:
                raise ValueError(f"boundary {entries!r} has {len(entries)} "
                                 f"entries; need one per grid axis ({ndim})")
        kinds, values = [], []
        for e in entries:
            if not isinstance(e, str):
                raise ValueError(f"per-axis boundary entries must be kind "
                                 f"names, got {e!r}")
            kind, _, val = e.partition(":")
            kinds.append(kind)
            if val:
                if kind != "constant":
                    raise ValueError(f"only 'constant' takes a ':value' "
                                     f"suffix, got {e!r}")
                try:
                    values.append(float(val))
                except ValueError:
                    raise ValueError(
                        f"boundary spec {e!r}: the constant fill must be "
                        f"a number (e.g. 'constant:80.0')") from None
        if len(set(values)) > 1:
            raise ValueError(f"conflicting constant fill values {values}; "
                             "all constant axes share one scalar")
        return cls(tuple(kinds), values[0] if values else 0.0)

    @classmethod
    def clamp(cls, ndim: int) -> "BoundaryCondition":
        """The paper's default: edge replication on every axis."""
        return cls(("clamp",) * ndim)

    # --- introspection ------------------------------------------------------
    @property
    def is_clamp(self) -> bool:
        return all(k == "clamp" for k in self.kinds)

    def token(self) -> str:
        """Stable human-readable identity for cache keys and reprs."""
        toks = [f"constant({self.value:g})" if k == "constant" else k
                for k in self.kinds]
        return toks[0] if len(set(toks)) == 1 else ",".join(toks)

    def validate_shape(self, shape: Sequence[int]) -> None:
        """Shape-dependent validation: reflect mirrors about the edge cells
        without repeating them, which needs at least 2 cells on that axis."""
        for ax, (k, d) in enumerate(zip(self.kinds, shape)):
            if k == "reflect" and d < 2:
                raise ValueError(
                    f"'reflect' boundary on axis {ax} needs extent >= 2 "
                    f"(got {d}); use 'clamp' for degenerate axes")


def kinds_of(bc, ndim: int) -> Tuple[str, ...]:
    """Per-axis kinds with ``None`` meaning the legacy default (clamp)."""
    return ("clamp",) * ndim if bc is None else bc.kinds


def fill_of(bc) -> float:
    return 0.0 if bc is None else bc.value


def pad_axis(arr: jnp.ndarray, axis: int, lo: int, hi: int, kind: str,
             value: float = 0.0) -> jnp.ndarray:
    """Pad one axis of ``arr`` by ``(lo, hi)`` ghost cells per the BC kind.

    ``reflect`` on a length-1 axis degrades to edge replication (the mirror
    is undefined there; problem validation rejects user-visible cases, this
    guard keeps internal garbage-tolerant uses total).
    """
    if lo == 0 and hi == 0:
        return arr
    pads = [(0, 0)] * arr.ndim
    pads[axis] = (lo, hi)
    if kind == "constant":
        return jnp.pad(arr, pads, mode="constant", constant_values=value)
    if kind == "periodic":
        return jnp.pad(arr, pads, mode="wrap")
    if kind == "reflect" and arr.shape[axis] >= 2:
        return jnp.pad(arr, pads, mode="reflect")
    return jnp.pad(arr, pads, mode="edge")


def map_index(idx: jnp.ndarray, lo, hi, kind: str) -> jnp.ndarray:
    """Map (possibly out-of-range) coordinates into ``[lo, hi]`` per the BC's
    index rule.  ``constant`` has no index rule — callers mask instead.
    ``lo``/``hi`` may be traced (the distributed runtime's per-shard bounds).
    """
    if kind == "periodic":
        return lo + jnp.mod(idx - lo, hi - lo + 1)
    if kind == "reflect":
        n = hi - lo + 1
        p = jnp.maximum(2 * n - 2, 1)    # degenerate n==1 -> everything at lo
        m = jnp.mod(idx - lo, p)
        return lo + jnp.where(m >= n, p - m, m)
    return jnp.clip(idx, lo, hi)         # clamp


def out_of_range(idx: jnp.ndarray, lo, hi) -> jnp.ndarray:
    """Mask of coordinates outside ``[lo, hi]`` (the 'constant' fill set)."""
    return (idx < lo) | (idx > hi)
