"""Block/halo geometry — paper Eqs. (1)-(7), adapted to TPU lane alignment.

The paper blocks the *fastest* dimension(s) and streams the remaining one:
  * 2D stencils: 1-D spatial blocking in x, streaming in y        (paper §3.1)
  * 3D stencils: 2-D spatial blocking in (x, y), streaming in z   (paper §3.1)

Array layout convention in this repo: the streaming dimension is axis 0
(y for 2D grids ``(ny, nx)``, z for 3D grids ``(nz, ny, nx)``); blocked
dimensions are the trailing axes.

Temporal blocking widens each halo to ``size_halo = rad * par_time``
(paper Eq. 2).  Overlapped blocks (Fig. 4) of extent ``bsize`` advance by the
compute-block stride ``csize = bsize - 2*size_halo`` (Eq. 4); the number of
blocks per dimension is ``ceil(dim / csize)`` (Eq. 5), and out-of-bound
compute in the last block is discarded at write time.

TPU alignment note (paper §3.3.3 analogue): the paper pads device buffers so
external accesses stay 512-bit aligned.  On TPU the analogous constraint is
lane alignment — we require ``csize % lane == 0`` (lane = 128 for f32) for the
innermost blocked dimension, which makes every block's start offset and every
compute-block write lane-aligned.  512 bits = 16 f32 on the FPGA; 128 lanes =
512 bytes on TPU — the same trick, one power of two up.

Stream-axis vectorization (paper §3.3 ``par_vec``): each pipeline tick
advances ``par_vec`` rows/planes instead of one, so the rolling windows hold
``win_slots`` slabs of ``par_vec`` rows, every DMA moves a ``(par_vec, ...)``
slab, and the tick count shrinks ~``par_vec``-fold.  On TPU the natural sweet
spot is the 8-sublane f32 tile: at V=1 Mosaic pads every window slot and DMA
landing buffer to 8 sublanes (waste ``vmem_bytes`` now accounts for); at V=8
each sublane carries a real row.  See DESIGN.md §2.2.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

LANE = 128      # lanes per VREG row on TPU (dtype-independent)
SUBLANE = 8     # sublanes of the 4-byte (f32) minimum tile; 16-bit tiles
                # use 16 — see repro.core.precision.sublanes_for


@dataclasses.dataclass(frozen=True)
class BlockGeometry:
    """Static description of one combined spatial/temporal blocking plan."""
    ndim: int                      # grid rank (1, 2 or 3; streaming axis 0)
    dims: Tuple[int, ...]          # grid extents, streaming axis first
    rad: int
    par_time: int                  # fused time-steps per HBM round-trip
    bsize: Tuple[int, ...]         # block extent per *blocked* dim (trailing axes)
    par_vec: int = 1               # rows/planes advanced per pipeline tick (V)

    def __post_init__(self):
        assert self.ndim == len(self.dims)
        assert len(self.bsize) == self.ndim - 1, "streaming axis is not blocked"
        if self.par_vec < 1:
            raise ValueError(f"par_vec must be >= 1, got {self.par_vec}")
        if any(b <= 2 * self.size_halo for b in self.bsize):
            raise ValueError(
                f"bsize {self.bsize} too small for halo {self.size_halo} "
                f"(need bsize > 2*rad*par_time = {2 * self.size_halo})")

    # --- paper Eq. (2): halo width per side, in the last PE -----------------
    @property
    def size_halo(self) -> int:
        return self.rad * self.par_time

    # --- paper Eq. (4): compute-block extent --------------------------------
    @property
    def csize(self) -> Tuple[int, ...]:
        return tuple(b - 2 * self.size_halo for b in self.bsize)

    # --- paper Eq. (5): blocks per blocked dimension -------------------------
    @property
    def bnum(self) -> Tuple[int, ...]:
        return tuple(math.ceil(d / c)
                     for d, c in zip(self.blocked_dims, self.csize))

    @property
    def stream_dim(self) -> int:
        return self.dims[0]

    # --- stream-axis vectorization (paper §3.3 par_vec on the TPU) ----------
    @property
    def slab_lag(self) -> int:
        """Slabs of ``par_vec`` rows each PE stage lags its producer by —
        the vector generalization of the per-stage ``rad``-row lag
        (``ceil(rad / par_vec)``; equals ``rad`` at V=1)."""
        return -(-self.rad // self.par_vec)

    @property
    def win_slots(self) -> int:
        """Slab slots per rolling stage window.  Stage ``t`` computing slab
        ``j`` taps rows ``j*V - rad .. (j+1)*V - 1 + rad`` of stage
        ``t-1``, i.e. slabs ``j - slab_lag .. j + slab_lag`` — the vector
        form of the ``2*rad + 1``-row window (which it equals at V=1)."""
        return 2 * self.slab_lag + 1

    def stream_slabs(self, stream: int | None = None) -> int:
        """Ticks needed to stream ``stream`` rows/planes, ``par_vec`` at a
        time (kernel wrappers pad the stream axis up to a slab multiple)."""
        n = self.stream_dim if stream is None else stream
        return -(-n // self.par_vec)

    @property
    def blocked_dims(self) -> Tuple[int, ...]:
        return self.dims[1:]

    # --- padded extents: bnum*csize + 2*halo (what the engine/kernels see) --
    @property
    def padded_dims(self) -> Tuple[int, ...]:
        return tuple(n * c + 2 * self.size_halo
                     for n, c in zip(self.bnum, self.csize))

    @property
    def num_blocks(self) -> int:
        return math.prod(self.bnum)

    # --- paper Eq. (7): traversed cells per blocked dimension ---------------
    @property
    def trav(self) -> Tuple[int, ...]:
        """Alias of :attr:`padded_dims`: the Eq. (7) 'traversed' extent
        (``bnum * csize + 2*halo``) is exactly the padded extent the
        engine/kernels see — one definition, two paper names."""
        return self.padded_dims

    # --- paper Eq. (6): cells read from external memory per input buffer ----
    @property
    def cells_read(self) -> int:
        r = self.stream_dim
        for n, b in zip(self.bnum, self.bsize):
            r *= n * b
        return r

    @property
    def cells_written(self) -> int:
        # writes masked to in-bounds compute cells only (paper §3.2/§4)
        return math.prod(self.dims)

    @property
    def redundancy(self) -> float:
        """Read amplification from overlapped halos + out-of-bound cells."""
        return self.cells_read / math.prod(self.dims)

    # --- VMEM working set of the streaming kernels (bytes) ------------------
    def vmem_bytes(self, cell_bytes: int = 4, has_aux: bool = False,
                   double_buffer: bool = True,
                   stage_radii: Sequence[int] | None = None,
                   dag_info: tuple | None = None) -> int:
        """Rolling-window footprint of the Pallas kernel for this geometry,
        **as Mosaic tiles it**: the second-to-last dim of every VMEM buffer
        is padded to a multiple of 8 sublanes (f32 (8, 128) tiling), so a
        V=1 2D kernel's ``(2*rad+1, bsize)`` window slots and its
        ``(1, bsize)`` DMA landing buffers each occupy 8 sublanes no matter
        how few rows they hold.  That padding is exactly what ``par_vec``
        reclaims: at V=8 every sublane of the ``(V, bsize)`` slab carries a
        real row.  Counting it here keeps autotune's VMEM feasibility filter
        from admitting candidates that OOM on hardware.

        Per chain entry (program stage × temporal stage): a slab window of
        ``2*ceil(r_i/V) + 1`` slots of ``par_vec`` rows/planes each, sized
        for *that* entry's radius; plus double-buffered input/output DMA
        slabs and, for Hotspot, an aux (power) window deep enough to feed
        the last entry (``Lag_total + 1`` slabs).  ``stage_radii`` prices a
        multi-stage :class:`~repro.programs.StencilProgram`'s heterogeneous
        chain; ``None`` is the classic single-operator chain (``rad`` per
        entry).

        ``dag_info`` prices a general DAG program instead: a
        ``(win_slots, n_in, n_out, aux_slabs)`` tuple from
        :meth:`~repro.programs.StencilProgram.dag_vmem_info`.  ``win_slots``
        enumerates every live value-node window's depth (in V-slabs) over
        the *already unrolled* graph — per-edge consumer reach, not the
        chain's uniform ``2*lag+1`` — so no ``par_time`` multiplier applies;
        ``n_in``/``n_out`` count the external field streams each needing
        their own DMA slabs; ``aux_slabs`` is the aux window depth (0 = no
        aux).
        """
        V = self.par_vec
        db = 2 if double_buffer else 1
        if dag_info is not None:
            slots, n_in, n_out, aux_slabs = dag_info
            slots = [w for w in slots if w > 0]
            pt = 1
            has_aux = has_aux and aux_slabs > 0
        else:
            radii = tuple(stage_radii) if stage_radii else (self.rad,)
            lags = [-(-r // V) for r in radii]          # per program stage
            slots = [2 * lg + 1 for lg in lags]
            aux_slabs = sum(lags) * self.par_time + 1   # Lag_total + 1
            n_in = n_out = 1
            pt = self.par_time

        # Mosaic's minimum-tile sublane count is dtype-dependent: 8 for
        # 4-byte cells, 16 for bf16, 32 for 1-byte (packed tiles) — thin
        # bf16 buffers pad to 16 sublanes, so the V that stops wasting
        # sublanes doubles (mirrored by perf_model's sub_eff pricing)
        sublanes = max(8, 32 // max(1, cell_bytes))

        def pad8(n: int) -> int:
            return -(-n // sublanes) * sublanes

        def padl(n: int) -> int:
            return -(-n // LANE) * LANE

        if self.ndim == 1:
            # 1-D buffers: the stream rows are the lane dim
            win = pt * sum(padl(w * V) for w in slots)
            stream = db * padl(V) * n_in
            out = db * padl(V) * n_out
            aux = (padl(aux_slabs * V) + db * padl(V)) if has_aux else 0
        elif self.ndim == 2:
            # stream rows are the sublane dim of every buffer
            bx = self.bsize[0]
            win = pt * sum(pad8(w * V) for w in slots) * bx
            stream = db * pad8(V) * bx * n_in
            out = db * pad8(V) * self.csize[0] * n_out
            # aux = rolling window + its own DMA landing double buffer
            aux = (pad8(aux_slabs * V) * bx + db * pad8(V) * bx) \
                if has_aux else 0
        else:
            # the blocked y extent is the sublane dim; V planes stack above
            plane = pad8(self.bsize[0]) * self.bsize[1]
            win = pt * sum(slots) * V * plane
            stream = db * V * plane * n_in
            out = db * V * pad8(self.csize[0]) * self.csize[1] * n_out
            aux = (aux_slabs * V * plane + db * V * plane) if has_aux else 0
        return (win + stream + out + aux) * cell_bytes


def stream_extension(geom: BlockGeometry, bc) -> int:
    """Streaming-axis cells *per side* the Pallas path materializes for a
    periodic stream BC (0 otherwise): the rolling VMEM window cannot reach
    the far end of the stream, so the wrap is staged in HBM as ``size_halo``
    extra rows/planes, exact up to garbage creep and refreshed per
    super-step.  The single definition shared by the kernels' padding/DMA
    accounting (``kernels.ops``), the perf model (``predict``) and
    ``StencilPlan.traffic_report`` — these must never drift apart, or the
    model-vs-kernel traffic-accuracy ratio silently lies."""
    if bc is not None and bc.kinds[0] == "periodic":
        return geom.size_halo
    return 0


def extended_geometry(geom: BlockGeometry, bc) -> BlockGeometry:
    """``geom`` with the periodic stream extension applied — the extents the
    kernels actually stream (and the ones traffic/compute are billed on)."""
    ext = stream_extension(geom, bc)
    if not ext:
        return geom
    return dataclasses.replace(
        geom, dims=(geom.stream_dim + 2 * ext,) + geom.blocked_dims)


def bsize_feasible(rad: int, par_time: int, bsize: Sequence[int]) -> bool:
    """True iff ``bsize`` yields a valid geometry after halo widening.

    Small grids at high ``par_time`` otherwise produce candidates that
    :class:`BlockGeometry` rejects: the compute block ``csize = bsize -
    2*rad*par_time`` collapses to <= 0.  (No grid-extent check is needed: a
    block can never exceed the padded extent, since ``padded = bnum*csize +
    2*halo >= csize + 2*halo = bsize`` whenever csize > 0.)"""
    halo = rad * par_time
    return all(b > 2 * halo for b in bsize)


def choose_bsize_candidates(ndim: int, dims: Sequence[int], rad: int = 1,
                            par_time: int | None = None) -> list:
    """Power-of-two block extents, lane-aligned (paper §5.3 restrictions).

    When ``par_time`` is given, candidates infeasible for that temporal
    depth (see :func:`bsize_feasible`) are dropped; the result may be empty
    — callers autotuning a small grid must handle that, not crash."""
    out = []
    if ndim == 1:
        return [()]                  # stream-only: nothing to block
    if ndim == 2:
        b = LANE * 2
        while b <= max(2 * LANE, min(dims[1], 1 << 14)):
            out.append((b,))
            b *= 2
    else:
        b = 32
        while b <= max(32, min(dims[1], dims[2], 512)):
            out.append((b, b))   # square blocks for 3D (paper §5.3)
            b *= 2
    if par_time is not None:
        out = [bs for bs in out if bsize_feasible(rad, par_time, bs)]
    return out


def superstep_traffic_bytes(geom: BlockGeometry, num_read: int, num_write: int,
                            cell_bytes: int = 4) -> int:
    """External-memory bytes moved per super-step (paper Eq. 7/8 numerator).

    Reads skip fully out-of-bound columns (paper: "we avoid out-of-bound
    memory reads"): per blocked dim the traversed extent is ``trav`` but reads
    are clipped to the grid, so the read footprint per input buffer is
    ``stream_dim * prod(min(trav_d, ...)...)`` — we keep the paper's 2D form
    generalized: cells_read minus the out-of-bound band(s).
    """
    # Out-of-bound clip, generalizing paper Eq. (7) to any rank:
    read_cells = geom.stream_dim
    for n, b, c, d in zip(geom.bnum, geom.bsize, geom.csize, geom.blocked_dims):
        # last block extends past the grid by (n*c + 2*halo - d) cells; those
        # reads are clipped (DMA clamp), so the per-dim read extent is:
        per_dim = n * b - max(0, (n * c + 2 * geom.size_halo) - d)
        read_cells *= per_dim
    return (read_cells * num_read + geom.cells_written * num_write) * cell_bytes
