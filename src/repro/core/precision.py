"""Mixed-precision policy — storage dtypes, f32 accumulation, ulp tolerances.

One module owns every dtype-dependent decision the system makes, so the
kernel builder, the pure-JAX engine, the oracle, the perf model, and the
conformance tests can never drift apart:

  * **Storage vs accumulation.**  Grids live in HBM/VMEM in the problem's
    *storage* dtype (``StencilProblem.dtype``); every stage application
    computes in the *accumulation* dtype.  For 16-bit floats (bf16) the
    accumulation dtype is f32: taps are widened on window read, the stencil
    arithmetic (multiply-adds against f32 coefficients) runs in f32, and the
    result is rounded back to storage exactly once per stage application —
    the cast on the output DMA.  32-bit (and wider) floats accumulate in
    their own dtype, so the f32 path is bit-identical to the pre-bf16 code.
    Rounding once per stage application is the semantics ALL backends
    implement (oracle / engine / Pallas / distributed), which is what makes
    a cross-backend bf16 conformance matrix meaningful at ulp-level
    tolerances.

  * **Tile shapes.**  Mosaic's minimum VMEM tile is ``(sublanes, 128)``
    lanes with a dtype-dependent sublane count — 8 for 4-byte, 16 for
    2-byte, 32 for 1-byte cells (packed tiles).  :func:`sublanes_for` is
    the single definition; ``blocking.vmem_bytes`` pads with it and
    ``perf_model.predict`` prices sublane utilization against it.  Halving
    the cell bytes therefore *doubles* the ``par_vec`` sweet spot (V=16
    fills a bf16 tile the way V=8 fills an f32 tile) and the sweep ceiling
    (:func:`repro.core.perf_model.par_vec_candidates` extends to V=32 for
    16-bit tiles).

  * **Tolerances.**  The conformance harness (``tests/test_precision.py``)
    asserts every backend against an f64-promoted numpy oracle under the
    explicit per-dtype ulp budgets of :data:`ULPS_PER_ITER` — see
    :func:`tolerance` for the exact formula and README "Precision" for the
    documented table.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

#: storage dtypes the full backend matrix (including the Pallas kernels)
#: supports; the engine/reference backends additionally run any float dtype
SUPPORTED_DTYPES: Tuple[str, ...] = ("float32", "bfloat16")

#: the accumulation dtype of every sub-32-bit float storage dtype
ACCUM_DTYPE = jnp.float32

#: machine epsilon (one ulp at 1.0) per supported storage dtype
MACHINE_EPS = {
    "float32": 2.0 ** -23,
    "bfloat16": 2.0 ** -8,
    "float64": 2.0 ** -52,
}

#: per-(fused-)iteration ulp budget of the conformance harness: the maximum
#: error growth per program iteration, in ulps of the *storage* dtype,
#: backed by margin measured against the f64-promoted oracle (see
#: tests/test_precision.py).  f32 stages accumulate in f32 (error ~ a few
#: ulps/iter of rounding + reassociation); bf16 stages accumulate in f32 but
#: round to bf16 once per stage application, so the per-iteration budget in
#: *bf16* ulps is actually smaller — each step contributes at most ~1/2 ulp
#: of output rounding plus shrunken inherited error (diffusion-type updates
#: are near-convex combinations).
ULPS_PER_ITER = {
    "float32": 16.0,
    "bfloat16": 4.0,
    "float64": 16.0,
}


def normalize_dtype(spec) -> str:
    """Canonical dtype name for any accepted spec form: a string
    (``"bfloat16"``/``"bf16"``), a ``np.dtype``, a numpy/ml_dtypes scalar
    type, or ``jnp.bfloat16``/``jnp.float32``.  The single normalization
    used by :class:`~repro.api.problem.StencilProblem` and the serving
    request path, so every spelling lands in the same bucket/cache key."""
    if isinstance(spec, str) and spec in ("bf16", "half-bfloat"):
        spec = "bfloat16"
    return jnp.dtype(spec).name


def cell_bytes(dtype) -> int:
    """Storage bytes per grid cell — what HBM/halo traffic and VMEM
    footprints scale with (4 for f32, 2 for bf16)."""
    return int(jnp.dtype(dtype).itemsize)


def sublanes_for(cb: int) -> int:
    """Sublane count of the minimum Mosaic tile for a ``cb``-byte dtype:
    (8, 128) f32, (16, 128) bf16, (32, 128) int8/fp8 — the second-to-last
    tile dim grows as cells shrink, the 128-lane last dim is fixed."""
    return max(8, 32 // max(1, int(cb)))


def sublanes_of(dtype) -> int:
    return sublanes_for(cell_bytes(dtype))


def accum_dtype(dtype):
    """The compute dtype of one stage application: f32 for sub-32-bit
    floats, the storage dtype itself otherwise (so f32/f64 are untouched)."""
    dt = jnp.dtype(dtype)
    if jnp.issubdtype(dt, jnp.floating) and dt.itemsize < 4:
        return ACCUM_DTYPE
    return dt


def needs_accum_cast(dtype) -> bool:
    """True when storage and accumulation dtypes differ (bf16: cast taps up
    on read, round the stage result back down on write)."""
    return jnp.dtype(accum_dtype(dtype)) != jnp.dtype(dtype)


def promote_getter(get):
    """Wrap a neighbor getter so every tap is widened to the accumulation
    dtype before it enters the stencil arithmetic."""
    def wide(off):
        return get(off).astype(ACCUM_DTYPE)
    return wide


def apply_stage(stencil, get_or_gets, coeffs, aux, storage_dtype):
    """One stage application under the storage/accumulation policy: the
    single choke point the oracle (``kernels/ref.py``) and the engine
    (``core/engine.py``) route through.

    For f32 (and any >= 32-bit float) this is *exactly*
    ``stencil.apply(...)`` — no casts are inserted, so those paths stay
    bit-identical to the pre-bf16 code.  For bf16 storage: taps widen to
    f32, the arithmetic runs in f32 (coefficients are resolved in f32 by
    the plan), and the result rounds to bf16 once.  The Pallas kernel
    builder implements the same policy with its own casts (window-read /
    output-DMA) — see ``kernels/builder.py``."""
    if not needs_accum_cast(storage_dtype):
        return stencil.apply(get_or_gets, coeffs, aux)
    if isinstance(get_or_gets, tuple):
        gets = tuple(promote_getter(g) for g in get_or_gets)
    else:
        gets = promote_getter(get_or_gets)
    if aux is not None:
        aux = aux.astype(ACCUM_DTYPE)
    return stencil.apply(gets, coeffs, aux).astype(jnp.dtype(storage_dtype))


def tolerance(dtype, iters: int = 1, stages: int = 1,
              scale: Optional[float] = None) -> dict:
    """``{"rtol": ..., "atol": ...}`` for comparing a ``dtype`` result of
    ``iters`` program iterations (x ``stages`` stage applications each)
    against the f64-promoted oracle.

    The budget is ``ULPS_PER_ITER[dtype] * iters * stages`` ulps: per-step
    rounding errors of near-convex stencil updates compound at most
    linearly (each step's inherited error passes through a convex
    combination, gaining <= 1/2 output-rounding ulp), so a linear-in-steps
    ulp budget with the documented per-dtype base is a sound, explicit
    bound — not a fitted fudge factor.  ``scale`` sets the absolute floor
    ``atol = rtol * scale`` for fields whose magnitude is far from 1
    (Hotspot temperatures ~80: pass ``scale=100``); default 1."""
    name = jnp.dtype(dtype).name
    eps = MACHINE_EPS[name]
    ulps = ULPS_PER_ITER[name] * max(1, int(iters)) * max(1, int(stages))
    rtol = ulps * eps
    return {"rtol": rtol, "atol": rtol * (scale if scale else 1.0)}
