from repro.checkpoint.checkpoint import (CheckpointManager, complete_steps,
                                         latest_step, restore_latest_valid,
                                         restore_pytree, save_pytree)

__all__ = ["CheckpointManager", "complete_steps", "latest_step",
           "restore_latest_valid", "restore_pytree", "save_pytree"]
