"""Sharded, atomic, async checkpointing (restart-safety substrate).

Layout:  <dir>/step_<N>/shard_<host>.npz  +  <dir>/step_<N>/MANIFEST.json

Guarantees:
  * **Atomicity**: shards are written to ``step_N.tmp/`` and the directory is
    renamed only after every shard + manifest lands → a crashed save never
    shadows the previous good step (restart picks the latest *complete* one).
  * **Integrity**: the manifest records per-leaf tree paths, shapes, dtypes
    and a content checksum; restore validates before handing params back.
  * **Resharding**: leaves are saved in full (per-host addressable slice on
    multi-host); restore accepts any target sharding — restart on a
    *different mesh* re-shards transparently (elastic scaling).
  * **Async**: ``CheckpointManager.save_async`` hands the host copy to a
    writer thread so the train loop only blocks for the device→host copy.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import warnings
from typing import Any, Optional

import jax
import numpy as np

from repro.resilience.faults import fault_point, register_point

#: fires after the shards+manifest land in ``step_N.tmp`` but BEFORE the
#: atomic rename publishes them — an injected crash here is exactly the
#: kill-mid-save the atomicity guarantee is about (the .tmp never shadows
#: the previous good step)
FP_SAVE = register_point(
    "checkpoint.save", "before the step_N.tmp -> step_N atomic publish")
FP_RESTORE = register_point(
    "checkpoint.restore", "at the start of one step's restore (a firing "
    "models a corrupt/unreadable step; restore_latest_valid falls back)")


_STORAGE_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                 "float8_e5m2": np.uint8}
# npz has no bf16/f8 support (stores them as opaque void) — save a same-width
# integer view and record the logical dtype in the manifest.


def _flatten(tree) -> dict:
    flat = {}
    dtypes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        view = _STORAGE_VIEW.get(str(arr.dtype))
        if view is not None:
            arr = arr.view(view)
        flat[key] = arr
    return flat, dtypes


def _logical(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if _STORAGE_VIEW.get(dtype_str) is not None:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, dtype_str))
    return arr


def save_pytree(tree: Any, directory: str, step: int, host_id: int = 0,
                num_hosts: int = 1) -> str:
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat, dtypes = _flatten(tree)
    shard_path = os.path.join(tmp, f"shard_{host_id:05d}.npz")
    np.savez(shard_path, **flat)
    digest = hashlib.sha256()
    for k in sorted(flat):
        digest.update(k.encode())
        digest.update(np.ascontiguousarray(flat[k]).tobytes())
    manifest = {
        "step": step, "num_hosts": num_hosts,
        "leaves": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                   for k, v in flat.items()},
        "checksum": {f"shard_{host_id:05d}": digest.hexdigest()},
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    fault_point(FP_SAVE, {"step": step, "directory": directory})
    if host_id == 0:
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    return final


def complete_steps(directory: str) -> list:
    """Published (non-``.tmp``, manifest-bearing) step numbers, ascending.
    "Published" is necessary but not sufficient — a step can still fail
    integrity at restore; :func:`restore_latest_valid` handles that."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "MANIFEST.json")):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = complete_steps(directory)
    return steps[-1] if steps else None


def restore_pytree(template: Any, directory: str, step: int,
                   host_id: int = 0, shardings=None) -> Any:
    path = os.path.join(directory, f"step_{step:08d}")
    fault_point(FP_RESTORE, {"step": step, "directory": directory})
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"shard_{host_id:05d}.npz"))
    digest = hashlib.sha256()
    for k in sorted(data.files):
        digest.update(k.encode())
        digest.update(np.ascontiguousarray(data[k]).tobytes())
    want = manifest["checksum"].get(f"shard_{host_id:05d}")
    if want is not None and want != digest.hexdigest():
        raise IOError(f"checkpoint {path} failed integrity check")

    leaves_paths = jax.tree_util.tree_flatten_with_path(template)
    flat_tpl, tdef = jax.tree_util.tree_flatten(template)
    out = []
    for (kpath, leaf) in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in kpath)
        arr = data[key]
        info = manifest["leaves"][key]
        if list(arr.shape) != info["shape"]:
            raise IOError(f"shape mismatch for {key}")
        out.append(_logical(arr, info["dtype"]))
    restored = jax.tree_util.tree_unflatten(tdef, out)
    if shardings is not None:
        restored = jax.device_put(restored, shardings)
    return restored


def restore_latest_valid(template: Any, directory: str, host_id: int = 0,
                         shardings=None):
    """Restore the newest step that actually restores: a corrupt manifest,
    truncated/mangled shard, failed checksum, or missing leaf **falls back
    to the previous complete step** (with a warning) instead of crashing the
    restart — the resume path's contract.  Returns ``(tree, step)`` or
    ``(None, None)`` when no step in the directory is restorable."""
    for step in reversed(complete_steps(directory)):
        try:
            return restore_pytree(template, directory, step, host_id,
                                  shardings), step
        except Exception as e:      # noqa: BLE001 — any broken step: skip it
            warnings.warn(
                f"checkpoint step {step} in {directory!r} is unusable "
                f"({type(e).__name__}: {e}); falling back to the previous "
                f"complete step", RuntimeWarning, stacklevel=2)
    return None, None


class CheckpointManager:
    """Async save + retention + restart discovery."""

    def __init__(self, directory: str, keep: int = 3, host_id: int = 0,
                 num_hosts: int = 1):
        self.directory = directory
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, tree: Any, step: int):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # device->host, blocking

        def work():
            save_pytree(host_tree, self.directory, step, self.host_id,
                        self.num_hosts)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, template: Any, shardings=None):
        """Newest *restorable* step (corrupt/truncated steps fall back to
        the previous complete one — see :func:`restore_latest_valid`)."""
        return restore_latest_valid(template, self.directory, self.host_id,
                                    shardings)
