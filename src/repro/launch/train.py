"""Production training launcher.

Wires the full stack: config registry -> mesh -> sharding rules -> data
pipeline -> jit'd train step -> fault-tolerant loop (checkpoint/restart,
straggler detection, failure retry).

On a real cluster each host runs this same entry point (jax.distributed
handles process groups); on the CPU container use ``--smoke`` to select the
reduced config of the same family:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 20 --batch 8 --seq 128

Elastic restart: re-launch with a different ``--mesh-shape``; the checkpoint
restores onto the new mesh (shardings are re-derived from the same logical
spec tree).
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.data import DataConfig, SyntheticLMDataset
from repro.launch.mesh import dp_axes, make_mesh
from repro.models import init_params, param_axes
from repro.optim import adamw_init
from repro.optim.adamw import AdamWConfig, AdamWState
from repro.parallel import use_sharding_rules
from repro.parallel.sharding import default_rules, resolve_spec
from repro.train import TrainLoopConfig, fault_tolerant_train, make_train_step


def _mesh_from_args(args):
    n = jax.device_count()
    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
    else:
        # default: all devices on the data axis
        shape = (n, 1)
    axes = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
    assert math.prod(shape) == n, (shape, n)
    return make_mesh(shape, axes)


def _shard_tree(tree, axes_tree, mesh, rules):
    def one(ax, leaf):
        if leaf is None:
            return None
        spec = resolve_spec(leaf.shape, ax, mesh, rules)
        return jax.device_put(leaf, NamedSharding(mesh, spec))
    return jax.tree.map(one, axes_tree, tree,
                        is_leaf=lambda x: type(x) is tuple)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of the same family (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh-shape", default=None,
                    help="comma list, e.g. 16,16 or 2,16,16")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress", type=float, default=None, metavar="RATIO",
                    help="EF-top-k gradient compression keep-ratio "
                    "(cross-pod DCN trick); e.g. 0.05")
    ap.add_argument("--attn-impl", default=None,
                    choices=["xla", "pallas", "stub"],
                    help="attention implementation override (pallas = "
                    "flash kernel; interpret mode off-TPU)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.attn_impl:
        import dataclasses
        cfg = dataclasses.replace(cfg, attn_impl=args.attn_impl)
    mesh = _mesh_from_args(args)
    rules = default_rules(multi_pod="pod" in mesh.axis_names,
                          fsdp_over_pod=cfg.n_params > 5e10)
    print(f"arch={cfg.name} params={cfg.n_params / 1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    data_mode = ("frames" if cfg.input_mode == "frames" else
                 "embeds_prefix" if cfg.input_mode == "embeds_prefix"
                 else "tokens")
    data = SyntheticLMDataset(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, prefix_len=cfg.prefix_len, d_model=cfg.d_model,
        mode=data_mode),
        host_id=jax.process_index(), num_hosts=jax.process_count())

    with use_sharding_rules(mesh, rules):
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        p_axes = param_axes(cfg)
        params = _shard_tree(params, p_axes, mesh, rules)
        opt_state = adamw_init(params)
        opt_state = AdamWState(
            step=opt_state.step,
            m=_shard_tree(opt_state.m, p_axes, mesh, rules),
            v=_shard_tree(opt_state.v, p_axes, mesh, rules),
            master=_shard_tree(opt_state.master, p_axes, mesh, rules))

        ocfg = AdamWConfig(lr=args.lr, total_steps=args.steps)
        if args.compress:
            from repro.train import make_compressed_train_step
            step = make_compressed_train_step(
                cfg, ocfg, microbatches=args.microbatches,
                keep_ratio=args.compress)
            opt_state = (opt_state, step.init_extra(params))
            step_fn = jax.jit(step, donate_argnums=(0, 1))
        else:
            step_fn = jax.jit(
                make_train_step(cfg, ocfg,
                                microbatches=args.microbatches),
                donate_argnums=(0, 1))

        def batch_at(s):
            host = data.batch_at(s)
            spec = rules.spec(("batch", None))
            return {k: jax.device_put(
                v, NamedSharding(mesh, rules.spec(
                    ("batch",) + (None,) * (v.ndim - 1))))
                for k, v in host.items()}

        loop_cfg = TrainLoopConfig(
            total_steps=args.steps, checkpoint_every=args.ckpt_every,
            checkpoint_dir=args.ckpt_dir or f"/tmp/repro_{cfg.name}_ckpt")
        t0 = time.time()
        params, opt_state, events = fault_tolerant_train(
            loop_cfg, step_fn, (params, opt_state), iter(data),
            batch_at)
        dt = time.time() - t0

    losses = events["losses"]
    if losses:
        k = max(1, len(losses) // 10)
        tok_s = args.batch * args.seq * len(losses) / dt
        print(f"loss {np.mean(losses[:k]):.4f} -> {np.mean(losses[-k:]):.4f}"
              f" over {len(losses)} steps; {tok_s:.0f} tok/s;"
              f" retries={events['retries']}"
              f" stragglers={len(events['stragglers'])}")


if __name__ == "__main__":
    main()
