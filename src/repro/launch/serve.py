"""Production serving launcher: batched prefill + KV-cache decode.

Builds the serving mesh, shards params and caches by the logical spec trees,
prefills a batch of prompts, then decodes tokens in lockstep. The decode
step is the same jit'd function the dry-run lowers for the ``decode_32k`` /
``long_500k`` cells.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.launch.mesh import make_mesh
from repro.models import (cache_axes, init_params, make_decode_caches,
                          param_axes)
from repro.parallel import use_sharding_rules
from repro.parallel.sharding import default_rules, resolve_spec
from repro.train import make_decode_fn, make_prefill_fn


def _shard_tree(tree, axes_tree, mesh, rules):
    def one(ax, leaf):
        if leaf is None:
            return None
        spec = resolve_spec(leaf.shape, ax, mesh, rules)
        return jax.device_put(leaf, NamedSharding(mesh, spec))
    return jax.tree.map(one, axes_tree, tree,
                        is_leaf=lambda x: type(x) is tuple)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--mesh-shape", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n = jax.device_count()
    shape = (tuple(int(x) for x in args.mesh_shape.split(","))
             if args.mesh_shape else (n, 1))
    axes = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
    assert math.prod(shape) == n
    mesh = make_mesh(shape, axes)
    rules = default_rules(multi_pod="pod" in mesh.axis_names)
    max_len = args.max_len or args.prompt_len + args.max_new
    print(f"arch={cfg.name} params={cfg.n_params / 1e6:.1f}M "
          f"batch={args.batch} max_len={max_len}")

    rng = np.random.default_rng(args.seed)
    tokens = rng.integers(1, cfg.vocab, (args.batch, args.prompt_len),
                          dtype=np.int32)

    with use_sharding_rules(mesh, rules):
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        params = _shard_tree(params, param_axes(cfg), mesh, rules)

        inputs = {"tokens": jnp.asarray(tokens)}
        if cfg.input_mode == "frames":
            inputs["frames"] = jnp.asarray(rng.standard_normal(
                (args.batch, args.prompt_len, cfg.d_model)), jnp.float32)
        if cfg.input_mode == "embeds_prefix":
            inputs["embeds"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.prefix_len, cfg.d_model)), jnp.float32)

        prefill_fn = jax.jit(make_prefill_fn(cfg, max_len))
        decode_fn = jax.jit(make_decode_fn(cfg), donate_argnums=(2,))

        t0 = time.time()
        logits, caches, memory = prefill_fn(params, inputs)
        caches = _shard_tree(caches, cache_axes(cfg), mesh, rules)
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        jax.block_until_ready(nxt)
        t_prefill = time.time() - t0

        out = [np.asarray(nxt)[:, 0]]
        t0 = time.time()
        for _ in range(args.max_new - 1):
            logits, caches = decode_fn(params, nxt, caches, memory)
            nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            out.append(np.asarray(nxt)[:, 0])
        jax.block_until_ready(nxt)
        t_decode = time.time() - t0

    gen = np.stack(out, axis=1)
    for b in range(min(args.batch, 4)):
        print(f"  seq {b}: {gen[b, :10].tolist()}...")
    tok_s = args.batch * (args.max_new - 1) / max(t_decode, 1e-9)
    print(f"prefill {t_prefill:.3f}s; decode {t_decode:.3f}s "
          f"({tok_s:.1f} tok/s on this host)")


if __name__ == "__main__":
    main()
