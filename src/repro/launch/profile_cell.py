import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

# Hillclimb instrumentation: compile one dry-run cell and print the top HBM /
# FLOP / collective contributors with their loop multipliers.
#
#   PYTHONPATH=src python -m repro.launch.profile_cell \
#       --arch granite-3-8b --shape train_4k

# ruff: noqa: E402
import argparse

from repro.launch import hlo_analysis
from repro.launch.dryrun import PEAK_BF16, HBM_BW, ICI_BW, build_cell, \
    build_stencil_cell
from repro.configs import STENCIL_IDS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--top", type=int, default=14)
    ap.add_argument("--attn", default=None, choices=[None, "xla", "stub"])
    ap.add_argument("--kernel-stub", action="store_true",
                    help="stencil cells: bill the Pallas kernel's DMA")
    args = ap.parse_args()

    if args.arch in STENCIL_IDS:
        mesh, st, fn, cell_args, best = build_stencil_cell(
            args.arch, args.mesh == "multi", kernel_stub=args.kernel_stub)
    else:
        mesh, cfg, fn, cell_args = build_cell(args.arch, args.shape,
                                              args.mesh == "multi",
                                              attn_impl=args.attn)
    compiled = fn.lower(*cell_args).compile()
    an = hlo_analysis.analyze(compiled.as_text())

    print(f"== {args.arch} x {args.shape} x {args.mesh} ==")
    print(f"t_compute={an.flops / PEAK_BF16:.3f}s  "
          f"t_memory={an.hbm_bytes / HBM_BW:.3f}s  "
          f"t_collective={an.coll_bytes / ICI_BW:.3f}s")
    print(f"while trips: {an.while_trips}")

    print(f"\ntop-{args.top} HBM traffic (per device):")
    for name, (op, b, mult) in an.top_traffic(args.top):
        print(f"  {b / 1e9:12.2f} GB  x{mult:<6.0f} {op:24s} {name[:60]}")
    print(f"\ntop-{args.top} FLOPs:")
    for name, (op, f, mult) in an.top_flops(args.top):
        print(f"  {f / 1e12:12.2f} TF  x{mult:<6.0f} {op:24s} {name[:60]}")
    print(f"\ntop-{args.top} collectives (wire bytes):")
    for name, (op, b, mult) in an.top_coll(args.top):
        print(f"  {b / 1e9:12.2f} GB  x{mult:<6.0f} {op:24s} {name[:60]}")


if __name__ == "__main__":
    main()
