"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-meshing)."""
    return compat.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
