"""Optimized-HLO analyzer: loop-aware FLOPs, collective bytes, HBM traffic.

Why this exists: ``compiled.cost_analysis()`` counts each ``while`` body
ONCE, so for scan-over-layers models it under-reports FLOPs/bytes by ~L×
(verified empirically — see EXPERIMENTS.md §Dry-run notes).  This module
parses ``compiled.as_text()`` (post-optimization, post-SPMD-partitioning, so
all quantities are **per device**) and:

  1. builds the computation call graph (fusion/call/while/conditional),
  2. infers each while loop's trip count from its condition computation
     (the ``constant(N)`` feeding the ``compare``; scan/fori lowerings are
     ``i < N`` with unit step),
  3. multiplies every instruction's contribution by the product of enclosing
     trip counts,
  4. reports per-device:
       * ``flops``        — dot/convolution FLOPs (2·M·N·K per dot; operand
                            shapes resolved through a per-computation symbol
                            table since optimized HLO prints bare operand
                            names)
       * ``coll_bytes``   — wire bytes of collectives with ring factors:
                            all-reduce 2(G-1)/G, all-gather/reduce-scatter/
                            all-to-all (G-1)/G, collective-permute 1x
       * ``hbm_bytes``    — Σ (operand+result bytes) over fusion-boundary
                            instructions: a materialization model of HBM
                            traffic (VMEM-resident reuse inside a fusion is
                            free; anything crossing a fusion boundary pays)
       * per-collective breakdowns + while trip counts.

Approximations bias consistently — exactly what the §Perf hillclimb needs
(before/after deltas on the same estimator).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([\d,]+)\]")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _shape_elems(type_str: str) -> int:
    return math.prod(_first_shape_dims(type_str)) if _SHAPE_RE.search(
        type_str) else 0


def _operand_span(line: str, opcode: str) -> str:
    """Text inside the opcode's parens (quote-aware, nesting-aware)."""
    start = line.find(opcode + "(")
    if start < 0:
        return ""
    i = start + len(opcode) + 1
    depth = 1
    out = []
    in_str = False
    while i < len(line) and depth:
        c = line[i]
        if in_str:
            if c == '"':
                in_str = False
        elif c == '"':
            in_str = True
        elif c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                break
        out.append(c)
        i += 1
    return "".join(out)


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result_type: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    types: Dict[str, str]


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation],
                                         Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    current: Optional[Computation] = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and "(" in line and \
                line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line)
            if m:
                current = Computation(m.group(2), [], {})
                comps[current.name] = current
                if m.group(1):
                    entry = current.name
                continue
        if current is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, rtype, opcode = mi.group(1), mi.group(2), mi.group(3)
            span = _operand_span(line, opcode)
            operands = _OPERAND_NAME_RE.findall(span)
            ins = Instruction(name, opcode, rtype, line, operands)
            current.instructions.append(ins)
            current.types[name] = rtype
    return comps, entry


def _operand_bytes(ins: Instruction, comp: Computation) -> float:
    total = 0.0
    for op in ins.operands:
        t = comp.types.get(op)
        if t:
            total += _shape_bytes(t)
    return total


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    out = _shape_elems(ins.result_type)
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    lhs_t = comp.types.get(ins.operands[0]) if ins.operands else None
    if mc and lhs_t:
        dims = _first_shape_dims(lhs_t)
        for idx in mc.group(1).split(","):
            if idx and int(idx) < len(dims):
                k *= dims[int(idx)]
    return 2.0 * out * k


def _conv_flops(ins: Instruction, comp: Computation) -> float:
    out = _shape_elems(ins.result_type)
    if len(ins.operands) >= 2:
        rhs_t = comp.types.get(ins.operands[1])
        if rhs_t:
            dims = _first_shape_dims(rhs_t)
            if dims:
                return 2.0 * out * math.prod(dims[:-1])
    return 2.0 * out


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        dims = [int(d) for d in m.group(1).split(",")]
        return math.prod(dims[1:]) if len(dims) > 1 else dims[0]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def while_trip_count(while_line: str, cond: Optional[Computation]) -> int:
    m = _TRIP_RE.search(while_line)          # authoritative backend_config
    if m:
        return int(m.group(1))
    if cond is not None:                     # fallback: bound constant in cond
        consts = []
        for ins in cond.instructions:
            if ins.opcode == "constant":
                mc = _CONST_RE.search(ins.line)
                if mc:
                    consts.append(int(mc.group(1)))
        if consts:
            return max(consts)
    return 1


_SKIP_OPS = frozenset([
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-done",
    "all-gather-done", "all-reduce-done", "collective-permute-done",
    "async-done", "send-done", "recv-done", "custom-call",
])

_SLICING_OPS = frozenset(["dynamic-slice", "gather"])


def _instr_traffic(ins: Instruction, comp: Computation) -> float:
    """HBM bytes for one top-level instruction — slice-aware.

    dynamic-slice/gather read only the slice (result-sized); DUS writes only
    the update; everything else pays operands+result. Without this, scan
    carry buffers (the (L, ...) stacked weights/ys sliced per layer) would be
    billed at full-buffer size per trip — a ~L× overcount.
    """
    res = _shape_bytes(ins.result_type)
    if ins.opcode in _SLICING_OPS:
        return 2.0 * res
    if ins.opcode == "dynamic-update-slice":
        upd = (comp.types.get(ins.operands[1])
               if len(ins.operands) > 1 else None)
        return 2.0 * (_shape_bytes(upd) if upd else res)
    if ins.opcode == "scatter":
        upd = (comp.types.get(ins.operands[2])
               if len(ins.operands) > 2 else None)
        return 2.0 * (_shape_bytes(upd) if upd else res)
    if ins.opcode == "broadcast":
        return res
    return res + _operand_bytes(ins, comp)


def _fusion_traffic(fusion_ins: Instruction, comp: Computation,
                    called: Optional[Computation],
                    comps: Optional[Dict[str, Computation]] = None) -> float:
    """Fusion-boundary traffic with slice-aware parameter consumption.

    A fused computation's parameter that is consumed *only* through
    dynamic-slice/gather reads just the slices; a fusion whose root is a
    dynamic-update-slice writes just the update. kLoop fusions around a
    per-layer weight slice otherwise bill the whole (L,...) stack per trip.
    Wholesale-consumed parameters bill their *source* bytes (resolved
    through pure-convert producers — CPU bf16-emulation correction).
    """
    if called is None:
        return _shape_bytes(fusion_ins.result_type) + _operand_bytes(
            fusion_ins, comp)
    # map parameter name -> operand bytes as consumed
    total = 0.0
    param_names = {}
    for ins in called.instructions:
        if ins.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.line)
            if m:
                param_names[ins.name] = int(m.group(1))

    uses_of: Dict[str, List[Instruction]] = defaultdict(list)
    for ins in called.instructions:
        for op in ins.operands:
            uses_of[op].append(ins)

    def _slice_consumed(u: Instruction, vname: str) -> Optional[float]:
        """Bytes this use actually touches of value ``vname``, or None if it
        consumes it wholesale.

        * dynamic-update-slice *destination* (operand 0) counts as
          slice-consumed: XLA aliases the buffer in place, so HBM pays only
          the update window (billed at the root), not the whole (L, ...)
          gradient/cache stack per loop trip.
        * convert/bitcast/copy are transparent: the CPU backend's bf16
          emulation wraps DUS in full-buffer convert pairs that a
          native-bf16 TPU never materializes — follow through to the real
          consumer. (See EXPERIMENTS.md §Perf, estimator notes.)
        """
        if u.opcode in _SLICING_OPS:
            return 2.0 * _shape_bytes(u.result_type)
        if u.opcode in ("convert", "bitcast", "copy", "reshape"):
            inner = [_slice_consumed(uu, u.name) for uu in uses_of[u.name]]
            if inner and all(b is not None for b in inner):
                return sum(inner)
            return None
        if u.opcode == "dynamic-update-slice" and u.operands and \
                u.operands[0] == vname and vname not in u.operands[1:]:
            return 0.0
        return None

    for pname, pidx in param_names.items():
        uses = uses_of[pname]
        per_use = [_slice_consumed(u, pname) for u in uses]
        if uses and all(b is not None for b in per_use):
            total += sum(per_use)
        else:
            t = called.types.get(pname)
            b = _shape_bytes(t) if t else 0.0
            if comps is not None and pidx < len(fusion_ins.operands):
                src = _source_bytes(fusion_ins.operands[pidx], comp, comps)
                if src:
                    b = min(b, src)
            total += b

    def _resolve_root(ins: Instruction) -> Instruction:
        seen = 0
        while ins.opcode in ("convert", "bitcast", "copy") and ins.operands \
                and seen < 8:
            nxt = next((i for i in called.instructions
                        if i.name == ins.operands[0]), None)
            if nxt is None:
                break
            ins, seen = nxt, seen + 1
        return ins

    root = called.instructions[-1] if called.instructions else None
    root = _resolve_root(root) if root is not None else None
    if root is not None and root.opcode == "dynamic-update-slice" and \
            len(root.operands) > 1:
        upd = called.types.get(root.operands[1])
        if upd is None:   # update may itself be a convert of a parameter
            upd_ins = next((i for i in called.instructions
                            if i.name == root.operands[1]), None)
            upd = upd_ins.result_type if upd_ins is not None else None
        total += 2.0 * (_shape_bytes(upd) if upd else 0.0)
    else:
        total += _shape_bytes(fusion_ins.result_type)
    return total


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    coll_bytes: float = 0.0
    hbm_bytes: float = 0.0
    coll_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    while_trips: Dict[str, int] = dataclasses.field(default_factory=dict)
    # per-instruction attribution (hillclimb instrumentation):
    # name -> (opcode, total bytes incl. trip multiplier, multiplier)
    traffic_by_instr: Dict[str, Tuple[str, float, float]] = dataclasses.field(
        default_factory=dict)
    flops_by_instr: Dict[str, Tuple[str, float, float]] = dataclasses.field(
        default_factory=dict)
    coll_by_instr: Dict[str, Tuple[str, float, float]] = dataclasses.field(
        default_factory=dict)

    def top_traffic(self, n: int = 15):
        return sorted(self.traffic_by_instr.items(),
                      key=lambda kv: -kv[1][1])[:n]

    def top_flops(self, n: int = 15):
        return sorted(self.flops_by_instr.items(),
                      key=lambda kv: -kv[1][1])[:n]

    def top_coll(self, n: int = 15):
        return sorted(self.coll_by_instr.items(),
                      key=lambda kv: -kv[1][1])[:n]

    def as_dict(self) -> dict:
        return {"flops": self.flops, "coll_bytes": self.coll_bytes,
                "hbm_bytes": self.hbm_bytes,
                "coll_by_op": dict(self.coll_by_op),
                "coll_count": dict(self.coll_count),
                "while_trips": dict(self.while_trips)}


def attention_stub_flops(ins: Instruction, comp: Computation) -> float:
    """Analytic MXU FLOPs for a flash-attention stub custom-call.

    Identified by its operand signature: rank-4 float tensors
    q (B,Sq,H,D), k (B,Skv,Hkv,D)[, v, do]. Three operands = forward
    (2 dots), four = backward (5 dots); causal halves the pair count.
    Non-matching callbacks bill zero FLOPs.
    """
    shapes = []
    for op in ins.operands:
        t = comp.types.get(op)
        if not t:
            continue
        m = _SHAPE_RE.search(t)
        if not m or not m.group(1).startswith(("f", "bf")):
            continue
        dims = _first_shape_dims(t)
        if len(dims) == 4:
            shapes.append(dims)
    if len(shapes) < 2:
        return 0.0
    B, Sq, H, D = shapes[0]
    Skv = shapes[1][1]
    pairs = 0.5 * B * H * Sq * Skv      # causal
    n_dots = 2 if len(shapes) == 3 else 5
    return n_dots * 2.0 * pairs * D


_PURE_CONVERT_OPS = frozenset([
    "parameter", "convert", "bitcast", "copy", "reshape", "tuple",
    "get-tuple-element", "transpose",
])


def _is_pure_convert_fusion(ins: Instruction, comps: Dict[str, Computation]
                            ) -> bool:
    """True if the fusion only moves/re-types data (no arithmetic).

    The CPU backend has no native bf16: FloatNormalization wraps bf16
    values in f32 convert fusions and runs collectives in f32. A native-
    bf16 TPU materializes none of this — such fusions bill zero traffic and
    consumers bill the *source* bytes (see ``_source_bytes``). Without this
    correction the CPU-proxy roofline over-bills bf16 activation traffic
    and collective bytes by up to 2x.
    """
    if ins.opcode != "fusion":
        return False
    m = re.search(r"calls=%?([\w.\-]+)", ins.line)
    called = comps.get(m.group(1)) if m else None
    if called is None:
        return False
    return all(i.opcode in _PURE_CONVERT_OPS for i in called.instructions)


def _source_bytes(name: str, comp: Computation,
                  comps: Dict[str, Computation], depth: int = 0) -> float:
    """Bytes of ``name`` resolved through pure-convert producers: the
    narrowest dtype the value exists in along its convert chain."""
    t = comp.types.get(name)
    here = _shape_bytes(t) if t else 0.0
    if depth >= 4:
        return here
    prod = next((i for i in comp.instructions if i.name == name), None)
    if prod is None:
        return here
    if prod.opcode in ("convert", "bitcast", "copy") and prod.operands:
        src = _source_bytes(prod.operands[0], comp, comps, depth + 1)
        return min(here, src) if src else here
    if _is_pure_convert_fusion(prod, comps) and prod.operands:
        # narrowest representation along the inside convert chain: a CPU
        # f32->bf16->f32 round-trip marks a value that is bf16 on TPU
        m = re.search(r"calls=%?([\w.\-]+)", prod.line)
        called = comps.get(m.group(1)) if m else None
        if called is not None:
            inner = [_shape_bytes(i.result_type)
                     for i in called.instructions
                     if i.opcode in ("parameter", "convert", "bitcast",
                                     "copy", "reshape", "transpose")]
            inner = [b for b in inner if b > 0]
            if inner:
                here = min(here, min(inner))
        srcs = [_source_bytes(o, comp, comps, depth + 1)
                for o in prod.operands]
        srcs = [s for s in srcs if s]
        if srcs:
            return min(here, max(srcs))
    return here


def analyze(hlo_text: str, default_group: int = 1) -> Analysis:
    comps, entry = parse_module(hlo_text)
    out = Analysis()
    if entry is None:
        for name in comps:
            if "main" in name:
                entry = name
                break
    if entry is None:
        return out

    def _bill_traffic(ins: Instruction, bytes_: float, mult: float):
        out.hbm_bytes += mult * bytes_
        old = out.traffic_by_instr.get(ins.name)
        tot = (old[1] if old else 0.0) + mult * bytes_
        out.traffic_by_instr[ins.name] = (ins.opcode, tot, mult)

    def _bill_flops(ins: Instruction, fl: float, mult: float):
        out.flops += mult * fl
        old = out.flops_by_instr.get(ins.name)
        tot = (old[1] if old else 0.0) + mult * fl
        out.flops_by_instr[ins.name] = (ins.opcode, tot, mult)

    def _visit_fusion_flops(comp: Computation, mult: float):
        """Dots/convs inside fused computations (flops only; traffic is
        billed at the fusion boundary)."""
        for ins in comp.instructions:
            if ins.opcode == "dot":
                _bill_flops(ins, _dot_flops(ins, comp), mult)
            elif ins.opcode == "convolution":
                _bill_flops(ins, _conv_flops(ins, comp), mult)

    def visit(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instructions:
            if ins.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mcnd = re.search(r"condition=%?([\w.\-]+)", ins.line)
                body = mb.group(1) if mb else None
                cond = mcnd.group(1) if mcnd else None
                trips = while_trip_count(ins.line, comps.get(cond))
                if body:
                    out.while_trips[body] = trips
                    visit(body, mult * trips)
                continue
            if ins.opcode == "conditional":
                mbr = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                names = []
                if mbr:
                    names = [b.strip().lstrip("%")
                             for b in mbr.group(1).split(",")]
                else:
                    for attr in ("true_computation", "false_computation"):
                        m = re.search(attr + r"=%?([\w.\-]+)", ins.line)
                        if m:
                            names.append(m.group(1))
                for b in names:
                    visit(b, mult)
                continue
            if ins.opcode == "fusion":
                if _is_pure_convert_fusion(ins, comps):
                    continue   # CPU bf16-emulation artifact: no TPU traffic
                m = re.search(r"calls=%?([\w.\-]+)", ins.line)
                called = comps.get(m.group(1)) if m else None
                if called is not None:
                    _visit_fusion_flops(called, mult)
                _bill_traffic(ins, _fusion_traffic(ins, comp, called, comps),
                              mult)
                continue
            if ins.opcode == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                if m:
                    visit(m.group(1), mult)
                continue
            if ins.opcode == "dot":
                _bill_flops(ins, _dot_flops(ins, comp), mult)
                _bill_traffic(ins, _shape_bytes(ins.result_type)
                              + _operand_bytes(ins, comp), mult)
                continue
            if ins.opcode == "convolution":
                _bill_flops(ins, _conv_flops(ins, comp), mult)
                _bill_traffic(ins, _shape_bytes(ins.result_type)
                              + _operand_bytes(ins, comp), mult)
                continue
            base = ins.opcode.replace("-start", "")
            if base in COLLECTIVES:
                opnd = _operand_bytes(ins, comp)
                res = _shape_bytes(ins.result_type)
                # native-bf16 correction: a collective fed by a pure f32
                # convert of a bf16 value moves bf16 on a TPU wire
                opnd_src = sum(_source_bytes(o, comp, comps)
                               for o in ins.operands)
                if 0 < opnd_src < opnd:
                    res *= opnd_src / opnd
                    opnd = opnd_src
                g = _group_size(ins.line, default_group)
                if base == "all-reduce":
                    wire = 2.0 * opnd * (g - 1) / max(g, 1)
                elif base == "all-gather":
                    wire = res * (g - 1) / max(g, 1)
                elif base in ("reduce-scatter", "all-to-all",
                              "ragged-all-to-all"):
                    wire = opnd * (g - 1) / max(g, 1)
                else:   # collective-permute
                    wire = opnd
                out.coll_bytes += mult * wire
                out.coll_by_op[base] += mult * wire
                out.coll_count[base] += mult
                old = out.coll_by_instr.get(ins.name)
                out.coll_by_instr[ins.name] = (
                    base, (old[1] if old else 0.0) + mult * wire, mult)
                continue
            if ins.opcode == "custom-call" and "callback" in ins.line:
                # kernel stub (e.g. flash attention): operands+result IS the
                # kernel's DMA schedule; MXU flops assigned analytically
                _bill_traffic(ins, _instr_traffic(ins, comp), mult)
                _bill_flops(ins, attention_stub_flops(ins, comp), mult)
                continue
            if ins.opcode in _SKIP_OPS:
                continue
            # generic top-level op: pays a materialization round-trip
            _bill_traffic(ins, _instr_traffic(ins, comp), mult)

    visit(entry, 1.0)
    return out
