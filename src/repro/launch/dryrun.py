import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the very first two lines: jax locks the device count on first
# init, and the production meshes below need 512 placeholder devices.
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

# ruff: noqa: E402
import argparse
import json
import math
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, STENCIL_IDS, get_config,
                           input_specs, shape_applicable)
from repro.core import STENCILS, autotune
from repro.core.distributed import build_distributed_fn
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import (cache_axes, init_params, make_decode_caches,
                          param_axes)
from repro.optim import adamw_init
from repro.optim.adamw import AdamWConfig
from repro.parallel import use_sharding_rules
from repro.parallel.sharding import default_rules, resolve_spec
from repro.train import make_decode_fn, make_prefill_fn, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# hardware constants (per chip) — DESIGN.md §7
PEAK_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

MICROBATCHES = 8
# NOTE (measured, EXPERIMENTS.md §Dry-run): raising microbatches to 32 for
# the >=70B single-pod train cells shrinks peak memory 37.9->24.5 GiB but
# multiplies per-layer FSDP weight gathers 4x (t_collective 119->492 s,
# fraction 0.199->0.018) — the right remedy for those two cells is the
# second pod (multi-pod FSDP), not deeper microbatching.

# stencil app cells (the paper's own benchmarks, spatially distributed)
STENCIL_DIMS = {
    "diffusion2d": (65536, 65536),
    "hotspot2d": (65536, 65536),
    "diffusion3d": (1024, 4096, 4096),
    "hotspot3d": (1024, 4096, 4096),
}
STENCIL_ITERS = 64


def _tree_with_shardings(struct_tree, axes_tree, mesh, rules):
    # Axes tree leads the map (its leaves are always tuples); the struct tree
    # may carry None leaves (e.g. AdamW master copies of f32 params), which a
    # struct-led map would treat as structural-empty and fail on.
    def one(ax, leaf):
        if leaf is None:
            return None
        spec = resolve_spec(leaf.shape, ax, mesh, rules)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(one, axes_tree, struct_tree,
                        is_leaf=lambda x: type(x) is tuple)


def _shardings_of(struct_tree):
    return jax.tree.map(lambda s: s.sharding, struct_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _adamw_axes(p_axes):
    from repro.optim.adamw import AdamWState
    return AdamWState(step=(), m=p_axes, v=p_axes, master=p_axes)


def build_cell(arch: str, shape: str, multi_pod: bool,
               attn_impl: str | None = None):
    """Returns (jitted_fn, example_args) for the cell — ready to .lower()."""
    import dataclasses as _dc
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if attn_impl:
        cfg = _dc.replace(cfg, attn_impl=attn_impl)
    rules = default_rules(multi_pod=multi_pod,
                          fsdp_over_pod=cfg.n_params > 5e10)
    info = SHAPES[shape]
    if shape == "long_500k":
        # 524288-cell cache / state shards over every mesh axis; batch=1
        rules["kv_seq"] = list(mesh.axis_names)
        rules["batch"] = None

    with use_sharding_rules(mesh, rules):
        params_struct = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        p_axes = param_axes(cfg)
        params_struct = _tree_with_shardings(params_struct, p_axes, mesh,
                                             rules)
        batch = input_specs(cfg, shape, mesh=mesh, rules=rules)

        if info["kind"] == "train":
            opt_struct = jax.eval_shape(adamw_init, params_struct)
            opt_struct = _tree_with_shardings(opt_struct, _adamw_axes(p_axes),
                                              mesh, rules)
            step = make_train_step(cfg, AdamWConfig(total_steps=1000),
                                   microbatches=MICROBATCHES)
            fn = jax.jit(step, donate_argnums=(0, 1),
                         out_shardings=(_shardings_of(params_struct),
                                        _shardings_of(opt_struct), None))
            args = (params_struct, opt_struct, batch)
        elif info["kind"] == "prefill":
            caches_struct = jax.eval_shape(
                lambda: make_decode_caches(cfg, info["batch"], info["seq"]))
            caches_struct = _tree_with_shardings(caches_struct,
                                                 cache_axes(cfg), mesh, rules)
            fn = jax.jit(make_prefill_fn(cfg, info["seq"]),
                         out_shardings=(None, _shardings_of(caches_struct),
                                        None))
            args = (params_struct, batch)
        else:   # decode
            caches_struct = jax.eval_shape(
                lambda: make_decode_caches(cfg, info["batch"], info["seq"]))
            caches_struct = _tree_with_shardings(caches_struct,
                                                 cache_axes(cfg), mesh, rules)
            decode = make_decode_fn(cfg)
            fn = jax.jit(decode, donate_argnums=(2,),
                         out_shardings=(None, _shardings_of(caches_struct)))
            memory = batch.pop("memory", None)
            args = (params_struct, batch["tokens"], caches_struct, memory)
        return mesh, cfg, _Tracable(fn, mesh, rules), args


class _Tracable:
    """jit wrapper that re-enters the sharding-rules context at trace time.

    ``logical_shard`` reads thread-local rules; tracing (``.lower()``)
    happens after ``build_cell`` returns, so without this every interior
    ``with_sharding_constraint`` in the model would silently be a no-op —
    XLA then loses batch sharding through gather/scan boundaries and
    replicates activations (measured: 14x traffic inflation on
    granite train_4k; see EXPERIMENTS.md §Perf iteration 1).
    """

    def __init__(self, fn, mesh, rules):
        self._fn, self._mesh, self._rules = fn, mesh, rules

    def lower(self, *args, **kw):
        with use_sharding_rules(self._mesh, self._rules):
            return self._fn.lower(*args, **kw)

    def __call__(self, *args, **kw):
        with use_sharding_rules(self._mesh, self._rules):
            return self._fn(*args, **kw)


def build_stencil_cell(name: str, multi_pod: bool,
                       kernel_stub: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    st = STENCILS[name]
    dims = STENCIL_DIMS[name]
    names = mesh.axis_names
    if len(dims) == 2:
        axis_map = ((names[:-1]), (names[-1],))
    else:
        axis_map = ((names[:-1]), (names[-1],), None)
    # autotune block geometry on the local shard with the perf model
    from repro.core.distributed import shard_extents
    local = shard_extents(dims, tuple(tuple(a) if a else None
                                      for a in axis_map), mesh)
    cand = autotune(st, local, STENCIL_ITERS)
    best = cand[0]
    fn = build_distributed_fn(st, dims, STENCIL_ITERS, best.geom.par_time,
                              best.geom.bsize, mesh,
                              axis_map, kernel_stub=kernel_stub)
    from repro.core.distributed import partition_spec
    spec = partition_spec(tuple(tuple(a) if a else None for a in axis_map))
    sh = NamedSharding(mesh, spec)
    g = jax.ShapeDtypeStruct(dims, jnp.float32, sharding=sh)
    aux = (jax.ShapeDtypeStruct(dims, jnp.float32, sharding=sh)
           if st.has_aux else jax.ShapeDtypeStruct((), jnp.float32))
    coeffs = {k: jax.ShapeDtypeStruct((), jnp.float32)
              for k in st.coeff_names}
    return mesh, st, fn, (g, aux, coeffs), best


def model_flops(cfg, shape: str) -> float:
    """Analytic MODEL_FLOPS (6·N·D train / 2·N·D inference; MoE: N_active)."""
    info = SHAPES[shape]
    n = cfg.n_active_params
    tokens = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1)
    mult = 6 if info["kind"] == "train" else 2
    return mult * n * tokens


def run_cell(arch: str, shape: str, mesh_kind: str,
             variant: str = "baseline") -> dict:
    """variant: 'baseline' = paper-faithful XLA program; 'optimized' =
    beyond-paper Pallas kernel paths (flash attention / streaming stencil
    kernel) billed at their DMA schedules. See EXPERIMENTS.md §Perf."""
    multi_pod = mesh_kind == "multi"
    t0 = time.time()
    result = {"arch": arch, "shape": shape, "mesh": mesh_kind,
              "variant": variant}
    opt = variant == "optimized"
    if arch in STENCIL_IDS:
        mesh, st, fn, args, best = build_stencil_cell(arch, multi_pod,
                                                      kernel_stub=opt)
        result["autotuned"] = {"bsize": best.geom.bsize,
                               "par_time": best.geom.par_time,
                               "predicted_gflops": best.gflops / 1e9,
                               "bound": best.bound}
        cfg = None
    else:
        cfg = get_config(arch)
        skip = shape_applicable(cfg, shape)
        if skip:
            result["skipped"] = skip
            return result
        mesh, cfg, fn, args = build_cell(arch, shape, multi_pod,
                                         attn_impl="stub" if opt else None)

    n_dev = mesh.devices.size
    t1 = time.time()
    lowered = fn.lower(*args)
    result["lower_s"] = round(time.time() - t1, 2)
    t2 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t2, 2)

    ma = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_per_device_gib": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
    }
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    result["xla_cost"] = {"flops_body_once": ca.get("flops", 0.0),
                          "bytes_body_once": ca.get("bytes accessed", 0.0)}

    hlo = compiled.as_text()
    an = hlo_analysis.analyze(hlo)
    result["hlo"] = an.as_dict()
    result["hlo_size"] = len(hlo)

    # --- roofline terms (per device == per chip; analyzer is per-device) ---
    t_compute = an.flops / PEAK_BF16
    t_memory = an.hbm_bytes / HBM_BW
    t_collective = an.coll_bytes / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_collective, "collective"))[1]
    result["roofline"] = {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_collective, "dominant": dominant,
        "n_devices": n_dev,
    }
    if cfg is not None:
        mf = model_flops(cfg, shape)
        result["roofline"]["model_flops_total"] = mf
        result["roofline"]["model_flops_per_dev"] = mf / n_dev
        result["roofline"]["useful_ratio"] = (
            mf / n_dev / an.flops if an.flops else 0.0)
        # roofline fraction: useful model flops per device over peak, against
        # the bound set by the dominant term
        t_bound = max(t_compute, t_memory, t_collective)
        result["roofline"]["roofline_fraction"] = (
            (mf / n_dev / PEAK_BF16) / t_bound if t_bound else 0.0)
    result["total_s"] = round(time.time() - t0, 2)
    return result


def cell_path(arch, shape, mesh_kind, variant="baseline"):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{mesh_kind}__{variant}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "optimized"])
    ap.add_argument("--all", action="store_true",
                    help="run every cell in subprocesses (cached)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    if args.all:
        cells = []
        for variant in ("baseline", "optimized"):
            for arch in ARCH_IDS:
                for shape in SHAPES:
                    for mesh_kind in ("single", "multi"):
                        cells.append((arch, shape, mesh_kind, variant))
            for name in STENCIL_IDS:
                for mesh_kind in ("single", "multi"):
                    cells.append((name, "superstep", mesh_kind, variant))
        todo = [c for c in cells
                if args.force or not os.path.exists(cell_path(*c))]
        print(f"{len(todo)}/{len(cells)} cells to run", flush=True)
        failures = []
        for arch, shape, mesh_kind, variant in todo:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                   "--variant", variant]
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ,
                                    "PYTHONPATH": os.environ.get(
                                        "PYTHONPATH", "src")})
            status = "ok" if r.returncode == 0 else "FAIL"
            print(f"[{status}] {arch} {shape} {mesh_kind} {variant} "
                  f"({time.time()-t0:.0f}s)", flush=True)
            if r.returncode != 0:
                failures.append((arch, shape, mesh_kind, variant))
                print(r.stdout[-2000:])
                print(r.stderr[-4000:])
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    arch, shape, mesh_kind = args.arch, args.shape, args.mesh
    try:
        result = run_cell(arch, shape, mesh_kind, args.variant)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    path = cell_path(arch, shape, mesh_kind, args.variant)
    with open(path, "w") as f:
        json.dump(result, f, indent=2, default=str)
    print(json.dumps({k: v for k, v in result.items()
                      if k in ("arch", "shape", "mesh", "skipped", "memory",
                               "roofline", "compile_s", "autotuned")},
                     indent=2, default=str))


if __name__ == "__main__":
    main()
