"""``repro.resilience`` — the failure layer: what fails, what survives,
what resumes.

Four pieces, designed to be used together (DESIGN.md §2.7):

* **Deterministic fault injection** (:mod:`~repro.resilience.faults`):
  named injection points threaded through the hot seams — backend
  execute/execute_batch, the serving launch path, schedule/executable cache
  reads, the distributed exchange, checkpoint save/restore — driven by a
  seedable :class:`FaultPlan`, so every failure mode below is testable
  without real hardware faults.
* **Numerical health guards** (:mod:`~repro.resilience.health`):
  :class:`HealthPolicy` NaN/Inf/amplitude checks on super-step boundaries,
  cheap enough to be on by default in serving; structured
  :class:`NumericalFault` / :class:`LaunchFailed` errors.
* **Retries + circuit breaking** (:mod:`~repro.resilience.retry`):
  capped-exponential :class:`RetryPolicy` per launch, per-bucket
  :class:`CircuitBreaker` degrading coalesced -> per-request -> reject.
* **Checkpointed long runs** (:mod:`~repro.resilience.checkpoint_run`):
  ``StencilPlan.run(..., checkpoint_every=, checkpoint_dir=)`` chunked over
  the atomic ``repro.checkpoint`` substrate — a SIGKILL'd run resumes from
  the last complete super-step, bit-identically, on any mesh.
"""
from repro.resilience.checkpoint_run import CheckpointedRun, run_checkpointed
from repro.resilience.faults import (FaultPlan, FaultSpec, InjectedFault,
                                     active_plan, corrupt_point, fault_point,
                                     register_point, registered_points)
from repro.resilience.health import (CheckpointMismatch, HealthPolicy,
                                     LaunchFailed, NumericalFault,
                                     ResilienceError)
from repro.resilience.retry import BreakerConfig, CircuitBreaker, RetryPolicy

__all__ = [
    "BreakerConfig", "CheckpointMismatch", "CheckpointedRun",
    "CircuitBreaker", "FaultPlan", "FaultSpec", "HealthPolicy",
    "InjectedFault", "LaunchFailed", "NumericalFault", "ResilienceError",
    "RetryPolicy", "active_plan", "corrupt_point", "fault_point",
    "register_point", "registered_points", "run_checkpointed",
]
