"""Retry budgets and the per-bucket circuit breaker.

:class:`RetryPolicy` is the capped-exponential-backoff budget a failing
launch spends before it is declared :class:`~repro.resilience.health.
LaunchFailed`.  :class:`CircuitBreaker` is the per-bucket meltdown guard
above it: consecutive launch failures degrade the bucket from coalesced
launches to per-request launches (blast radius 1), then to rejecting
admissions with a retry-after — the service sheds load instead of burning
its retry budget on every queued request while the backend is down.

Breaker states::

    closed ──(fail_threshold consecutive launch failures)──► degraded
    degraded ──(recovery_successes consecutive successes)──► closed
    degraded ──(open_threshold further consecutive failures)──► open
    open ──(open_cooldown_s elapsed)──► degraded   (probe traffic again)

Only infrastructure failures (:class:`LaunchFailed` after retries) move the
breaker; a :class:`NumericalFault` is the *request's* fault, not the
backend's, and must never trip capacity for healthy neighbors.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff: attempt ``k`` (1-based) sleeps
    ``min(base_backoff_s * 2**(k-1), max_backoff_s)`` before retrying,
    up to ``max_attempts`` total attempts."""
    max_attempts: int = 3
    base_backoff_s: float = 0.05
    max_backoff_s: float = 2.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        if self.base_backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff seconds must be >= 0")

    @classmethod
    def make(cls, spec) -> "RetryPolicy":
        if isinstance(spec, cls):
            return spec
        if spec is None:
            return cls()
        if spec is False:
            return cls(max_attempts=1)      # no retries
        if isinstance(spec, dict):
            return cls(**spec)
        raise ValueError(f"retry spec must be a RetryPolicy, dict, False or "
                         f"None, got {type(spec).__name__}")

    def backoff_s(self, attempt: int) -> float:
        """Sleep before the retry that follows failed attempt ``attempt``."""
        return min(self.base_backoff_s * (2 ** max(0, attempt - 1)),
                   self.max_backoff_s)


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Thresholds of the per-bucket circuit breaker (see module doc)."""
    fail_threshold: int = 3        #: closed -> degraded after this many
    open_threshold: int = 3        #: degraded -> open after this many more
    recovery_successes: int = 2    #: degraded -> closed after this many
    open_cooldown_s: float = 5.0   #: open -> degraded (probe) after this

    def __post_init__(self):
        for f in ("fail_threshold", "open_threshold", "recovery_successes"):
            if getattr(self, f) < 1:
                raise ValueError(f"{f} must be >= 1, got {getattr(self, f)}")
        if self.open_cooldown_s <= 0:
            raise ValueError(f"open_cooldown_s must be > 0, "
                             f"got {self.open_cooldown_s}")

    @classmethod
    def make(cls, spec) -> Optional["BreakerConfig"]:
        """None/True -> defaults; False -> disabled (returns None)."""
        if isinstance(spec, cls):
            return spec
        if spec is None or spec is True:
            return cls()
        if spec is False:
            return None
        if isinstance(spec, dict):
            return cls(**spec)
        raise ValueError(f"breaker spec must be a BreakerConfig, dict or "
                         f"bool, got {type(spec).__name__}")


class CircuitBreaker:
    """Mutable per-bucket breaker state (single-threaded: the service only
    touches it from the event loop)."""

    def __init__(self, cfg: BreakerConfig):
        self.cfg = cfg
        self.state = "closed"
        self._consecutive_failures = 0
        self._consecutive_successes = 0
        self._opened_at: Optional[float] = None
        #: lifetime transition log (state, at) — snapshot-able history
        self.transitions: list = []

    def _to(self, state: str, now: float) -> None:
        if state != self.state:
            self.state = state
            self.transitions.append((state, now))

    # --- events --------------------------------------------------------------
    def on_failure(self, now: float) -> None:
        """One launch spent its whole retry budget (infrastructure failure —
        numerical faults must NOT be reported here)."""
        self._consecutive_successes = 0
        self._consecutive_failures += 1
        if self.state == "closed":
            if self._consecutive_failures >= self.cfg.fail_threshold:
                self._consecutive_failures = 0
                self._to("degraded", now)
        elif self.state == "degraded":
            if self._consecutive_failures >= self.cfg.open_threshold:
                self._consecutive_failures = 0
                self._opened_at = now
                self._to("open", now)

    def on_success(self, now: float) -> None:
        self._consecutive_failures = 0
        self._consecutive_successes += 1
        if self.state == "degraded" \
                and self._consecutive_successes >= self.cfg.recovery_successes:
            self._consecutive_successes = 0
            self._to("closed", now)

    # --- queries -------------------------------------------------------------
    def mode(self, now: float) -> str:
        """Current state, advancing ``open -> degraded`` when the cooldown
        has elapsed (the probe re-admission)."""
        if self.state == "open" and self._opened_at is not None \
                and now - self._opened_at >= self.cfg.open_cooldown_s:
            self._opened_at = None
            self._to("degraded", now)
        return self.state

    def admits(self, now: float) -> bool:
        return self.mode(now) != "open"

    def retry_after_s(self, now: float) -> float:
        """How long an open breaker asks callers to stay away."""
        if self.state != "open" or self._opened_at is None:
            return 0.0
        return max(0.0, self.cfg.open_cooldown_s - (now - self._opened_at))
