"""Deterministic, seedable fault injection — every failure mode testable.

The production code is threaded with named **injection points** at its hot
seams (backend execute, the serving launch path, cache reads, the
distributed exchange, checkpoint save/restore).  Each seam registers its
point at import time (:func:`register_point`) and calls :func:`fault_point`
(control seams) or :func:`corrupt_point` (result-producing seams) on every
pass.  With no plan installed both are a single global ``None`` check —
the resilience layer costs nothing when it is off.

A :class:`FaultPlan` is a set of :class:`FaultSpec` triggers::

    plan = FaultPlan([
        # the 2nd coalesced launch raises (transient infra failure)
        FaultSpec("serve.launch", nth=2),
        # every backend batch result gets member 1 poisoned with NaN
        FaultSpec("backend.execute_batch.result", action="nan", member=1,
                  max_fires=None),
        # 10% of schedule-cache reads fail like a flaky filesystem
        FaultSpec("schedule_cache.get", p=0.1, exc=OSError),
    ], seed=7)
    with plan.active():
        ...

Determinism: ``nth`` counts calls per point (1-based); probabilistic
triggers draw from a per-(plan seed, point, spec index) ``numpy``
``default_rng`` stream — the same plan against the same call sequence fires
the same faults, every run, on every machine.  ``action="kill"`` sends the
process ``SIGKILL`` (crash-testing checkpoint resume); ``match`` narrows a
spec to calls whose context satisfies a predicate (e.g. "only launches
containing request #3" — how the quarantine-bisection tests pin the poison
member deterministically).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import signal
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """Default exception an injection raises (``FaultSpec.exc`` overrides —
    e.g. ``OSError`` to model a real filesystem failure at a cache seam)."""


#: every injection point the production code declares, name -> doc.  The
#: chaos matrix (tests/test_resilience.py) iterates this registry, so a new
#: seam is automatically covered the day it registers.
_REGISTRY: Dict[str, str] = {}
_lock = threading.Lock()


def register_point(name: str, doc: str = "") -> str:
    """Declare an injection point (idempotent; returns ``name`` so seams can
    do ``POINT = register_point(...)``)."""
    with _lock:
        _REGISTRY.setdefault(name, doc)
    return name


def registered_points() -> Dict[str, str]:
    """Snapshot of every declared injection point (name -> doc)."""
    with _lock:
        return dict(_REGISTRY)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One trigger: *where* (``point``), *when* (``nth`` / ``p`` /
    ``match``), *what* (``action``), and *how often* (``max_fires``).

    Parameters
    ----------
    point:
        Injection-point name (see :func:`registered_points`).
    action:
        ``"raise"`` (default) raises ``exc``; ``"nan"`` poisons the value a
        :func:`corrupt_point` seam passes through (no-op at plain
        :func:`fault_point` seams); ``"kill"`` sends the process
        ``SIGKILL`` — no cleanup, no atexit: exactly what a crashed host
        looks like to the checkpoint substrate.
    nth:
        Fire on the Nth call at this point (1-based, counted per plan
        installation).  ``None`` = every call is eligible.
    p:
        Per-call firing probability, drawn from a deterministic per-spec
        stream seeded by (plan seed, point, spec index).
    max_fires:
        Stop firing after this many firings (``None`` = unlimited).
        Defaults to 1 for ``nth``/plain specs — a *transient* fault a retry
        survives — and must be explicit for always-on faults.
    exc:
        Exception type ``"raise"`` throws (default :class:`InjectedFault`).
        Pick the type a real failure would produce (``OSError`` at
        filesystem seams) to exercise the same handler.
    member:
        For ``"nan"`` at a batched result seam: which batch member to
        poison (leading-axis index).  ``None`` poisons element 0 of an
        unbatched value.
    match:
        Optional predicate on the call's context dict (seams pass one where
        it is meaningful, e.g. the serving launch passes request seqs) —
        the spec fires only when ``match(ctx)`` is truthy.
    """
    point: str
    action: str = "raise"
    nth: Optional[int] = None
    p: Optional[float] = None
    max_fires: Optional[int] = 1
    exc: type = InjectedFault
    member: Optional[int] = None
    match: Optional[Callable[[dict], bool]] = None

    def __post_init__(self):
        if self.action not in ("raise", "nan", "kill"):
            raise ValueError(f"unknown fault action {self.action!r}; "
                             "expected 'raise', 'nan' or 'kill'")
        if self.nth is not None and self.nth < 1:
            raise ValueError(f"nth is 1-based, got {self.nth}")
        if self.p is not None and not (0.0 <= self.p <= 1.0):
            raise ValueError(f"p must be in [0, 1], got {self.p}")
        if self.nth is not None and self.p is not None:
            raise ValueError("give nth OR p, not both")


class FaultPlan:
    """An installable set of :class:`FaultSpec` triggers with deterministic
    per-point call counting and seeded probability streams.

    Install exactly one plan at a time (``install()``/``uninstall()`` or the
    ``active()`` context manager).  Counters reset at install, so a plan is
    reusable and every installation replays identically."""

    def __init__(self, specs, seed: int = 0, strict: bool = True):
        self.specs: Tuple[FaultSpec, ...] = tuple(
            s if isinstance(s, FaultSpec) else FaultSpec(**s) for s in specs)
        self.seed = int(seed)
        self.strict = bool(strict)
        self._lock = threading.Lock()
        self._calls: Dict[str, int] = {}
        self._fires: Dict[int, int] = {}
        self._rngs: Dict[int, np.random.Generator] = {}
        self.fired: list = []    #: (point, spec index, call number) log

    def _reset(self) -> None:
        with self._lock:
            self._calls.clear()
            self._fires.clear()
            self.fired = []
            self._rngs = {
                i: np.random.default_rng(
                    [self.seed,
                     int.from_bytes(hashlib.sha1(
                         s.point.encode()).digest()[:4], "big"), i])
                for i, s in enumerate(self.specs)}

    # --- lifecycle -----------------------------------------------------------
    def install(self) -> "FaultPlan":
        global _ACTIVE
        if self.strict:
            known = registered_points()
            for s in self.specs:
                if s.point not in known:
                    raise ValueError(
                        f"unknown injection point {s.point!r}; registered: "
                        f"{sorted(known)} (strict=False skips this check)")
        self._reset()
        _ACTIVE = self
        return self

    def uninstall(self) -> None:
        global _ACTIVE
        if _ACTIVE is self:
            _ACTIVE = None

    def active(self):
        """``with plan.active(): ...`` — install on enter, uninstall on
        exit (exceptions included)."""
        return _PlanContext(self)

    # --- firing --------------------------------------------------------------
    def _arm(self, point: str, ctx: Optional[dict]) -> Optional[FaultSpec]:
        """One call at ``point``: count it and return the firing spec (first
        match wins), or None."""
        with self._lock:
            n = self._calls.get(point, 0) + 1
            self._calls[point] = n
            for i, s in enumerate(self.specs):
                if s.point != point:
                    continue
                if s.max_fires is not None \
                        and self._fires.get(i, 0) >= s.max_fires:
                    continue
                if s.match is not None and not s.match(ctx or {}):
                    continue
                if s.nth is not None:
                    if n != s.nth:
                        continue
                elif s.p is not None:
                    if self._rngs[i].random() >= s.p:
                        continue
                self._fires[i] = self._fires.get(i, 0) + 1
                self.fired.append((point, i, n))
                return s
        return None

    def calls(self, point: str) -> int:
        with self._lock:
            return self._calls.get(point, 0)


class _PlanContext:
    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def __enter__(self) -> FaultPlan:
        return self.plan.install()

    def __exit__(self, *exc) -> None:
        self.plan.uninstall()


_ACTIVE: Optional[FaultPlan] = None


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


def _execute(spec: FaultSpec, point: str) -> None:
    if spec.action == "kill":
        # a crashed host: no cleanup, no atexit, no finally blocks
        os.kill(os.getpid(), signal.SIGKILL)
    if spec.action == "raise":
        raise spec.exc(f"injected fault at {point!r}")
    # action == "nan" at a control-only seam: nothing to poison — no-op


def fault_point(name: str, ctx: Optional[dict] = None) -> None:
    """Control seam: raises (or kills) when the installed plan fires here.
    A single global check when no plan is installed."""
    plan = _ACTIVE
    if plan is None:
        return
    spec = plan._arm(name, ctx)
    if spec is not None:
        _execute(spec, name)


def corrupt_point(name: str, value: Any, ctx: Optional[dict] = None) -> Any:
    """Result seam: passes ``value`` through, poisoned with NaN when a
    ``"nan"`` spec fires (``member`` selects the leading-axis index of a
    batched value); ``"raise"``/``"kill"`` specs behave as at
    :func:`fault_point`."""
    plan = _ACTIVE
    if plan is None:
        return value
    spec = plan._arm(name, ctx)
    if spec is None:
        return value
    if spec.action != "nan":
        _execute(spec, name)
        return value
    return _poison(value, spec.member)


def _poison(value: Any, member: Optional[int]) -> Any:
    """One NaN written into ``value`` (jnp or numpy): into batch member
    ``member`` when given, else into the first element — enough for any
    finite-ness check to trip, cheap enough to leave the rest bit-intact."""
    import jax.numpy as jnp
    arr = jnp.asarray(value)
    if member is not None:
        idx = (member,) + (0,) * (arr.ndim - 1)
    else:
        idx = (0,) * arr.ndim
    return arr.at[idx].set(jnp.nan)
