"""Checkpointed long runs: chunked ``plan.run`` over the atomic checkpoint
substrate, resumable after a kill — on a different mesh if need be.

A long stencil integration (hours of super-steps) must not restart from
iteration 0 because the host died.  :func:`run_checkpointed` advances the
plan in chunks of ``checkpoint_every`` iterations and persists
``{grid, step}`` after each chunk through ``repro.checkpoint`` — whose
atomic ``step_N.tmp -> step_N`` rename guarantees a kill mid-save leaves
the previous complete step intact.  On start it restores the newest *valid*
step in the directory (corrupt manifests and truncated shards fall back to
the previous complete step) and continues from there.

Bit-identity: the chunk length is aligned **up to a multiple of the plan's
``par_time``**, so chunk boundaries coincide with super-step boundaries and
the chunked run applies the identical super-step schedule as one
uninterrupted ``run(iters)`` call — a resumed run's final grid is
bit-identical to a never-killed one.  (Geometry-less reference plans
iterate one step at a time, so any chunking is exact there.)

The directory is stamped with a ``meta.json`` identity (program
fingerprint, state shape, dtype, total iters): resuming a *different*
computation from the same directory refuses loudly
(:class:`~repro.resilience.health.CheckpointMismatch`) instead of silently
continuing someone else's grid.  The grid is saved in full, so a restart
may plan on a different mesh — the restored state re-shards on entry.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
from typing import Any, Optional

import numpy as np

from repro.resilience.health import CheckpointMismatch, HealthPolicy

# NOTE: repro.checkpoint is imported lazily inside run_checkpointed —
# checkpoint.py itself registers fault-injection points with
# repro.resilience.faults, so a module-level import here would close an
# import cycle for whichever package is imported first.

META_NAME = "meta.json"


@dataclasses.dataclass
class CheckpointedRun:
    """Outcome of one :func:`run_checkpointed` call."""
    grid: Any
    #: iteration count the run resumed from (0 = fresh start)
    resumed_from: int
    #: chunks executed by THIS call (0 when the directory was already final)
    chunks_run: int
    #: checkpoint steps this call saved
    steps_saved: tuple
    #: the chunk length actually used (par_time-aligned)
    checkpoint_every: int


def _aligned_every(plan, checkpoint_every: int) -> int:
    """Round the chunk length up to a super-step multiple so chunk seams
    coincide with super-step seams (the bit-identity condition)."""
    every = int(checkpoint_every)
    if every < 1:
        raise ValueError(f"checkpoint_every must be >= 1, got "
                         f"{checkpoint_every}")
    if plan.geometry is not None:
        pt = plan.geometry.par_time
        every = ((every + pt - 1) // pt) * pt
    return every


def _identity(plan, iters: int) -> dict:
    from repro.api.schedule_cache import stencil_fingerprint
    return {
        "fingerprint": stencil_fingerprint(plan.problem.stencil),
        "state_shape": list(plan.problem.state_shape),
        "dtype": plan.problem.dtype,
        "iters": int(iters),
    }


def _check_meta(directory: str, ident: dict) -> None:
    """Stamp a fresh directory; refuse one stamped for another computation.
    A mesh/backend change is fine (the grid re-shards); a different
    fingerprint/shape/dtype/iters is a different computation."""
    path = os.path.join(directory, META_NAME)
    if os.path.exists(path):
        try:
            with open(path) as f:
                have = json.load(f)
        except (OSError, ValueError):
            have = None
        if have != ident:
            raise CheckpointMismatch(
                f"checkpoint dir {directory!r} holds a different "
                f"computation: {have} != {ident} — point "
                f"checkpoint_dir somewhere else (or delete it)")
        return
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(ident, f)
    os.replace(tmp, path)


def _gc_steps(directory: str, keep: int) -> None:
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def run_checkpointed(plan, grid, iters: int, coeffs=None, *, aux=None,
                     checkpoint_every: int, checkpoint_dir: str,
                     health=None, keep: int = 3) -> CheckpointedRun:
    """Advance ``grid`` by ``iters`` iterations with a checkpoint every
    (par_time-aligned) ``checkpoint_every`` iterations, resuming from the
    newest valid checkpoint in ``checkpoint_dir`` when one exists.

    ``health`` (:class:`HealthPolicy` spec) is checked at every chunk
    boundary *before* the chunk is persisted — a NaN'd grid raises
    :class:`~repro.resilience.health.NumericalFault` and is never
    checkpointed, so the directory only ever holds healthy state and a
    post-mortem resume restarts from the last good super-step."""
    import jax.numpy as jnp

    from repro.checkpoint import restore_latest_valid, save_pytree
    iters = int(iters)
    if iters < 0:
        raise ValueError(f"iters must be >= 0, got {iters}")
    every = _aligned_every(plan, checkpoint_every)
    policy = HealthPolicy.make(health) if health is not None else None
    _check_meta(checkpoint_dir, _identity(plan, iters))

    template = {"grid": np.zeros(
        plan.problem.state_shape,
        np.asarray(jnp.zeros((), plan.problem.jnp_dtype)).dtype)}
    restored, step = restore_latest_valid(template, checkpoint_dir)
    done = 0
    if restored is not None and step is not None:
        if step > iters:
            raise CheckpointMismatch(
                f"checkpoint step {step} exceeds requested iters {iters} "
                f"in {checkpoint_dir!r}")
        grid, done = restored["grid"], int(step)

    grid = jnp.asarray(grid, plan.problem.jnp_dtype)
    chunks, saved = 0, []
    while done < iters:
        chunk = min(every, iters - done)
        grid = plan.run(grid, chunk, coeffs, aux=aux)
        done += chunk
        chunks += 1
        host = np.asarray(grid)
        if policy is not None:
            fault = policy.fault_of(host, where=f"iteration {done}")
            if fault is not None:
                raise fault
        save_pytree({"grid": host}, checkpoint_dir, done)
        saved.append(done)
        _gc_steps(checkpoint_dir, keep)
    return CheckpointedRun(grid=grid, resumed_from=(int(step) if restored
                                                    is not None else 0),
                           chunks_run=chunks, steps_saved=tuple(saved),
                           checkpoint_every=every)
