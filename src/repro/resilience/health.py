"""Numerical health guards: NaN/Inf/amplitude-blowup detection.

A :class:`HealthPolicy` is the cheap invariant check that runs on super-step
boundaries: "is this grid still finite, and is its amplitude still sane?"
It costs two reductions over the grid (an ``isfinite`` all-reduce and a
``max(abs)``), which is noise next to a super-step's compute — cheap enough
to be **on by default in serving** — and it is what turns a silent
NaN-producing request into a structured, per-request
:class:`NumericalFault` instead of a poisoned batch.

The exceptions here are the resilience layer's vocabulary; ``repro.serve``
subclasses them into its ``ServeError`` hierarchy so a serving client can
catch either family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


class ResilienceError(Exception):
    """Base class of the resilience layer's structured failures."""


class NumericalFault(ResilienceError):
    """A grid failed its health check.  ``kind`` is ``"nan"``, ``"inf"`` or
    ``"blowup"``; ``member`` is the batch index when the check ran on one
    member of a coalesced launch; ``max_abs`` is the observed amplitude."""

    def __init__(self, message: str, *, kind: str = "nan",
                 member: Optional[int] = None,
                 max_abs: Optional[float] = None):
        super().__init__(message)
        self.kind = kind
        self.member = member
        self.max_abs = max_abs


class LaunchFailed(ResilienceError):
    """A launch (or rebuild on its behalf) kept failing after the retry
    budget was spent.  ``attempts`` counts tries; ``__cause__`` carries the
    last underlying error."""

    def __init__(self, message: str, *, attempts: int = 1):
        super().__init__(message)
        self.attempts = attempts


class CheckpointMismatch(ResilienceError):
    """A checkpoint directory holds state for a different computation
    (fingerprint / shape / dtype disagree) — resuming from it would
    silently compute garbage, so it is refused loudly."""


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """When (and how hard) to judge a grid unhealthy.

    Parameters
    ----------
    check_nonfinite:
        Fail on any NaN or Inf cell (the default, and the cheap half).
    max_abs:
        Absolute amplitude ceiling: a finite grid whose ``max(|x|)``
        exceeds this fails with ``kind="blowup"`` (diverging schemes grow
        for many iterations before they overflow to Inf — this catches
        them at the super-step boundary where they first go wrong).
        ``None`` disables the amplitude check.
    enabled:
        Master switch; a disabled policy's :meth:`check` is a no-op.
    """
    check_nonfinite: bool = True
    max_abs: Optional[float] = None
    enabled: bool = True

    @classmethod
    def make(cls, spec) -> "HealthPolicy":
        """Normalize config forms: policy | dict | bool | None (defaults)."""
        if isinstance(spec, cls):
            return spec
        if spec is None or spec is True:
            return cls()
        if spec is False:
            return cls(enabled=False)
        if isinstance(spec, dict):
            return cls(**spec)
        raise ValueError(f"health spec must be a HealthPolicy, dict or bool, "
                         f"got {type(spec).__name__}")

    # --- checks --------------------------------------------------------------
    def fault_of(self, grid, *, member: Optional[int] = None,
                 where: str = "") -> Optional[NumericalFault]:
        """The :class:`NumericalFault` this grid deserves, or ``None``.
        Runs on the host (one ``np.asarray`` view of an already-materialized
        grid is free; a device grid pays one transfer)."""
        if not self.enabled:
            return None
        a = np.asarray(grid)
        # bf16 & friends: numpy reductions need a native float view
        if a.dtype.kind not in "fc":
            a = a.astype(np.float32)
        tag = f" in {where}" if where else ""
        at = "" if member is None else f" (batch member {member})"
        if self.check_nonfinite:
            if np.isnan(a).any():
                return NumericalFault(f"NaN cells{tag}{at}", kind="nan",
                                      member=member)
            if np.isinf(a).any():
                return NumericalFault(f"Inf cells{tag}{at}", kind="inf",
                                      member=member)
        if self.max_abs is not None and a.size:
            m = float(np.max(np.abs(a)))
            if m > self.max_abs:
                return NumericalFault(
                    f"amplitude blowup{tag}{at}: max|x|={m:.3e} > "
                    f"{self.max_abs:.3e}", kind="blowup", member=member,
                    max_abs=m)
        return None

    def check(self, grid, *, where: str = "") -> None:
        """Raise the grid's :class:`NumericalFault`, if any."""
        fault = self.fault_of(grid, where=where)
        if fault is not None:
            raise fault
