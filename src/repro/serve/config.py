"""Declarative service configuration: dict/JSON -> booted service.

Follows the config-class factory idiom (cf. xformers' ``model_factory``):
every config object can be built from a plain dict — so a whole service is
one JSON document away — while accepting already-constructed
``StencilProblem`` / ``RunConfig`` objects for programmatic use::

    cfg = ServiceConfig.make({
        "buckets": [
            {"problem": {"stencil": "diffusion2d", "shape": [256, 512]},
             "run": {"backend": "engine", "autotune": True},
             "max_batch": 8, "max_wait_ms": 2.0, "queue_cap": 32},
        ],
    })
    service = await repro.serve.serve(cfg)     # booted + pre-warmed

A :class:`BucketConfig` declares one admission bucket: the exact problem it
serves, how to run it, and the coalescing/backpressure policy.  The bucket
set is closed at boot — that is what makes pre-warming the executable and
schedule caches possible.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple, Union

from repro.api.config import RunConfig
from repro.api.problem import StencilProblem
from repro.resilience import BreakerConfig, HealthPolicy, RetryPolicy

from repro.serve.request import bucket_key


def _default_batch_classes(max_batch: int) -> Tuple[int, ...]:
    """Powers of two up to ``max_batch``, plus ``max_batch`` itself: the
    pre-warmed batch sizes a coalesced launch is padded up to.  A small
    closed set keeps the executable cache small (one compiled program per
    class) while wasting at most ~2x compute on a worst-case fill."""
    classes = []
    c = 1
    while c < max_batch:
        classes.append(c)
        c *= 2
    classes.append(max_batch)
    return tuple(classes)


def _make_problem(spec) -> StencilProblem:
    if isinstance(spec, StencilProblem):
        return spec
    if isinstance(spec, dict):
        spec = dict(spec)
        stencil = spec.pop("stencil", None)
        shape = spec.pop("shape", None)
        if stencil is None or shape is None:
            raise ValueError("bucket problem dict needs 'stencil' and "
                             f"'shape'; got keys {sorted(spec)}")
        return StencilProblem(stencil, tuple(int(d) for d in shape), **spec)
    raise ValueError(f"bucket 'problem' must be a StencilProblem or a dict, "
                     f"got {type(spec).__name__}")


def _make_run(spec) -> RunConfig:
    if spec is None:
        return RunConfig()
    if isinstance(spec, RunConfig):
        return spec
    if isinstance(spec, dict):
        return RunConfig(**spec)
    raise ValueError(f"bucket 'run' must be a RunConfig or a dict, "
                     f"got {type(spec).__name__}")


@dataclasses.dataclass(frozen=True)
class BucketConfig:
    """One admission bucket: problem + RunConfig + coalescing policy.

    Parameters
    ----------
    problem:
        The exact :class:`StencilProblem` this bucket serves (or a dict
        with ``stencil``/``shape`` and optional ``dtype``/``boundary``).
        Requests whose (fingerprint, state shape, BC, dtype) match are
        admitted here.
    run:
        How to execute: a :class:`RunConfig` or kwargs dict.
    max_batch:
        Most real requests coalesced into one ``run_batch`` launch.
    max_wait_ms:
        Coalescing window: after the first request arrives the launch waits
        at most this long for co-batchable traffic (a full batch launches
        immediately).
    queue_cap:
        Bounded admission queue; a submit beyond this depth is rejected
        with :class:`~repro.serve.request.ServiceOverloaded` (429-style),
        never silently dropped.
    batch_classes:
        The pre-warmed batch sizes; a launch of B real requests is padded
        (batch-axis edge replication — bit-exact, members are independent)
        up to the smallest class >= B.  Default: powers of two up to
        ``max_batch``.
    max_rounds:
        Most *distinct* iteration counts one launch carries: mixed-iters
        batches advance in stages (run to the smallest iters, deliver the
        finished members, keep going), so each extra distinct value costs
        one more round on the full padded batch.
    name:
        Metrics/debugging label (defaults to ``stencil@shape``).
    """
    problem: Union[StencilProblem, dict]
    run: Union[RunConfig, dict, None] = None
    max_batch: int = 8
    max_wait_ms: float = 2.0
    queue_cap: int = 64
    batch_classes: Optional[Tuple[int, ...]] = None
    max_rounds: int = 4
    name: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "problem", _make_problem(self.problem))
        object.__setattr__(self, "run", _make_run(self.run))
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, "
                             f"got {self.max_wait_ms}")
        if self.queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {self.queue_cap}")
        if self.max_rounds < 1:
            raise ValueError(f"max_rounds must be >= 1, got {self.max_rounds}")
        if self.batch_classes is None:
            classes = _default_batch_classes(self.max_batch)
        else:
            classes = tuple(sorted({int(c) for c in self.batch_classes}))
            if not classes or classes[0] < 1:
                raise ValueError(f"batch_classes must be positive, "
                                 f"got {self.batch_classes}")
            if classes[-1] < self.max_batch:
                raise ValueError(
                    f"max(batch_classes)={classes[-1]} < max_batch="
                    f"{self.max_batch}: a full batch would have no class "
                    "to pad up to")
        object.__setattr__(self, "batch_classes", classes)
        if self.name is None:
            shape = "x".join(str(d) for d in self.problem.shape)
            object.__setattr__(
                self, "name", f"{self.problem.stencil.name}@{shape}")

    @property
    def key(self) -> tuple:
        return bucket_key(self.problem)

    @property
    def max_wait_s(self) -> float:
        return self.max_wait_ms / 1e3

    def pad_to_class(self, n: int) -> int:
        """Smallest pre-warmed batch class >= n."""
        for c in self.batch_classes:
            if c >= n:
                return c
        return self.batch_classes[-1]

    @classmethod
    def make(cls, spec) -> "BucketConfig":
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, dict):
            return cls(**spec)
        raise ValueError(f"bucket spec must be a BucketConfig or a dict, "
                         f"got {type(spec).__name__}")


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """The whole service: the closed bucket set plus global policy.

    ``max_concurrent_batches`` bounds how many coalesced launches may be in
    flight at once across buckets (1 serializes the device; >1 keeps
    multiple execution pipes saturated when the runtime can overlap them).
    ``offload_compute`` moves each launch's compute into a worker thread so
    the event loop stays responsive during it; the default (``None``) picks
    automatically — offload only when launches can overlap
    (``max_concurrent_batches > 1``), because on a serialized device the
    thread hop only adds context switches to the critical path.
    ``drain_timeout_s`` bounds graceful shutdown: ``stop()`` flushes every
    admitted request, then gives up after this long.

    Resilience policy (DESIGN.md §2.7; all three accept an instance, a
    kwargs dict, a bool, or ``None`` for the defaults):

    * ``health`` — per-request numerical health check on delivered results
      (:class:`~repro.resilience.HealthPolicy`; **on by default** — two
      host reductions per member is noise next to a launch).  A member that
      fails is quarantined with :class:`~repro.serve.request.
      NumericalFault`; healthy co-batched neighbors are delivered
      unchanged, bit-identical to a fault-free run.
    * ``retry`` — capped-exponential launch retry budget
      (:class:`~repro.resilience.RetryPolicy`; ``False`` = no retries).
      When a multi-member launch spends it, the batch is bisected to
      isolate the poison member(s); the healthy remainder is retried.
    * ``breaker`` — per-bucket circuit breaker
      (:class:`~repro.resilience.BreakerConfig`; ``False`` disables):
      consecutive launch failures degrade the bucket from coalesced to
      per-request launches, then to rejecting admissions with retry-after.
    * ``checkpoint_dir`` — root directory for serving-side checkpointed
      requests (``StencilRequest.checkpoint_key``); ``None`` (default)
      rejects such requests at admission.
    """
    buckets: Tuple[Union[BucketConfig, dict], ...] = ()
    max_concurrent_batches: int = 1
    offload_compute: Optional[bool] = None
    drain_timeout_s: float = 30.0
    health: Union[HealthPolicy, dict, bool, None] = None
    retry: Union[RetryPolicy, dict, bool, None] = None
    breaker: Union[BreakerConfig, dict, bool, None] = None
    checkpoint_dir: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "health", HealthPolicy.make(self.health))
        object.__setattr__(self, "retry", RetryPolicy.make(self.retry))
        object.__setattr__(self, "breaker", BreakerConfig.make(self.breaker))
        buckets = tuple(BucketConfig.make(b) for b in self.buckets)
        if not buckets:
            raise ValueError("a service needs at least one bucket")
        if self.max_concurrent_batches < 1:
            raise ValueError(f"max_concurrent_batches must be >= 1, got "
                             f"{self.max_concurrent_batches}")
        if self.drain_timeout_s <= 0:
            raise ValueError(f"drain_timeout_s must be > 0, got "
                             f"{self.drain_timeout_s}")
        seen = {}
        for b in buckets:
            if b.key in seen:
                raise ValueError(
                    f"buckets {seen[b.key]!r} and {b.name!r} serve the same "
                    "(stencil, shape, bc, dtype) — merge them")
            seen[b.key] = b.name
        object.__setattr__(self, "buckets", buckets)

    @classmethod
    def make(cls, spec) -> "ServiceConfig":
        """Normalize any spec form: ServiceConfig | dict | JSON string."""
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            spec = json.loads(spec)
        if isinstance(spec, dict):
            return cls(**spec)
        if isinstance(spec, (list, tuple)):
            return cls(buckets=tuple(spec))
        raise ValueError(f"service spec must be a ServiceConfig, dict, "
                         f"JSON string or bucket list, "
                         f"got {type(spec).__name__}")
