"""``repro.serve`` — async stencil serving over the ``plan()`` substrate.

Admission -> coalesce -> padded ``run_batch``::

    from repro.serve import StencilRequest, from_config

    service = await from_config({
        "buckets": [{"problem": {"stencil": "diffusion2d",
                                 "shape": [256, 512]},
                     "run": {"backend": "engine", "autotune": True},
                     "max_batch": 8, "max_wait_ms": 2.0}],
    })
    out = await service.submit(StencilRequest("diffusion2d", grid, iters=50))
    print(service.snapshot()["latency_ms"])
    await service.stop()

Requests bucket by (stencil/program fingerprint, state shape, boundary
condition, dtype); each bucket coalesces compatible requests into one
``run_batch`` launch, padded along the batch axis to a pre-warmed batch
class — results are bit-identical to per-request ``plan().run()`` wherever
the backend's ``run_batch`` is (everywhere but periodic-BC Pallas reshapes,
which are ulp-close).  Queues are bounded: overload answers
``ServiceOverloaded`` with a retry-after hint, never a silent drop.

Failure model (DESIGN.md §2.7): launches retry under a capped-exponential
budget; a still-failing coalesced launch is bisected so only the poison
request(s) fail (:class:`LaunchFailed`) while healthy neighbors are served;
delivered results pass a per-member numerical health check (on by default)
that quarantines NaN/Inf/blowup members with :class:`NumericalFault`; a
per-bucket circuit breaker degrades a persistently failing bucket from
coalesced to per-request launches, then to rejection with retry-after.
"""
from repro.serve.batcher import BucketState, PendingRequest
from repro.serve.config import BucketConfig, ServiceConfig
from repro.serve.metrics import ServiceMetrics, percentile
from repro.serve.request import (DeadlineExceeded, LaunchFailed,
                                 NoMatchingBucket, NumericalFault,
                                 ServeError, ServeResult, ServiceClosed,
                                 ServiceOverloaded, StencilRequest,
                                 bucket_key)
from repro.serve.service import (StencilService, coeffs_signature,
                                 from_config, serve)

__all__ = [
    "BucketConfig", "BucketState", "DeadlineExceeded", "LaunchFailed",
    "NoMatchingBucket", "NumericalFault", "PendingRequest", "ServeError",
    "ServeResult", "ServiceClosed", "ServiceConfig", "ServiceMetrics",
    "ServiceOverloaded", "StencilRequest", "StencilService", "bucket_key",
    "coeffs_signature", "from_config", "percentile", "serve",
]
