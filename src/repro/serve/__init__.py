"""``repro.serve`` — async stencil serving over the ``plan()`` substrate.

Admission -> coalesce -> padded ``run_batch``::

    from repro.serve import StencilRequest, from_config

    service = await from_config({
        "buckets": [{"problem": {"stencil": "diffusion2d",
                                 "shape": [256, 512]},
                     "run": {"backend": "engine", "autotune": True},
                     "max_batch": 8, "max_wait_ms": 2.0}],
    })
    out = await service.submit(StencilRequest("diffusion2d", grid, iters=50))
    print(service.snapshot()["latency_ms"])
    await service.stop()

Requests bucket by (stencil/program fingerprint, state shape, boundary
condition, dtype); each bucket coalesces compatible requests into one
``run_batch`` launch, padded along the batch axis to a pre-warmed batch
class — results are bit-identical to per-request ``plan().run()`` wherever
the backend's ``run_batch`` is (everywhere but periodic-BC Pallas reshapes,
which are ulp-close).  Queues are bounded: overload answers
``ServiceOverloaded`` with a retry-after hint, never a silent drop.
"""
from repro.serve.batcher import BucketState, PendingRequest
from repro.serve.config import BucketConfig, ServiceConfig
from repro.serve.metrics import ServiceMetrics, percentile
from repro.serve.request import (DeadlineExceeded, NoMatchingBucket,
                                 ServeError, ServeResult, ServiceClosed,
                                 ServiceOverloaded, StencilRequest,
                                 bucket_key)
from repro.serve.service import (StencilService, coeffs_signature,
                                 from_config, serve)

__all__ = [
    "BucketConfig", "BucketState", "DeadlineExceeded", "NoMatchingBucket",
    "PendingRequest", "ServeError", "ServeResult", "ServiceClosed",
    "ServiceConfig", "ServiceMetrics", "ServiceOverloaded", "StencilRequest",
    "StencilService", "bucket_key", "coeffs_signature", "from_config",
    "percentile", "serve",
]
