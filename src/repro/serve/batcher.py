"""Deterministic coalescing core: admission queue -> launchable batches.

This module is the service's brain with the event loop removed: it is
synchronous, clock-parameterized (every method takes ``now``), and touches
no arrays — so the coalescing-window, backpressure, and deadline logic is
unit-testable with a hand-rolled clock.  ``repro.serve.service`` drives it
from asyncio and owns the actual compute.

Policy (per bucket):

* the first admission into an empty queue **arms the window**: a launch
  happens when ``max_batch`` co-batchable requests are pending, when the
  window (``max_wait_ms``) expires, or immediately when draining;
* a launch takes the head-of-line request plus FIFO-order requests with the
  *same resolved coefficients* (different coefficients cannot share one
  ``run_batch`` call), up to ``max_batch`` real members and ``max_rounds``
  distinct iteration counts (mixed iters advance in stages);
* expired requests are swept out at launch time and failed with
  ``DeadlineExceeded`` — queue slots are never burned computing results
  nobody will read;
* admission beyond ``queue_cap`` is refused (the service turns that into a
  ``ServiceOverloaded`` with a retry-after hint).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, List, Optional, Tuple

from repro.serve.config import BucketConfig


@dataclasses.dataclass
class PendingRequest:
    """One admitted request as the batcher sees it: scheduling fields only
    (the request itself is opaque payload until launch)."""
    seq: int
    request: Any
    submitted_at: float
    expires_at: Optional[float]
    #: hashable signature of the *resolved* coefficients — members of one
    #: launch must agree (run_batch takes one coefficient set)
    coeffs_sig: Any
    iters: int
    #: delivery slot (an asyncio.Future in the live service)
    future: Any = None


class BucketState:
    """Pending queue + window state for one bucket.  Synchronous and
    clock-free: callers pass ``now`` everywhere."""

    def __init__(self, cfg: BucketConfig):
        self.cfg = cfg
        self.pending: "deque[PendingRequest]" = deque()
        self.window_start: Optional[float] = None

    def depth(self) -> int:
        return len(self.pending)

    def admit(self, rec: PendingRequest, now: float) -> bool:
        """Queue ``rec``; False when the queue is at ``queue_cap`` (the
        caller rejects with backpressure — nothing was enqueued)."""
        if len(self.pending) >= self.cfg.queue_cap:
            return False
        if not self.pending:
            self.window_start = now
        self.pending.append(rec)
        return True

    def ready_at(self, now: float) -> Optional[float]:
        """Earliest time a launch is due: ``now`` when a full batch is
        already pending, the window expiry otherwise, None when empty."""
        if not self.pending:
            return None
        if self._head_batch_full():
            return now
        return (self.window_start or now) + self.cfg.max_wait_s

    def ready(self, now: float, draining: bool = False) -> bool:
        at = self.ready_at(now)
        if at is None:
            return False
        return draining or at <= now

    def _head_batch_full(self) -> bool:
        """Whether the head-of-line coalescing group already fills
        ``max_batch`` (no point waiting out the window)."""
        head_sig = self.pending[0].coeffs_sig
        n = 0
        for rec in self.pending:
            if rec.coeffs_sig == head_sig:
                n += 1
                if n >= self.cfg.max_batch:
                    return True
        return False

    def take_batch(self, now: float, limit: Optional[int] = None
                   ) -> Tuple[List[PendingRequest], List[PendingRequest]]:
        """Assemble one launch: ``(batch, expired)``.

        Sweeps deadline-expired requests out of the whole queue, then takes
        the head-of-line request plus FIFO requests sharing its coefficient
        signature, capped at ``max_batch`` members and ``max_rounds``
        distinct iteration counts.  Skipped requests keep their order; a
        non-empty remainder re-arms the window at ``now``.

        ``limit`` caps the launch below ``max_batch`` — the circuit
        breaker's degraded mode passes 1 so a flaky backend sees blast
        radius 1 per launch instead of a whole coalesced batch."""
        cap = self.cfg.max_batch if limit is None \
            else min(limit, self.cfg.max_batch)
        expired = [r for r in self.pending
                   if r.expires_at is not None and r.expires_at <= now]
        if expired:
            gone = {r.seq for r in expired}
            self.pending = deque(r for r in self.pending
                                 if r.seq not in gone)
        batch: List[PendingRequest] = []
        if self.pending:
            head_sig = self.pending[0].coeffs_sig
            iters_set = set()
            kept: List[PendingRequest] = []
            for rec in self.pending:
                if len(batch) >= cap:
                    kept.append(rec)
                    continue
                if rec.coeffs_sig != head_sig:
                    kept.append(rec)
                    continue
                if (rec.iters not in iters_set
                        and len(iters_set) >= self.cfg.max_rounds):
                    kept.append(rec)
                    continue
                iters_set.add(rec.iters)
                batch.append(rec)
            self.pending = deque(kept)
        self.window_start = now if self.pending else None
        return batch, expired
