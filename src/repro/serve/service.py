"""The asyncio stencil service: admission -> coalesce -> padded run_batch.

Request lifecycle::

    submit(StencilRequest)                       (event loop)
      └─ bucket lookup by (fingerprint, state shape, BC, dtype)
         └─ bounded-queue admission  — full -> ServiceOverloaded(retry_after)
            └─ per-bucket worker coalesces under (max_batch, max_wait_ms)
               └─ deadline sweep     — expired -> DeadlineExceeded
                  └─ batch padded to a pre-warmed batch class (edge
                     replication along the batch axis — bit-exact) and
                     advanced by staged run_batch rounds  (compute thread)
                     └─ futures resolved with ServeResult   (event loop)

With ``max_concurrent_batches > 1`` compute runs in worker threads
(``asyncio.to_thread``) so launches overlap and admission stays responsive
while the device crunches; with a single launch slot the thread hop would
only add context switches to the critical path, so compute runs inline on
the loop by default (``ServiceConfig.offload_compute`` overrides either
way).
Shutdown is graceful: ``stop()`` refuses new admissions, flushes every
queued request (launching immediately, windows ignored), and joins the
workers — bounded queues make the drain bounded.
"""
from __future__ import annotations

import asyncio
import os
import time
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import StencilPlan, plan as make_plan
from repro.resilience.checkpoint_run import run_checkpointed
from repro.resilience.faults import fault_point, register_point
from repro.resilience.health import CheckpointMismatch
from repro.resilience.health import NumericalFault as _ResNumericalFault
from repro.resilience.retry import CircuitBreaker
from repro.serve.batcher import BucketState, PendingRequest
from repro.serve.config import BucketConfig, ServiceConfig
from repro.serve.metrics import ServiceMetrics
from repro.serve.request import (DeadlineExceeded, LaunchFailed,
                                 NoMatchingBucket, NumericalFault,
                                 ServeResult, ServiceClosed,
                                 ServiceOverloaded, StencilRequest)

#: fires at the head of every coalesced launch's compute (possibly on a
#: worker thread) — the serving-path injection seam: a raise here exercises
#: retry -> bisection -> breaker without touching any backend internals
FP_LAUNCH = register_point(
    "serve.launch", "at the head of a coalesced run_batch launch "
    "(ctx: bucket, batch size, member seqs)")


#: signature of a request with no coefficient overrides — computed without
#: resolving (resolution materializes per-stage jnp scalars and ``float()``
#: blocks on each one: ~0.4 ms of admission latency per request, on the
#: event-loop thread).  Default-coeff requests are the common case and all
#: resolve identically within a bucket, so a sentinel groups them exactly.
_DEFAULT_SIG = ("@default-coeffs",)


def coeffs_signature(problem, coeffs):
    """Hashable identity of the *resolved* coefficient payload.  Two
    requests coalesce into one ``run_batch`` call only when these agree —
    the call takes a single coefficient set for the whole batch.  (A
    request passing overrides that happen to equal the defaults lands in a
    different sub-group than a ``coeffs=None`` request: a fill loss, never
    a correctness loss.)"""
    if not coeffs:
        return _DEFAULT_SIG
    resolved = problem.resolve_coeffs(coeffs)
    parts = []
    for stage in resolved:
        for name in sorted(stage):
            v = stage[name]
            try:
                parts.append((name, float(v)))
            except (TypeError, ValueError):     # array-valued coefficient
                a = np.asarray(v)
                parts.append((name, a.shape, a.tobytes()))
    return tuple(parts)


def _stage(arrays, padded: int, dtype) -> np.ndarray:
    """Host-side batch assembly: member arrays (numpy or device) into one
    contiguous ``(padded, *shape)`` numpy block, edge-replicating the last
    real member along the batch axis."""
    members = [np.asarray(a, dtype) for a in arrays]
    members += [members[-1]] * (padded - len(members))
    return np.stack(members)


class _Bucket:
    """Runtime state of one configured bucket."""

    def __init__(self, cfg: BucketConfig, breaker: Optional[CircuitBreaker]):
        self.cfg = cfg
        self.state = BucketState(cfg)
        self.plan: Optional[StencilPlan] = None
        self.wake: Optional[asyncio.Event] = None   # bound at start()
        self.task: Optional[asyncio.Task] = None
        #: trailing per-launch seconds (retry-after estimation)
        self.last_batch_s: float = 0.0
        #: per-bucket circuit breaker (None when disabled in ServiceConfig)
        self.breaker = breaker


class StencilService:
    """Bucketed, coalescing, pre-warmed stencil server.

    Build one directly and ``await service.start()``, or use the
    :func:`serve` / :func:`from_config` factories.  ``clock`` is injectable
    for deterministic tests (must agree with the loop's notion of elapsed
    real time, since coalescing windows sleep on the loop)."""

    def __init__(self, config: Union[ServiceConfig, dict, str, list], *,
                 clock=time.monotonic):
        self.config = ServiceConfig.make(config)
        self._clock = clock
        self.metrics = ServiceMetrics(clock=clock)
        self._buckets: Dict[tuple, _Bucket] = {}
        for bcfg in self.config.buckets:
            breaker = (CircuitBreaker(self.config.breaker)
                       if self.config.breaker is not None else None)
            self._buckets[bcfg.key] = _Bucket(bcfg, breaker)
        self._started = False
        self._closing = False
        self._closed = False
        self._seq = 0
        #: offload auto-policy: a worker thread only pays for itself when
        #: launches can overlap; with one launch slot the hop just inserts
        #: two context switches into every launch's critical path
        self._offload = (self.config.offload_compute
                         if self.config.offload_compute is not None
                         else self.config.max_concurrent_batches > 1)
        self._sem: Optional[asyncio.Semaphore] = None
        #: in-flight coalesced launches (tasks) — awaited by stop()
        self._launches: set = set()

    # --- lifecycle ----------------------------------------------------------
    async def start(self, prewarm: bool = True) -> "StencilService":
        """Boot: build every bucket's plan, optionally pre-warm the
        executables for the declared batch classes, spawn the workers."""
        if self._started:
            raise RuntimeError("service already started")
        self._sem = asyncio.Semaphore(self.config.max_concurrent_batches)
        for b in self._buckets.values():
            # plan() may consult the schedule cache / run the measured
            # tuner — keep the loop responsive while it does
            b.plan = await asyncio.to_thread(
                make_plan, b.cfg.problem, b.cfg.run)
            if prewarm:
                t0 = self._clock()
                await asyncio.to_thread(self._prewarm_bucket, b)
                self.metrics.note_prewarm(b.cfg.name, self._clock() - t0)
        for b in self._buckets.values():
            b.wake = asyncio.Event()
            b.task = asyncio.create_task(self._worker(b),
                                         name=f"serve-{b.cfg.name}")
            self.metrics.note_breaker(
                b.cfg.name, "disabled" if b.breaker is None
                else b.breaker.mode(self._clock()))
        self._started = True
        self.metrics.note_started()
        return self

    async def stop(self, drain: bool = True) -> None:
        """Graceful shutdown: refuse new admissions, flush queued requests
        (``drain=True``) or fail them with :class:`ServiceClosed`, join the
        workers."""
        if self._closed:
            return
        self._closing = True
        if not drain:
            for b in self._buckets.values():
                while b.state.pending:
                    rec = b.state.pending.popleft()
                    self._fail(rec, ServiceClosed("service stopped"), "closed")
        for b in self._buckets.values():
            if b.wake is not None:
                b.wake.set()
        tasks = [b.task for b in self._buckets.values() if b.task is not None]
        if tasks:
            done, pending = await asyncio.wait(
                tasks, timeout=self.config.drain_timeout_s)
            for t in pending:
                t.cancel()
            if pending:
                await asyncio.gather(*pending, return_exceptions=True)
        # the workers only *dispatch* launches; join the in-flight ones so
        # every already-admitted request gets its answer before we close
        if self._launches:
            await asyncio.gather(*list(self._launches),
                                 return_exceptions=True)
        # anything a cancelled worker left behind still gets an answer
        for b in self._buckets.values():
            while b.state.pending:
                rec = b.state.pending.popleft()
                self._fail(rec, ServiceClosed("drain timed out"), "closed")
        self._closed = True

    async def __aenter__(self) -> "StencilService":
        if not self._started:
            await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # --- admission ----------------------------------------------------------
    async def submit(self, request: StencilRequest) -> ServeResult:
        """Admit one request and await its result.  Raises the typed
        rejections (:class:`ServiceOverloaded`, :class:`DeadlineExceeded`,
        :class:`NoMatchingBucket`, :class:`ServiceClosed`) — admission
        errors synchronously, queued failures through the future."""
        return await self.submit_nowait(request)

    def submit_nowait(self, request: StencilRequest) -> "asyncio.Future":
        """Admit without awaiting: returns the result future (open-loop
        load generation), raising admission rejections immediately."""
        if not self._started:
            raise RuntimeError("service not started — await start() first")
        if not isinstance(request, StencilRequest):
            # caller bug, checked before the submit counter moves — a
            # non-request must not show up as forever-in-flight
            raise TypeError(f"submit takes a StencilRequest, "
                            f"got {type(request).__name__}")
        now = self._clock()
        self.metrics.note_submitted()
        if self._closing:
            self.metrics.note_rejected("closed")
            raise ServiceClosed("service is draining; resubmit elsewhere")
        b = self._buckets.get(request.bucket_key)
        if b is None:
            self.metrics.note_rejected("no_bucket")
            raise NoMatchingBucket(
                f"no bucket serves {request.problem.stencil.name} "
                f"{request.problem.state_shape} "
                f"bc={request.problem.bc.token()} "
                f"dtype={request.problem.dtype}; declared: "
                f"{[bk.cfg.name for bk in self._buckets.values()]}")
        if b.breaker is not None and not b.breaker.admits(now):
            self.metrics.note_rejected("breaker")
            self.metrics.note_breaker(b.cfg.name, b.breaker.mode(now))
            raise ServiceOverloaded(
                f"bucket {b.cfg.name!r} circuit breaker is open (backend "
                f"kept failing); retry after the cooldown",
                retry_after_s=b.breaker.retry_after_s(now))
        if request.checkpoint_key is not None \
                and self.config.checkpoint_dir is None:
            self.metrics.note_rejected("no_bucket")
            raise NoMatchingBucket(
                "request has checkpoint_key but the service has no "
                "checkpoint_dir configured (ServiceConfig.checkpoint_dir)")
        sig = coeffs_signature(request.problem, request.coeffs)
        self._seq += 1
        if request.checkpoint_key is not None:
            # a checkpointed launch is stateful (it writes its own resume
            # directory), so it must never coalesce with other traffic —
            # a per-admission unique signature makes it a batch of one
            sig = (sig, ("@ckpt", request.checkpoint_key, self._seq))
        rec = PendingRequest(
            seq=self._seq, request=request, submitted_at=now,
            expires_at=(now + request.deadline_s
                        if request.deadline_s is not None else None),
            coeffs_sig=sig, iters=request.iters,
            future=asyncio.get_event_loop().create_future())
        if not b.state.admit(rec, now):
            self.metrics.note_rejected("overload")
            raise ServiceOverloaded(
                f"bucket {b.cfg.name!r} queue is full "
                f"({b.cfg.queue_cap} pending)",
                retry_after_s=self._retry_after(b))
        depth = b.state.depth()
        self.metrics.note_depth(b.cfg.name, depth)
        # wake the worker only when this admission can change its decision:
        # the queue just became non-empty (arm the window) or a full batch
        # may now exist (early launch — a full coeff-subgroup implies depth
        # >= max_batch).  Admissions inside an armed window never shorten
        # it, so waking the worker for each one is pure churn.
        if depth == 1 or depth >= b.cfg.max_batch:
            b.wake.set()
        return rec.future

    def _retry_after(self, b: _Bucket) -> float:
        """Backpressure hint: queued launches ahead x trailing launch time,
        floored at one coalescing window."""
        launches_ahead = max(
            1, -(-b.state.depth() // b.cfg.max_batch))   # ceil div
        est = launches_ahead * (b.last_batch_s or b.cfg.max_wait_s)
        return max(est, b.cfg.max_wait_s)

    # --- the per-bucket worker ----------------------------------------------
    async def _worker(self, b: _Bucket) -> None:
        state = b.state
        while True:
            now = self._clock()
            if state.depth() == 0:
                if self._closing:
                    return
                b.wake.clear()
                await b.wake.wait()
                continue
            due = state.ready_at(now)
            if not self._closing and due is not None and due > now:
                # coalescing window still open: sleep until it expires or
                # a new admission re-evaluates (a full batch launches early)
                b.wake.clear()
                try:
                    await asyncio.wait_for(b.wake.wait(), due - now)
                except asyncio.TimeoutError:
                    pass
                continue
            # degraded/probing breaker: launch one request at a time so a
            # flaky backend gets blast radius 1 (open rejects at admission)
            limit = (1 if b.breaker is not None
                     and b.breaker.mode(now) != "closed" else None)
            batch, expired = state.take_batch(now, limit=limit)
            for rec in expired:
                self._fail(rec, DeadlineExceeded(
                    f"deadline expired after "
                    f"{now - rec.submitted_at:.3f}s in queue "
                    f"(bucket {b.cfg.name!r})"), "deadline")
            self.metrics.note_depth(b.cfg.name, state.depth())
            if not batch:
                continue
            # dispatch without awaiting completion: the worker goes straight
            # back to assembling the next batch, so batch assembly overlaps
            # device compute (up to max_concurrent_batches in flight — the
            # semaphore is the backpressure on dispatch, not completion)
            await self._sem.acquire()
            task = asyncio.create_task(self._launch(b, batch),
                                       name=f"launch-{b.cfg.name}")
            self._launches.add(task)
            task.add_done_callback(self._launches.discard)

    async def _launch(self, b: _Bucket,
                      batch: List[PendingRequest]) -> None:
        """One coalesced launch through the resilience pipeline (retry ->
        bisect -> quarantine; see :meth:`_resilient_batch`).  Holds one
        ``max_concurrent_batches`` slot (acquired by the caller)."""
        try:
            await self._resilient_batch(b, batch)
        finally:
            self._sem.release()

    async def _resilient_batch(self, b: _Bucket,
                               batch: List[PendingRequest]) -> None:
        """Launch ``batch``; every member ends resolved or failed — never
        dropped.  The resilience ladder (DESIGN.md §2.7):

        1. the launch is attempted under the service retry budget
           (capped exponential backoff, ``ServiceConfig.retry``);
        2. a launch that spends the budget is **bisected**: each half is
           relaunched independently (recursively), so the poison member(s)
           fail alone with :class:`LaunchFailed` and the healthy remainder
           — retried as smaller launches — is still served, bit-identical
           (sub-batch launches are bit-exact, see ``_run_batch``);
        3. delivered members pass the per-member health check
           (``ServiceConfig.health``); an unhealthy one is quarantined with
           :class:`NumericalFault` while its neighbors deliver normally
           (members are independent, so one member's NaN is its own);
        4. the bucket's circuit breaker sees infrastructure outcomes only
           (launch success/failure after retries — never numerical faults,
           which are the request's fault, not the backend's).
        """
        t0 = self._clock()
        try:
            outs, padded, rounds = await self._attempt_with_retry(b, batch)
        except Exception as e:              # noqa: BLE001 — fail, don't drop
            infra = not isinstance(e, (_ResNumericalFault,
                                       CheckpointMismatch))
            if infra:
                self._note_breaker(b, failed=True)
            if len(batch) > 1 and infra:
                mid = len(batch) // 2
                await self._resilient_batch(b, batch[:mid])
                await self._resilient_batch(b, batch[mid:])
                return
            for rec in batch:
                self._fail_exec(rec, *self._classify(e))
            return
        self._note_breaker(b, failed=False)
        exec_s = self._clock() - t0
        b.last_batch_s = exec_s
        self.metrics.note_batch(len(batch), padded, rounds, exec_s)
        now = self._clock()
        fill = len(batch) / padded
        health = self.config.health
        for i, (rec, out) in enumerate(zip(batch, outs)):
            fault = health.fault_of(out, member=i,
                                    where=f"bucket {b.cfg.name!r}")
            if fault is not None:
                self._fail_exec(
                    rec, NumericalFault(str(fault), kind=fault.kind,
                                        member=i, max_abs=fault.max_abs),
                    "numerical_fault", quarantined=len(batch) > 1)
                continue
            if rec.future.cancelled():
                continue
            latency = now - rec.submitted_at
            shape = rec.request.problem.shape
            cells = rec.iters
            for d in shape:
                cells *= d
            self.metrics.note_completed(latency, cells)
            rec.future.set_result(ServeResult(
                grid=out, iters=rec.iters, latency_s=latency,
                bucket=b.cfg.name, batch_size=len(batch),
                batch_fill=fill, rounds=rounds))

    async def _attempt_with_retry(self, b: _Bucket,
                                  batch: List[PendingRequest]):
        """Run :meth:`_run_batch` under the retry budget.  Deterministic
        request-side failures (a health fault inside a checkpointed run, a
        checkpoint identity mismatch) are not retried — the same inputs
        would fail the same way; everything else backs off exponentially
        and, when the budget is spent, raises with the last error as
        ``__cause__`` (the caller bisects or fails the members)."""
        policy = self.config.retry
        attempt = 0
        while True:
            attempt += 1
            try:
                if self._offload:
                    return await asyncio.to_thread(self._run_batch, b, batch)
                return self._run_batch(b, batch)
            except (_ResNumericalFault, CheckpointMismatch):
                raise
            except Exception as e:          # noqa: BLE001 — judged below
                if attempt >= policy.max_attempts:
                    raise LaunchFailed(
                        f"bucket {b.cfg.name!r} launch of {len(batch)} "
                        f"request(s) failed {attempt}x "
                        f"({type(e).__name__}: {e})",
                        attempts=attempt) from e
                self.metrics.note_retry()
                await asyncio.sleep(policy.backoff_s(attempt))

    def _note_breaker(self, b: _Bucket, failed: bool) -> None:
        if b.breaker is None:
            return
        now = self._clock()
        if failed:
            b.breaker.on_failure(now)
        else:
            b.breaker.on_success(now)
        mode = b.breaker.mode(now)
        self.metrics.note_breaker(b.cfg.name, mode)
        if mode != "closed":
            # degraded/open decisions are made at take_batch/admission
            # time; wake the worker so an already-armed window re-evaluates
            b.wake.set()

    def _fail(self, rec: PendingRequest, exc: Exception, kind: str) -> None:
        self.metrics.note_rejected(kind)
        if rec.future is not None and not rec.future.cancelled():
            rec.future.set_exception(exc)

    def _fail_exec(self, rec: PendingRequest, exc: Exception, kind: str,
                   quarantined: bool = False) -> None:
        """A launch failure is not a rejection — it lands in the ``failed``
        counters (``kind`` in ``metrics.FAIL_KINDS``) and surfaces the
        typed error on the member's future."""
        self.metrics.note_failed(kind, quarantined=quarantined)
        if rec.future is not None and not rec.future.cancelled():
            rec.future.set_exception(exc)

    @staticmethod
    def _classify(e: Exception):
        """(exception-to-surface, FAIL_KINDS counter) for a terminal launch
        error on a single request."""
        if isinstance(e, _ResNumericalFault):
            return (NumericalFault(str(e), kind=e.kind, member=e.member,
                                   max_abs=e.max_abs), "numerical_fault")
        if isinstance(e, CheckpointMismatch):
            return e, "launch_failed"
        if isinstance(e, LaunchFailed):
            return e, "launch_failed"
        return (LaunchFailed(f"launch failed: {type(e).__name__}: {e}"),
                "launch_failed")

    # --- compute (worker thread) --------------------------------------------
    def _prewarm_bucket(self, b: _Bucket) -> None:
        """Push one zero-grid launch through :meth:`_run_batch` for every
        declared batch class: compiles the backend executables (what
        ``StencilPlan.prewarm`` covers) AND the serving-side stack/slice
        ops, so the first real launch of any class re-traces nothing."""
        prob = b.plan.problem
        zeros = jnp.zeros(prob.state_shape, prob.jnp_dtype)
        aux = (jnp.zeros(prob.shape, prob.jnp_dtype)
               if prob.needs_aux else None)
        req = StencilRequest(prob, zeros, 1, aux=aux)
        for c in b.cfg.batch_classes:
            recs = [PendingRequest(seq=-1, request=req, submitted_at=0.0,
                                   expires_at=None, coeffs_sig=None,
                                   iters=1) for _ in range(c)]
            outs, _, _ = self._run_batch(b, recs)
            jax.block_until_ready(outs[-1])

    def _run_batch(self, b: _Bucket, batch: List[PendingRequest]):
        """One coalesced launch: stack, pad to a batch class, advance by
        staged rounds, slice each member out at its own iteration count.

        Bit-exactness: batch members are independent under every backend's
        ``run_batch`` (verified by the throughput suite), so padding the
        batch axis by replicating the last real member — "edge" padding of
        the ``(B, *state)`` tensor — changes no real member's result, and
        staged advance (``run k1 then k2-k1``) applies the identical
        per-iteration arithmetic as one ``run k2`` call."""
        fault_point(FP_LAUNCH, {"bucket": b.cfg.name, "batch": len(batch),
                                "seqs": tuple(r.seq for r in batch)})
        if batch[0].request.checkpoint_key is not None:
            return self._run_checkpointed(b, batch[0])
        p = b.plan
        prob = p.problem
        dtype = prob.jnp_dtype
        padded = b.cfg.pad_to_class(len(batch))
        # pad by replicating the last member BEFORE the stack, and stage
        # the batch on the host: np.stack + one device transfer is ~4x
        # cheaper than stacking B device arrays (which compiles one
        # concatenate per batch class and dispatches B member conversions),
        # and a repeat+concatenate pad pair would compile per (real,
        # padded) shape combination (~60 ms each, first use)
        grids = jnp.asarray(_stage(
            [r.request.grid for r in batch], padded, dtype))
        aux = None
        if prob.needs_aux:
            aux = jnp.asarray(_stage(
                [r.request.aux for r in batch], padded, dtype))
        coeffs = batch[0].request.coeffs    # members share the resolved sig
        stops = sorted({r.iters for r in batch})
        outs: Dict[int, Any] = {}
        cur, prev = grids, 0
        for it in stops:
            cur = p.run_batch(cur, it - prev, coeffs, aux=aux)
            prev = it
            # one host materialization per round (it also syncs the round,
            # like block_until_ready would): member results become free
            # numpy views instead of B separate device slice dispatches
            host = np.asarray(cur)
            for i, rec in enumerate(batch):
                if rec.iters == it:
                    outs[i] = host[i]
        return [outs[i] for i in range(len(batch))], padded, len(stops)

    def _run_checkpointed(self, b: _Bucket, rec: PendingRequest):
        """Serving-side checkpointed execution: one stateful request,
        chunked through :func:`repro.resilience.run_checkpointed` under
        ``<checkpoint_dir>/<checkpoint_key>``.  A crashed service (or an
        injected SIGKILL) resumes the same key from the last complete
        super-step on resubmission — bit-identically, because chunk seams
        are aligned to super-step seams.  Same ``(outs, padded, rounds)``
        shape as a coalesced launch; ``rounds`` reports the chunks run."""
        req = rec.request
        res = run_checkpointed(
            b.plan, req.grid, rec.iters, req.coeffs, aux=req.aux,
            checkpoint_every=req.checkpoint_every,
            checkpoint_dir=os.path.join(self.config.checkpoint_dir,
                                        req.checkpoint_key),
            health=self.config.health)
        return [np.asarray(res.grid)], 1, max(1, res.chunks_run)

    # --- observability ------------------------------------------------------
    def snapshot(self) -> dict:
        """Metrics snapshot, extended with per-bucket configuration and
        live queue depth."""
        snap = self.metrics.snapshot()
        snap["buckets"] = {
            b.cfg.name: {
                "backend": b.cfg.run.backend,
                "shape": list(b.cfg.problem.shape),
                "dtype": b.cfg.problem.dtype,
                "bc": b.cfg.problem.bc.token(),
                "max_batch": b.cfg.max_batch,
                "max_wait_ms": b.cfg.max_wait_ms,
                "queue_cap": b.cfg.queue_cap,
                "batch_classes": list(b.cfg.batch_classes),
                "depth": b.state.depth(),
                "last_batch_s": b.last_batch_s,
                "breaker": ("disabled" if b.breaker is None
                            else b.breaker.mode(self._clock())),
            } for b in self._buckets.values()
        }
        return snap

    @property
    def buckets(self) -> Dict[str, BucketConfig]:
        return {b.cfg.name: b.cfg for b in self._buckets.values()}


async def serve(config, *, prewarm: bool = True,
                clock=time.monotonic) -> StencilService:
    """Build and boot a :class:`StencilService` (plans built, executables
    pre-warmed for every declared batch class, workers running)."""
    service = StencilService(config, clock=clock)
    await service.start(prewarm=prewarm)
    return service


async def from_config(spec, *, prewarm: bool = True,
                      clock=time.monotonic) -> StencilService:
    """Declarative boot: dict / JSON string / ``ServiceConfig`` -> running
    service (the ``model_factory`` idiom — the whole service is one JSON
    document)."""
    return await serve(ServiceConfig.make(spec), prewarm=prewarm,
                       clock=clock)
