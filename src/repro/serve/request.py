"""Request-facing types of the serving subsystem.

A :class:`StencilRequest` is one unit of admission: a grid to advance, an
iteration count, and (optionally) run-time coefficients, an aux stream, and
a deadline.  The service answers with a :class:`ServeResult` or one of the
typed rejections below — a request is **never silently dropped**: every
admitted request either resolves to a result or fails with an explicit
:class:`ServeError` subclass.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

from repro.api.problem import StencilProblem
from repro.api.schedule_cache import stencil_fingerprint
from repro.resilience.health import LaunchFailed as _LaunchFailed
from repro.resilience.health import NumericalFault as _NumericalFault


# --- typed rejections --------------------------------------------------------

class ServeError(Exception):
    """Base class of every serving-path failure the service raises."""


class ServiceOverloaded(ServeError):
    """The target bucket's admission queue is full (backpressure — the
    429-style rejection).  ``retry_after_s`` is the service's hint for when
    capacity is expected: roughly the queued work ahead divided by the
    bucket's recent batch throughput."""

    def __init__(self, message: str, retry_after_s: float = 0.0):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


class DeadlineExceeded(ServeError):
    """The request's deadline expired while it was still queued — the
    service fails it instead of spending compute on a result nobody will
    read."""


class NoMatchingBucket(ServeError):
    """No declared bucket covers this request's (stencil fingerprint, grid
    shape, boundary condition, dtype).  The bucket set is declared at boot
    (``ServiceConfig``) so executables can be pre-warmed; arbitrary shapes
    go through ``plan().run()`` directly."""


class ServiceClosed(ServeError):
    """The service is draining or stopped; no new admissions."""


class LaunchFailed(ServeError, _LaunchFailed):
    """A coalesced launch kept failing after its whole retry budget (and,
    for multi-member batches, after bisection isolated this request as a
    culprit).  Subclasses both :class:`ServeError` and the resilience
    layer's ``LaunchFailed`` so clients can catch either family;
    ``attempts`` counts tries, ``__cause__`` carries the last error."""


class NumericalFault(ServeError, _NumericalFault):
    """This request's result failed the bucket's numerical health check
    (NaN/Inf cells or amplitude blowup) — the *request* is quarantined and
    failed; healthy co-batched neighbors are delivered unchanged.  Carries
    the resilience fault's ``kind`` / ``member`` / ``max_abs`` fields."""


# --- the request/result pair -------------------------------------------------

def _normalize_problem(problem, grid) -> StencilProblem:
    if isinstance(problem, StencilProblem):
        return problem
    # name / Stencil / stage-sequence forms: the grid supplies the shape
    # AND the storage dtype — a bf16 grid must land in a bf16 bucket, not
    # silently inherit the f32 default (single-field only — multi-field
    # programs carry a (F, *shape) state stack, so their requests must pass
    # a full StencilProblem)
    shape = tuple(int(d) for d in grid.shape)
    dtype = getattr(grid, "dtype", "float32")
    return StencilProblem(problem, shape, dtype=dtype)


@dataclasses.dataclass
class StencilRequest:
    """One serving request: advance ``grid`` by ``iters`` program iterations.

    Parameters
    ----------
    problem:
        What to compute: a :class:`~repro.api.problem.StencilProblem`, or a
        registered stencil name (the grid then supplies the shape; default
        clamp BC).  The problem's (stencil fingerprint, state shape,
        boundary condition, dtype) selects the bucket.
    grid:
        Initial state, ``problem.state_shape``-shaped.
    iters:
        Program iterations to advance (>= 1).
    coeffs:
        Run-time coefficient overrides (as for ``StencilPlan.run``).
        Requests coalesce into one ``run_batch`` call only with requests
        whose *resolved* coefficients agree — a different dt/conductivity
        sub-groups the bucket, it never corrupts neighbors.
    aux:
        Auxiliary input grid (Hotspot's ``power``), required iff the
        problem needs one.  Per-request aux grids batch together.
    deadline_s:
        Relative deadline: if the request is still queued this many seconds
        after submission, it fails with :class:`DeadlineExceeded` instead
        of launching.
    checkpoint_key:
        Opt into checkpointed execution: the run is chunked and each chunk's
        state lands atomically under
        ``<ServiceConfig.checkpoint_dir>/<checkpoint_key>`` — resubmitting
        the *same key* after a crash (the service's, or an injected one)
        resumes from the last complete super-step instead of starting over.
        Keys name the computation, so they must be unique per logical run.
        A checkpointed request never coalesces with other traffic (its
        launch is stateful) and requires ``checkpoint_every``.
    checkpoint_every:
        Checkpoint cadence in program iterations (rounded up to the plan's
        super-step length, so chunk seams stay bit-exact).
    """
    problem: Union[StencilProblem, str, Any]
    grid: Any
    iters: int
    coeffs: Optional[Any] = None
    aux: Optional[Any] = None
    deadline_s: Optional[float] = None
    checkpoint_key: Optional[str] = None
    checkpoint_every: Optional[int] = None

    def __post_init__(self):
        self.problem = _normalize_problem(self.problem, self.grid)
        self.iters = int(self.iters)
        if self.iters < 1:
            raise ValueError(f"iters must be >= 1, got {self.iters}")
        if tuple(self.grid.shape) != self.problem.state_shape:
            raise ValueError(
                f"grid shape {tuple(self.grid.shape)} != problem state "
                f"shape {self.problem.state_shape}")
        if self.deadline_s is not None:
            self.deadline_s = float(self.deadline_s)
            if self.deadline_s <= 0:
                raise ValueError(
                    f"deadline_s must be > 0, got {self.deadline_s}")
        if self.problem.needs_aux:
            if self.aux is None:
                raise ValueError(
                    f"{self.problem.stencil.name} needs an aux grid")
            if tuple(self.aux.shape) != self.problem.shape:
                raise ValueError(
                    f"aux shape {tuple(self.aux.shape)} != problem shape "
                    f"{self.problem.shape}")
        elif self.aux is not None:
            raise ValueError(
                f"{self.problem.stencil.name} takes no aux grid")
        if self.checkpoint_key is not None:
            if not isinstance(self.checkpoint_key, str) \
                    or not self.checkpoint_key \
                    or "/" in self.checkpoint_key \
                    or self.checkpoint_key in (".", ".."):
                raise ValueError(
                    f"checkpoint_key must be a non-empty path-component "
                    f"string, got {self.checkpoint_key!r}")
            if self.checkpoint_every is None:
                raise ValueError(
                    "a checkpointed request needs checkpoint_every")
            self.checkpoint_every = int(self.checkpoint_every)
            if self.checkpoint_every < 1:
                raise ValueError(f"checkpoint_every must be >= 1, "
                                 f"got {self.checkpoint_every}")
        elif self.checkpoint_every is not None:
            raise ValueError("checkpoint_every requires checkpoint_key")

    @property
    def bucket_key(self) -> tuple:
        return bucket_key(self.problem)


def bucket_key(problem: StencilProblem) -> tuple:
    """What makes two requests batchable into one executable: the stencil/
    program *fingerprint* (not just the name — user stencils can change
    under one name), the exact state shape, the boundary condition, and the
    dtype.  Grid shapes are NOT padded across requests: spatial edge
    padding changes clamp semantics from the second iteration on (the pad
    cells evolve freely instead of tracking the edge — see DESIGN.md §2.6),
    so a bucket serves exactly one shape and padding happens along the
    batch axis only, which is bit-exact."""
    return (stencil_fingerprint(problem.stencil), problem.state_shape,
            problem.bc.token(), problem.dtype)


@dataclasses.dataclass
class ServeResult:
    """A completed request: the advanced grid plus serving telemetry."""
    grid: Any
    iters: int
    #: end-to-end seconds from admission to delivery
    latency_s: float
    #: name of the bucket that served the request
    bucket: str
    #: real requests in the coalesced launch (before batch-class padding)
    batch_size: int
    #: real / padded batch size of the launch this request rode in
    batch_fill: float
    #: staged-advance rounds the launch ran (1 unless iters were mixed)
    rounds: int
