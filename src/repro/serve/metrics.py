"""Serving observability: counters, latency percentiles, batch-fill, JSON.

``ServiceMetrics`` is updated only from the event-loop thread (admission
and delivery both run there), so it needs no locking; ``snapshot()`` folds
in the process-level executable-cache statistics — including the per-key
hit/miss breakdown — so batch-fill problems and cache thrash are
distinguishable from one JSON document.
"""
from __future__ import annotations

import json
import time
from collections import deque
from pathlib import Path
from typing import Dict, Optional


def percentile(samples, q: float) -> Optional[float]:
    """Nearest-rank percentile (q in [0, 100]) of an unsorted sample list."""
    if not samples:
        return None
    s = sorted(samples)
    if len(s) == 1:
        return float(s[0])
    rank = max(0, min(len(s) - 1, round(q / 100.0 * (len(s) - 1))))
    return float(s[rank])


#: rejection kinds — every non-served request lands in exactly one counter,
#: which is what "never silently dropped" means operationally
REJECT_KINDS = ("overload", "deadline", "no_bucket", "closed")


class ServiceMetrics:
    """Mutable service telemetry; ``snapshot()`` renders it immutably."""

    def __init__(self, clock=time.monotonic, window: int = 4096):
        self._clock = clock
        self._window = window
        self.reset()

    def reset(self) -> None:
        """Zero every counter and sample window (benchmark warm-up passes
        reset before the measured interval; pre-warm timings survive via
        :meth:`note_prewarm` being re-recorded at boot only)."""
        self.started_at: Optional[float] = None
        self.submitted = 0
        self.completed = 0
        self.rejected: Dict[str, int] = {k: 0 for k in REJECT_KINDS}
        self.batches = 0
        self.rounds = 0
        self.busy_s = 0.0
        #: delivered cell-updates (sum over completed requests of
        #: prod(shape) * iters) — the serving-throughput numerator
        self.cells = 0
        self.prewarm_s: Dict[str, float] = {}
        self.queue_depth: Dict[str, int] = {}
        self._latency_s = deque(maxlen=self._window)
        self._fills = deque(maxlen=self._window)
        self._batch_sizes = deque(maxlen=self._window)

    # --- recording (event-loop thread only) ---------------------------------
    def note_started(self) -> None:
        self.started_at = self._clock()

    def note_submitted(self) -> None:
        self.submitted += 1

    def note_rejected(self, kind: str) -> None:
        self.rejected[kind] += 1

    def note_depth(self, bucket: str, depth: int) -> None:
        self.queue_depth[bucket] = depth

    def note_prewarm(self, bucket: str, seconds: float) -> None:
        self.prewarm_s[bucket] = seconds

    def note_batch(self, real: int, padded: int, rounds: int,
                   exec_s: float) -> None:
        self.batches += 1
        self.rounds += rounds
        self.busy_s += exec_s
        self._fills.append(real / padded)
        self._batch_sizes.append(real)

    def note_completed(self, latency_s: float, cell_updates: int) -> None:
        self.completed += 1
        self.cells += cell_updates
        self._latency_s.append(latency_s)

    # --- reporting ----------------------------------------------------------
    @property
    def batch_fill(self) -> Optional[float]:
        if not self._fills:
            return None
        return sum(self._fills) / len(self._fills)

    def snapshot(self) -> dict:
        """One JSON-serializable document of everything above, plus the
        executable-cache statistics (global and per-key)."""
        from repro.api.backends import exec_cache_stats
        now = self._clock()
        lat = list(self._latency_s)
        wall = (now - self.started_at) if self.started_at is not None else None
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": dict(self.rejected),
            "rejected_total": sum(self.rejected.values()),
            "in_flight": (self.submitted - self.completed
                          - sum(self.rejected.values())),
            "batches": self.batches,
            "rounds": self.rounds,
            "batch_fill": self.batch_fill,
            "batch_size_mean": (sum(self._batch_sizes)
                                / len(self._batch_sizes)
                                if self._batch_sizes else None),
            "latency_ms": {
                "p50": _ms(percentile(lat, 50)),
                "p90": _ms(percentile(lat, 90)),
                "p99": _ms(percentile(lat, 99)),
                "max": _ms(max(lat)) if lat else None,
                "n": len(lat),
            },
            "cells": self.cells,
            "busy_s": self.busy_s,
            "wall_s": wall,
            "cells_s_busy": self.cells / self.busy_s if self.busy_s else None,
            "cells_s_wall": (self.cells / wall if wall else None),
            "queue_depth": dict(self.queue_depth),
            "prewarm_s": dict(self.prewarm_s),
            "exec_cache": exec_cache_stats(),
        }

    def write_json(self, path) -> Path:
        """Snapshot to a JSON file (parents created); returns the path."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.snapshot(), indent=1, sort_keys=True)
                     + "\n")
        return p


def _ms(seconds: Optional[float]) -> Optional[float]:
    return None if seconds is None else seconds * 1e3
