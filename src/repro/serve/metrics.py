"""Serving observability: counters, latency percentiles, batch-fill, JSON.

``ServiceMetrics`` is updated only from the event-loop thread (admission
and delivery both run there), so it needs no locking; ``snapshot()`` folds
in the process-level executable-cache statistics — including the per-key
hit/miss breakdown — so batch-fill problems and cache thrash are
distinguishable from one JSON document.
"""
from __future__ import annotations

import json
import time
from collections import deque
from pathlib import Path
from typing import Dict, Optional


def percentile(samples, q: float) -> Optional[float]:
    """Nearest-rank percentile (q in [0, 100]) of an unsorted sample list."""
    if not samples:
        return None
    s = sorted(samples)
    if len(s) == 1:
        return float(s[0])
    rank = max(0, min(len(s) - 1, round(q / 100.0 * (len(s) - 1))))
    return float(s[rank])


#: rejection kinds — every non-served request lands in exactly one counter,
#: which is what "never silently dropped" means operationally.  "breaker"
#: is the circuit breaker shedding load while a bucket's backend is down.
REJECT_KINDS = ("overload", "deadline", "no_bucket", "closed", "breaker")

#: failure kinds — requests that were *admitted and launched* but could not
#: be served: the launch kept erroring after its retry budget
#: ("launch_failed") or the result failed the numerical health check
#: ("numerical_fault").  Disjoint from both ``completed`` and ``rejected``,
#: so conservation reads
#: ``submitted == completed + rejected + failed + in_flight``.
FAIL_KINDS = ("launch_failed", "numerical_fault")


class ServiceMetrics:
    """Mutable service telemetry; ``snapshot()`` renders it immutably."""

    def __init__(self, clock=time.monotonic, window: int = 4096):
        self._clock = clock
        self._window = window
        self.reset()

    def reset(self) -> None:
        """Zero every counter and sample window (benchmark warm-up passes
        reset before the measured interval; pre-warm timings survive via
        :meth:`note_prewarm` being re-recorded at boot only)."""
        self.started_at: Optional[float] = None
        self.submitted = 0
        self.completed = 0
        self.rejected: Dict[str, int] = {k: 0 for k in REJECT_KINDS}
        self.failed: Dict[str, int] = {k: 0 for k in FAIL_KINDS}
        #: requests failed by the *per-member* health check while their
        #: co-batched neighbors were delivered (a subset of
        #: ``failed["numerical_fault"]`` — solo numerical faults count in
        #: the kind counter but not here)
        self.quarantined = 0
        #: launch retries spent (attempts beyond the first, incl. bisection
        #: sub-launches after a coalesced launch failed)
        self.retries = 0
        #: latest circuit-breaker state per bucket ("closed" when none)
        self.breaker: Dict[str, str] = {}
        self.batches = 0
        self.rounds = 0
        self.busy_s = 0.0
        #: delivered cell-updates (sum over completed requests of
        #: prod(shape) * iters) — the serving-throughput numerator
        self.cells = 0
        self.prewarm_s: Dict[str, float] = {}
        self.queue_depth: Dict[str, int] = {}
        self._latency_s = deque(maxlen=self._window)
        self._fills = deque(maxlen=self._window)
        self._batch_sizes = deque(maxlen=self._window)

    # --- recording (event-loop thread only) ---------------------------------
    def note_started(self) -> None:
        self.started_at = self._clock()

    def note_submitted(self) -> None:
        self.submitted += 1

    def note_rejected(self, kind: str) -> None:
        self.rejected[kind] += 1

    def note_failed(self, kind: str, quarantined: bool = False) -> None:
        """One admitted-and-launched request failed (see ``FAIL_KINDS``);
        ``quarantined=True`` when its healthy co-batched neighbors were
        still delivered."""
        self.failed[kind] += 1
        if quarantined:
            self.quarantined += 1

    def note_retry(self, n: int = 1) -> None:
        self.retries += n

    def note_breaker(self, bucket: str, mode: str) -> None:
        self.breaker[bucket] = mode

    def note_depth(self, bucket: str, depth: int) -> None:
        self.queue_depth[bucket] = depth

    def note_prewarm(self, bucket: str, seconds: float) -> None:
        self.prewarm_s[bucket] = seconds

    def note_batch(self, real: int, padded: int, rounds: int,
                   exec_s: float) -> None:
        self.batches += 1
        self.rounds += rounds
        self.busy_s += exec_s
        self._fills.append(real / padded)
        self._batch_sizes.append(real)

    def note_completed(self, latency_s: float, cell_updates: int) -> None:
        self.completed += 1
        self.cells += cell_updates
        self._latency_s.append(latency_s)

    # --- reporting ----------------------------------------------------------
    @property
    def batch_fill(self) -> Optional[float]:
        if not self._fills:
            return None
        return sum(self._fills) / len(self._fills)

    def snapshot(self) -> dict:
        """One JSON-serializable document of everything above, plus the
        executable-cache statistics (global and per-key)."""
        from repro.api.backends import exec_cache_stats
        now = self._clock()
        lat = list(self._latency_s)
        wall = (now - self.started_at) if self.started_at is not None else None
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": dict(self.rejected),
            "rejected_total": sum(self.rejected.values()),
            "failed": dict(self.failed),
            "failed_total": sum(self.failed.values()),
            "quarantined": self.quarantined,
            "retries": self.retries,
            "breaker": dict(self.breaker),
            # conservation: submitted == completed + rejected + failed +
            # in_flight — asserted by the test suite after every drain, so
            # a request that fell through a crack shows up as a nonzero
            # in_flight on an idle service
            "in_flight": (self.submitted - self.completed
                          - sum(self.rejected.values())
                          - sum(self.failed.values())),
            "batches": self.batches,
            "rounds": self.rounds,
            "batch_fill": self.batch_fill,
            "batch_size_mean": (sum(self._batch_sizes)
                                / len(self._batch_sizes)
                                if self._batch_sizes else None),
            "latency_ms": {
                "p50": _ms(percentile(lat, 50)),
                "p90": _ms(percentile(lat, 90)),
                "p99": _ms(percentile(lat, 99)),
                "max": _ms(max(lat)) if lat else None,
                "n": len(lat),
            },
            "cells": self.cells,
            "busy_s": self.busy_s,
            "wall_s": wall,
            "cells_s_busy": self.cells / self.busy_s if self.busy_s else None,
            "cells_s_wall": (self.cells / wall if wall else None),
            "queue_depth": dict(self.queue_depth),
            "prewarm_s": dict(self.prewarm_s),
            "exec_cache": exec_cache_stats(),
        }

    def write_json(self, path) -> Path:
        """Snapshot to a JSON file (parents created); returns the path."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.snapshot(), indent=1, sort_keys=True)
                     + "\n")
        return p


def _ms(seconds: Optional[float]) -> Optional[float]:
    return None if seconds is None else seconds * 1e3
