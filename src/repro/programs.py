"""Multi-stage stencil programs — chained operators fused into one super-step.

The paper's PE chain (§3.2) fuses ``par_time`` temporal iterations of *one*
operator; StencilFlow (arXiv:2010.15218) observes that a linear chain of
*dependent* stencil stages maps onto exactly the same structure — a stage
boundary is just another temporal step with a different stencil and
coefficients, so intermediates never round-trip external memory.  This module
is the declarative half of that idea:

  * :class:`StencilStage` — one operator application: a stencil plus optional
    per-stage coefficient overrides and an optional per-stage boundary
    condition.
  * :class:`StencilProgram` — a validated linear chain of stages (the
    DAG-ready representation: today a path graph, by construction).

A ``StencilProgram`` is accepted everywhere a bare stencil is today
(``StencilProblem(stencil=...)``): one *iteration* of the problem applies the
stages in order, and a program of S stages at temporal depth ``par_time=T``
unrolls to ``S*T`` chained PE stages per super-step.  Aggregate properties
(``radius`` = per-iteration halo growth = sum of stage radii, ``flop_pcu`` =
sum, ...) duck-type the :class:`~repro.core.stencils.Stencil` bookkeeping the
geometry/perf-model layers read, so the whole planning stack prices the
heterogeneous chain without special cases.

Per-stage boundary conditions: each stage's *input* is read under that
stage's BC (defaulting to the problem-level one).  The periodic/non-periodic
split per axis must be uniform across stages — periodicity is structural
(wrap-padding layout, the materialized stream extension, the distributed
ring exchange), while the local kinds (clamp/reflect/constant) are
re-imposed per sub-step and may differ freely between stages.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

from repro.core.boundary import BCSpec, BoundaryCondition
from repro.core.stencils import STENCILS, Stencil


def _freeze_coeffs(coeffs) -> Optional[Tuple[Tuple[str, float], ...]]:
    """Normalize a stage's static coefficient overrides to a hashable,
    order-independent tuple (stages live inside jit static arguments)."""
    if coeffs is None:
        return None
    if isinstance(coeffs, tuple):   # already frozen (dataclasses.replace
        items = coeffs              # re-runs __post_init__): idempotent
    elif isinstance(coeffs, Mapping):
        items = coeffs.items()
    else:
        raise TypeError(f"stage coeffs must be a mapping, got "
                        f"{type(coeffs).__name__}")
    return tuple(sorted((str(k), float(v)) for k, v in items))


@dataclasses.dataclass(frozen=True)
class StencilStage:
    """One stage of a program: stencil + optional coeffs/BC overrides.

    Parameters
    ----------
    stencil:
        A :class:`~repro.core.stencils.Stencil` or a registered name.
    coeffs:
        Optional static scalar coefficient overrides for this stage, merged
        over :func:`~repro.core.stencils.default_coeffs` at run time (and
        under any per-run ``coeffs`` handed to ``StencilPlan.run``).  Keys
        must be coefficient names of the stencil.
    boundary:
        Optional per-stage boundary condition (same specs as
        ``StencilProblem.boundary``); ``None`` inherits the problem-level BC.
        Normalized to a :class:`~repro.core.boundary.BoundaryCondition` when
        the owning problem resolves the program.
    name:
        Optional label for reports; defaults to the stencil name.
    """
    stencil: Union[Stencil, str]
    coeffs: Optional[Mapping] = None
    boundary: Optional[BCSpec] = None
    name: Optional[str] = None

    def __post_init__(self):
        st = self.stencil
        if isinstance(st, str):
            if st not in STENCILS:
                raise ValueError(f"unknown stencil {st!r}; "
                                 f"registered: {sorted(STENCILS)}")
            st = STENCILS[st]
            object.__setattr__(self, "stencil", st)
        elif not isinstance(st, Stencil):
            raise TypeError(f"stage stencil must be a Stencil or name, got "
                            f"{type(st).__name__}")
        frozen = _freeze_coeffs(self.coeffs)
        if frozen:
            unknown = [k for k, _ in frozen if k not in st.coeff_names]
            if unknown:
                raise ValueError(
                    f"stage coeffs {unknown} are not coefficients of "
                    f"{st.name} (has {list(st.coeff_names)})")
        object.__setattr__(self, "coeffs", frozen)
        # a sequence BC spec must be hashable for jit-static stages
        if isinstance(self.boundary, list):
            object.__setattr__(self, "boundary", tuple(self.boundary))
        if self.name is None:
            object.__setattr__(self, "name", st.name)

    @property
    def bc(self) -> Optional[BoundaryCondition]:
        """The stage BC if already normalized (a resolved program), else
        whatever raw spec was given (``None`` = inherit)."""
        b = self.boundary
        return b if isinstance(b, BoundaryCondition) or b is None else None


#: anything :func:`StencilProgram.make` accepts as one stage
StageLike = Union[StencilStage, Stencil, str]


@dataclasses.dataclass(frozen=True)
class StencilProgram:
    """A validated linear chain of :class:`StencilStage`.

    One *iteration* applies the stages in order (stage ``i+1`` consumes stage
    ``i``'s output); the fused backends run the whole chain — all stages ×
    all ``par_time`` iterations of a super-step — without materializing any
    intermediate in HBM.

    Duck-types the ``Stencil`` bookkeeping the planning layers read:
    ``radius`` (per-iteration halo growth: the *sum* of stage radii —
    geometry's ``rad``), ``flop_pcu`` (sum), ``num_read``/``num_write``
    (external streams of the fused chain: one grid in, one out, plus aux),
    ``has_aux`` (any stage), ``ndim``, ``name``.
    """
    stages: Tuple[StencilStage, ...]

    def __post_init__(self):
        stages = tuple(
            s if isinstance(s, StencilStage) else StencilStage(s)
            for s in self.stages)
        if not stages:
            raise ValueError("a StencilProgram needs at least one stage")
        nd = stages[0].stencil.ndim
        for s in stages:
            if s.stencil.ndim != nd:
                raise ValueError(
                    f"all stages must share a rank: got {nd}D and "
                    f"{s.stencil.ndim}D ({s.name})")
        object.__setattr__(self, "stages", stages)

    # --- construction -------------------------------------------------------
    @classmethod
    def make(cls, spec: Union["StencilProgram", StageLike,
                              Sequence[StageLike]]) -> "StencilProgram":
        """Normalize anything stage-like into a program: a program (as-is),
        a single stencil/name/stage, or a sequence of them."""
        if isinstance(spec, StencilProgram):
            return spec
        if isinstance(spec, (StencilStage, Stencil, str)):
            return cls((spec if isinstance(spec, StencilStage)
                        else StencilStage(spec),))
        if isinstance(spec, Sequence):
            return cls(tuple(s if isinstance(s, StencilStage)
                             else StencilStage(s) for s in spec))
        raise TypeError(f"cannot build a StencilProgram from "
                        f"{type(spec).__name__}")

    def resolved(self, default_boundary: BCSpec,
                 shape: Tuple[int, ...]) -> "StencilProgram":
        """Program with every stage's BC normalized to a
        :class:`BoundaryCondition` (``None`` -> the problem default) and
        validated: per-axis periodicity must be uniform across stages."""
        nd = self.ndim
        default_bc = BoundaryCondition.make(default_boundary, nd)
        out = []
        for s in self.stages:
            bc = (default_bc if s.boundary is None
                  else BoundaryCondition.make(s.boundary, nd))
            bc.validate_shape(shape)
            out.append(dataclasses.replace(s, boundary=bc))
        for ax in range(nd):
            per = {s.boundary.kinds[ax] == "periodic" for s in out}
            if len(per) > 1:
                raise ValueError(
                    f"axis {ax}: stages mix periodic and non-periodic BCs "
                    f"({[s.boundary.kinds[ax] for s in out]}) — periodicity "
                    "is structural (wrap layout / stream extension / ring "
                    "exchange) and must be uniform across a program's stages")
        return StencilProgram(tuple(out))

    # --- container protocol -------------------------------------------------
    def __len__(self) -> int:
        return len(self.stages)

    def __iter__(self):
        return iter(self.stages)

    # --- Stencil duck-typed aggregates (what geometry/perf-model read) ------
    @property
    def ndim(self) -> int:
        return self.stages[0].stencil.ndim

    @property
    def name(self) -> str:
        if len(self.stages) == 1:
            return self.stages[0].stencil.name
        return "program(" + "+".join(s.name for s in self.stages) + ")"

    @property
    def stage_radii(self) -> Tuple[int, ...]:
        return tuple(s.stencil.radius for s in self.stages)

    @property
    def radius(self) -> int:
        """Per-iteration halo growth of the chain: one iteration applies
        every stage, so the dependency cone widens by the *sum* of stage
        radii — this is the ``rad`` that sizes ``size_halo = rad*par_time``."""
        return sum(self.stage_radii)

    @property
    def flop_pcu(self) -> int:
        return sum(s.stencil.flop_pcu for s in self.stages)

    @property
    def has_aux(self) -> bool:
        return any(s.stencil.has_aux for s in self.stages)

    @property
    def num_read(self) -> int:
        """External input streams of the *fused* chain per cell update
        column: the stage-0 grid plus (if any stage needs it) the aux
        stream.  Intermediates never touch external memory."""
        return 1 + (1 if self.has_aux else 0)

    @property
    def num_write(self) -> int:
        return 1
