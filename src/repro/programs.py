"""Multi-stage stencil programs — operator DAGs fused into one super-step.

The paper's PE chain (§3.2) fuses ``par_time`` temporal iterations of *one*
operator; StencilFlow (arXiv:2010.15218) observes that a general *DAG* of
dependent stencil stages maps onto exactly the same streaming structure given
per-edge buffer-depth analysis — a stage boundary is just another temporal
step with a different stencil and coefficients, fan-out is one producer
window tapped by several consumers, and fan-in is a multi-input stage.  This
module is the declarative half of that idea:

  * :class:`StencilStage` — one operator application: a stencil plus optional
    per-stage coefficient overrides, an optional per-stage boundary
    condition, and optional explicit ``inputs`` (names of fields or earlier
    stages; default = the previous stage, preserving chain syntax verbatim).
  * :class:`StencilProgram` — a validated stage DAG over named external
    ``fields`` (e.g. ``("u", "u_prev")`` for the wave equation) with
    per-field ``updates`` declaring which value each field takes after one
    iteration.  Validation covers dangling references, reference ambiguity,
    arity mismatches, cycles (Kahn toposort) and unconsumed stages.

A ``StencilProgram`` is accepted everywhere a bare stencil is
(``StencilProblem(stencil=...)``): one *iteration* of the problem evaluates
the stages in topological order and then updates every field
simultaneously.  Aggregate properties (``radius`` = per-iteration halo
growth = the DAG's critical-path cumulative radius, ``flop_pcu`` = sum over
stages, ``num_read``/``num_write`` = external field streams, ...) duck-type
the :class:`~repro.core.stencils.Stencil` bookkeeping the geometry and
perf-model layers read, so the whole planning stack prices the DAG without
special cases.

Linear chains (single field, default inputs, default update) remain a
recognized fast path — :attr:`StencilProgram.is_linear` — and compile to
bit-identical kernels and unchanged cache fingerprints versus the chain-only
implementation.

Per-stage boundary conditions: each stage's *inputs* are read under that
stage's BC (defaulting to the problem-level one).  The periodic/non-periodic
split per axis must be uniform across all stages — periodicity is structural
(wrap-padding layout, the materialized stream extension, the distributed
ring exchange), while the local kinds (clamp/reflect/constant) are
re-imposed per read and may differ freely between stages and branches.

The bottom half of the module is the shared, jax-free unroll machinery:
:func:`unroll_dag` flattens ``par_time`` iterations of a :class:`DagSpec`
into a value graph of :class:`DagNode` entries, and :func:`dag_layout`
derives per-producer lags and circular-window slot counts (StencilFlow's
buffer-depth analysis).  Both the Pallas kernel builder and the perf model
consume it, so VMEM pricing and the emitted kernel can never disagree.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Mapping, Optional, Sequence, Tuple, Union

from repro.core.boundary import BCSpec, BoundaryCondition
from repro.core.stencils import STENCILS, Stencil


def _freeze_coeffs(coeffs) -> Optional[Tuple[Tuple[str, float], ...]]:
    """Normalize a stage's static coefficient overrides to a hashable,
    order-independent tuple (stages live inside jit static arguments)."""
    if coeffs is None:
        return None
    if isinstance(coeffs, tuple):   # already frozen (dataclasses.replace
        items = coeffs              # re-runs __post_init__): idempotent
    elif isinstance(coeffs, Mapping):
        items = coeffs.items()
    else:
        raise TypeError(f"stage coeffs must be a mapping, got "
                        f"{type(coeffs).__name__}")
    return tuple(sorted((str(k), float(v)) for k, v in items))


@dataclasses.dataclass(frozen=True)
class StencilStage:
    """One stage of a program: stencil + optional coeffs/BC/input overrides.

    Parameters
    ----------
    stencil:
        A :class:`~repro.core.stencils.Stencil` or a registered name.
    coeffs:
        Optional static scalar coefficient overrides for this stage, merged
        over :func:`~repro.core.stencils.default_coeffs` at run time (and
        under any per-run ``coeffs`` handed to ``StencilPlan.run``).  Keys
        must be coefficient names of the stencil.
    boundary:
        Optional per-stage boundary condition (same specs as
        ``StencilProblem.boundary``); ``None`` inherits the problem-level BC.
        Normalized to a :class:`~repro.core.boundary.BoundaryCondition` when
        the owning problem resolves the program.
    name:
        Optional label for reports and for ``inputs`` references from other
        stages; defaults to the stencil name.  The positional aliases
        ``stage0``, ``stage1``, ... always resolve regardless of naming.
    inputs:
        Optional explicit input references — a tuple of field or stage names,
        one per stencil input (``stencil.arity`` of them).  ``None`` keeps
        the chain default: stage 0 reads the first field, stage ``i`` reads
        stage ``i-1``.  A multi-input (fan-in) stencil *requires* explicit
        inputs.
    """
    stencil: Union[Stencil, str]
    coeffs: Optional[Mapping] = None
    boundary: Optional[BCSpec] = None
    name: Optional[str] = None
    inputs: Optional[Sequence[str]] = None

    def __post_init__(self):
        st = self.stencil
        if isinstance(st, str):
            if st not in STENCILS:
                raise ValueError(f"unknown stencil {st!r}; "
                                 f"registered: {sorted(STENCILS)}")
            st = STENCILS[st]
            object.__setattr__(self, "stencil", st)
        elif not isinstance(st, Stencil):
            raise TypeError(f"stage stencil must be a Stencil or name, got "
                            f"{type(st).__name__}")
        frozen = _freeze_coeffs(self.coeffs)
        if frozen:
            unknown = [k for k, _ in frozen if k not in st.coeff_names]
            if unknown:
                raise ValueError(
                    f"stage coeffs {unknown} are not coefficients of "
                    f"{st.name} (has {list(st.coeff_names)})")
        object.__setattr__(self, "coeffs", frozen)
        # a sequence BC spec must be hashable for jit-static stages
        if isinstance(self.boundary, list):
            object.__setattr__(self, "boundary", tuple(self.boundary))
        if self.name is None:
            object.__setattr__(self, "name", st.name)
        if self.inputs is not None:
            ins = self.inputs
            if isinstance(ins, str):
                ins = (ins,)
            ins = tuple(str(r) for r in ins)
            if len(ins) != st.arity:
                raise ValueError(
                    f"stage {self.name!r}: {len(ins)} inputs given but "
                    f"stencil {st.name} has arity {st.arity}")
            object.__setattr__(self, "inputs", ins)

    @property
    def bc(self) -> Optional[BoundaryCondition]:
        """The stage BC if already normalized (a resolved program), else
        whatever raw spec was given (``None`` = inherit)."""
        b = self.boundary
        return b if isinstance(b, BoundaryCondition) or b is None else None


#: anything :func:`StencilProgram.make` accepts as one stage
StageLike = Union[StencilStage, Stencil, str]


@dataclasses.dataclass(frozen=True)
class DagSpec:
    """Static, hashable execution form of a resolved program DAG.

    ``stages[i] = (stencil, bc, refs)`` in *authored* order (coefficient
    packing order); ``refs`` are int-encoded value references: ``r >= 0``
    reads stage ``r``'s output, ``r < 0`` reads external field ``~r``.
    ``updates[k]`` gives field ``k``'s next-iteration value in the same
    encoding (``~k`` = the field is carried unchanged).  ``topo`` is a
    topological evaluation order over the stage indices.
    """
    stages: Tuple[Tuple[Stencil, Optional[BoundaryCondition],
                        Tuple[int, ...]], ...]
    n_fields: int
    updates: Tuple[int, ...]
    topo: Tuple[int, ...]


def chain_dag(stages) -> DagSpec:
    """The path-graph :class:`DagSpec` of a linear chain.  ``stages`` is the
    legacy executor contract: a tuple of ``(stencil, bc)`` pairs."""
    L = len(stages)
    return DagSpec(
        stages=tuple((st, bc, ((i - 1,) if i else (-1,)))
                     for i, (st, bc) in enumerate(stages)),
        n_fields=1, updates=(L - 1,), topo=tuple(range(L)))


def dag_is_chain(dag: DagSpec) -> bool:
    """True iff ``dag`` is the single-field path graph (the PR 6 chain) —
    the shape that takes the bit-identical linear kernel fast path."""
    L = len(dag.stages)
    return (dag.n_fields == 1 and dag.updates == (L - 1,)
            and all(st.arity == 1
                    and refs == ((i - 1,) if i else (-1,))
                    for i, (st, _, refs) in enumerate(dag.stages)))


def dag_radius(dag: DagSpec) -> int:
    """Per-iteration halo growth: the critical-path cumulative radius over
    the DAG, maximized over the field updates (= the sum of stage radii for
    a chain).  This is the ``rad`` that sizes ``size_halo = rad*par_time``
    and the distributed halo exchange."""
    cum = [0] * len(dag.stages)
    for si in dag.topo:
        st, _, refs = dag.stages[si]
        cum[si] = st.radius + max((cum[r] for r in refs if r >= 0), default=0)
    return max((cum[u] for u in dag.updates if u >= 0), default=0)


@dataclasses.dataclass(frozen=True)
class DagNode:
    """One value node of the unrolled per-super-step graph.

    ``stencil`` entries compute one stage application; ``stencil is None``
    marks a *state* (select) node — the PE-forwarding generalization for
    DAGs: ``inputs = (updated, fallback)`` and the node selects the updated
    value while ``iteration < steps``, else forwards the fallback (the
    field's previous value), so partial super-steps stay exact.  Linear
    chains instead fuse the select into every entry (``fused_select``),
    reproducing the PR 6 chain op-for-op.

    ``inputs`` are value ids: ``0..n_streams-1`` = external field streams,
    ``n_streams + e`` = unrolled entry ``e``.
    """
    stencil: Optional[Stencil]
    bc: object                    # BoundaryCondition or None (= clamp)
    coeff_lo: int                 # slice start into the packed coeff vector
    inputs: Tuple[int, ...]
    iteration: int                # which program iteration this entry is in
    fused_select: bool = False


@dataclasses.dataclass(frozen=True)
class UnrollPlan:
    """``par_time`` iterations of a :class:`DagSpec` flattened to a value
    graph: entry ``e`` is value id ``n_streams + e``; ``outputs[k]`` is the
    value id field ``k`` holds after the super-step (possibly a stream id,
    for fields carried unchanged)."""
    n_streams: int
    entries: Tuple[DagNode, ...]
    outputs: Tuple[int, ...]
    linear: bool


def unroll_dag(dag: DagSpec, par_time: int) -> UnrollPlan:
    """Topological unroll: ``par_time`` repeats of the DAG, stages evaluated
    in ``dag.topo`` order per iteration, then (non-linear DAGs) one state
    node per updated field selecting new-vs-previous value so every field
    advances simultaneously and partial super-steps forward correctly."""
    F = dag.n_fields
    L = len(dag.stages)
    los, acc = [], 0
    for st, _, _ in dag.stages:
        los.append(acc)
        acc += len(st.coeff_names)
    linear = dag_is_chain(dag)
    entries = []
    cur = list(range(F))          # value id currently holding each field

    def vid():
        return F + len(entries)

    for t in range(par_time):
        vals: list = [None] * L
        for si in dag.topo:
            st, bc, refs = dag.stages[si]
            ins = tuple(vals[r] if r >= 0 else cur[~r] for r in refs)
            v = vid()
            entries.append(DagNode(st, bc, los[si], ins, t,
                                   fused_select=linear))
            vals[si] = v
        if linear:
            cur[0] = vals[L - 1]
            continue
        new = list(cur)
        for k, u in enumerate(dag.updates):
            if u == ~k:           # field carried unchanged: no node
                continue
            src = vals[u] if u >= 0 else cur[~u]
            new[k] = vid()
            entries.append(DagNode(None, None, -1, (src, cur[k]), t))
        cur = new
    return UnrollPlan(F, tuple(entries), tuple(cur), linear)


@dataclasses.dataclass(frozen=True)
class DagLayout:
    """Buffer-depth analysis of an :class:`UnrollPlan` at vector width ``V``
    (StencilFlow, arXiv:2010.15218 §4): per-entry slab radii
    ``R_e = ceil(rad_e/V)``, per-value lags, and per-producer circular
    window slot counts.

    ``lags[v]``: entry ``v`` computes stream slab ``k - lags[v]`` at tick
    ``k`` (streams have lag 0).  ``wins[v]``: slots the producer's window
    must hold — ``max over consumer edges of (lag_c + R_c) - lag_p + 1`` —
    which reduces to the chain's ``2R+1`` when producer and consumer are
    adjacent and grows by exactly the lag *difference* when an edge skips
    levels; ``0`` means no window (the value feeds only the output DMA).
    """
    radii: Tuple[int, ...]        # per entry (slabs); state nodes are 0
    lags: Tuple[int, ...]         # per value id
    wins: Tuple[int, ...]         # per value id; 0 = no window needed
    out_lag: int                  # max lag over output producers
    aux_depth: int                # aux window depth, in slabs


def dag_layout(plan: UnrollPlan, par_vec: int) -> DagLayout:
    F = plan.n_streams
    radii = tuple(0 if e.stencil is None
                  else -(-e.stencil.radius // par_vec)
                  for e in plan.entries)
    lags = [0] * (F + len(plan.entries))
    for i, e in enumerate(plan.entries):
        lags[F + i] = radii[i] + max((lags[p] for p in e.inputs), default=0)
    wins = [0] * (F + len(plan.entries))
    for i, e in enumerate(plan.entries):
        need = lags[F + i] + radii[i] + 1
        for p in set(e.inputs):
            wins[p] = max(wins[p], need - lags[p])
    out_lag = max(lags[o] for o in plan.outputs)
    if plan.linear:
        aux_depth = lags[-1] + 1          # PR 6 chain: Lag_total + 1
    else:
        al = [lags[F + i] for i, e in enumerate(plan.entries)
              if e.stencil is not None and e.stencil.has_aux]
        aux_depth = (max(al) + 1) if al else 1
    return DagLayout(radii, tuple(lags), tuple(wins), out_lag, aux_depth)


@dataclasses.dataclass(frozen=True)
class StencilProgram:
    """A validated DAG of :class:`StencilStage` over named external fields.

    One *iteration* evaluates the stages in topological order — each stage
    reading its declared ``inputs`` (fields or other stages) — and then
    applies ``updates`` simultaneously: every field takes its declared
    next value (a stage output or another field).  The fused backends run
    the whole DAG — all stages × all ``par_time`` iterations of a
    super-step — without materializing any intermediate in HBM.

    Defaults preserve the linear-chain syntax verbatim: one field ``"u"``,
    stage ``i`` reads stage ``i-1`` (stage 0 reads the field), and the field
    updates to the last stage — :attr:`is_linear` programs compile through
    the unchanged chain fast path with identical kernels and fingerprints.

    Duck-types the ``Stencil`` bookkeeping the planning layers read:
    ``radius`` (per-iteration halo growth: the DAG's critical-path
    cumulative radius — geometry's ``rad``), ``flop_pcu`` (sum),
    ``num_read``/``num_write`` (external streams: one per field, plus aux),
    ``has_aux`` (any stage), ``ndim``, ``name``.
    """
    stages: Tuple[StencilStage, ...]
    fields: Tuple[str, ...] = ("u",)
    updates: Optional[Mapping] = None

    def __post_init__(self):
        stages = tuple(
            s if isinstance(s, StencilStage) else StencilStage(s)
            for s in self.stages)
        if not stages:
            raise ValueError("a StencilProgram needs at least one stage")
        nd = stages[0].stencil.ndim
        for s in stages:
            if s.stencil.ndim != nd:
                raise ValueError(
                    f"all stages must share a rank: got {nd}D and "
                    f"{s.stencil.ndim}D ({s.name})")
        object.__setattr__(self, "stages", stages)

        fields = self.fields
        if isinstance(fields, str):
            fields = (fields,)
        fields = tuple(str(f) for f in fields)
        if not fields:
            raise ValueError("a StencilProgram needs at least one field")
        if len(set(fields)) != len(fields):
            raise ValueError(f"duplicate field names in {fields}")
        object.__setattr__(self, "fields", fields)

        # normalize updates to a per-field-ordered tuple of (field, ref)
        upd = self.updates
        if upd is not None:
            if isinstance(upd, Mapping):
                items = list(upd.items())
            elif isinstance(upd, tuple) and all(
                    isinstance(p, tuple) and len(p) == 2 for p in upd):
                items = list(upd)       # already frozen: idempotent
            else:
                raise TypeError("updates must be a mapping "
                                "{field: stage-or-field name}")
            for f, _ in items:
                if f not in fields:
                    raise ValueError(f"updates key {f!r} is not a field "
                                     f"(fields: {list(fields)})")
            by_field = dict((str(f), str(r)) for f, r in items)
            upd = tuple((f, by_field[f]) for f in fields if f in by_field)
            object.__setattr__(self, "updates", upd)

        self._resolve_dag()

    # --- DAG resolution and validation --------------------------------------
    def _resolve_dag(self) -> None:
        stages, fields = self.stages, self.fields
        L = len(stages)
        field_pos = {f: k for k, f in enumerate(fields)}
        auto = {f"stage{i}": i for i in range(L)}
        counts = Counter(s.name for s in stages)
        by_name = {s.name: i for i, s in enumerate(stages)
                   if counts[s.name] == 1}

        def resolve(ref: str, where: str) -> int:
            si = auto.get(ref, by_name.get(ref))
            fi = field_pos.get(ref)
            if si is not None and fi is not None:
                raise ValueError(
                    f"{where}: reference {ref!r} is ambiguous — it names "
                    f"both a field and a stage; rename one or use the "
                    f"positional alias stage{si}")
            if si is not None:
                return si
            if fi is not None:
                return ~fi
            if counts.get(ref, 0) > 1:
                raise ValueError(
                    f"{where}: reference {ref!r} is ambiguous — "
                    f"{counts[ref]} stages share that name; use the "
                    f"positional aliases stage0..stage{L - 1}")
            raise ValueError(
                f"{where}: dangling reference {ref!r} — not a field "
                f"{list(fields)} or a stage "
                f"{sorted(set(auto) | set(by_name))}")

        inputs_idx = []
        for i, s in enumerate(stages):
            if s.inputs is None:
                if s.stencil.arity != 1:
                    raise ValueError(
                        f"stage {s.name!r} (stage{i}): stencil "
                        f"{s.stencil.name} has arity {s.stencil.arity} and "
                        f"needs explicit inputs=(...)")
                inputs_idx.append(((i - 1,) if i else (~0,)))
            else:
                inputs_idx.append(tuple(
                    resolve(r, f"stage {s.name!r} (stage{i}) inputs")
                    for r in s.inputs))
        inputs_idx = tuple(inputs_idx)

        if self.updates is None:
            updates_idx = tuple((L - 1) if k == 0 else ~k
                                for k in range(len(fields)))
        else:
            declared = dict(self.updates)
            updates_idx = tuple(
                resolve(declared[f], f"updates[{f!r}]") if f in declared
                else ~k
                for k, f in enumerate(fields))

        # Kahn toposort over stage->stage edges (authored order preserved;
        # forward references are legal, cycles are not)
        preds = [sorted({r for r in ins if r >= 0}) for ins in inputs_idx]
        indeg = [len(p) for p in preds]
        succs = [[] for _ in range(L)]
        for i, ps in enumerate(preds):
            for p in ps:
                succs[p].append(i)
        ready = sorted(i for i in range(L) if not indeg[i])
        topo = []
        while ready:
            i = ready.pop(0)
            topo.append(i)
            for c in succs[i]:
                indeg[c] -= 1
                if not indeg[c]:
                    ready.append(c)
            ready.sort()
        if len(topo) != L:
            stuck = [f"stage{i}({stages[i].name})"
                     for i in range(L) if i not in topo]
            raise ValueError(f"program DAG has a cycle through {stuck}")

        consumed = {r for ins in inputs_idx for r in ins if r >= 0}
        consumed |= {u for u in updates_idx if u >= 0}
        unused = [i for i in range(L) if i not in consumed]
        if unused:
            raise ValueError(
                "stage output(s) never consumed (dead stages): "
                + ", ".join(f"stage{i}({stages[i].name})" for i in unused))

        linear = (len(fields) == 1
                  and updates_idx == (L - 1,)
                  and all(s.stencil.arity == 1 for s in stages)
                  and all(inputs_idx[i] == (((i - 1),) if i else (~0,))
                          for i in range(L)))
        object.__setattr__(self, "_inputs_idx", inputs_idx)
        object.__setattr__(self, "_updates_idx", updates_idx)
        object.__setattr__(self, "_topo", tuple(topo))
        object.__setattr__(self, "_linear", linear)

    # --- construction -------------------------------------------------------
    @classmethod
    def make(cls, spec: Union["StencilProgram", StageLike,
                              Sequence[StageLike]]) -> "StencilProgram":
        """Normalize anything stage-like into a program: a program (as-is),
        a single stencil/name/stage, or a sequence of them."""
        if isinstance(spec, StencilProgram):
            return spec
        if isinstance(spec, (StencilStage, Stencil, str)):
            return cls((spec if isinstance(spec, StencilStage)
                        else StencilStage(spec),))
        if isinstance(spec, Sequence):
            return cls(tuple(s if isinstance(s, StencilStage)
                             else StencilStage(s) for s in spec))
        raise TypeError(f"cannot build a StencilProgram from "
                        f"{type(spec).__name__}")

    def resolved(self, default_boundary: BCSpec,
                 shape: Tuple[int, ...]) -> "StencilProgram":
        """Program with every stage's BC normalized to a
        :class:`BoundaryCondition` (``None`` -> the problem default) and
        validated: per-axis periodicity must be uniform across stages."""
        nd = self.ndim
        default_bc = BoundaryCondition.make(default_boundary, nd)
        out = []
        for s in self.stages:
            bc = (default_bc if s.boundary is None
                  else BoundaryCondition.make(s.boundary, nd))
            bc.validate_shape(shape)
            out.append(dataclasses.replace(s, boundary=bc))
        for ax in range(nd):
            per = {s.boundary.kinds[ax] == "periodic" for s in out}
            if len(per) > 1:
                raise ValueError(
                    f"axis {ax}: stages mix periodic and non-periodic BCs "
                    f"({[s.boundary.kinds[ax] for s in out]}) — periodicity "
                    "is structural (wrap layout / stream extension / ring "
                    "exchange) and must be uniform across a program's stages")
        return dataclasses.replace(self, stages=tuple(out))

    # --- container protocol -------------------------------------------------
    def __len__(self) -> int:
        return len(self.stages)

    def __iter__(self):
        return iter(self.stages)

    # --- DAG views ----------------------------------------------------------
    @property
    def is_linear(self) -> bool:
        """True for single-field default-wired chains — the shape PR 6
        shipped, compiled through the unchanged chain fast path."""
        return self._linear

    @property
    def inputs_idx(self) -> Tuple[Tuple[int, ...], ...]:
        """Per-stage resolved input references (``>= 0`` stage, ``< 0``
        field ``~r``)."""
        return self._inputs_idx

    @property
    def updates_idx(self) -> Tuple[int, ...]:
        """Per-field resolved next-value references (``~k`` = unchanged)."""
        return self._updates_idx

    @property
    def topo(self) -> Tuple[int, ...]:
        return self._topo

    @property
    def dag(self) -> DagSpec:
        """The static execution form handed to every backend (stage BCs are
        whatever this program carries — resolve first for executors)."""
        return DagSpec(
            stages=tuple((s.stencil, s.bc, self._inputs_idx[i])
                         for i, s in enumerate(self.stages)),
            n_fields=len(self.fields),
            updates=self._updates_idx,
            topo=self._topo)

    def dag_vmem_info(self, par_time: int, par_vec: int):
        """Exact unrolled buffer-depth accounting for the perf model:
        ``(window_slot_counts, n_in_streams, n_out_streams, aux_slabs)``,
        or ``None`` for linear programs (priced by the chain formula,
        unchanged from PR 6)."""
        if self._linear:
            return None
        plan = unroll_dag(self.dag, par_time)
        lay = dag_layout(plan, par_vec)
        return (tuple(w for w in lay.wins if w),
                len(self.fields), len(self.fields),
                lay.aux_depth if self.has_aux else 0)

    # --- Stencil duck-typed aggregates (what geometry/perf-model read) ------
    @property
    def ndim(self) -> int:
        return self.stages[0].stencil.ndim

    @property
    def name(self) -> str:
        if len(self.stages) == 1 and self._linear:
            return self.stages[0].stencil.name
        return "program(" + "+".join(s.name for s in self.stages) + ")"

    @property
    def stage_radii(self) -> Tuple[int, ...]:
        return tuple(s.stencil.radius for s in self.stages)

    @property
    def radius(self) -> int:
        """Per-iteration halo growth: the critical-path cumulative radius
        over the DAG (= the *sum* of stage radii for a chain) — this is the
        ``rad`` that sizes ``size_halo = rad*par_time``."""
        return dag_radius(self.dag)

    @property
    def flop_pcu(self) -> int:
        return sum(s.stencil.flop_pcu for s in self.stages)

    @property
    def has_aux(self) -> bool:
        return any(s.stencil.has_aux for s in self.stages)

    @property
    def num_read(self) -> int:
        """External input streams of the *fused* DAG per cell update
        column: one per field plus (if any stage needs it) the aux stream.
        Intermediates never touch external memory."""
        return len(self.fields) + (1 if self.has_aux else 0)

    @property
    def num_write(self) -> int:
        return len(self.fields)
