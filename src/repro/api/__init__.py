"""Public API: ``StencilProblem`` -> ``plan()`` -> ``StencilPlan``.

    from repro.api import StencilProblem, RunConfig, plan

    problem = StencilProblem("diffusion2d", (4096, 4096))
    p = plan(problem, RunConfig(backend="pallas_interpret", autotune=True))
    out = p.run(grid, iters=1000)
    print(p.describe(), p.traffic_report())

``RunConfig(autotune="measure")`` (or the :func:`tune` helper) upgrades the
perf-model tuning to *measured* tuning: the model's top-K candidates are
timed on the selected backend and the winner is persisted to a schedule
cache, so the timing cost is paid once per (problem, backend, device) key.

Backends are pluggable via :func:`register_backend`; the built-ins are
``reference``, ``engine``, ``pallas``, ``pallas_interpret`` and
``distributed`` (a mesh is just config — see ``RunConfig.mesh``).

Multi-stage programs (``repro.programs``) drop in wherever a stencil goes::

    prog = [StencilStage("advect2d", coeffs={...}),
            StencilStage("diffusion2d")]
    p = plan(StencilProblem(prog, (4096, 4096)), RunConfig(...))

— each iteration applies the stages in order, fused into one super-step
executable: intermediates never round-trip through HBM.
"""
from repro.api.backends import (Backend, BackendProgram, as_program,
                                clear_exec_cache, exec_cache_stats,
                                get_backend, list_backends, register_backend)
from repro.api.config import RunConfig
from repro.core.boundary import BoundaryCondition
from repro.api.plan import StencilPlan, plan
from repro.api.problem import StencilProblem
from repro.api.schedule_cache import ScheduleCache
from repro.api.tuner import TunedCandidate, tune
from repro.programs import StencilProgram, StencilStage

#: serving-subsystem names re-exported lazily from ``repro.serve`` —
#: lazily because ``repro.serve`` itself imports this package, and because
#: plain plan/run users should not pay the asyncio import
_SERVE_EXPORTS = ("BucketConfig", "ServeResult", "ServiceConfig",
                  "ServiceMetrics", "StencilRequest", "StencilService",
                  "from_config", "serve")

__all__ = [
    "Backend", "BackendProgram", "BoundaryCondition", "RunConfig",
    "ScheduleCache", "StencilPlan", "StencilProblem", "StencilProgram",
    "StencilStage", "TunedCandidate", "as_program", "clear_exec_cache",
    "exec_cache_stats", "get_backend", "list_backends", "plan",
    "register_backend", "tune", *_SERVE_EXPORTS,
]


def __getattr__(name):
    if name in _SERVE_EXPORTS:
        import repro.serve as _serve
        return getattr(_serve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
