"""Public API: ``StencilProblem`` -> ``plan()`` -> ``StencilPlan``.

    from repro.api import StencilProblem, RunConfig, plan

    problem = StencilProblem("diffusion2d", (4096, 4096))
    p = plan(problem, RunConfig(backend="pallas_interpret", autotune=True))
    out = p.run(grid, iters=1000)
    print(p.describe(), p.traffic_report())

Backends are pluggable via :func:`register_backend`; the built-ins are
``reference``, ``engine``, ``pallas``, ``pallas_interpret`` and
``distributed`` (a mesh is just config — see ``RunConfig.mesh``).
"""
from repro.api.backends import (Backend, get_backend, list_backends,
                                register_backend)
from repro.api.config import RunConfig
from repro.api.plan import StencilPlan, plan
from repro.api.problem import StencilProblem

__all__ = [
    "Backend", "RunConfig", "StencilPlan", "StencilProblem", "get_backend",
    "list_backends", "plan", "register_backend",
]
