"""Execution configuration — how to run a :class:`StencilProblem`.

``RunConfig`` carries everything the planner needs that is *not* part of the
problem statement: which backend, the (bsize, par_time) schedule (or
``autotune="model"``/``"measure"`` to let the tuner choose), the device model
used for prediction/pruning, the measured-tuning knobs and schedule-cache
location, and the mesh/sharding spec for the distributed backend.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.core import precision
from repro.core.perf_model import DEVICES, Device

#: Accepted ``RunConfig.autotune`` modes (``False`` disables; the legacy
#: booleans are aliases: ``True`` -> ``"model"``).
AUTOTUNE_MODES = ("model", "measure")


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Backend + schedule + placement for one plan.

    ``par_time``/``bsize`` left as ``None`` (or ``autotune`` set) hands the
    choice to the tuner.  ``autotune="model"`` (alias ``True``) ranks
    candidates by the performance model alone (paper §5.3);
    ``autotune="measure"`` takes the model's ``tune_top_k`` shortlist, times
    each candidate on the selected backend (``repro.api.tuner``) and compiles
    the measured winner — consulting/filling the persistent schedule cache
    (``repro.api.schedule_cache``) so the timing cost is paid once per
    (problem, backend, device) key.  Specifying only one of
    ``par_time``/``bsize`` constrains the tuner to configurations matching
    it.

    ``cache``: ``None`` uses the default cache location (the
    ``REPRO_SCHEDULE_CACHE`` env var, else ``~/.cache/repro/schedules.json``);
    a path string overrides it; ``False`` disables persistence entirely.
    """
    backend: str = "engine"
    par_time: Optional[int] = None
    bsize: Optional[Union[int, Tuple[int, ...]]] = None
    #: stream-axis vector width V (rows/planes per kernel tick, paper §3.3).
    #: ``None`` hands the choice to the tuner (sweeping
    #: ``perf_model.PAR_VEC_CANDIDATES``) when autotuning, else defaults to 1.
    par_vec: Optional[int] = None
    autotune: Union[bool, str] = False
    device: Union[Device, str] = "tpu_v5e"
    #: storage bytes per cell used for traffic/VMEM pricing. ``None`` (the
    #: default) derives it from the problem's storage dtype via
    #: :func:`repro.core.precision.cell_bytes` (4 for f32, 2 for bf16); an
    #: explicit int overrides — see :meth:`resolved_cell_bytes`.
    cell_bytes: Optional[int] = None
    par_time_max: int = 64
    iters_hint: int = 100        # iteration count used for ranking/prediction
    mesh: Optional[object] = None          # jax.sharding.Mesh (distributed)
    axis_map: Optional[Tuple] = None       # grid axis -> mesh axis names
    interpret: bool = False      # force Pallas interpret mode
    # --- throughput knobs (serving path) ------------------------------------
    #: let backends donate the *internal* padded super-step carry to XLA
    #: (donate_argnums on the padded grid — never on a caller-visible array,
    #: so plans stay reusable).  Only takes effect on platforms that
    #: implement donation (TPU/GPU); a no-op on CPU.
    donate: bool = True
    #: consult/populate the process-level executable cache
    #: (``repro.api.backends``): plans with the same (stencil fingerprint,
    #: geometry, batch, backend) key share one compiled program instead of
    #: re-tracing.  Disable to force a private executable per plan.
    exec_cache: bool = True
    #: opt-in Megacore parallelism (pallas backends): compile the kernel
    #: grid's block dimension(s) with ``"parallel"`` instead of
    #: ``"arbitrary"`` semantics.  Blocks are independent by construction
    #: (halos are redundantly computed; every block writes a disjoint
    #: compute region), so Mosaic may split them across TensorCores;
    #: results are bit-identical to the sequential grid.
    block_parallel: bool = False
    # --- measured-tuning knobs (autotune="measure") -------------------------
    cache: Union[None, bool, str] = None   # schedule-cache path / False = off
    tune_top_k: int = 4          # model candidates the tuner times
    tune_warmup: int = 1         # untimed runs per candidate (compile+warm)
    tune_repeats: int = 3        # timed runs per candidate (min is kept)
    tune_iters: Optional[int] = None  # iters per timed run (None: 1 super-step)

    def __post_init__(self):
        if isinstance(self.autotune, bool):
            object.__setattr__(self, "autotune",
                               "model" if self.autotune else False)
        elif self.autotune not in AUTOTUNE_MODES:
            raise ValueError(f"autotune must be a bool or one of "
                             f"{AUTOTUNE_MODES}, got {self.autotune!r}")
        if self.tune_top_k < 1:
            raise ValueError(f"tune_top_k must be >= 1, got {self.tune_top_k}")
        if self.tune_warmup < 0 or self.tune_repeats < 1:
            raise ValueError("need tune_warmup >= 0 and tune_repeats >= 1, "
                             f"got {self.tune_warmup}/{self.tune_repeats}")
        if self.tune_iters is not None and self.tune_iters < 1:
            raise ValueError(f"tune_iters must be >= 1, got {self.tune_iters}")
        if self.par_time is not None and self.par_time < 1:
            raise ValueError(f"par_time must be >= 1, got {self.par_time}")
        if self.par_vec is not None and self.par_vec < 1:
            raise ValueError(f"par_vec must be >= 1, got {self.par_vec}")
        if self.bsize is not None and not isinstance(self.bsize, int):
            object.__setattr__(self, "bsize",
                               tuple(int(b) for b in self.bsize))
        if self.axis_map is not None:
            # a bare string is one axis name, not a sequence of characters
            object.__setattr__(
                self, "axis_map",
                tuple((a,) if isinstance(a, str) else tuple(a) if a else None
                      for a in self.axis_map))

    def resolved_cell_bytes(self, dtype="float32") -> int:
        """The cell bytes traffic/VMEM pricing and cache keys use: the
        explicit override when set, else the storage dtype's itemsize."""
        if self.cell_bytes is not None:
            return int(self.cell_bytes)
        return precision.cell_bytes(dtype)

    def resolved_device(self) -> Device:
        if isinstance(self.device, Device):
            return self.device
        if self.device not in DEVICES:
            raise ValueError(f"unknown device {self.device!r}; "
                             f"have: {sorted(DEVICES)}")
        return DEVICES[self.device]

    def normalized_bsize(self, ndim: int) -> Optional[Tuple[int, ...]]:
        """bsize as a per-blocked-dim tuple (``ndim - 1`` entries)."""
        if self.bsize is None:
            return None
        if isinstance(self.bsize, int):
            return (self.bsize,) * (ndim - 1)
        if len(self.bsize) != ndim - 1:
            raise ValueError(f"bsize {self.bsize} has {len(self.bsize)} "
                             f"entries; a {ndim}D grid blocks {ndim - 1} dims")
        return self.bsize
