"""Execution configuration — how to run a :class:`StencilProblem`.

``RunConfig`` carries everything the planner needs that is *not* part of the
problem statement: which backend, the (bsize, par_time) schedule (or
``autotune=True`` to let the performance model choose, paper §5.3), the
device model used for prediction/pruning, and the mesh/sharding spec for the
distributed backend.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.core.perf_model import DEVICES, Device


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Backend + schedule + placement for one plan.

    ``par_time``/``bsize`` left as ``None`` (or ``autotune=True``) hands the
    choice to the performance model: candidates are enumerated, pruned by the
    VMEM budget and ranked by predicted run time (paper §5.3).  Specifying
    only one of the two constrains the autotuner to configurations matching
    it.
    """
    backend: str = "engine"
    par_time: Optional[int] = None
    bsize: Optional[Union[int, Tuple[int, ...]]] = None
    autotune: bool = False
    device: Union[Device, str] = "tpu_v5e"
    cell_bytes: int = 4
    par_time_max: int = 64
    iters_hint: int = 100        # iteration count used for ranking/prediction
    mesh: Optional[object] = None          # jax.sharding.Mesh (distributed)
    axis_map: Optional[Tuple] = None       # grid axis -> mesh axis names
    interpret: bool = False      # force Pallas interpret mode

    def __post_init__(self):
        if self.par_time is not None and self.par_time < 1:
            raise ValueError(f"par_time must be >= 1, got {self.par_time}")
        if self.bsize is not None and not isinstance(self.bsize, int):
            object.__setattr__(self, "bsize",
                               tuple(int(b) for b in self.bsize))
        if self.axis_map is not None:
            # a bare string is one axis name, not a sequence of characters
            object.__setattr__(
                self, "axis_map",
                tuple((a,) if isinstance(a, str) else tuple(a) if a else None
                      for a in self.axis_map))

    def resolved_device(self) -> Device:
        if isinstance(self.device, Device):
            return self.device
        if self.device not in DEVICES:
            raise ValueError(f"unknown device {self.device!r}; "
                             f"have: {sorted(DEVICES)}")
        return DEVICES[self.device]

    def normalized_bsize(self, ndim: int) -> Optional[Tuple[int, ...]]:
        """bsize as a per-blocked-dim tuple (``ndim - 1`` entries)."""
        if self.bsize is None:
            return None
        if isinstance(self.bsize, int):
            return (self.bsize,) * (ndim - 1)
        if len(self.bsize) != ndim - 1:
            raise ValueError(f"bsize {self.bsize} has {len(self.bsize)} "
                             f"entries; a {ndim}D grid blocks {ndim - 1} dims")
        return self.bsize
