"""Persistent schedule cache — measured-tuning winners, paid for once.

The measured autotuner (``repro.api.tuner``) is the expensive half of
``plan(..., RunConfig(autotune="measure"))``: it compiles and times several
candidate schedules on the real backend.  A production process (the ROADMAP's
serving north-star) cannot afford that on every boot, so winners are
persisted to a small JSON file keyed by everything that determines the
optimum:

    (stencil, shape, dtype, boundary condition, cell_bytes, backend,
     interpret flag, execution platform, device, n_chips / chip_grid,
     pinned par_time/bsize, code-version salt)

The *code-version salt* is a content hash of the stencil/kernel/engine/
blocking sources: editing any of them silently invalidates every cached
schedule
(stale winners are never served), with no manual version bump to forget.

Cache resolution (see ``RunConfig.cache``): ``None``/``True`` -> the
``REPRO_SCHEDULE_CACHE`` env var, else ``~/.cache/repro/schedules.json``
(honoring ``XDG_CACHE_HOME``); a path string -> that file; ``False`` ->
caching disabled.  The file is human-readable JSON; deleting it (or any
entry) is always safe — the only cost is re-tuning on the next miss.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import tempfile
import time
import warnings
from pathlib import Path
from typing import Optional, Union

from repro.resilience.faults import fault_point, register_point

try:
    import fcntl
except ImportError:          # non-POSIX: writes fall back to merge-no-lock
    fcntl = None

#: inside ``_load``'s degradation envelope: an injected ``OSError`` here
#: behaves exactly like a flaky filesystem — the cache treats it as a miss
#: (re-tune), never a crash
FP_LOAD = register_point(
    "schedule_cache.get", "on every schedule-cache file read (inject "
    "exc=OSError to model a real filesystem failure)")
FP_PUT = register_point(
    "schedule_cache.put", "before a measured winner is persisted")

#: Bump when the on-disk entry layout changes (not for code changes — those
#: are covered by the content salt).
CACHE_FORMAT_VERSION = 1

_salt_cache: Optional[str] = None


def code_version_salt() -> str:
    """Content hash of the sources that determine a schedule's performance."""
    global _salt_cache
    if _salt_cache is None:
        from repro import programs
        from repro.core import blocking, engine, stencils
        from repro.kernels import builder, ops
        h = hashlib.sha1()
        for mod in (blocking, engine, stencils, ops, builder, programs):
            with open(mod.__file__, "rb") as f:
                h.update(f.read())
        _salt_cache = h.hexdigest()[:12]
    return _salt_cache


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_SCHEDULE_CACHE")
    if env:
        return Path(env)
    base = os.environ.get("XDG_CACHE_HOME") or (Path.home() / ".cache")
    return Path(base) / "repro" / "schedules.json"


def stencil_fingerprint(st) -> str:
    """Hash of what makes a stencil *itself*: name alone is not identity for
    user-defined stencils, whose ``apply`` can change under the same name.

    Shared by the persistent schedule cache (this module) and the
    process-level executable cache (``repro.api.backends``).

    A multi-stage :class:`~repro.programs.StencilProgram` fingerprints as
    the ordered chain of its stages — each stage's stencil fingerprint plus
    its static coefficient overrides and per-stage BC — so two programs
    collide only when they compute the same thing.  DAG wiring (explicit
    ``inputs=``, extra ``fields=``, ``updates=``) folds in only when
    present, so every pre-DAG linear program keeps its exact historical
    fingerprint (cached schedules stay valid)."""
    if hasattr(st, "stages"):    # StencilProgram
        h = hashlib.sha1()
        for s in st.stages:
            btok = (s.boundary.token() if hasattr(s.boundary, "token")
                    else repr(s.boundary))
            h.update(stencil_fingerprint(s.stencil).encode())
            h.update(repr((s.name, s.coeffs, btok)).encode())
            if s.inputs is not None:
                h.update(repr(("inputs", s.inputs)).encode())
        if st.fields != ("u",) or st.updates is not None:
            h.update(repr(("state", st.fields, st.updates)).encode())
        return h.hexdigest()[:8]
    h = hashlib.sha1()
    h.update(repr((st.ndim, st.radius, st.flop_pcu, st.num_read,
                   st.num_write, st.has_aux, st.coeff_names,
                   st.offsets)).encode())
    if getattr(st, "arity", 1) != 1:
        h.update(repr(("arity", st.arity)).encode())
    code = getattr(st.apply, "__code__", None)
    if code is not None:
        h.update(code.co_code)
        # nested code objects repr with process-dependent addresses: skip
        h.update(repr([c for c in code.co_consts
                       if not hasattr(c, "co_code")]).encode())
    return h.hexdigest()[:8]


def schedule_key(problem, config, device, n_chips: int, chip_grid,
                 salt: Optional[str] = None) -> str:
    """Stable, human-readable cache key for one tuning context.

    ``iters_hint`` is deliberately excluded: winners are ranked by amortized
    per-iteration time (see ``repro.api.tuner``), a steady-state metric that
    does not depend on how many super-steps a run chains.
    Everything that constrains the swept candidate set *is* included —
    pinned ``par_time``/``bsize``, ``par_time_max`` and ``tune_top_k`` — so
    a winner found under a tight constraint never shadows (or violates) a
    search run under a looser one.
    """
    import jax
    shape = "x".join(str(d) for d in problem.shape)
    grid = "x".join(str(c) for c in chip_grid) if chip_grid else "-"
    pin_bs = config.normalized_bsize(problem.ndim)
    pin = (f"{config.par_time if config.par_time is not None else '-'}"
           f",{'x'.join(str(b) for b in pin_bs) if pin_bs else '-'}"
           f",{config.par_vec if config.par_vec is not None else '-'}")
    return "|".join([
        problem.stencil.name, f"st={stencil_fingerprint(problem.stencil)}",
        f"shape={shape}", f"dtype={problem.dtype}",
        # the BC shapes the compiled program and its traffic (periodic adds
        # a stream extension): a winner tuned under clamp must never be
        # served to a periodic plan
        f"bc={problem.bc.token()}",
        f"cb={config.resolved_cell_bytes(problem.dtype)}",
        f"backend={config.backend}",
        # interpret-mode timings have no relation to compiled ordering:
        # never let one serve the other from the cache
        f"interp={int(bool(config.interpret))}",
        # config.device is only the perf-model's label; the stopwatch ran on
        # the actual jax platform — a shared cache file must not let a
        # CPU-timed winner serve a TPU process (or vice versa)
        f"host={jax.default_backend()}",
        f"device={device.name}", f"chips={n_chips}", f"grid={grid}",
        f"pin={pin}",
        f"lim={config.par_time_max}/{config.tune_top_k}",
        f"salt={salt or code_version_salt()}",
    ])


class ScheduleCache:
    """A JSON file of measured-tuning winners, safe to share and to delete.

    Writes are atomic (tempfile + ``os.replace``) and re-read the file first,
    so concurrent tuners lose at worst one entry, never the file.
    """

    def __init__(self, path: Union[str, Path]):
        self.path = Path(path)

    @classmethod
    def resolve(cls, cache: Union[None, bool, str, Path]
                ) -> Optional["ScheduleCache"]:
        """``RunConfig.cache`` -> a cache instance, or None when disabled."""
        if cache is False:
            return None
        if cache is None or cache is True:
            return cls(default_cache_path())
        return cls(cache)

    def _load(self) -> dict:
        try:
            fault_point(FP_LOAD, {"path": str(self.path)})
            with open(self.path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return {}
        if (not isinstance(data, dict)
                or data.get("version") != CACHE_FORMAT_VERSION
                or not isinstance(data.get("entries"), dict)):
            return {}    # unknown layout: treat as empty, overwrite on put
        return data["entries"]

    def get(self, key: str) -> Optional[dict]:
        entry = self._load().get(key)
        return dict(entry) if isinstance(entry, dict) else None

    @contextlib.contextmanager
    def _write_lock(self):
        """Exclusive advisory lock over the cache file's writers.

        Without it, two concurrent ``plan()`` processes race the
        read-modify-write in :meth:`put`: both load, both write, and the
        ``os.replace`` that lands second silently drops the other's freshly
        measured entry.  ``flock`` on a sidecar ``.lock`` file serializes
        the load→merge→replace critical section (the sidecar, not the cache
        file itself, because ``os.replace`` swaps the cache inode out from
        under any lock held on it).  Non-POSIX hosts (no ``fcntl``) fall
        back to merging immediately before the replace — a much smaller
        window than the old load-at-entry, not a guarantee."""
        if fcntl is None:
            yield
            return
        lock_path = self.path.with_name(self.path.name + ".lock")
        with open(lock_path, "a") as lockf:
            fcntl.flock(lockf, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lockf, fcntl.LOCK_UN)

    def put(self, key: str, entry: dict) -> None:
        """Persist ``entry``; an unwritable path degrades to a warning — the
        cache is an optimization, and a write failure must not discard the
        freshly measured winner by crashing ``plan()``.

        Concurrent-writer safe: the on-disk state is (re)loaded and merged
        with this entry *inside* the write lock, immediately before the
        atomic ``os.replace`` — two processes tuning different problems
        both keep their winners (regression-tested with real concurrent
        processes in tests/test_resilience.py)."""
        tmp = None
        try:
            fault_point(FP_PUT, {"path": str(self.path), "key": key})
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with self._write_lock():
                entries = self._load()      # fresh read, under the lock
                entries[key] = dict(entry, saved_at=time.time())
                fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                           prefix=self.path.name,
                                           suffix=".tmp")
                with os.fdopen(fd, "w") as f:
                    json.dump({"version": CACHE_FORMAT_VERSION,
                               "entries": entries}, f, indent=1,
                              sort_keys=True)
                os.replace(tmp, self.path)
        except OSError as e:
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            warnings.warn(f"schedule cache not persisted to {self.path}: {e}",
                          RuntimeWarning, stacklevel=2)

    def __len__(self) -> int:
        return len(self._load())
