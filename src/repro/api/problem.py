"""Declarative problem description — what to compute, not how.

``StencilProblem`` is the immutable front half of the two-phase workflow the
paper prescribes (§4, §5.3): describe the computation once, then let
``repro.api.plan`` pair it with a :class:`~repro.api.config.RunConfig` to
produce an executable :class:`~repro.api.plan.StencilPlan`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax.numpy as jnp

from repro.core.boundary import BCSpec, BoundaryCondition
from repro.core.stencils import STENCILS, Stencil

#: Supported boundary-condition kinds (per axis, mixable).  The paper (§5.1)
#: clamps every out-of-bound neighbor to the boundary cell (edge
#: replication); the other kinds open the ROADMAP's PDE/wave/periodic-domain
#: workloads — see ``repro.core.boundary``.
BOUNDARIES = ("clamp", "periodic", "reflect", "constant")


@dataclasses.dataclass(frozen=True)
class StencilProblem:
    """An iterated-stencil computation on a fixed grid.

    Parameters
    ----------
    stencil:
        A :class:`~repro.core.stencils.Stencil` or the name of one of the
        registered paper stencils (``"diffusion2d"``, ``"hotspot3d"``, ...).
    shape:
        Grid extents, streaming axis first (``(ny, nx)`` / ``(nz, ny, nx)``).
    dtype:
        Cell dtype (normalized to a canonical string; f32 is the paper's).
    boundary:
        Boundary condition: a kind name applied to every axis (``"clamp"``,
        ``"periodic"``, ``"reflect"``, ``"constant"`` / ``"constant:VALUE"``),
        a per-axis sequence mixing kinds (streaming axis first, e.g.
        ``("clamp", "periodic")``), or a
        :class:`~repro.core.boundary.BoundaryCondition`.  Normalized to a
        ``BoundaryCondition`` (also exposed as :attr:`bc`).  Default: the
        paper's clamp (§5.1).
    aux:
        Auxiliary-input spec: ``None`` inherits ``stencil.has_aux`` (Hotspot's
        ``power`` grid); an explicit bool must agree with the stencil.
    """
    stencil: Union[Stencil, str]
    shape: Tuple[int, ...]
    dtype: str = "float32"
    boundary: BCSpec = "clamp"
    aux: Optional[bool] = None

    def __post_init__(self):
        st = self.stencil
        if isinstance(st, str):
            if st not in STENCILS:
                raise ValueError(f"unknown stencil {st!r}; "
                                 f"registered: {sorted(STENCILS)}")
            st = STENCILS[st]
            object.__setattr__(self, "stencil", st)
        shape = tuple(int(d) for d in self.shape)
        object.__setattr__(self, "shape", shape)
        if len(shape) != st.ndim:
            raise ValueError(f"{st.name} is {st.ndim}D but shape={shape}")
        if any(d < 1 for d in shape):
            raise ValueError(f"non-positive grid extent in {shape}")
        bc = BoundaryCondition.make(self.boundary, st.ndim)
        bc.validate_shape(shape)
        object.__setattr__(self, "boundary", bc)
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype).name)
        if self.aux is not None and bool(self.aux) != st.has_aux:
            raise ValueError(
                f"aux={self.aux} conflicts with {st.name} "
                f"(stencil.has_aux={st.has_aux})")

    @property
    def bc(self) -> BoundaryCondition:
        """The normalized per-axis boundary condition."""
        return self.boundary

    @property
    def ndim(self) -> int:
        return self.stencil.ndim

    @property
    def needs_aux(self) -> bool:
        return self.stencil.has_aux if self.aux is None else bool(self.aux)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)
