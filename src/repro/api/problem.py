"""Declarative problem description — what to compute, not how.

``StencilProblem`` is the immutable front half of the two-phase workflow the
paper prescribes (§4, §5.3): describe the computation once, then let
``repro.api.plan`` pair it with a :class:`~repro.api.config.RunConfig` to
produce an executable :class:`~repro.api.plan.StencilPlan`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax.numpy as jnp

from repro.core import precision
from repro.core.boundary import BCSpec, BoundaryCondition
from repro.core.stencils import STENCILS, Stencil, default_coeffs
from repro.programs import StencilProgram, StencilStage

#: Supported boundary-condition kinds (per axis, mixable).  The paper (§5.1)
#: clamps every out-of-bound neighbor to the boundary cell (edge
#: replication); the other kinds open the ROADMAP's PDE/wave/periodic-domain
#: workloads — see ``repro.core.boundary``.
BOUNDARIES = ("clamp", "periodic", "reflect", "constant")


@dataclasses.dataclass(frozen=True)
class StencilProblem:
    """An iterated-stencil computation on a fixed grid.

    Parameters
    ----------
    stencil:
        A :class:`~repro.core.stencils.Stencil`, the name of one of the
        registered paper stencils (``"diffusion2d"``, ``"hotspot3d"``, ...),
        or a multi-stage program: a
        :class:`~repro.programs.StencilProgram`, a
        :class:`~repro.programs.StencilStage`, or a sequence of
        stage-likes.  A program's stages run in order each iteration; the
        fused backends keep every intermediate on-chip.  For a single plain
        stage this field normalizes to the bare ``Stencil`` (legacy
        behavior); for programs it holds the resolved
        ``StencilProgram``, which duck-types the ``Stencil`` bookkeeping
        (``radius`` = sum of stage radii, etc.).  :attr:`program` always
        exposes the resolved program form.
    shape:
        Grid extents, streaming axis first (``(ny, nx)`` / ``(nz, ny, nx)``).
    dtype:
        Cell dtype (normalized to a canonical string; f32 is the paper's).
    boundary:
        Boundary condition: a kind name applied to every axis (``"clamp"``,
        ``"periodic"``, ``"reflect"``, ``"constant"`` / ``"constant:VALUE"``),
        a per-axis sequence mixing kinds (streaming axis first, e.g.
        ``("clamp", "periodic")``), or a
        :class:`~repro.core.boundary.BoundaryCondition`.  Normalized to a
        ``BoundaryCondition`` (also exposed as :attr:`bc`).  Default: the
        paper's clamp (§5.1).
    aux:
        Auxiliary-input spec: ``None`` inherits ``stencil.has_aux`` (Hotspot's
        ``power`` grid); an explicit bool must agree with the stencil.
    """
    stencil: Union[Stencil, str, StencilProgram, StencilStage, Sequence]
    shape: Tuple[int, ...]
    dtype: str = "float32"
    boundary: BCSpec = "clamp"
    aux: Optional[bool] = None

    def __post_init__(self):
        st = self.stencil
        if isinstance(st, str):
            if st not in STENCILS:
                raise ValueError(f"unknown stencil {st!r}; "
                                 f"registered: {sorted(STENCILS)}")
            st = STENCILS[st]
        elif not isinstance(st, Stencil):
            # program forms: StencilProgram | StencilStage | sequence
            st = StencilProgram.make(st)
        shape = tuple(int(d) for d in self.shape)
        object.__setattr__(self, "shape", shape)
        if len(shape) != st.ndim:
            raise ValueError(f"{st.name} is {st.ndim}D but shape={shape}")
        if any(d < 1 for d in shape):
            raise ValueError(f"non-positive grid extent in {shape}")
        bc = BoundaryCondition.make(self.boundary, st.ndim)
        bc.validate_shape(shape)
        object.__setattr__(self, "boundary", bc)
        if isinstance(st, StencilProgram):
            # resolve per-stage BCs against the problem default + shape
            program = st.resolved(bc, shape)
            if (len(program) == 1 and program.is_linear
                    and program.stages[0].coeffs is None
                    and program.stages[0].boundary == bc):
                # a plain single stage IS the legacy problem — normalize
                # `stencil` back to the bare Stencil (exact old behavior,
                # cache keys included)
                st = program.stages[0].stencil
            else:
                st = program
        else:
            program = StencilProgram((StencilStage(st, boundary=bc),))
        object.__setattr__(self, "stencil", st)
        object.__setattr__(self, "_program", program)
        # accept np.dtype / jnp.bfloat16 / "bf16" / string forms uniformly
        object.__setattr__(self, "dtype", precision.normalize_dtype(self.dtype))
        if self.aux is not None and bool(self.aux) != st.has_aux:
            raise ValueError(
                f"aux={self.aux} conflicts with {st.name} "
                f"(stencil.has_aux={st.has_aux})")

    @property
    def bc(self) -> BoundaryCondition:
        """The normalized per-axis boundary condition (the problem-level
        default; stages may override the local kinds — see
        :attr:`structural_bc`)."""
        return self.boundary

    @property
    def program(self) -> StencilProgram:
        """The resolved program form: every problem is a (possibly
        single-stage) chain with per-stage ``BoundaryCondition``s."""
        return self._program

    @property
    def stages(self) -> Tuple[StencilStage, ...]:
        return self._program.stages

    @property
    def n_stages(self) -> int:
        return len(self._program)

    @property
    def is_program(self) -> bool:
        """True when the problem carries more than the legacy bare stencil:
        multiple stages, or a single stage with coeff/BC overrides."""
        return isinstance(self.stencil, StencilProgram)

    @property
    def exec_stages(self) -> Tuple[Tuple[Stencil, BoundaryCondition], ...]:
        """The static ``((stencil, bc), ...)`` tuple the chain executors
        (engine / kernel builder / oracle) take."""
        return tuple((s.stencil, s.boundary) for s in self.stages)

    @property
    def structural_bc(self) -> BoundaryCondition:
        """Stage 0's BC — what sizes padding, the periodic stream extension
        and the halo exchange (per-axis periodicity is uniform across
        stages; equals :attr:`bc` for non-program problems)."""
        return self.stages[0].boundary

    @property
    def is_dag(self) -> bool:
        """True when the program is a general DAG (multi-field state, fan-in/
        fan-out, or non-default wiring) — the backends then route through the
        topological DAG executors instead of the linear chain fast path."""
        return not self._program.is_linear

    @property
    def fields(self) -> Tuple[str, ...]:
        """The program's external field names (``("u",)`` for plain
        problems)."""
        return self._program.fields

    @property
    def state_shape(self) -> Tuple[int, ...]:
        """Shape of the array ``run()`` takes: the plain grid ``shape`` for
        single-field problems, ``(n_fields, *shape)`` for multi-field
        programs (field axis leading, fields in declaration order)."""
        F = len(self._program.fields)
        return ((F,) + self.shape) if F > 1 else self.shape

    @property
    def exec_dag(self):
        """The resolved program's static :class:`~repro.programs.DagSpec` —
        what the DAG executors (oracle / engine / kernel builder /
        distributed) take."""
        return self._program.dag

    def resolve_coeffs(self, coeffs=None, dtype=None) -> Tuple[dict, ...]:
        """Per-stage coefficient dicts: stencil defaults, overlaid with each
        stage's static overrides, overlaid with run-time ``coeffs`` —
        a single dict (applied to the only stage) for single-stage problems,
        or a sequence of per-stage dicts/None for programs.  Unknown names
        are rejected."""
        if coeffs is None:
            per_stage = (None,) * self.n_stages
        elif isinstance(coeffs, dict):
            if self.n_stages > 1:
                raise ValueError(
                    f"{self.stencil.name} has {self.n_stages} stages: pass "
                    "coeffs as a sequence of per-stage dicts (None entries "
                    "keep that stage's defaults), not a single dict")
            per_stage = (coeffs,)
        else:
            per_stage = tuple(coeffs)
            if len(per_stage) != self.n_stages:
                raise ValueError(
                    f"got {len(per_stage)} coefficient dicts for "
                    f"{self.n_stages} stages")
        out = []
        for stage, run_c in zip(self.stages, per_stage):
            merged = dict(default_coeffs(stage.stencil, dtype)
                          if dtype is not None
                          else default_coeffs(stage.stencil))
            if stage.coeffs:
                merged.update(stage.coeffs)
            if run_c:
                unknown = [k for k in run_c if k not in merged]
                if unknown:
                    raise ValueError(
                        f"unknown coefficients {unknown} for stage "
                        f"{stage.name} (has {list(stage.stencil.coeff_names)})")
                merged.update(run_c)
            out.append(merged)
        return tuple(out)

    @property
    def ndim(self) -> int:
        return self.stencil.ndim

    @property
    def needs_aux(self) -> bool:
        return self.stencil.has_aux if self.aux is None else bool(self.aux)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def cell_bytes(self) -> int:
        """Storage bytes per cell — what HBM/halo traffic scales with
        (2 for bf16, 4 for f32)."""
        return precision.cell_bytes(self.dtype)

    @property
    def accum_dtype(self):
        """The dtype stage arithmetic runs in: f32 for sub-32-bit float
        storage (bf16), the storage dtype itself otherwise.  See
        ``repro.core.precision``."""
        return precision.accum_dtype(self.dtype)
