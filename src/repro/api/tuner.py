"""Measured autotuning — time the model's shortlist, compile the winner.

The paper tunes ``(bsize, par_time)`` from its performance model alone
(§4, §5.3).  That ranking is only as good as the model, so
``RunConfig(autotune="measure")`` closes the loop the way Table 4 does for
the FPGA boards: take the model's ``tune_top_k`` best candidates, run each on
the *selected backend* with a small warm-up + timed-repeat harness, and keep
the one that is actually fastest.  Each candidate records its measured
seconds and the paper's "model accuracy" (estimated/measured time, §6.2) —
``StencilPlan.candidates`` then reads like a Table 4 row.

Timing protocol (per candidate): ``tune_warmup`` untimed executions absorb
compilation and cache warming, then ``tune_repeats`` timed executions of
``tune_iters`` iterations (rounded up to whole super-steps — a partial
super-step costs the same as a full one and would skew deep-``par_time``
candidates cheap) and the *minimum* is kept — the standard low-noise
estimator for a deterministic kernel.  Measurements are normalized to
seconds per super-step; candidates are ranked by *amortized per-iteration*
time (``measured_s / par_time``), the steady-state metric that does not
depend on any particular run's iteration count — which is what lets the
schedule cache serve one winner to runs of every length.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional, Sequence, Tuple

import jax

from repro.api.backends import as_program, get_backend
from repro.api.config import RunConfig
from repro.api.problem import StencilProblem
from repro.core import perf_model
from repro.core.perf_model import Prediction
from repro.data import make_stencil_inputs


@dataclasses.dataclass(frozen=True)
class TunedCandidate:
    """One measured schedule: the model's view plus the stopwatch's."""
    prediction: Prediction
    measured_s: float          # seconds per super-step (min over repeats)
    measured_run_time: float   # extrapolated seconds at iters_hint
    model_accuracy: float      # paper §6.2: estimated / measured time
    from_cache: bool = False   # True when served by the schedule cache

    @property
    def geom(self):
        return self.prediction.geom

    @property
    def s_per_iter(self) -> float:
        """Amortized seconds per time-step — the (iters-independent) metric
        candidates are ranked by."""
        return self.measured_s / self.geom.par_time

    def describe(self) -> str:
        src = "cache" if self.from_cache else "measured"
        return (f"bsize={self.geom.bsize} par_time={self.geom.par_time} "
                f"-> {self.measured_s * 1e3:.3f} ms/super ({src}, "
                f"model_accuracy={self.model_accuracy:.3g})")


def _time_once(execute, grid, coeffs, iters: int, aux) -> float:
    t0 = time.perf_counter()
    out = execute(grid, coeffs, iters, aux)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def measure_candidate(problem: StencilProblem, config: RunConfig,
                      prediction: Prediction, grid, coeffs, aux) -> TunedCandidate:
    """Time one candidate schedule on the configured backend."""
    geom = prediction.geom
    factory = get_backend(config.backend)
    execute = as_program(factory(problem, config, geom)).execute
    # time whole super-steps: a partial one costs the same as a full one
    # (PE forwarding) and would under-bill deep-par_time candidates
    n_super = math.ceil((config.tune_iters or 1) / geom.par_time)
    iters = n_super * geom.par_time
    for _ in range(config.tune_warmup):
        _time_once(execute, grid, coeffs, iters, aux)
    best = min(_time_once(execute, grid, coeffs, iters, aux)
               for _ in range(config.tune_repeats))
    per_super = best / n_super
    run_time = per_super * prediction.n_super
    return TunedCandidate(
        prediction=prediction, measured_s=per_super,
        measured_run_time=run_time,
        model_accuracy=perf_model.model_accuracy(run_time, prediction))


def measure_candidates(problem: StencilProblem, config: RunConfig,
                       predictions: Sequence[Prediction],
                       ) -> Tuple[TunedCandidate, ...]:
    """Time every candidate; return them ranked by amortized per-iteration
    measured time (steady-state fastest first)."""
    # the exact payload shape the backends take: one dict for single-stage
    # problems, a tuple of per-stage dicts for programs
    resolved = problem.resolve_coeffs(dtype=problem.jnp_dtype)
    coeffs = resolved[0] if problem.n_stages == 1 else resolved
    grid, aux = make_stencil_inputs(jax.random.PRNGKey(0), problem.shape,
                                    problem.needs_aux)
    grid = grid.astype(problem.jnp_dtype)
    if aux is not None:
        aux = aux.astype(problem.jnp_dtype)
    tuned = [measure_candidate(problem, config, p, grid, coeffs, aux)
             for p in predictions]
    tuned.sort(key=lambda c: c.s_per_iter)
    return tuple(tuned)


def tune(problem: StencilProblem, config: Optional[RunConfig] = None,
         **overrides) -> "repro.api.plan.StencilPlan":  # noqa: F821
    """Measured-autotune ``problem`` and return the compiled plan.

    Sugar for ``plan(problem, replace(config, autotune="measure"))``: the
    returned ``StencilPlan.candidates`` carry per-candidate measured seconds
    and model accuracy (the paper's Table 4 columns), and the winner is
    persisted to the schedule cache unless ``cache=False``.
    """
    from repro.api.plan import plan    # circular at module load, not at call
    overrides.pop("autotune", None)    # redundant autotune= kwarg is harmless
    config = dataclasses.replace(config or RunConfig(),
                                 autotune="measure", **overrides)
    return plan(problem, config)
