"""``plan(problem, config) -> StencilPlan`` — the single public entry point.

Mirrors the paper's two-phase workflow: the performance model prunes the
(bsize, par_time) design space *offline* (§4, §5.3), then a fixed
configuration executes many iterations.  A ``StencilPlan`` is that fixed
configuration: reusable across calls and iteration counts, and introspectable
(``predicted()``, ``traffic_report()``, ``describe()``) without running
anything.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.api import schedule_cache, tuner
from repro.api.backends import (ExecuteFn, as_program, get_backend,
                                resolve_axis_map)
from repro.api.config import RunConfig
from repro.api.problem import StencilProblem
from repro.core import perf_model
from repro.core.blocking import (BlockGeometry, extended_geometry,
                                 superstep_traffic_bytes)
from repro.core.perf_model import Device, Prediction


def _chip_layout(problem: StencilProblem, config: RunConfig):
    """(n_chips, chip_grid) for the perf model; (1, None) off-mesh."""
    if config.backend != "distributed" or config.mesh is None:
        return 1, None
    mesh = config.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axis_map = resolve_axis_map(problem, config)
    chip_grid = tuple(
        math.prod(sizes[a] for a in names) if names else 1
        for names in axis_map)
    return math.prod(chip_grid), chip_grid


#: backends whose executables realize ``par_vec`` (the streaming Pallas
#: kernels).  The others (engine/reference/distributed) run scalar-tick
#: code, so sweeping V for them would only distort the (bsize, par_time)
#: ranking and fill measured-tuning shortlists with V-duplicates.
PAR_VEC_BACKENDS = ("pallas", "pallas_interpret")

#: built-in backends that execute scalar ticks: a *pinned* ``par_vec > 1``
#: there would silently report (and price) a vector width the executable
#: never realizes, so ``plan()`` rejects it.  Custom registered backends
#: are unrestricted — they may well wrap the vectorized kernels.
SCALAR_TICK_BACKENDS = ("engine", "reference", "distributed")


def _candidate_shortlist(problem: StencilProblem, config: RunConfig,
                         device: Device, n_chips: int, chip_grid,
                         top_k: Optional[int] = None):
    """Model-ranked predictions (§5.3 pruning), best first.

    A pinned ``par_time``, ``bsize`` or ``par_vec`` constrains the sweep to
    exactly that value (the paper's tuned depths, e.g. 36, need not be
    powers of two); the free dimension(s) are enumerated, pruned by the
    VMEM budget and by geometric feasibility, and ranked by predicted run
    time.  ``par_vec`` is only swept for backends that realize it
    (:data:`PAR_VEC_BACKENDS`); elsewhere an unpinned V stays 1.  ``top_k``
    truncates to the shortlist the measured tuner times."""
    par_vec = config.par_vec
    if par_vec is None and config.backend not in PAR_VEC_BACKENDS:
        par_vec = 1
    cands = perf_model.autotune(
        problem.stencil, problem.shape, config.iters_hint, device,
        config.resolved_cell_bytes(problem.dtype),
        config.par_time_max, n_chips, chip_grid,
        par_time=config.par_time,
        bsize=config.normalized_bsize(problem.ndim),
        par_vec=par_vec, top_k=top_k,
        bc=problem.structural_bc)
    if not cands:
        raise ValueError(
            f"no VMEM-feasible (bsize, par_time, par_vec) for "
            f"{problem.stencil.name} "
            f"on {problem.shape} under {device.name} "
            f"(par_time={config.par_time}, bsize={config.bsize}, "
            f"par_vec={config.par_vec}, "
            f"par_time_max={config.par_time_max})")
    return cands


def _resolve_schedule(problem: StencilProblem, config: RunConfig,
                      device: Device, n_chips: int, chip_grid):
    """Pick (par_time, bsize, par_vec): explicit, or perf-model autotuned
    (§5.3).  An unpinned ``par_vec`` on a fully pinned schedule defaults to
    1 (today's scalar tick) rather than triggering a sweep."""
    par_time = config.par_time
    bsize = config.normalized_bsize(problem.ndim)
    if not config.autotune and par_time is not None and bsize is not None:
        return par_time, bsize, config.par_vec or 1, ()
    cands = _candidate_shortlist(problem, config, device, n_chips, chip_grid)
    best = cands[0].geom
    return best.par_time, best.bsize, best.par_vec, tuple(cands)


def _resolve_measured(problem: StencilProblem, config: RunConfig,
                      device: Device, n_chips: int, chip_grid):
    """autotune="measure": serve the schedule from the persistent cache, or
    time the model's shortlist on the real backend and persist the winner.

    Returns ``(par_time, bsize, par_vec, candidates, from_cache)`` where
    candidates are :class:`~repro.api.tuner.TunedCandidate`, measured-best
    first.
    """
    cache = schedule_cache.ScheduleCache.resolve(config.cache)
    key = schedule_cache.schedule_key(problem, config, device,
                                      n_chips, chip_grid)
    if cache is not None:
        entry = cache.get(key)
        if entry is not None:
            # The cache file is documented as hand-editable JSON: a mangled
            # or future-layout entry is a miss (re-tune), never a crash.
            try:
                par_time = int(entry["par_time"])
                bsize = tuple(int(b) for b in entry["bsize"])
                # pre-par_vec entries (or hand-written ones) mean V=1
                par_vec = int(entry.get("par_vec", 1))
                measured_s = float(entry["measured_s"])
                accuracy = float(entry["model_accuracy"])
                if (par_time < 1 or par_vec < 1
                        or len(bsize) != problem.ndim - 1
                        or any(b < 1 for b in bsize) or measured_s <= 0):
                    raise ValueError("mangled schedule-cache entry")
                pred = perf_model.predict(
                    problem.stencil, problem.shape, config.iters_hint, bsize,
                    par_time, device,
                    config.resolved_cell_bytes(problem.dtype),
                    n_chips, chip_grid,
                    bc=problem.structural_bc, par_vec=par_vec)
            except (KeyError, TypeError, ValueError):
                entry = None
            else:
                cand = tuner.TunedCandidate(
                    prediction=pred, measured_s=measured_s,
                    measured_run_time=measured_s * pred.n_super,
                    model_accuracy=accuracy, from_cache=True)
                return par_time, bsize, par_vec, (cand,), True
    shortlist = _candidate_shortlist(problem, config, device,
                                     n_chips, chip_grid,
                                     top_k=config.tune_top_k)
    tuned = tuner.measure_candidates(problem, config, shortlist)
    best = tuned[0]
    if cache is not None:
        cache.put(key, {
            "stencil": problem.stencil.name,
            "par_time": best.geom.par_time, "bsize": list(best.geom.bsize),
            "par_vec": best.geom.par_vec,
            "measured_s": best.measured_s,
            "model_accuracy": best.model_accuracy,
        })
    return best.geom.par_time, best.geom.bsize, best.geom.par_vec, tuned, False


def _validate_distributed(problem: StencilProblem, config: RunConfig) -> None:
    """Fail at plan time (not first ``run()``) when the mesh cannot shard the
    grid evenly — ``predict`` ceil-divides, so only this check catches it."""
    if config.backend != "distributed" or config.mesh is None:
        return
    from repro.core.distributed import shard_extents
    shard_extents(problem.shape, resolve_axis_map(problem, config),
                  config.mesh)


def plan(problem: StencilProblem, config: Optional[RunConfig] = None,
         ) -> "StencilPlan":
    """Compile ``problem`` under ``config`` into a reusable ``StencilPlan``."""
    if config is None:
        config = RunConfig()
    factory = get_backend(config.backend)       # fail fast on unknown names
    _validate_distributed(problem, config)
    device = config.resolved_device()
    n_chips, chip_grid = _chip_layout(problem, config)
    # The unblocked oracle ignores (bsize, par_time): an unresolvable or
    # invalid schedule degrades a 'reference' plan to geometry-less instead
    # of failing (legacy stencil_run never validated the oracle's schedule).
    geom, cands, from_cache = None, (), False
    try:
        if (config.par_vec is not None and config.par_vec > 1
                and config.backend in SCALAR_TICK_BACKENDS):
            # inside the try block: the reference oracle degrades schedule
            # errors to a geometry-less plan (legacy), the others raise
            raise ValueError(
                f"par_vec={config.par_vec} is a Pallas streaming-kernel "
                f"knob; backend={config.backend!r} executes scalar ticks "
                f"and cannot honor it — pin par_vec only for "
                f"{list(PAR_VEC_BACKENDS)} (or leave it unset)")
        if config.autotune == "measure":
            par_time, bsize, par_vec, cands, from_cache = _resolve_measured(
                problem, config, device, n_chips, chip_grid)
        else:
            par_time, bsize, par_vec, cands = _resolve_schedule(
                problem, config, device, n_chips, chip_grid)
        geom = BlockGeometry(problem.ndim, problem.shape,
                             problem.stencil.radius, par_time, tuple(bsize),
                             par_vec)
    except ValueError:
        if config.backend != "reference":
            raise
    program = as_program(factory(problem, config, geom))
    return StencilPlan(problem=problem, config=config, geometry=geom,
                       backend=config.backend, device=device,
                       n_chips=n_chips, chip_grid=chip_grid,
                       candidates=cands, _execute=program.execute,
                       _execute_batch=program.execute_batch,
                       tuned_from_cache=from_cache)


@dataclasses.dataclass
class StencilPlan:
    """A compiled, reusable executable for one (problem, config) pair."""
    problem: StencilProblem
    config: RunConfig
    geometry: Optional[BlockGeometry]
    backend: str
    device: Device
    n_chips: int
    chip_grid: Optional[tuple]
    #: autotuner candidates ranked best-first (empty when the schedule was
    #: pinned explicitly) — candidates[0] is the compiled schedule.  Model
    #: autotuning yields :class:`~repro.core.perf_model.Prediction`s;
    #: measured autotuning yields :class:`~repro.api.tuner.TunedCandidate`s
    #: carrying measured seconds and model accuracy per candidate.
    candidates: tuple
    _execute: ExecuteFn = dataclasses.field(repr=False)
    #: batched entry point (None for backends without one — ``run_batch``
    #: then falls back to a per-element loop)
    _execute_batch: Optional[ExecuteFn] = dataclasses.field(
        default=None, repr=False)
    #: True when the measured schedule was served by the persistent cache
    #: (no candidate was re-timed for this plan)
    tuned_from_cache: bool = False

    # --- execution ----------------------------------------------------------
    def run(self, grid, iters: int, coeffs=None, *,
            aux=None, checkpoint_every: Optional[int] = None,
            checkpoint_dir: Optional[str] = None) -> jnp.ndarray:
        """Advance ``grid`` by ``iters`` time-steps (program iterations —
        each applies every stage in order).

        ``coeffs`` defaults to :func:`~repro.core.stencils.default_coeffs`
        overlaid with any per-stage overrides; pass a dict (single-stage
        problems) or a sequence of per-stage dicts/None (programs) to
        override at run time.  ``aux`` is the Hotspot ``power`` grid
        (required iff any stage has an aux stream).  Multi-field programs
        take (and return) the ``(n_fields, *shape)`` field stack —
        ``problem.state_shape`` — fields in declaration order.  The plan is
        reusable: call ``run`` any number of times, with any ``iters``.

        ``checkpoint_every`` + ``checkpoint_dir`` make the run restartable
        (:func:`repro.resilience.run_checkpointed`): state is persisted
        atomically every (super-step-aligned) ``checkpoint_every``
        iterations, and a killed process that calls ``run`` again with the
        same directory resumes from the last complete step — the final grid
        is bit-identical to an uninterrupted run, even when the resume
        happens on a different mesh (the grid re-shards on entry)."""
        if (checkpoint_every is None) != (checkpoint_dir is None):
            raise ValueError("checkpoint_every and checkpoint_dir go "
                             "together — pass both or neither")
        if checkpoint_dir is not None:
            from repro.resilience.checkpoint_run import run_checkpointed
            return run_checkpointed(
                self, grid, iters, coeffs, aux=aux,
                checkpoint_every=checkpoint_every,
                checkpoint_dir=checkpoint_dir).grid
        grid = jnp.asarray(grid, self.problem.jnp_dtype)
        if tuple(grid.shape) != self.problem.state_shape:
            raise ValueError(f"grid shape {grid.shape} != problem state "
                             f"shape {self.problem.state_shape}")
        iters = int(iters)
        if iters < 0:
            raise ValueError(f"iters must be >= 0, got {iters}")
        coeffs = self._coeff_payload(coeffs)
        if self.problem.needs_aux:
            if aux is None:
                raise ValueError(f"{self.problem.stencil.name} needs an aux "
                                 "(power) grid")
            aux = jnp.asarray(aux, self.problem.jnp_dtype)
            if tuple(aux.shape) != self.problem.shape:
                raise ValueError(f"aux shape {aux.shape} != problem shape "
                                 f"{self.problem.shape}")
        elif aux is not None:
            raise ValueError(f"{self.problem.stencil.name} takes no aux grid")
        if iters == 0:
            return grid
        return self._execute(grid, coeffs, iters, aux)

    def _coeff_payload(self, coeffs):
        """Resolve run-time coefficients into the backend payload: a plain
        dict for single-stage problems (the legacy custom-backend contract),
        a tuple of per-stage dicts for programs.  The no-override payload is
        resolved once and memoized — it is the common case on the serving
        hot path, and re-resolving materializes fresh jnp scalars per call."""
        # coefficients are resolved in the ACCUMULATION dtype, not storage:
        # bf16 grids multiply f32 coefficients inside the f32 PE arithmetic
        # (repro.core.precision); for f32 problems the two dtypes coincide
        dtype = self.problem.accum_dtype
        if coeffs is None:
            cached = getattr(self, "_default_payload", None)
            if cached is None:
                resolved = self.problem.resolve_coeffs(None, dtype=dtype)
                cached = (resolved[0] if self.problem.n_stages == 1
                          else resolved)
                object.__setattr__(self, "_default_payload", cached)
            return cached
        resolved = self.problem.resolve_coeffs(coeffs, dtype=dtype)
        return resolved[0] if self.problem.n_stages == 1 else resolved

    def run_batch(self, grids, iters: int, coeffs=None, *,
                  aux=None) -> jnp.ndarray:
        """Advance a batch of grids ``(B, *shape)`` by ``iters`` time-steps
        through ONE compiled executable (the serving path).

        Unlike a Python loop of :meth:`run` calls — B dispatches, B sets of
        host round-trips — the whole batch advances in a single fused
        program: reference/engine vmap the super-step loop, pallas maps the
        batch inside one executable, distributed aggregates all members'
        halos into one exchange per mesh axis per super-step.  Results are
        bit-identical to the sequential loop.

        ``aux`` (Hotspot ``power``): one grid of ``shape`` shared by the
        whole batch, or a matching batch ``(B, *shape)``.  Backends without
        a batched entry point fall back to a per-element loop (correct, not
        fast)."""
        grids = jnp.asarray(grids, self.problem.jnp_dtype)
        shape = self.problem.state_shape
        if grids.ndim != len(shape) + 1 \
                or tuple(grids.shape[1:]) != shape:
            raise ValueError(f"run_batch needs grids of shape (B, *{shape}); "
                             f"got {tuple(grids.shape)}")
        if grids.shape[0] < 1:
            raise ValueError("run_batch needs a batch of at least 1 grid")
        iters = int(iters)
        if iters < 0:
            raise ValueError(f"iters must be >= 0, got {iters}")
        coeffs = self._coeff_payload(coeffs)
        if self.problem.needs_aux:
            if aux is None:
                raise ValueError(f"{self.problem.stencil.name} needs an aux "
                                 "(power) grid")
            aux = jnp.asarray(aux, self.problem.jnp_dtype)
            aux_ok = (self.problem.shape,
                      (grids.shape[0],) + self.problem.shape)
            if tuple(aux.shape) not in aux_ok:
                raise ValueError(
                    f"aux shape {tuple(aux.shape)} must be {aux_ok[0]} "
                    f"(shared) or {aux_ok[1]} (per-batch)")
        elif aux is not None:
            raise ValueError(f"{self.problem.stencil.name} takes no aux grid")
        if iters == 0:
            return grids
        if self._execute_batch is None:
            outs = [self._execute(
                grids[b], coeffs, iters,
                aux if aux is None or aux.ndim == self.problem.ndim
                else aux[b]) for b in range(grids.shape[0])]
            return jnp.stack(outs)
        return self._execute_batch(grids, coeffs, iters, aux)

    def prewarm(self, batch_sizes=(1,), *, iters: int = 1, coeffs=None,
                single: bool = True) -> dict:
        """Compile (and warm) the executables this plan will need, before
        traffic arrives.

        Until now warm-up was an undocumented side effect of the first
        ``run``/``run_batch`` call — the first request of every batch size
        paid the trace+compile cost.  ``prewarm`` makes it explicit: it
        pushes zero grids through ``run_batch`` for every size in
        ``batch_sizes`` (and through ``run`` when ``single=True``), which
        populates the process-level executable cache, so same-key plans —
        including this one — serve every listed batch size with zero new
        traces.  ``iters=1`` keeps each warming run to a single super-step.

        Aux-taking stencils warm the *per-batch* aux mode — each batch
        member carrying its own aux grid — because that is the mode the
        serving path uses (per-request aux grids stacked); a shared-aux
        ``run_batch`` call compiles its own executable on first use.

        Returns ``{"single": seconds} | {B: seconds}`` per warmed entry
        (compile + one warm execution each)."""
        import time as _time
        if int(iters) < 1:
            raise ValueError(f"prewarm iters must be >= 1, got {iters}")
        zeros = jnp.zeros(self.problem.state_shape, self.problem.jnp_dtype)
        aux = (jnp.zeros(self.problem.shape, self.problem.jnp_dtype)
               if self.problem.needs_aux else None)
        timings: dict = {}
        if single:
            t0 = _time.perf_counter()
            jax.block_until_ready(self.run(zeros, iters, coeffs, aux=aux))
            timings["single"] = _time.perf_counter() - t0
        for b in sorted({int(b) for b in batch_sizes}):
            if b < 1:
                raise ValueError(f"batch sizes must be >= 1, got {b}")
            aux_b = (jnp.zeros((b,) + self.problem.shape,
                               self.problem.jnp_dtype)
                     if self.problem.needs_aux else None)
            t0 = _time.perf_counter()
            jax.block_until_ready(self.run_batch(
                jnp.zeros((b,) + self.problem.state_shape,
                          self.problem.jnp_dtype),
                iters, coeffs, aux=aux_b))
            timings[b] = _time.perf_counter() - t0
        return timings

    # --- introspection ------------------------------------------------------
    def predicted(self, iters: Optional[int] = None,
                  device: Optional[Device] = None,
                  batch: int = 1) -> Prediction:
        """Performance-model :class:`Prediction` for this plan (paper §4).

        ``batch > 1`` models :meth:`run_batch`: per-problem traffic and
        compute scale with the batch, while the read-only aux stream (and
        the scalar coefficients) are loaded once for the whole batch."""
        geom = self._require_geometry("predicted()")
        return perf_model.predict(
            self.problem.stencil, self.problem.shape,
            iters if iters is not None else self.config.iters_hint,
            geom.bsize, geom.par_time, device or self.device,
            self.config.resolved_cell_bytes(self.problem.dtype),
            self.n_chips, self.chip_grid,
            batch=batch, bc=self.problem.structural_bc, par_vec=geom.par_vec)

    def traffic_report(self, iters: Optional[int] = None) -> dict:
        """Model traffic (paper Eq. 7/8) vs. the Pallas kernels' exact DMA
        schedule — the hardware-free 'model accuracy' of Table 4."""
        from repro.kernels.ops import dma_traffic_bytes
        geom = self._require_geometry("traffic_report()")
        st = self.problem.stencil
        cb = self.config.resolved_cell_bytes(self.problem.dtype)
        bc = self.problem.structural_bc
        # a periodic streaming axis is billed on the extended stream the
        # kernels actually move (the materialized wrap), matching predict()
        geom_t = extended_geometry(geom, bc)
        model = superstep_traffic_bytes(geom_t, st.num_read, st.num_write, cb)
        kernel = dma_traffic_bytes(st, geom, cb, bc=bc)
        report = {
            "model_bytes_per_superstep": model,
            "kernel_dma_bytes_per_superstep": kernel,
            "traffic_accuracy": model / kernel,
            "redundancy": geom.redundancy,
            "par_vec": geom.par_vec,
            "vmem_bytes": geom.vmem_bytes(
                cb, st.has_aux,
                stage_radii=getattr(st, "stage_radii", None),
                dag_info=(st.dag_vmem_info(geom.par_time, geom.par_vec)
                          if hasattr(st, "dag_vmem_info") else None)),
        }
        n_stages = self.problem.n_stages
        if n_stages > 1:
            # fusion accounting: the chained stages' intermediates live only
            # in the rolling VMEM windows — zero HBM round-trip bytes —
            # where S sequential single-stage plans would write and re-read
            # every intermediate once per program iteration
            cells = math.prod(self.problem.shape)
            report["stages"] = [
                {"name": s.name, "radius": s.stencil.radius,
                 "flop_pcu": s.stencil.flop_pcu, "bc": s.boundary.token()}
                for s in self.problem.stages]
            report["intermediate_hbm_bytes_per_superstep"] = 0
            report["unfused_intermediate_bytes_per_superstep"] = (
                2 * (n_stages - 1) * cells * cb * geom.par_time)
        if iters is not None:
            n_super = math.ceil(iters / geom.par_time)
            report["n_super"] = n_super
            report["model_bytes_total"] = model * n_super
            report["kernel_dma_bytes_total"] = kernel * n_super
        return report

    def describe(self) -> str:
        st = self.problem.stencil
        lines = [f"StencilPlan[{self.backend}] {st.name} "
                 f"{self.problem.shape} {self.problem.dtype} "
                 f"bc={self.problem.bc.token()}"]
        if self.problem.n_stages > 1:
            for i, s in enumerate(self.problem.stages):
                lines.append(f"  stage {i}: {s.name} rad={s.stencil.radius} "
                             f"flop_pcu={s.stencil.flop_pcu} "
                             f"bc={s.boundary.token()}")
        if self.geometry is not None:
            g = self.geometry
            lines.append(f"  schedule: bsize={g.bsize} par_time={g.par_time} "
                         f"par_vec={g.par_vec} "
                         f"csize={g.csize} bnum={g.bnum} "
                         f"redundancy={g.redundancy:.3f}")
            lines.append("  predicted: " + self.predicted().describe())
            if self.candidates and isinstance(self.candidates[0],
                                              tuner.TunedCandidate):
                lines.append("  measured:  " + self.candidates[0].describe())
        else:
            lines.append("  schedule: none (unblocked oracle)")
        if self.n_chips > 1:
            lines.append(f"  mesh: {self.n_chips} chips, "
                         f"chip_grid={self.chip_grid}")
        return "\n".join(lines)

    def _require_geometry(self, what: str) -> BlockGeometry:
        if self.geometry is None:
            raise ValueError(f"{what} needs a block geometry; this "
                             f"'{self.backend}' plan was built without a "
                             "feasible (bsize, par_time)")
        return self.geometry
