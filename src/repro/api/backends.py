"""Backend protocol + registry.

A *backend* is one executable implementation of the combined spatial/temporal
blocked computation.  It is registered as a factory::

    register_backend(name, factory)
    factory(problem: StencilProblem, config: RunConfig,
            geom: BlockGeometry | None) -> ExecuteFn
    ExecuteFn(grid, coeffs, iters, aux) -> grid

``plan()`` resolves the name through the registry, so adding a backend (GPU
Pallas, batched ensembles, ...) is one ``register_backend`` call — no
if/elif dispatch chain to edit.  The built-ins registered below:

  ``reference``         unblocked oracle (kernels/ref.py) — ground truth
  ``engine``            pure-JAX blocked engine (core/engine.py)
  ``pallas``            Pallas kernels compiled for TPU (kernels/stencil*.py)
  ``pallas_interpret``  same kernels, interpret mode (CPU-correctness)
  ``distributed``       shard_map runtime over ``config.mesh``
                        (core/distributed.py); the mesh is just config
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Protocol

import jax.numpy as jnp

from repro.core.blocking import BlockGeometry
from repro.api.config import RunConfig
from repro.api.problem import StencilProblem

#: (grid, coeffs, iters, aux) -> final grid
ExecuteFn = Callable[..., jnp.ndarray]


class Backend(Protocol):
    """Factory protocol every registered backend implements."""

    def __call__(self, problem: StencilProblem, config: RunConfig,
                 geom: Optional[BlockGeometry]) -> ExecuteFn:
        ...


_REGISTRY: Dict[str, Backend] = {}


def register_backend(name: str, factory: Backend, *,
                     overwrite: bool = False) -> None:
    """Register ``factory`` under ``name`` for use as ``RunConfig.backend``."""
    if not callable(factory):
        raise TypeError(f"backend factory for {name!r} is not callable")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[name] = factory


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; "
                         f"registered: {list_backends()}") from None


def list_backends() -> list:
    return sorted(_REGISTRY)


# --- built-in backends -------------------------------------------------------

def _reference_backend(problem, config, geom):
    from repro.kernels.ref import oracle_run
    st = problem.stencil

    def execute(grid, coeffs, iters, aux=None):
        return oracle_run(st, grid, coeffs, iters, aux)
    return execute


def _engine_backend(problem, config, geom):
    from repro.core.engine import run_blocked
    st = problem.stencil
    par_time, bsize = geom.par_time, geom.bsize

    def execute(grid, coeffs, iters, aux=None):
        return run_blocked(st, grid, coeffs, iters, par_time, bsize, aux)
    return execute


def _make_pallas_backend(force_interpret: bool):
    def factory(problem, config, geom):
        from repro.kernels.ops import pack_coeffs, run_pallas
        if problem.jnp_dtype != jnp.float32:
            raise ValueError("the Pallas kernels are f32-only "
                             f"(problem.dtype={problem.dtype})")
        st = problem.stencil
        interpret = force_interpret or config.interpret

        def execute(grid, coeffs, iters, aux=None):
            return run_pallas(st, geom, grid, pack_coeffs(st, coeffs),
                              iters, aux, interpret)
        return execute
    return factory


def resolve_axis_map(problem: StencilProblem, config: RunConfig):
    """The grid-axis -> mesh-axes decomposition the distributed backend uses.

    Default when ``config.axis_map`` is unset: shard the streaming axis over
    every mesh axis, replicate the blocked axes."""
    if config.mesh is None:
        raise ValueError("backend='distributed' needs config.mesh "
                         "(and optionally config.axis_map)")
    if config.axis_map is not None:
        if len(config.axis_map) != problem.ndim:
            raise ValueError(f"axis_map {config.axis_map} must have one entry "
                             f"per grid axis ({problem.ndim})")
        return config.axis_map
    return (tuple(config.mesh.axis_names),) + (None,) * (problem.ndim - 1)


def _distributed_backend(problem, config, geom):
    from repro.core.distributed import build_distributed_fn
    st = problem.stencil
    mesh = config.mesh
    axis_map = resolve_axis_map(problem, config)
    par_time, bsize = geom.par_time, geom.bsize
    compiled: Dict[int, Callable] = {}    # one shard_map program per iters

    def execute(grid, coeffs, iters, aux=None):
        fn = compiled.get(iters)
        if fn is None:
            fn = build_distributed_fn(st, problem.shape, iters, par_time,
                                      bsize, mesh, axis_map)
            compiled[iters] = fn
        aux_in = aux if aux is not None else jnp.zeros((), jnp.float32)
        return fn(grid, aux_in, coeffs)
    return execute


register_backend("reference", _reference_backend)
register_backend("engine", _engine_backend)
register_backend("pallas", _make_pallas_backend(force_interpret=False))
register_backend("pallas_interpret", _make_pallas_backend(force_interpret=True))
register_backend("distributed", _distributed_backend)
