"""Backend protocol + registry + process-level executable cache.

A *backend* is one executable implementation of the combined spatial/temporal
blocked computation.  It is registered as a factory::

    register_backend(name, factory)
    factory(problem: StencilProblem, config: RunConfig,
            geom: BlockGeometry | None) -> ExecuteFn | BackendProgram
    ExecuteFn(grid, coeffs, iters, aux) -> grid

``plan()`` resolves the name through the registry, so adding a backend (GPU
Pallas, batched ensembles, ...) is one ``register_backend`` call — no
if/elif dispatch chain to edit.  A factory may return a bare ``ExecuteFn``
(legacy/custom backends) or a :class:`BackendProgram` that additionally
carries a batched entry point; ``plan()`` normalizes via :func:`as_program`.
The built-ins registered below:

  ``reference``         unblocked oracle (kernels/ref.py) — ground truth
  ``engine``            pure-JAX blocked engine (core/engine.py)
  ``pallas``            Pallas kernels compiled for TPU (kernels/stencil*.py)
  ``pallas_interpret``  same kernels, interpret mode (CPU-correctness)
  ``distributed``       shard_map runtime over ``config.mesh``
                        (core/distributed.py); the mesh is just config

Throughput subsystem (the ROADMAP's serving path)
-------------------------------------------------
Every built-in compiles through a **process-level executable cache**: one
compiled program per

    (kind, stencil fingerprint, shape, dtype, geometry, iters-shape class,
     batch size, aux mode, backend specifics)

key, shared by every plan in the process.  ``iters`` is always passed into
the executable as a *dynamic* scalar (iters class ``"dyn"``): the super-step
trip count is computed in-trace, so repeated ``plan().run()`` calls with
different iteration counts — the serving pattern — never re-trace.  This
generalizes the distributed backend's old per-``iters`` compiled dict to all
backends.  ``RunConfig.exec_cache=False`` opts a plan out (it gets private
executables); ``clear_exec_cache()`` resets the process.

Tracing is observable: each cached program bumps ``TRACE_COUNTS[tag]`` when
its Python body is (re)traced, so tests — and operators — can verify that a
cache hit really skipped a trace.

Batched execution (``StencilPlan.run_batch``) compiles ONE executable over a
leading batch axis:

  * reference/engine vmap the fused super-step loop (the blocked update is
    data-parallel across batch members);
  * pallas maps the batch *sequentially inside one executable*
    (``lax.map``) — ``vmap`` over the manual-DMA kernels silently corrupts
    the per-block DMA offsets (verified), and sequential mapping preserves
    each kernel instance's exact DMA schedule while still amortizing
    dispatch and compile across the batch;
  * distributed replicates the batch axis over the mesh and aggregates all
    batch members' halos into one exchange per mesh axis per super-step.

Buffer donation (``RunConfig.donate``): the pallas backends stage an
edge-padded copy of the grid, run the whole super-step loop on it, and slice
once at the end — the padded carry is backend-owned, so it is donated to XLA
(``donate_argnums``) and reused in place across the loop.  Caller arrays are
never donated: a plan stays reusable and ``run``/``run_batch`` never
invalidate their inputs.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, Optional, Protocol, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.blocking import BlockGeometry
from repro.api.config import RunConfig
from repro.api.problem import StencilProblem
from repro.resilience.faults import (corrupt_point, fault_point,
                                     register_point)

#: (grid, coeffs, iters, aux) -> final grid
ExecuteFn = Callable[..., jnp.ndarray]

# --- fault-injection seams (repro.resilience; no-ops with no plan active) ----
FP_EXECUTE = register_point(
    "backend.execute", "before any backend's single-grid execute")
FP_EXECUTE_RESULT = register_point(
    "backend.execute.result", "a single-grid result passes through "
    "(action='nan' poisons it)")
FP_EXECUTE_BATCH = register_point(
    "backend.execute_batch", "before any backend's batched execute")
FP_EXECUTE_BATCH_RESULT = register_point(
    "backend.execute_batch.result", "a batched result passes through "
    "(action='nan' + member=i poisons one member)")
FP_EXEC_CACHE = register_point(
    "exec_cache.get", "on every process-level executable-cache lookup")

#: dtypes the Pallas streaming kernels support (plan-time validation):
#: f32, and bf16 storage with f32 accumulation inside the PE chain — see
#: ``repro.core.precision`` for the policy and ``kernels/builder.py`` for
#: the window-read / output-DMA casts that implement it
PALLAS_SUPPORTED_DTYPES = ("float32", "bfloat16")


class Backend(Protocol):
    """Factory protocol every registered backend implements."""

    def __call__(self, problem: StencilProblem, config: RunConfig,
                 geom: Optional[BlockGeometry]
                 ) -> Union[ExecuteFn, "BackendProgram"]:
        ...


@dataclasses.dataclass
class BackendProgram:
    """What a backend factory hands ``plan()``: the unbatched entry point,
    plus (optionally) a batched one.

    ``execute_batch(grids, coeffs, iters, aux)`` takes grids with a leading
    batch axis ``(B, *shape)``; ``aux`` may be ``None``, one shared grid of
    ``shape``, or a batch of ``(B, *shape)``.  Backends that do not provide
    it (``execute_batch=None``) still serve ``StencilPlan.run_batch`` via a
    per-element fallback loop."""
    execute: ExecuteFn
    execute_batch: Optional[ExecuteFn] = None


def as_program(obj: Union[ExecuteFn, BackendProgram]) -> BackendProgram:
    """Normalize a factory's return value (bare callable or program), and
    thread the resilience seams through it: every backend — built-in or
    custom-registered — gets the ``backend.execute*`` injection points for
    free, so the whole failure matrix is testable against any of them."""
    if isinstance(obj, BackendProgram):
        program = obj
    elif callable(obj):
        program = BackendProgram(execute=obj)
    else:
        raise TypeError(f"backend factory returned {type(obj).__name__}; "
                        "expected a callable or BackendProgram")
    return _instrument(program)


def _instrument(program: BackendProgram) -> BackendProgram:
    """Wrap the entry points with their fault seams (idempotent)."""
    if getattr(program.execute, "_fault_instrumented", False):
        return program
    inner, inner_batch = program.execute, program.execute_batch

    def execute(grid, coeffs, iters, aux=None):
        fault_point(FP_EXECUTE)
        return corrupt_point(FP_EXECUTE_RESULT,
                             inner(grid, coeffs, iters, aux))
    execute._fault_instrumented = True

    execute_batch = None
    if inner_batch is not None:
        def execute_batch(grids, coeffs, iters, aux=None):
            fault_point(FP_EXECUTE_BATCH, {"batch": grids.shape[0]})
            return corrupt_point(FP_EXECUTE_BATCH_RESULT,
                                 inner_batch(grids, coeffs, iters, aux),
                                 {"batch": grids.shape[0]})
        execute_batch._fault_instrumented = True

    return BackendProgram(execute=execute, execute_batch=execute_batch)


_REGISTRY: Dict[str, Backend] = {}


def register_backend(name: str, factory: Backend, *,
                     overwrite: bool = False) -> None:
    """Register ``factory`` under ``name`` for use as ``RunConfig.backend``."""
    if not callable(factory):
        raise TypeError(f"backend factory for {name!r} is not callable")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[name] = factory


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; "
                         f"registered: {list_backends()}") from None


def list_backends() -> list:
    return sorted(_REGISTRY)


# --- process-level executable cache ------------------------------------------

_EXEC_CACHE: Dict[tuple, Callable] = {}
_EXEC_STATS = {"hits": 0, "misses": 0}
#: per-key hit/miss breakdown — the global totals cannot distinguish "one
#: hot executable" from "N executables each compiled once" (batch-fill vs
#: cache-thrash); this can, and the serving metrics snapshot exports it
_EXEC_KEY_STATS: Dict[tuple, Dict[str, int]] = {}

#: how many times each cached program's Python body was (re)traced — the
#: observable proof that an executable-cache hit skipped a re-trace
TRACE_COUNTS: "collections.Counter[str]" = collections.Counter()


def _note_trace(tag: str) -> None:
    """Called from *inside* a to-be-jitted body: runs once per trace, never
    per execution, so it counts exactly the re-traces."""
    TRACE_COUNTS[tag] += 1


def _key_str(key: tuple) -> str:
    """Human-scannable rendering of an executable-cache key for reports
    (the raw tuple mixes nested tuples and tagged strings)."""
    return " ".join(str(part) for part in key)


def exec_cache_stats() -> dict:
    """Executable-cache observability: entry count, hit/miss totals, the
    per-backend trace counts, and the per-key hit/miss breakdown
    (``by_key``) — so a metrics snapshot can tell a saturated hot program
    from a thrashing key population."""
    return {"size": len(_EXEC_CACHE), "hits": _EXEC_STATS["hits"],
            "misses": _EXEC_STATS["misses"], "traces": dict(TRACE_COUNTS),
            "by_key": {_key_str(k): dict(v)
                       for k, v in _EXEC_KEY_STATS.items()}}


def clear_exec_cache() -> None:
    """Drop every cached executable and reset the counters (tests; or to
    release compiled programs in a long-lived process)."""
    _EXEC_CACHE.clear()
    _EXEC_STATS["hits"] = 0
    _EXEC_STATS["misses"] = 0
    _EXEC_KEY_STATS.clear()
    TRACE_COUNTS.clear()


def _program_cache(use_cache: bool) -> Callable:
    """Program lookup for one factory: the process-level cache when enabled,
    else a private per-plan dict — an opted-out plan gets executables no
    other plan can see, but must still never rebuild (re-trace) one on every
    call."""
    if use_cache:
        def get(key, build):
            fault_point(FP_EXEC_CACHE, {"key": key})
            per_key = _EXEC_KEY_STATS.setdefault(
                key, {"hits": 0, "misses": 0})
            fn = _EXEC_CACHE.get(key)
            if fn is None:
                _EXEC_STATS["misses"] += 1
                per_key["misses"] += 1
                fn = _EXEC_CACHE[key] = build()
            else:
                _EXEC_STATS["hits"] += 1
                per_key["hits"] += 1
            return fn
    else:
        local: Dict[tuple, Callable] = {}

        def get(key, build):
            fn = local.get(key)
            if fn is None:
                fn = local[key] = build()
            return fn
    return get


def _exec_key(kind: str, problem: StencilProblem,
              geom: Optional[BlockGeometry], *,
              batch=None, aux_mode=None, extra: Tuple = ()) -> tuple:
    """Cache key: everything that determines the compiled program.

    ``iters`` never appears — every program takes it as a dynamic scalar
    (iters-shape class ``"dyn"``), which is exactly what makes the cache
    worth having for serving loops."""
    from repro.api.schedule_cache import stencil_fingerprint
    # par_vec changes the compiled kernel's window layout, DMA schedule and
    # stream padding: a V=8 executable must never serve a V=1 plan
    gsig = (None if geom is None
            else (geom.par_time, geom.bsize, geom.par_vec))
    # the BC changes the compiled program (pad modes, re-imposition tables,
    # the periodic stream extension): it MUST split the cache key, or a
    # clamp-compiled program would serve a periodic plan
    return (kind, problem.stencil.name, stencil_fingerprint(problem.stencil),
            problem.shape, problem.dtype, f"bc={problem.bc.token()}", gsig,
            "iters=dyn", batch, aux_mode, *extra)


def _aux_mode(problem: StencilProblem, aux) -> Optional[str]:
    """``None`` (no aux) | ``"shared"`` (one grid) | ``"batched"`` (B grids).
    The plan validates shapes before execution; this only classifies."""
    if aux is None:
        return None
    return "batched" if aux.ndim == problem.ndim + 1 else "shared"


def _donate_ok(config: RunConfig) -> bool:
    """Donation is requested AND the platform implements it (CPU does not —
    donating there only emits warnings)."""
    return config.donate and jax.default_backend() in ("tpu", "gpu")


# --- built-in backends -------------------------------------------------------

def _vmapped_program(kind: str, problem, config, key_geom,
                     body: Callable) -> BackendProgram:
    """Shared scaffolding for backends whose batched form is a vmap of the
    single-grid ``body(grid, coeffs, iters, aux)``: reference (unblocked
    oracle) and engine (fused blocked loop)."""
    get = _program_cache(config.exec_cache)
    single = get(_exec_key(kind, problem, key_geom), lambda: jax.jit(body))

    def execute(grid, coeffs, iters, aux=None):
        return single(grid, coeffs, jnp.asarray(iters, jnp.int32), aux)

    def execute_batch(grids, coeffs, iters, aux=None):
        mode = _aux_mode(problem, aux)
        key = _exec_key(kind, problem, key_geom,
                        batch=grids.shape[0], aux_mode=mode)
        fn = get(key, lambda: jax.jit(jax.vmap(
            body, in_axes=(0, None, None, 0 if mode == "batched" else None))))
        return fn(grids, coeffs, jnp.asarray(iters, jnp.int32), aux)

    return BackendProgram(execute, execute_batch)


def _dag_coeffs(coeffs):
    """Normalize the plan's coefficient payload for the DAG executors: a
    single-stage DAG program gets a bare dict from ``_coeff_payload`` (the
    legacy contract) — the executors always take one dict per stage."""
    return coeffs if isinstance(coeffs, tuple) else (coeffs,)


def _reference_backend(problem, config, geom):
    if problem.is_dag:
        from repro.kernels.ref import oracle_dag_run
        dag = problem.exec_dag

        def body(grid, coeffs, iters, aux):
            _note_trace("reference")
            return oracle_dag_run(dag, grid, _dag_coeffs(coeffs), iters, aux)
    elif problem.n_stages > 1:
        from repro.kernels.ref import oracle_program_run
        stages = problem.exec_stages

        def body(grid, coeffs, iters, aux):
            _note_trace("reference")
            return oracle_program_run(stages, grid, coeffs, iters, aux)
    else:
        from repro.kernels.ref import oracle_run
        st, bc = problem.exec_stages[0]

        def body(grid, coeffs, iters, aux):
            _note_trace("reference")
            return oracle_run(st, grid, coeffs, iters, aux, bc=bc)

    # the oracle ignores blocking: key by problem only, not geometry
    return _vmapped_program("reference", problem, config, None, body)


def _engine_backend(problem, config, geom):
    if problem.is_dag:
        from repro.core.engine import superstep_loop_dag
        dag = problem.exec_dag

        def body(grid, coeffs, iters, aux):
            _note_trace("engine")
            return superstep_loop_dag(dag, geom, grid, _dag_coeffs(coeffs),
                                      iters, aux)
    elif problem.n_stages > 1:
        from repro.core.engine import superstep_loop_chain
        stages = problem.exec_stages

        def body(grid, coeffs, iters, aux):
            _note_trace("engine")
            return superstep_loop_chain(stages, geom, grid, coeffs, iters,
                                        aux)
    else:
        from repro.core.engine import superstep_loop
        st, bc = problem.exec_stages[0]

        def body(grid, coeffs, iters, aux):
            _note_trace("engine")
            return superstep_loop(st, geom, grid, coeffs, iters, aux, bc=bc)

    return _vmapped_program("engine", problem, config, geom, body)


def _make_pallas_backend(force_interpret: bool):
    def factory(problem, config, geom):
        from repro.kernels.ops import (fused_chain_loop, fused_dag_loop,
                                       fused_superstep_loop, pack_coeffs,
                                       pack_dag_coeffs, pack_program_coeffs,
                                       _pad_blocked)
        # plan-time validation (satellite bugfix): fail before any execute,
        # and say what IS supported
        if problem.dtype not in PALLAS_SUPPORTED_DTYPES:
            raise ValueError(
                f"the Pallas kernels support dtypes "
                f"{list(PALLAS_SUPPORTED_DTYPES)}; "
                f"got problem.dtype={problem.dtype!r} — use the 'engine' or "
                f"'reference' backend for other dtypes")
        bc = problem.structural_bc   # sizes padding + the stream extension
        interpret = force_interpret or config.interpret
        tag = "pallas_interpret" if interpret else "pallas"
        get = _program_cache(config.exec_cache)
        donate = _donate_ok(config)
        # Megacore opt-in recompiles the kernel grid's dimension semantics:
        # it must split the executable cache alongside donation
        mc = config.block_parallel
        extra = ("donate", donate, "mc", mc)

        if problem.is_dag:
            dag = problem.exec_dag

            def run_loop(gp, coeffs_packed, iters, aux_p):
                return fused_dag_loop(dag, geom, gp, coeffs_packed,
                                      iters, aux_p, interpret,
                                      block_parallel=mc)

            def pack(coeffs):
                return pack_dag_coeffs(dag, _dag_coeffs(coeffs))
        elif problem.n_stages > 1:
            stages = problem.exec_stages

            def run_loop(gp, coeffs_packed, iters, aux_p):
                return fused_chain_loop(stages, geom, gp, coeffs_packed,
                                        iters, aux_p, interpret,
                                        block_parallel=mc)

            def pack(coeffs):
                return pack_program_coeffs(stages, coeffs)
        else:
            st, bc1 = problem.exec_stages[0]

            def run_loop(gp, coeffs_packed, iters, aux_p):
                return fused_superstep_loop(st, geom, gp, coeffs_packed,
                                            iters, aux_p, interpret, bc1,
                                            block_parallel=mc)

            def pack(coeffs):
                return pack_coeffs(st, coeffs)

        def loop_body(gp, coeffs_packed, iters, aux_p):
            # gp is the backend-owned padded carry: safe to donate
            _note_trace(tag)
            return run_loop(gp, coeffs_packed, iters, aux_p)

        def build_single():
            return jax.jit(loop_body,
                           donate_argnums=(0,) if donate else ())

        single = get(_exec_key(tag, problem, geom, extra=extra),
                     build_single)

        def execute(grid, coeffs, iters, aux=None):
            gp = _pad_blocked(grid, geom, bc)
            aux_p = _pad_blocked(aux, geom, bc) if aux is not None else None
            return single(gp, pack(coeffs),
                          jnp.asarray(iters, jnp.int32), aux_p)

        def build_batch(mode):
            # vmap over the manual-DMA pallas_call mis-addresses the per-block
            # DMAs (wrong results, verified empirically) — map the batch
            # sequentially INSIDE one executable instead: one dispatch, one
            # compile, exact per-instance DMA schedules.
            def batched(gps, coeffs_packed, iters, aux_p):
                _note_trace(tag)
                if mode == "batched":
                    return jax.lax.map(
                        lambda ga: run_loop(ga[0], coeffs_packed, iters,
                                            ga[1]),
                        (gps, aux_p))
                return jax.lax.map(
                    lambda g: run_loop(g, coeffs_packed, iters, aux_p),
                    gps)
            return jax.jit(batched, donate_argnums=(0,) if donate else ())

        def execute_batch(grids, coeffs, iters, aux=None):
            mode = _aux_mode(problem, aux)
            key = _exec_key(tag, problem, geom, batch=grids.shape[0],
                            aux_mode=mode, extra=extra)
            fn = get(key, lambda: build_batch(mode))
            gps = _pad_blocked(grids, geom, bc)
            aux_p = _pad_blocked(aux, geom, bc) if aux is not None else None
            return fn(gps, pack(coeffs),
                      jnp.asarray(iters, jnp.int32), aux_p)

        return BackendProgram(execute, execute_batch)
    return factory


def resolve_axis_map(problem: StencilProblem, config: RunConfig):
    """The grid-axis -> mesh-axes decomposition the distributed backend uses.

    Default when ``config.axis_map`` is unset: shard the streaming axis over
    every mesh axis, replicate the blocked axes."""
    if config.mesh is None:
        raise ValueError("backend='distributed' needs config.mesh "
                         "(and optionally config.axis_map)")
    if config.axis_map is not None:
        if len(config.axis_map) != problem.ndim:
            raise ValueError(f"axis_map {config.axis_map} must have one entry "
                             f"per grid axis ({problem.ndim})")
        return config.axis_map
    return (tuple(config.mesh.axis_names),) + (None,) * (problem.ndim - 1)


def _mesh_sig(mesh) -> tuple:
    """Mesh identity for the executable cache.  Structure alone is not enough
    (two same-shape meshes over different devices need different programs),
    so the object id is included — at worst an id reuse costs a re-build,
    never a wrong-mesh program, because the id is paired with structure."""
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape), id(mesh))


def _distributed_backend(problem, config, geom):
    from repro.core.distributed import build_distributed_fn
    st = problem.stencil
    mesh = config.mesh
    axis_map = resolve_axis_map(problem, config)
    par_time, bsize = geom.par_time, geom.bsize
    get = _program_cache(config.exec_cache)
    base_key = ("mesh", _mesh_sig(mesh), "amap", axis_map)

    def build(batch, aux_batched):
        return build_distributed_fn(
            st, problem.shape, None, par_time, bsize, mesh, axis_map,
            batch=batch, aux_batched=aux_batched,
            trace_hook=lambda: _note_trace("distributed"),
            bc=problem.structural_bc,
            stages=(problem.exec_stages
                    if problem.n_stages > 1 and not problem.is_dag else None),
            dag=problem.exec_dag if problem.is_dag else None)

    def execute(grid, coeffs, iters, aux=None):
        # built lazily on first call (not at plan time): plan() must stay
        # executable-free for the distributed backend so schedulers can plan
        # against a mesh description without touching real devices
        single = get(_exec_key("distributed", problem, geom, extra=base_key),
                     lambda: build(False, False))
        aux_in = aux if aux is not None else jnp.zeros((), jnp.float32)
        return single(grid, aux_in, coeffs, jnp.asarray(iters, jnp.int32))

    def execute_batch(grids, coeffs, iters, aux=None):
        mode = _aux_mode(problem, aux)
        key = _exec_key("distributed", problem, geom, batch=grids.shape[0],
                        aux_mode=mode, extra=base_key)
        fn = get(key, lambda: build(True, mode == "batched"))
        aux_in = aux if aux is not None else jnp.zeros((), jnp.float32)
        return fn(grids, aux_in, coeffs, jnp.asarray(iters, jnp.int32))

    return BackendProgram(execute, execute_batch)


register_backend("reference", _reference_backend)
register_backend("engine", _engine_backend)
register_backend("pallas", _make_pallas_backend(force_interpret=False))
register_backend("pallas_interpret", _make_pallas_backend(force_interpret=True))
register_backend("distributed", _distributed_backend)
