from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               cosine_schedule, global_norm)
from repro.optim.compression import (ef_compress_update, init_ef_state)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "ef_compress_update", "init_ef_state"]
