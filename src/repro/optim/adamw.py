"""AdamW + global-norm clipping + cosine schedule (pure JAX, shard-friendly).

Moments are f32 regardless of param dtype (bf16 training keeps f32 master
weights in the optimizer state); every state leaf inherits the param's
sharding spec, so ZeRO-style sharding falls out of the param spec tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any          # f32 master copy (None leaves if params already f32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(
        lambda p: p.astype(jnp.float32) if p.dtype != jnp.float32 else None,
        params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros), master)


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr * (update + cfg.weight_decay * base)
        new_p = new.astype(p.dtype)
        new_master = new if master is not None else None
        return new_p, m2, v2, new_master

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    flat_ma = tdef.flatten_up_to(state.master)
    outs = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v,
                                       flat_ma)]
    new_p = tdef.unflatten([o[0] for o in outs])
    new_m = tdef.unflatten([o[1] for o in outs])
    new_v = tdef.unflatten([o[2] for o in outs])
    new_ma = tdef.unflatten([o[3] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v, new_ma), metrics
