"""Error-feedback gradient compression for cross-pod (DCN) reduction.

At 1000+ nodes the slow axis is the cross-pod gradient all-reduce. We provide
EF21-style compression: per-leaf top-k magnitude sparsification (+ int8
quantization of the kept values), with the residual fed back into the next
step. The compressed representation is what would cross the DCN; the local
(fast, ICI) reduction stays exact.

Usage (see train.fault-tolerant loop): compress per-pod-aggregated grads,
all-reduce the compressed values over 'pod', decompress, apply.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_ef_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_mask(x: jnp.ndarray, keep_ratio: float) -> jnp.ndarray:
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(flat.size * keep_ratio))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def _quant_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_update(grads, ef_state, keep_ratio: float = 0.05,
                       quantize: bool = True):
    """Compress (grads + residual); return (compressed-decompressed grads,
    new residual, wire-bytes estimate).

    The returned grads are the values a receiver reconstructs; reducing them
    across pods is equivalent to reducing the compressed messages.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        mask = _topk_mask(gf, keep_ratio)
        kept = gf * mask
        if quantize:
            q, scale = _quant_int8(kept)
            kept = _dequant(q, scale) * mask
        residual = gf - kept
        wire = jnp.asarray(mask.sum() * (1 if quantize else 4)
                           + 4 * jnp.ceil(mask.sum() / 8), jnp.float32)
        return kept.astype(g.dtype), residual, wire

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = tdef.unflatten([o[0] for o in outs])
    new_ef = tdef.unflatten([o[1] for o in outs])
    wire_bytes = sum(o[2] for o in outs)
    return comp, new_ef, wire_bytes
