"""Pallas TPU flash attention (fwd + bwd) — the LM-side hot-spot kernel.

This is the stencil paper's insight applied to attention: the (Sq, Skv)
score matrix is the "grid", and materializing it to HBM is what kills the
memory roofline term (measured: ~4 TB/device/step of score traffic on
granite-3-8b train_4k — EXPERIMENTS.md §Perf). The kernel tiles Q into
VMEM blocks (spatial blocking), streams KV tiles through a running online
softmax (the rolling-window/temporal dimension), and writes only the
(Sq, D) output — one HBM round-trip for the whole operator:

    HBM traffic: read Q + K + V (+dO, O, lse for bwd), write O (dQ,dK,dV)
    vs XLA chunked attention: s/p tiles cross HBM once per chunk pair.

Layout/tiling choices (TPU-native, not a GPU port):
  * block_q x d_head tiles sit in VMEM as (block_q, d_head) f32; MXU dims
    are d_head = 128-multiples; block_kv is a lane-aligned 128-multiple.
  * grid = (batch*heads, Sq/block_q); the kv loop is a fori_loop *inside*
    the kernel with `pl.when` causal skipping (block-level the same trick
    as the paper's "compute halos redundantly, mask only writes").
  * GQA: K/V are indexed by head-group via the BlockSpec index_map — no
    repeated K/V materialization (XLA path pays a G-times K/V blow-up).
  * backward recomputes s/p per tile pair (flash-2 style: no (Sq,Skv)
    residual; only O, lse, and the row-sum delta are read back).

Validated in interpret mode against ``ref_attention`` (tests/test_flash.py)
over shape/dtype/causal/GQA sweeps.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512


def ref_attention(q, k, v, *, causal: bool = True):
    """Pure-jnp oracle: q (B,Sq,H,D); k,v (B,Skv,Hkv,D), GQA-aware."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        Skv = k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)


# --- forward kernel ----------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *,
                causal: bool, block_kv: int, skv: int, scale: float):
    """One (batch*head, q-block) program: stream kv blocks, online softmax.

    q_ref (Bq, D); k_ref/v_ref (Skv, D) in ANY/VMEM; o_ref (Bq, D);
    lse_ref (Bq, 1).
    """
    Bq, D = q_ref.shape
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    nkv = skv // block_kv

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(j * block_kv, block_kv), :].astype(jnp.float32)
        v = v_ref[pl.ds(j * block_kv, block_kv), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * Bq + jax.lax.broadcasted_iota(jnp.int32, (Bq, block_kv), 0)
            kpos = j * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (Bq, block_kv), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m2 = jnp.maximum(m, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m - m2)
        p = jnp.exp(s - m2)
        l2 = l * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc2 = acc * corr + pv
        return m2, l2, acc2

    m0 = jnp.full((Bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Bq, 1), jnp.float32)
    a0 = jnp.zeros((Bq, D), jnp.float32)
    if causal:
        # block-level early exit: kv blocks fully above the diagonal of this
        # q block contribute nothing (paper's "control only the writes",
        # lifted to control flow since whole blocks are skippable)
        last = (qi + 1) * Bq  # first kv index NOT needed
        nkv_eff = jnp.minimum(nkv, pl.cdiv(last, block_kv))
    else:
        nkv_eff = nkv
    m, l, acc = jax.lax.fori_loop(0, nkv_eff, body, (m0, l0, a0))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
    lse_ref[...] = lse.astype(lse_ref.dtype)


def _flash_fwd_pallas(q, k, v, causal: bool, block_q: int, block_kv: int,
                      interpret: bool):
    """q (B,Sq,H,D); k/v (B,Skv,Hkv,D) -> (o, lse)."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    scale = D ** -0.5

    # (B,S,H,D) -> (B*H, S, D) program-major layout
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)

    grid = (B * H, Sq // block_q)
    kernel = functools.partial(_fwd_kernel, causal=causal,
                               block_kv=block_kv, skv=Skv, scale=scale)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, Skv, D), lambda h, i, G=G: (h // G, 0, 0)),
            pl.BlockSpec((None, Skv, D), lambda h, i, G=G: (h // G, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, block_q, 1), lambda h, i: (h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((B * H, Sq, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(qt, kt, vt)
    o = o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    lse = lse.reshape(B, H, Sq)
    return o, lse


# --- backward kernels --------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dlt_ref, dq_ref, *,
                   causal: bool, block_kv: int, skv: int, scale: float):
    Bq, D = q_ref.shape
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...].astype(jnp.float32)
    dlt = dlt_ref[...].astype(jnp.float32)
    nkv = skv // block_kv

    def body(j, dq):
        k = k_ref[pl.ds(j * block_kv, block_kv), :].astype(jnp.float32)
        v = v_ref[pl.ds(j * block_kv, block_kv), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * Bq + jax.lax.broadcasted_iota(jnp.int32, (Bq, block_kv), 0)
            kpos = j * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (Bq, block_kv), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dlt)
        return dq + jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    if causal:
        nkv_eff = jnp.minimum(nkv, pl.cdiv((qi + 1) * Bq, block_kv))
    else:
        nkv_eff = nkv
    dq = jax.lax.fori_loop(0, nkv_eff, body,
                           jnp.zeros((Bq, D), jnp.float32))
    dq_ref[...] = (dq * scale).astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dlt_ref,
                    dk_ref, dv_ref, *, causal: bool, block_q: int, sq: int,
                    scale: float):
    Bk, D = k_ref.shape
    ki = pl.program_id(1)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    nq = sq // block_q

    def body(i, carry):
        dk, dv = carry
        q = q_ref[pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        do = do_ref[pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        lse = lse_ref[pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        dlt = dlt_ref[pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, Bk), 0)
            kpos = ki * Bk + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, Bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse)
        dv2 = dv + jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dlt)
        dk2 = dk + jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
        return dk2, dv2

    if causal:
        # q blocks strictly above this kv block's diagonal see none of it
        first = (ki * Bk) // block_q
    else:
        first = 0
    dk0 = jnp.zeros((Bk, D), jnp.float32)
    dv0 = jnp.zeros((Bk, D), jnp.float32)
    dk, dv = jax.lax.fori_loop(first, nq, body, (dk0, dv0))
    dk_ref[...] = (dk * scale).astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _flash_bwd_pallas(q, k, v, o, lse, do, causal: bool, block_q: int,
                      block_kv: int, interpret: bool):
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    scale = D ** -0.5

    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                               # (B,Sq,H)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    dot = do.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    lset = lse.reshape(B * H, Sq, 1)
    dltt = delta.transpose(0, 2, 1).reshape(B * H, Sq, 1)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, causal=causal, block_kv=block_kv,
                          skv=Skv, scale=scale),
        grid=(B * H, Sq // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, Skv, D), lambda h, i, G=G: (h // G, 0, 0)),
            pl.BlockSpec((None, Skv, D), lambda h, i, G=G: (h // G, 0, 0)),
            pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, block_q, 1), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, block_q, 1), lambda h, i: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(qt, kt, vt, dot, lset, dltt)

    # dk/dv per q-head, then sum over the G query heads of each kv head
    dkh, dvh = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, causal=causal, block_q=block_q,
                          sq=Sq, scale=scale),
        grid=(B * H, Skv // block_kv),
        in_specs=[
            pl.BlockSpec((None, Sq, D), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((None, block_kv, D), lambda h, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((None, block_kv, D), lambda h, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((None, Sq, D), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((None, Sq, 1), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((None, Sq, 1), lambda h, j: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_kv, D), lambda h, j: (h, j, 0)),
            pl.BlockSpec((None, block_kv, D), lambda h, j: (h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Skv, D), jnp.float32),
            jax.ShapeDtypeStruct((B * H, Skv, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(qt, kt, vt, dot, lset, dltt)

    dq = dq.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    dkh = dkh.reshape(B, Hkv, G, Skv, D).sum(axis=2)
    dvh = dvh.reshape(B, Hkv, G, Skv, D).sum(axis=2)
    dk = dkh.transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dvh.transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


# --- custom-vjp wrapper ------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_kv: int = DEFAULT_BLOCK_KV,
                    interpret: bool = True):
    """Flash attention via Pallas. q (B,Sq,H,D); k/v (B,Skv,Hkv,D)."""
    o, _ = _flash_fwd_pallas(q, k, v, causal, block_q, block_kv, interpret)
    return o


def _fa_fwd(q, k, v, causal, block_q, block_kv, interpret):
    o, lse = _flash_fwd_pallas(q, k, v, causal, block_q, block_kv, interpret)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, block_q, block_kv, interpret, res, do):
    q, k, v, o, lse = res
    return _flash_bwd_pallas(q, k, v, o, lse, do, causal, block_q, block_kv,
                             interpret)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def flash_traffic_bytes(B: int, Sq: int, Skv: int, H: int, Hkv: int, D: int,
                        bytes_el: int = 2, train: bool = True) -> int:
    """Exact HBM traffic of the kernel's DMA schedule (cf. dma_traffic_bytes
    for the stencil kernels): fwd reads Q + K,V per q-block pass (K/V are
    re-streamed from HBM once per q-block row when they exceed VMEM; for
    per-device shapes here K/V fit VMEM, so one read), writes O + lse; bwd
    reads Q,K,V,O,dO,lse and writes dQ,dK,dV."""
    qb = B * Sq * H * D * bytes_el
    kvb = 2 * B * Skv * Hkv * D * bytes_el
    ob = qb
    lseb = B * Sq * H * 4
    fwd = qb + kvb + ob + lseb
    if not train:
        return fwd
    bwd = (qb + kvb + ob + qb + lseb + lseb) + (qb + kvb)
    return fwd + bwd


def flash_flops(B: int, Sq: int, Skv: int, H: int, D: int,
                causal: bool = True, train: bool = True) -> float:
    """MXU FLOPs of the kernel: 2 dots fwd (4·S²·D per head), 5 dots bwd."""
    pairs = Sq * Skv * (0.5 if causal else 1.0)
    fwd = 2 * 2 * B * H * pairs * D
    if not train:
        return fwd
    return fwd + 5 * 2 * B * H * pairs * D
