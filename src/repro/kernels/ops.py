"""Pallas dispatch + legacy entry-point shim.

The public API lives in ``repro.api`` (``StencilProblem`` -> ``plan()`` ->
``StencilPlan``); this module keeps the Pallas super-step driver that the
``pallas``/``pallas_interpret`` backends compile to, the exact DMA-traffic
accounting, and ``stencil_run`` — the deprecated pre-``plan()`` entry point,
now a thin shim.

The Pallas path mirrors the engine's super-step loop: edge-pad the blocked
dims, launch one kernel per super-step (``ceil(iters/par_time)``), slice the
compute columns back out.  ``iters % par_time`` is handled in-kernel by PE
forwarding, exactly like the paper's unused PEs.
"""
from __future__ import annotations

import math
import warnings
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import boundary
from repro.core.blocking import BlockGeometry, stream_extension as _stream_ext
from repro.core.stencils import Stencil
from repro.kernels.builder import superstep_chain, superstep_dag


def pack_coeffs(stencil: Stencil, coeffs: dict) -> jnp.ndarray:
    return jnp.stack([jnp.asarray(coeffs[n], jnp.float32)
                      for n in stencil.coeff_names])


def pack_program_coeffs(stages, stage_coeffs) -> jnp.ndarray:
    """Concatenate per-stage coefficient vectors in *authored* stage order —
    the layout :func:`repro.programs.unroll_dag` assigns ``coeff_lo``
    offsets into.  ``stages`` is the static ``((stencil, bc), ...)`` tuple,
    ``stage_coeffs`` one coefficient dict per stage."""
    return jnp.concatenate([pack_coeffs(st, c)
                            for (st, _), c in zip(stages, stage_coeffs)])


def pack_dag_coeffs(dag, stage_coeffs) -> jnp.ndarray:
    """DAG variant of :func:`pack_program_coeffs`: authored stage order of a
    :class:`repro.programs.DagSpec` (evaluation order is the DAG's ``topo``
    permutation, but coefficient packing stays positional)."""
    return jnp.concatenate([pack_coeffs(st, c)
                            for (st, _, _), c in zip(dag.stages,
                                                     stage_coeffs)])


def _pad_blocked(grid: jnp.ndarray, geom: BlockGeometry,
                 bc=None) -> jnp.ndarray:
    """BC-pad the blocked (trailing) dims — halo left, halo + out-of-bound
    overhang right — plus the periodic stream extension (``_stream_ext``),
    plus edge rows padding the stream extent up to a ``par_vec`` multiple
    (the kernels tick in whole ``(V, ...)`` slabs; pad rows are computed and
    discarded but never tapped — stream reads are BC-mapped into the true
    domain first).  Leading batch axes (in front of the streaming axis) are
    left untouched.
    """
    h = geom.size_halo
    kinds = boundary.kinds_of(bc, geom.ndim)
    fill = boundary.fill_of(bc)
    lead = grid.ndim - (geom.ndim - 1)       # batch axes + streaming axis
    out = grid
    for i, (d, p) in enumerate(zip(geom.blocked_dims, geom.padded_dims)):
        out = boundary.pad_axis(out, lead + i, h, p - d - h, kinds[i + 1],
                                fill)
    ext = _stream_ext(geom, bc)
    if ext:
        out = boundary.pad_axis(out, lead - 1, ext, ext, "periodic")
    dom = geom.stream_dim + 2 * ext
    vpad = geom.stream_slabs(dom) * geom.par_vec - dom
    if vpad:
        out = boundary.pad_axis(out, lead - 1, 0, vpad, "clamp")
    return out


def _slice_blocked(gp: jnp.ndarray, geom: BlockGeometry,
                   bc=None) -> jnp.ndarray:
    h = geom.size_halo
    ext = _stream_ext(geom, bc)
    idx = ((Ellipsis, slice(ext, ext + geom.stream_dim))
           + tuple(slice(h, h + d) for d in geom.blocked_dims))
    return gp[idx]


def _reclamp_padded(gp: jnp.ndarray, geom: BlockGeometry,
                    bc=None) -> jnp.ndarray:
    """Refresh the halo + out-of-bound columns of a padded grid from its real
    columns, per each axis' BC rule.  Bit-identical to
    ``_pad_blocked(_slice_blocked(gp))``, but keeps the array in the padded
    layout so a fused super-step loop can carry it — and an enclosing ``jit``
    can donate it — without leaving the padded representation.

    Axes whose pad is zero are skipped outright: a degenerate gather there
    is wasted work and, for the constant BC, would wrongly treat real edge
    columns as ghost positions (the zero-pad seam case — e.g. a stream-only
    stencil embedded in a higher-rank grid)."""
    h = geom.size_halo
    kinds = boundary.kinds_of(bc, geom.ndim)
    fill = boundary.fill_of(bc)
    ext = _stream_ext(geom, bc)
    if ext:
        axis = gp.ndim - geom.ndim
        d = geom.stream_dim
        core = jnp.mod(jnp.arange(d + 2 * ext) - ext, d) + ext
        # par_vec pad rows beyond the wrap live past the domain: map them to
        # themselves (their values are never tapped, only re-computed)
        tail = jnp.arange(d + 2 * ext, gp.shape[axis])
        gp = jnp.take(gp, jnp.concatenate([core, tail]), axis=axis)
    for i, (d, p) in enumerate(zip(geom.blocked_dims, geom.padded_dims)):
        if p == d:
            continue
        axis = gp.ndim - (geom.ndim - 1) + i
        kind = kinds[i + 1]
        if kind == "constant":
            pos = jnp.arange(p) - h
            mask = boundary.out_of_range(pos, 0, d - 1)
            shape = [1] * gp.ndim
            shape[axis] = p
            gp = jnp.where(mask.reshape(shape),
                           jnp.asarray(fill, gp.dtype), gp)
        else:
            idx = boundary.map_index(jnp.arange(p) - h, 0, d - 1, kind) + h
            gp = jnp.take(gp, idx, axis=axis)
    return gp


def fused_chain_loop(stages, geom: BlockGeometry, gp: jnp.ndarray,
                     coeffs_packed: jnp.ndarray, iters,
                     aux_p: jnp.ndarray | None, interpret: bool,
                     block_parallel: bool = False) -> jnp.ndarray:
    """The throughput subsystem's fused driver: the whole ``iters`` loop of a
    stage chain over the *pre-padded* grid ``gp``, returning the unpadded
    result.  ``stages`` is the static ``((stencil, bc), ...)`` tuple of the
    program (S=1 recovers the classic single-operator loop).

    Why this shape:
      * ``iters`` may be a traced scalar — the super-step trip count is
        computed in-trace and the loop lowers to a dynamic ``while``, so one
        compiled executable serves every iteration count (no per-``iters``
        re-trace in a serving loop).
      * The carry stays in the padded layout: halos are refreshed in place
        (``_reclamp_padded``) instead of slice+re-pad round-trips, and a
        caller that jits this function with ``donate_argnums`` on ``gp`` lets
        XLA reuse the padded buffer for the loop carry (no copy-on-update) —
        ``gp`` is an intermediate the backend owns, so donation never
        invalidates a caller-visible array.

    Padding, the stream extension and inter-super-step halo refresh use stage
    0's BC: that is the BC the chain's first entry reads the carry under
    (periodicity is uniform across stages by construction, and each later
    entry re-imposes its own BC in-kernel).
    """
    bc0 = stages[0][1]
    par_time = geom.par_time
    n_super = (iters + par_time - 1) // par_time

    def body(s, g):
        steps = jnp.minimum(par_time, iters - s * par_time)
        op = superstep_chain(stages, geom, g, coeffs_packed, steps, aux_p,
                             interpret=interpret,
                             block_parallel=block_parallel)
        return _reclamp_padded(op, geom, bc0)

    return _slice_blocked(jax.lax.fori_loop(0, n_super, body, gp), geom, bc0)


def fused_dag_loop(dag, geom: BlockGeometry, gp: jnp.ndarray,
                   coeffs_packed: jnp.ndarray, iters,
                   aux_p: jnp.ndarray | None, interpret: bool,
                   block_parallel: bool = False) -> jnp.ndarray:
    """DAG analogue of :func:`fused_chain_loop`: the whole ``iters`` loop of
    a stage DAG (:class:`repro.programs.DagSpec`) over the *pre-padded*
    state ``gp`` (``(ns, *padded)`` single-field, ``(F, ns, *padded)``
    multi-field — every field padded identically), returning the unpadded
    result.  The carry stays padded; halos of all fields are refreshed in
    one ``_reclamp_padded`` per super-step under stage 0's BC (periodicity
    is uniform by construction; each entry re-imposes its own BC
    in-kernel)."""
    bc0 = dag.stages[0][1]
    par_time = geom.par_time
    n_super = (iters + par_time - 1) // par_time

    def body(s, g):
        steps = jnp.minimum(par_time, iters - s * par_time)
        op = superstep_dag(dag, geom, g, coeffs_packed, steps, aux_p,
                           interpret=interpret,
                           block_parallel=block_parallel)
        return _reclamp_padded(op, geom, bc0)

    return _slice_blocked(jax.lax.fori_loop(0, n_super, body, gp), geom, bc0)


def fused_superstep_loop(stencil: Stencil, geom: BlockGeometry,
                         gp: jnp.ndarray, coeffs_packed: jnp.ndarray, iters,
                         aux_p: jnp.ndarray | None, interpret: bool,
                         bc=None, block_parallel: bool = False) -> jnp.ndarray:
    """Single-operator special case of :func:`fused_chain_loop` (legacy
    entry point, semantics unchanged)."""
    return fused_chain_loop(((stencil, bc),), geom, gp, coeffs_packed, iters,
                            aux_p, interpret, block_parallel)


@partial(jax.jit, static_argnames=("stencil", "geom", "interpret", "bc",
                                   "block_parallel"))
def run_pallas(stencil: Stencil, geom: BlockGeometry, grid: jnp.ndarray,
               coeffs_packed: jnp.ndarray, iters,
               aux: jnp.ndarray | None, interpret: bool,
               bc=None, block_parallel: bool = False) -> jnp.ndarray:
    """``iters`` time-steps via the streaming Pallas kernels.

    ``iters`` is dynamic (traced): one executable per (stencil, geom, bc)
    serves all iteration counts — see :func:`fused_superstep_loop`."""
    aux_p = _pad_blocked(aux, geom, bc) if aux is not None else None
    return fused_superstep_loop(stencil, geom, _pad_blocked(grid, geom, bc),
                                coeffs_packed, iters, aux_p, interpret, bc,
                                block_parallel)


@partial(jax.jit, static_argnames=("stages", "geom", "interpret",
                                   "block_parallel"))
def run_pallas_chain(stages, geom: BlockGeometry, grid: jnp.ndarray,
                     coeffs_packed: jnp.ndarray, iters,
                     aux: jnp.ndarray | None, interpret: bool,
                     block_parallel: bool = False) -> jnp.ndarray:
    """``iters`` program iterations via the fused streaming chain kernel.
    ``stages`` is the static ``((stencil, bc), ...)`` tuple; padding uses
    stage 0's BC (see :func:`fused_chain_loop`)."""
    bc0 = stages[0][1]
    aux_p = _pad_blocked(aux, geom, bc0) if aux is not None else None
    return fused_chain_loop(stages, geom, _pad_blocked(grid, geom, bc0),
                            coeffs_packed, iters, aux_p, interpret,
                            block_parallel)


@partial(jax.jit, static_argnames=("dag", "geom", "interpret",
                                   "block_parallel"))
def run_pallas_dag(dag, geom: BlockGeometry, state: jnp.ndarray,
                   coeffs_packed: jnp.ndarray, iters,
                   aux: jnp.ndarray | None, interpret: bool,
                   block_parallel: bool = False) -> jnp.ndarray:
    """``iters`` program iterations via the fused streaming DAG kernel.
    ``state`` is the plain grid for single-field programs, else the
    ``(F, *shape)`` field stack (the leading field axis rides through
    ``_pad_blocked`` like a batch axis); padding uses stage 0's BC."""
    bc0 = dag.stages[0][1]
    aux_p = _pad_blocked(aux, geom, bc0) if aux is not None else None
    return fused_dag_loop(dag, geom, _pad_blocked(state, geom, bc0),
                          coeffs_packed, iters, aux_p, interpret,
                          block_parallel)


def dma_traffic_bytes(stencil: Stencil, geom: BlockGeometry,
                      cell_bytes: int = 4, bc=None) -> int:
    """Exact HBM traffic of one Pallas super-step, from its DMA schedule.

    The kernels' HBM accesses are fully explicit (manual async copies), so
    traffic is countable without hardware:
      * input: every block streams ``stream`` rows (2D) / planes (3D) of
        extent ``prod(bsize)`` — the pipeline runs ``stream + size_halo``
        ticks to drain the PE chain, but the trailing ticks fetch nothing
        (the prefetch stops at the last real row; out-of-grid reads are
        clamped window reads, not DMAs); halo columns overlap between
        adjacent blocks.
      * aux (Hotspot power): same stream per block.
      * output: every block writes ``stream`` rows/planes of the compute
        extent ``prod(csize)`` (out-of-bound columns land in padding and
        are counted — the wrapper slices them off in HBM).

    This is what the perf model's Eq. 7/8 idealizes; the ratio
    ``superstep_traffic_bytes / dma_traffic_bytes`` is the model's traffic
    accuracy for the kernel implementation.

    ``par_vec`` rounds the streamed extent up to whole ``(V, ...)`` slabs
    (the wrapper's stream-axis pad): a non-divisible stream bills the pad
    rows its DMAs actually move.
    """
    dom = geom.stream_dim + 2 * _stream_ext(geom, bc)
    stream = geom.stream_slabs(dom) * geom.par_vec
    block_in = math.prod(geom.bsize)
    block_out = math.prod(geom.csize)
    n_blocks = geom.num_blocks
    # num_read/num_write count the external streams (fields + aux / fields):
    # 1 + aux for every plain stencil and linear chain, F + aux / F for a
    # multi-field DAG — each field streams in and drains out per block
    reads = n_blocks * stream * block_in * stencil.num_read
    writes = n_blocks * stream * block_out * stencil.num_write
    return (reads + writes) * cell_bytes


def stencil_run(stencil: Stencil, grid: jnp.ndarray, coeffs: dict, iters: int,
                par_time: int, bsize, aux: jnp.ndarray | None = None,
                backend: str = "pallas_interpret") -> jnp.ndarray:
    """Deprecated: use ``repro.api.plan`` instead.

    Thin shim over ``plan(StencilProblem(...), RunConfig(...)).run(...)``,
    kept for old call sites.  Results are identical to the plan path.
    """
    warnings.warn(
        "stencil_run is deprecated; use repro.api.plan(StencilProblem(...), "
        "RunConfig(backend=...)).run(grid, iters, coeffs, aux=aux)",
        DeprecationWarning, stacklevel=2)
    from repro.api import RunConfig, StencilProblem, plan
    grid = jnp.asarray(grid)
    problem = StencilProblem(stencil, tuple(grid.shape),
                             dtype=grid.dtype.name)   # legacy: dtype-generic
    config = RunConfig(backend=backend, par_time=par_time, bsize=bsize)
    return plan(problem, config).run(grid, iters, coeffs, aux=aux)
