"""Pallas dispatch + legacy entry-point shim.

The public API lives in ``repro.api`` (``StencilProblem`` -> ``plan()`` ->
``StencilPlan``); this module keeps the Pallas super-step driver that the
``pallas``/``pallas_interpret`` backends compile to, the exact DMA-traffic
accounting, and ``stencil_run`` — the deprecated pre-``plan()`` entry point,
now a thin shim.

The Pallas path mirrors the engine's super-step loop: edge-pad the blocked
dims, launch one kernel per super-step (``ceil(iters/par_time)``), slice the
compute columns back out.  ``iters % par_time`` is handled in-kernel by PE
forwarding, exactly like the paper's unused PEs.
"""
from __future__ import annotations

import math
import warnings
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.blocking import BlockGeometry
from repro.core.stencils import Stencil
from repro.kernels.stencil2d import superstep_2d
from repro.kernels.stencil3d import superstep_3d


def pack_coeffs(stencil: Stencil, coeffs: dict) -> jnp.ndarray:
    return jnp.stack([jnp.asarray(coeffs[n], jnp.float32)
                      for n in stencil.coeff_names])


def _pad_blocked(grid: jnp.ndarray, geom: BlockGeometry) -> jnp.ndarray:
    h = geom.size_halo
    pads = [(0, 0)]
    for d, p in zip(geom.blocked_dims, geom.padded_dims):
        pads.append((h, p - d - h))
    return jnp.pad(grid, pads, mode="edge")


def _slice_blocked(gp: jnp.ndarray, geom: BlockGeometry) -> jnp.ndarray:
    h = geom.size_halo
    idx = (slice(None),) + tuple(slice(h, h + d) for d in geom.blocked_dims)
    return gp[idx]


@partial(jax.jit,
         static_argnames=("stencil", "geom", "iters", "interpret"))
def run_pallas(stencil: Stencil, geom: BlockGeometry, grid: jnp.ndarray,
               coeffs_packed: jnp.ndarray, iters: int,
               aux: jnp.ndarray | None, interpret: bool) -> jnp.ndarray:
    """``iters`` time-steps via the streaming Pallas kernels."""
    superstep = superstep_2d if geom.ndim == 2 else superstep_3d
    n_super = math.ceil(iters / geom.par_time)
    aux_p = _pad_blocked(aux, geom) if aux is not None else None

    def body(s, g):
        steps = jnp.minimum(geom.par_time, iters - s * geom.par_time)
        gp = _pad_blocked(g, geom)
        op = superstep(stencil, geom, gp, coeffs_packed, steps, aux_p,
                       interpret=interpret)
        return _slice_blocked(op, geom)

    return jax.lax.fori_loop(0, n_super, body, grid)


def dma_traffic_bytes(stencil: Stencil, geom: BlockGeometry,
                      cell_bytes: int = 4) -> int:
    """Exact HBM traffic of one Pallas super-step, from its DMA schedule.

    The kernels' HBM accesses are fully explicit (manual async copies), so
    traffic is countable without hardware:
      * input: every block streams ``stream`` rows (2D) / planes (3D) of
        extent ``prod(bsize)`` — the pipeline runs ``stream + size_halo``
        ticks to drain the PE chain, but the trailing ticks fetch nothing
        (the prefetch stops at the last real row; out-of-grid reads are
        clamped window reads, not DMAs); halo columns overlap between
        adjacent blocks.
      * aux (Hotspot power): same stream per block.
      * output: every block writes ``stream`` rows/planes of the compute
        extent ``prod(csize)`` (out-of-bound columns land in padding and
        are counted — the wrapper slices them off in HBM).

    This is what the perf model's Eq. 7/8 idealizes; the ratio
    ``superstep_traffic_bytes / dma_traffic_bytes`` is the model's traffic
    accuracy for the kernel implementation.
    """
    stream = geom.stream_dim
    block_in = math.prod(geom.bsize)
    block_out = math.prod(geom.csize)
    n_blocks = geom.num_blocks
    reads = n_blocks * stream * block_in * (2 if stencil.has_aux else 1)
    writes = n_blocks * stream * block_out
    return (reads + writes) * cell_bytes


def stencil_run(stencil: Stencil, grid: jnp.ndarray, coeffs: dict, iters: int,
                par_time: int, bsize, aux: jnp.ndarray | None = None,
                backend: str = "pallas_interpret") -> jnp.ndarray:
    """Deprecated: use ``repro.api.plan`` instead.

    Thin shim over ``plan(StencilProblem(...), RunConfig(...)).run(...)``,
    kept for old call sites.  Results are identical to the plan path.
    """
    warnings.warn(
        "stencil_run is deprecated; use repro.api.plan(StencilProblem(...), "
        "RunConfig(backend=...)).run(grid, iters, coeffs, aux=aux)",
        DeprecationWarning, stacklevel=2)
    from repro.api import RunConfig, StencilProblem, plan
    grid = jnp.asarray(grid)
    problem = StencilProblem(stencil, tuple(grid.shape),
                             dtype=grid.dtype.name)   # legacy: dtype-generic
    config = RunConfig(backend=backend, par_time=par_time, bsize=bsize)
    return plan(problem, config).run(grid, iters, coeffs, aux=aux)
