"""Rank- and DAG-generic Pallas kernel builder — ONE streaming kernel.

This module replaces the former ``stencil2d.py``/``stencil3d.py`` twins (now
thin compatibility shims) with a single builder that emits the combined
spatial/temporal-blocking kernel for

  * any grid rank with streaming axis 0 (1D: stream only; 2D: 1-D blocking
    in x; 3D: 2-D blocking in (y, x) — the paper's §3.1 layouts), and
  * any *DAG* of PE stages: ``par_time`` repeats of one stencil (the classic
    S=1 temporal chain), a linear multi-stage
    :class:`~repro.programs.StencilProgram` chain, or a general stage DAG —
    fan-out, fan-in (multi-input combine stages), multi-field state —
    topologically unrolled ``par_time`` times per super-step (StencilFlow,
    arXiv:2010.15218).  Intermediates live only in the rolling VMEM windows:
    zero HBM round-trips.

Architecture (see DESIGN.md §2 and §2.5):

  * one rolling circular slab window per *producer* value (external field
    stream or unrolled entry) that other entries consume, sized by
    StencilFlow buffer-depth analysis (:func:`repro.programs.dag_layout`):
    ``max over consumer edges of (Lag_c + R_c) - Lag_p + 1`` slots of
    ``par_vec`` rows — which is the chain's ``2*ceil(rad/V)+1`` when
    producer and consumer are adjacent, and grows by exactly the lag
    *difference* where an edge skips levels (a diamond's short branch);
  * fan-out is one producer window tapped by several consumers (no copies);
    each consumer re-imposes *its own* blocked-axis BC on every slab it
    reads, and applies its stream-axis BC in its window gathers;
  * entry ``e`` lags the stream head by ``Lag_e = max over inputs of Lag_p
    + R_e`` slabs (the per-PE ``rad``-row lag of the paper, generalized to
    DAG edges and vector slabs);
  * double-buffered async slab DMA per external field stream in, per field
    out; prefetch stops at the last real slab; the tick loop runs ``nslabs
    + max output lag`` ticks;
  * partial super-steps (``steps < par_time``): linear chains fuse the
    select into every entry (identical to the classic PE forwarding);
    general DAGs insert radius-0 *state* nodes per updated field selecting
    new-vs-previous value, so every field advances simultaneously and
    un-taken iterations forward exactly.
"""
from __future__ import annotations

import functools
import itertools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.core import precision
from repro.core.blocking import BlockGeometry, stream_extension
from repro.programs import (DagNode, DagSpec, chain_dag, dag_layout,
                            unroll_dag)

#: Compatibility alias: the multi-input generalization of the former
#: single-input ``ChainStage`` (now carries value-id ``inputs``).
ChainStage = DagNode


def unroll_chain(stages, par_time: int):
    """``stages`` (a tuple of ``(stencil, bc)`` per program stage) unrolled
    ``par_time`` times into the per-super-step PE chain — the path-graph
    special case of :func:`repro.programs.unroll_dag`."""
    return unroll_dag(chain_dag(stages), par_time).entries


def _chain_lags(chain, par_vec: int):
    """Per-entry slab radius ``R_i = ceil(rad_i/V)`` and cumulative lag
    ``Lag_i = sum_{u<=i} R_u`` — only meaningful for linear chains (DAG lags
    live in :func:`repro.programs.dag_layout`)."""
    rs = [0 if e.stencil is None else -(-e.stencil.radius // par_vec)
          for e in chain]
    return rs, list(itertools.accumulate(rs))


def _dag_kernel(*refs, plan, lay, geom: BlockGeometry, ns: int, dom: int,
                sdtype=jnp.float32):
    # mixed precision (repro.core.precision): every VMEM buffer — windows,
    # DMA slabs — holds the STORAGE dtype ``sdtype``; stage arithmetic runs
    # in f32.  For bf16 that means: widen the concatenated window read (and
    # the aux slab) to f32, apply the stencil against the f32 coefficients,
    # round the result back to bf16 exactly once per entry — the same
    # once-per-stage-application rounding the oracle/engine implement.  For
    # f32 ``needs_cast`` is False and ZERO casts are emitted: the trace is
    # identical to the pre-bf16 kernel, bit for bit.
    needs_cast = precision.needs_accum_cast(sdtype)
    nb = geom.ndim - 1                       # blocked (trailing) dims
    V = geom.par_vec
    F = plan.n_streams
    multi = F > 1
    entries = plan.entries
    BS = geom.bsize
    CS = geom.csize
    h = geom.size_halo
    radii, lags, wins = lay.radii, lay.lags, lay.wins
    HA = lay.aux_depth                       # aux window depth, in slabs
    nslabs = ns // V
    nticks = nslabs + lay.out_lag
    has_aux = any(e.stencil is not None and e.stencil.has_aux
                  for e in entries)
    blanks = (slice(None),) * nb

    # value ids that need a rolling window, in id order (streams first)
    win_ids = [v for v in range(F + len(entries)) if wins[v] > 0]
    # out producers: value id -> field indices it drains to
    out_of: dict = {}
    for kf, o in enumerate(plan.outputs):
        out_of.setdefault(o, []).append(kf)

    # --- unpack the positional refs (operands, output, scratch) -------------
    steps_ref, coeff_ref, gp_ref = refs[0], refs[1], refs[2]
    p = 3
    aux_ref = None
    if has_aux:
        aux_ref, p = refs[p], p + 1
    out_ref, p = refs[p], p + 1
    win_refs, p = refs[p:p + len(win_ids)], p + len(win_ids)
    win_of = dict(zip(win_ids, win_refs))
    in_buf, in_sems, p = refs[p], refs[p + 1], p + 2
    aux_win = aux_buf = aux_sems = None
    if has_aux:
        aux_win, aux_buf, aux_sems = refs[p:p + 3]
        p += 3
    out_buf, out_sems = refs[p], refs[p + 1]

    starts = tuple(pl.program_id(d) * CS[d] for d in range(nb))
    steps = steps_ref[0, 0]
    iv = jax.lax.iota(jnp.int32, V)          # row offsets within a slab

    # --- per-stage coefficient dicts (shared across par_time repeats) -------
    # built at kernel top level: values read inside a pl.when branch must not
    # be reused by a later branch (cross-trace constants)
    cdicts = {}
    for e in entries:
        if e.stencil is not None and e.coeff_lo not in cdicts:
            cdicts[e.coeff_lo] = {
                name: coeff_ref[0, e.coeff_lo + ci]
                for ci, name in enumerate(e.stencil.coeff_names)}

    def coeffs_of(entry):
        return cdicts[entry.coeff_lo]

    # --- blocked-axis boundary re-imposition, per consuming entry's BC ------
    # (only grid-edge blocks ever act; applied to every slab an entry reads,
    # so fan-out consumers each see their own BC on a shared producer)
    iotas = [jax.lax.broadcasted_iota(jnp.int32, (V,) + BS, 1 + ax)
             for ax in range(nb)]
    los = tuple(h - s for s in starts)
    his = tuple((d - 1) + h - s for d, s in zip(geom.blocked_dims, starts))

    def _reimpose_axis(slab, kind, ax, fill):
        if kind == "periodic":
            # wrap-padded halos are exact translated copies: no re-imposition
            return slab
        n, axis = BS[ax], 1 + ax
        lo, hi, iota = los[ax], his[ax], iotas[ax]
        if kind == "constant":
            slab = jnp.where(iota < lo, fill, slab)
            return jnp.where(iota > hi, fill, slab)
        if kind == "reflect":
            flipped = jnp.flip(slab, axis=axis)
            mlo = jnp.roll(flipped, 2 * lo + 1 - n, axis=axis)
            mhi = jnp.roll(flipped, 2 * hi + 1 - n, axis=axis)
            slab = jnp.where(iota < lo, mlo, slab)
            return jnp.where(iota > hi, mhi, slab)
        sizes = tuple(1 if a == axis else s
                      for a, s in enumerate((V,) + BS))
        at = lambda p_: tuple(p_ if a == axis else 0     # noqa: E731
                              for a in range(1 + nb))
        lo_band = jax.lax.dynamic_slice(slab, at(jnp.clip(lo, 0, n - 1)),
                                        sizes)
        hi_band = jax.lax.dynamic_slice(slab, at(jnp.clip(hi, 0, n - 1)),
                                        sizes)
        slab = jnp.where(iota < lo, lo_band, slab)
        return jnp.where(iota > hi, hi_band, slab)

    def reclamp_for(bc):
        kinds = ("clamp",) * nb if bc is None else tuple(bc.kinds[1:])
        fill = 0.0 if bc is None else bc.value

        def reclamp(slab):
            for ax in range(nb):
                slab = _reimpose_axis(slab, kinds[ax], ax, fill)
            return slab
        return reclamp

    reclamps = [reclamp_for(e.bc) for e in entries]

    # --- DMA plumbing --------------------------------------------------------
    in_idx = tuple(pl.ds(s, b) for s, b in zip(starts, BS))
    out_idx = tuple(pl.ds(s + h, c) for s, c in zip(starts, CS))

    def in_copy(kf, j, slot):
        src = jnp.clip(j, 0, nslabs - 1) * V
        lead = (kf,) if multi else ()
        return pltpu.make_async_copy(
            gp_ref.at[lead + (pl.ds(src, V),) + in_idx],
            in_buf.at[lead + (slot,)], in_sems.at[lead + (slot,)])

    def aux_copy(j, slot):
        src = jnp.clip(j, 0, nslabs - 1) * V
        return pltpu.make_async_copy(
            aux_ref.at[(pl.ds(src, V),) + in_idx],
            aux_buf.at[slot], aux_sems.at[slot])

    def out_copy(kf, j, slot):
        lead = (kf,) if multi else ()
        return pltpu.make_async_copy(
            out_buf.at[lead + (slot,)],
            out_ref.at[lead + (pl.ds(j * V, V),) + out_idx],
            out_sems.at[lead + (slot,)])

    def in_slab(kf, slot):
        return in_buf[((kf, slot) if multi else (slot,))]

    for kf in range(F):
        in_copy(kf, 0, 0).start()
    if has_aux:
        aux_copy(0, 0).start()

    def emit_out(vid, j, val):
        """Drain ``val`` (a compute slab) to every field this value id
        feeds: crop the compute columns, double-buffer, start the DMA."""
        for kf in out_of[vid]:
            oslot = j % 2

            @pl.when(j >= 2)
            def _(kf=kf, oslot=oslot):   # slot reuse: prior copy must drain
                out_copy(kf, j - 2, oslot).wait()

            crop = val[(slice(None),) + tuple(slice(h, h + c) for c in CS)]
            if multi:
                out_buf[kf, oslot] = crop
            else:
                out_buf[oslot] = crop
            out_copy(kf, j, oslot).start()

    def body(k, _):
        # wait input slab k; prefetch slab k+1 (both stop at the last real
        # slab — later ticks only drain the DAG, fetching nothing)
        slot = k % 2
        for kf in range(F):
            @pl.when(k <= nslabs - 1)
            def _(kf=kf):
                in_copy(kf, k, slot).wait()

            @pl.when(k + 1 <= nslabs - 1)
            def _(kf=kf):
                in_copy(kf, k + 1, (k + 1) % 2).start()

            @pl.when(k <= nslabs - 1)
            def _(kf=kf):
                # push the input slab into the stream's window (pre-padded
                # => BC-ok) and drain pass-through fields straight to out
                if wins[kf] > 0:
                    win_of[kf][(pl.ds((k % wins[kf]) * V, V),) + blanks] = (
                        in_slab(kf, slot))
                if kf in out_of:
                    emit_out(kf, k, in_slab(kf, slot))

        if has_aux:
            @pl.when(k <= nslabs - 1)
            def _():
                aux_copy(k, slot).wait()

            @pl.when(k + 1 <= nslabs - 1)
            def _():
                aux_copy(k + 1, (k + 1) % 2).start()

            @pl.when(k <= nslabs - 1)
            def _():
                aux_win[(pl.ds((k % HA) * V, V),) + blanks] = aux_buf[slot]

        # -- unrolled DAG: entry e computes slab k - Lag_e -------------------
        for i, entry in enumerate(entries):
            vid = F + i
            j = k - lags[vid]
            R = radii[i]

            @pl.when((j >= 0) & (j <= nslabs - 1))
            def _(i=i, entry=entry, vid=vid, j=j, R=R):
                def read_slab(pid, jj):
                    W = wins[pid]
                    return win_of[pid][(pl.ds((jj % W) * V, V),) + blanks]

                if entry.stencil is None:
                    # state node: select the updated value while this
                    # iteration is real, else forward the field's previous
                    # value (PE forwarding, generalized per field)
                    val = jnp.where(entry.iteration + 1 <= steps,
                                    read_slab(entry.inputs[0], j),
                                    read_slab(entry.inputs[1], j))
                else:
                    base = (j - R) * V   # logical stream row of cat[0]
                    limit = jnp.minimum((j + R) * V + V - 1, dom - 1)
                    bc = entry.bc
                    kind_s = "clamp" if bc is None else bc.kinds[0]
                    fill = 0.0 if bc is None else bc.value
                    if needs_cast:
                        # the stream-axis constant fill is applied AFTER the
                        # widening cast: round it through storage (on host —
                        # np, not a traced op) so it equals the bf16 padding
                        # the other backends read
                        fill = float(np.asarray(fill, jnp.dtype(sdtype)))
                    rec = reclamps[i]

                    def cat_of(pid):
                        """Producer ``pid``'s slabs j-R..j+R in logical
                        order, each re-imposed under *this* entry's
                        blocked-axis BC.  Linear chains skip this entirely:
                        the stream window is pre-padded under stage 0's BC
                        and every other slab was re-imposed with the (sole)
                        consumer's BC at push time — the PR 6 chain
                        op-for-op."""
                        slabs = [read_slab(pid, j + o)
                                 for o in range(-R, R + 1)]
                        if not plan.linear:
                            slabs = [rec(s) for s in slabs]
                        cat = jnp.concatenate(slabs, axis=0)
                        # window READ cast: widen storage to the f32
                        # accumulation dtype before any arithmetic
                        return cat.astype(jnp.float32) if needs_cast else cat

                    def make_get(cat):
                        def stream_tap(ds_):
                            """(V, *BS) slab of stream rows ``j*V+ds_ ..``
                            with this entry's stream-axis BC applied per
                            row: clamp clips, reflect mirrors (the target
                            provably stays in the window), constant
                            overrides out-of-domain rows with the fill;
                            periodic was materialized as a stream extension
                            by the wrapper.  ``limit`` stops reads at the
                            newest pushed row."""
                            rows = j * V + ds_ + iv
                            if kind_s == "reflect":
                                p_ = max(2 * dom - 2, 1)
                                m = jnp.mod(rows, p_)
                                rows_m = jnp.where(m >= dom, p_ - m, m)
                            else:
                                rows_m = rows
                            pos = jnp.clip(rows_m, 0, limit) - base
                            vals = jnp.take(cat, pos, axis=0)
                            if kind_s == "constant":
                                oob = (rows < 0) | (rows > dom - 1)
                                vals = jnp.where(
                                    oob.reshape((V,) + (1,) * nb),
                                    fill, vals)
                            return vals

                        # tap memo: one window gather per distinct stream
                        # offset, one lane/sublane rotate per full offset
                        taps = {}
                        zero = (0,) * nb

                        def get(off):
                            ds_, db = off[0], tuple(off[1:])
                            tap = taps.get(tuple(off))
                            if tap is None:
                                tap = taps.get((ds_,) + zero)
                                if tap is None:
                                    tap = taps[(ds_,) + zero] = (
                                        stream_tap(ds_))
                                for ax, d in enumerate(db):
                                    if d:
                                        tap = jnp.roll(tap, -d, axis=1 + ax)
                                taps[tuple(off)] = tap
                            return tap
                        return get

                    cats = {}
                    for pid in entry.inputs:
                        if pid not in cats:
                            cats[pid] = make_get(cat_of(pid))
                    gets = [cats[pid] for pid in entry.inputs]

                    aux_slab = None
                    if entry.stencil.has_aux:
                        ja = jnp.clip(j, 0, nslabs - 1)
                        aux_slab = aux_win[(pl.ds((ja % HA) * V, V),)
                                           + blanks]
                        if needs_cast:
                            aux_slab = aux_slab.astype(jnp.float32)
                    val = entry.stencil.apply(
                        tuple(gets) if entry.stencil.arity > 1 else gets[0],
                        coeffs_of(entry), aux_slab)
                    if entry.fused_select:
                        # linear-chain PE forwarding: un-taken repeats
                        # forward their input slab unchanged
                        val = jnp.where(entry.iteration + 1 <= steps, val,
                                        gets[0]((0,) * geom.ndim))
                    if needs_cast:
                        # output cast: round to storage ONCE per entry (=
                        # per stage application) before the value re-enters
                        # a VMEM window or the output DMA buffer
                        val = val.astype(sdtype)

                if wins[vid] > 0:
                    # linear chains re-impose the sole consumer's (entry
                    # i+1's) blocked-axis BC at push time; DAG fan-out
                    # defers to read time, where each consumer applies its
                    # own (see cat_of)
                    stored = reclamps[i + 1](val) if plan.linear else val
                    win_of[vid][(pl.ds((j % wins[vid]) * V, V),) + blanks] = (
                        stored)
                if vid in out_of:
                    emit_out(vid, j, val)
        return 0

    jax.lax.fori_loop(0, nticks, body, 0)

    # drain outstanding output DMAs (last two slabs; nslabs is static)
    for kf in range(F):
        if nslabs >= 2:
            out_copy(kf, nslabs - 2, (nslabs - 2) % 2).wait()
        out_copy(kf, nslabs - 1, (nslabs - 1) % 2).wait()


def _superstep_dag_impl(dag: DagSpec, geom: BlockGeometry, gp: jnp.ndarray,
                        coeffs_packed: jnp.ndarray, steps: jnp.ndarray,
                        aux_p: Optional[jnp.ndarray], interpret: bool,
                        block_parallel: bool) -> jnp.ndarray:
    nb = geom.ndim - 1
    V = geom.par_vec
    F = dag.n_fields
    multi = F > 1
    if multi and gp.shape[0] != F:
        raise ValueError(f"multi-field program: leading axis {gp.shape[0]} "
                         f"!= {F} fields")
    ns = gp.shape[1] if multi else gp.shape[0]
    bc0 = dag.stages[0][1]
    dom = geom.stream_dim + 2 * stream_extension(geom, bc0)
    if ns != geom.stream_slabs(dom) * V:
        raise ValueError(
            f"padded stream extent {ns} != ceil({dom}/{V})*{V} "
            f"= {geom.stream_slabs(dom) * V}: the wrapper must pad the "
            f"stream axis to a slab multiple (kernels/ops._pad_blocked)")
    plan = unroll_dag(dag, geom.par_time)
    lay = dag_layout(plan, V)
    has_aux = any(st.has_aux for st, _, _ in dag.stages)
    BS, CS = geom.bsize, geom.csize

    # every VMEM buffer holds the STORAGE dtype (bf16 windows halve the
    # working set); the kernel widens reads to f32 for the stage arithmetic
    sdtype = gp.dtype
    kernel = functools.partial(_dag_kernel, plan=plan, lay=lay, geom=geom,
                               ns=ns, dom=dom, sdtype=sdtype)
    # one rolling window per consumed producer value, buffer-depth sized
    scratch = [pltpu.VMEM((w * V,) + BS, sdtype)
               for w in lay.wins if w > 0]
    lead = (F,) if multi else ()
    scratch += [pltpu.VMEM(lead + (2, V) + BS, sdtype),  # in dbl buffer
                pltpu.SemaphoreType.DMA(lead + (2,))]
    if has_aux:
        scratch += [pltpu.VMEM((lay.aux_depth * V,) + BS, sdtype),
                    pltpu.VMEM((2, V) + BS, sdtype),
                    pltpu.SemaphoreType.DMA((2,))]
    scratch += [pltpu.VMEM(lead + (2, V) + CS, sdtype),  # out dbl buffer
                pltpu.SemaphoreType.DMA(lead + (2,))]

    n_hbm_in = 2 if has_aux else 1
    operands = (coeffs_packed.reshape(1, -1), gp) + (
        (aux_p,) if has_aux else ())
    steps_arr = jnp.asarray(steps, jnp.int32).reshape(1, 1)
    grid = geom.bnum if nb else (1,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM)]
        + [pl.BlockSpec(memory_space=pl.ANY)] * n_hbm_in,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=scratch,
        out_shape=jax.ShapeDtypeStruct(gp.shape, sdtype),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                ("parallel" if block_parallel else "arbitrary",) * len(grid))),
    )(steps_arr, *operands)


@functools.partial(jax.jit,
                   static_argnames=("dag", "geom", "interpret",
                                    "block_parallel"))
def superstep_dag(dag: DagSpec, geom: BlockGeometry, gp: jnp.ndarray,
                  coeffs_packed: jnp.ndarray, steps: jnp.ndarray,
                  aux_p: Optional[jnp.ndarray] = None,
                  interpret: bool = True,
                  block_parallel: bool = False) -> jnp.ndarray:
    """One super-step (<= ``par_time`` fused program iterations) of a stage
    DAG over the padded state ``gp`` (``(ns, *padded)`` for single-field
    programs, ``(F, ns, *padded)`` for multi-field), through the unrolled
    per-super-step value graph.

    ``gp``/``aux_p`` are BC-padded by the wrapper (``kernels/ops``) under
    stage 0's BC: blocked dims to ``bnum*csize + 2*halo``, the stream axis
    extended ``2*size_halo`` when periodic and padded up to a ``par_vec``
    multiple.  Returns the padded output (only compute columns/rows are
    meaningful).

    ``block_parallel`` opts the kernel grid into Megacore ("parallel"
    dimension semantics): blocks are independent by construction, so the
    result is bit-identical to the sequential grid.
    """
    return _superstep_dag_impl(dag, geom, gp, coeffs_packed, steps, aux_p,
                               interpret, block_parallel)


@functools.partial(jax.jit,
                   static_argnames=("stages", "geom", "interpret",
                                    "block_parallel"))
def superstep_chain(stages, geom: BlockGeometry, gp: jnp.ndarray,
                    coeffs_packed: jnp.ndarray, steps: jnp.ndarray,
                    aux_p: Optional[jnp.ndarray] = None,
                    interpret: bool = True,
                    block_parallel: bool = False) -> jnp.ndarray:
    """One super-step through the ``len(stages) * par_time``-entry PE chain.

    ``stages``: static tuple of ``(stencil, bc)`` per program stage (S=1
    recovers the classic single-operator super-step exactly — see
    ``superstep_2d``/``superstep_3d``).  The path-graph special case of
    :func:`superstep_dag`: linear chains unroll to the identical entry list
    (fused per-entry PE-forwarding selects, same windows, same scratch), so
    this builds the same kernel PR 6 shipped, bit for bit.
    """
    return _superstep_dag_impl(chain_dag(stages), geom, gp, coeffs_packed,
                               steps, aux_p, interpret, block_parallel)
