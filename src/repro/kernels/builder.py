"""Rank- and stage-generic Pallas kernel builder — ONE streaming kernel.

This module replaces the former ``stencil2d.py``/``stencil3d.py`` twins (now
thin compatibility shims) with a single builder that emits the combined
spatial/temporal-blocking kernel for

  * any grid rank with streaming axis 0 (1D: stream only; 2D: 1-D blocking
    in x; 3D: 2-D blocking in (y, x) — the paper's §3.1 layouts), and
  * any *chain* of PE stages: ``par_time`` repeats of one stencil (the
    classic S=1 temporal chain) or a whole multi-stage
    :class:`~repro.programs.StencilProgram` unrolled ``par_time`` times —
    ``S*T`` fused stages per super-step, stage boundaries being just
    temporal steps with a different stencil/coeffs/BC (StencilFlow,
    arXiv:2010.15218).  Intermediates live only in the rolling VMEM windows:
    zero HBM round-trips.

Architecture (see DESIGN.md §2 and the original module docstrings, which
this kernel reproduces op-for-op for S=1):

  * one rolling circular slab window per chain entry, sized for *that*
    entry's radius (``2*ceil(rad_i/V)+1`` slots of ``par_vec`` rows) —
    heterogeneous radii pay only their own window;
  * chain entry ``i`` lags the stream head by ``Lag_i = sum_{u<=i}
    ceil(rad_u/V)`` slabs (the per-PE ``rad``-row lag of the paper,
    generalized to per-stage radii and vector slabs);
  * double-buffered async slab DMA in/out, prefetch stopping at the last
    real slab; drain runs ``nslabs + Lag_total`` ticks;
  * stream-axis BCs via per-row BC-mapped window gathers, blocked-axis BCs
    re-imposed on every pushed slab — both per *entry* (each stage reads its
    input under its own BC);
  * PE forwarding for partial super-steps: with ``steps < par_time`` real
    iterations remaining, entries ``i >= steps*S`` forward their input slab
    unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.core.blocking import BlockGeometry, stream_extension
from repro.core.stencils import Stencil


@dataclasses.dataclass(frozen=True)
class ChainStage:
    """One fused PE stage of a super-step chain (static kernel metadata)."""
    stencil: Stencil
    bc: object                    # BoundaryCondition or None (= clamp)
    coeff_lo: int                 # slice start into the packed coeff vector


def unroll_chain(stages, par_time: int) -> Tuple[ChainStage, ...]:
    """``stages`` (a tuple of ``(stencil, bc)`` per program stage) unrolled
    ``par_time`` times into the per-super-step PE chain, with each stage's
    offset into the packed coefficient vector."""
    lo, entries = 0, []
    for st, bc in stages:
        entries.append(ChainStage(st, bc, lo))
        lo += len(st.coeff_names)
    return tuple(entries) * par_time


def _chain_lags(chain, par_vec: int):
    """Per-entry slab radius ``R_i = ceil(rad_i/V)`` and cumulative lag
    ``Lag_i = sum_{u<=i} R_u`` (entry ``i`` computes slab ``k - Lag_i`` at
    stream tick ``k``)."""
    rs = [-(-e.stencil.radius // par_vec) for e in chain]
    return rs, list(itertools.accumulate(rs))


def _chain_kernel(*refs, chain, geom: BlockGeometry, ns: int, dom: int):
    nb = geom.ndim - 1                       # blocked (trailing) dims
    V = geom.par_vec
    L = len(chain)
    S = L // geom.par_time                   # program stages per iteration
    BS = geom.bsize
    CS = geom.csize
    h = geom.size_halo
    Rs, lag = _chain_lags(chain, V)
    Ws = [2 * r + 1 for r in Rs]             # window slots feeding entry i
    HA = (lag[-1] if L else 0) + 1           # aux window depth, in slabs
    nslabs = ns // V
    nticks = nslabs + (lag[-1] if L else 0)
    has_aux = any(e.stencil.has_aux for e in chain)
    blanks = (slice(None),) * nb

    # --- unpack the positional refs (operands, output, scratch) -------------
    steps_ref, coeff_ref, gp_ref = refs[0], refs[1], refs[2]
    p = 3
    aux_ref = None
    if has_aux:
        aux_ref, p = refs[p], p + 1
    out_ref, p = refs[p], p + 1
    wins, p = refs[p:p + L], p + L
    in_buf, in_sems, p = refs[p], refs[p + 1], p + 2
    aux_win = aux_buf = aux_sems = None
    if has_aux:
        aux_win, aux_buf, aux_sems = refs[p:p + 3]
        p += 3
    out_buf, out_sems = refs[p], refs[p + 1]

    starts = tuple(pl.program_id(d) * CS[d] for d in range(nb))
    steps = steps_ref[0, 0]
    iv = jax.lax.iota(jnp.int32, V)          # row offsets within a slab

    # --- per-stage coefficient dicts (shared across par_time repeats) -------
    # built at kernel top level: values read inside a pl.when branch must not
    # be reused by a later branch (cross-trace constants)
    cdicts = {}
    for e in chain:
        if e.coeff_lo not in cdicts:
            cdicts[e.coeff_lo] = {
                name: coeff_ref[0, e.coeff_lo + ci]
                for ci, name in enumerate(e.stencil.coeff_names)}

    def coeffs_of(entry):
        return cdicts[entry.coeff_lo]

    # --- blocked-axis boundary re-imposition, per entry BC ------------------
    # (only grid-edge blocks ever act; mirrors the former per-rank reclamps)
    iotas = [jax.lax.broadcasted_iota(jnp.int32, (V,) + BS, 1 + ax)
             for ax in range(nb)]
    los = tuple(h - s for s in starts)
    his = tuple((d - 1) + h - s for d, s in zip(geom.blocked_dims, starts))

    def _reimpose_axis(slab, kind, ax, fill):
        if kind == "periodic":
            # wrap-padded halos are exact translated copies: no re-imposition
            return slab
        n, axis = BS[ax], 1 + ax
        lo, hi, iota = los[ax], his[ax], iotas[ax]
        if kind == "constant":
            slab = jnp.where(iota < lo, fill, slab)
            return jnp.where(iota > hi, fill, slab)
        if kind == "reflect":
            flipped = jnp.flip(slab, axis=axis)
            mlo = jnp.roll(flipped, 2 * lo + 1 - n, axis=axis)
            mhi = jnp.roll(flipped, 2 * hi + 1 - n, axis=axis)
            slab = jnp.where(iota < lo, mlo, slab)
            return jnp.where(iota > hi, mhi, slab)
        sizes = tuple(1 if a == axis else s
                      for a, s in enumerate((V,) + BS))
        at = lambda p_: tuple(p_ if a == axis else 0     # noqa: E731
                              for a in range(1 + nb))
        lo_band = jax.lax.dynamic_slice(slab, at(jnp.clip(lo, 0, n - 1)),
                                        sizes)
        hi_band = jax.lax.dynamic_slice(slab, at(jnp.clip(hi, 0, n - 1)),
                                        sizes)
        slab = jnp.where(iota < lo, lo_band, slab)
        return jnp.where(iota > hi, hi_band, slab)

    def reclamp_for(bc):
        kinds = ("clamp",) * nb if bc is None else tuple(bc.kinds[1:])
        fill = 0.0 if bc is None else bc.value

        def reclamp(slab):
            for ax in range(nb):
                slab = _reimpose_axis(slab, kinds[ax], ax, fill)
            return slab
        return reclamp

    reclamps = [reclamp_for(e.bc) for e in chain]

    # --- DMA plumbing --------------------------------------------------------
    in_idx = tuple(pl.ds(s, b) for s, b in zip(starts, BS))
    out_idx = tuple(pl.ds(s + h, c) for s, c in zip(starts, CS))

    def in_copy(j, slot):
        src = jnp.clip(j, 0, nslabs - 1) * V
        return pltpu.make_async_copy(
            gp_ref.at[(pl.ds(src, V),) + in_idx],
            in_buf.at[slot], in_sems.at[slot])

    def aux_copy(j, slot):
        src = jnp.clip(j, 0, nslabs - 1) * V
        return pltpu.make_async_copy(
            aux_ref.at[(pl.ds(src, V),) + in_idx],
            aux_buf.at[slot], aux_sems.at[slot])

    def out_copy(j, slot):
        return pltpu.make_async_copy(
            out_buf.at[slot],
            out_ref.at[(pl.ds(j * V, V),) + out_idx], out_sems.at[slot])

    in_copy(0, 0).start()
    if has_aux:
        aux_copy(0, 0).start()

    def body(k, _):
        # wait input slab k; prefetch slab k+1 (both stop at the last real
        # slab — later ticks only drain the chain, fetching nothing)
        slot = k % 2

        @pl.when(k <= nslabs - 1)
        def _():
            in_copy(k, slot).wait()

        @pl.when(k + 1 <= nslabs - 1)
        def _():
            in_copy(k + 1, (k + 1) % 2).start()

        @pl.when(k <= nslabs - 1)
        def _():   # push the input slab into window 0 (pre-padded => BC-ok)
            wins[0][(pl.ds((k % Ws[0]) * V, V),) + blanks] = in_buf[slot]

        if has_aux:
            @pl.when(k <= nslabs - 1)
            def _():
                aux_copy(k, slot).wait()

            @pl.when(k + 1 <= nslabs - 1)
            def _():
                aux_copy(k + 1, (k + 1) % 2).start()

            @pl.when(k <= nslabs - 1)
            def _():
                aux_win[(pl.ds((k % HA) * V, V),) + blanks] = aux_buf[slot]

        # -- PE chain: entry i computes slab k - Lag_i -----------------------
        for i, entry in enumerate(chain):
            j = k - lag[i]
            R, W = Rs[i], Ws[i]
            newest = j + R               # newest slab entry i's producer owns

            @pl.when((j >= 0) & (j <= nslabs - 1))
            def _(i=i, entry=entry, j=j, R=R, W=W, newest=newest):
                # input slabs j-R..j+R of window i, in logical order
                cat = jnp.concatenate(
                    [wins[i][(pl.ds(((j + o) % W) * V, V),) + blanks]
                     for o in range(-R, R + 1)], axis=0)
                base = (j - R) * V       # logical stream row of cat[0]
                limit = jnp.minimum(newest * V + V - 1, dom - 1)
                kind_s = "clamp" if entry.bc is None else entry.bc.kinds[0]
                fill = 0.0 if entry.bc is None else entry.bc.value

                def stream_tap(ds_):
                    """(V, *BS) slab of stream rows ``j*V+ds_ ..`` with this
                    entry's stream-axis BC applied per row: clamp clips,
                    reflect mirrors (the target provably stays in the
                    window), constant overrides out-of-domain rows with the
                    fill; periodic was materialized as a stream extension by
                    the wrapper.  ``limit`` stops reads at the newest pushed
                    row."""
                    rows = j * V + ds_ + iv
                    if kind_s == "reflect":
                        p_ = max(2 * dom - 2, 1)
                        m = jnp.mod(rows, p_)
                        rows_m = jnp.where(m >= dom, p_ - m, m)
                    else:
                        rows_m = rows
                    pos = jnp.clip(rows_m, 0, limit) - base
                    vals = jnp.take(cat, pos, axis=0)
                    if kind_s == "constant":
                        oob = (rows < 0) | (rows > dom - 1)
                        vals = jnp.where(oob.reshape((V,) + (1,) * nb),
                                         fill, vals)
                    return vals

                # tap memo: one window gather per distinct stream offset,
                # one lane/sublane rotate per distinct full offset
                taps = {}
                zero = (0,) * nb

                def get(off):
                    ds_, db = off[0], tuple(off[1:])
                    tap = taps.get(tuple(off))
                    if tap is None:
                        tap = taps.get((ds_,) + zero)
                        if tap is None:
                            tap = taps[(ds_,) + zero] = stream_tap(ds_)
                        for ax, d in enumerate(db):
                            if d:
                                tap = jnp.roll(tap, -d, axis=1 + ax)
                        taps[tuple(off)] = tap
                    return tap

                aux_slab = None
                if entry.stencil.has_aux:
                    ja = jnp.clip(j, 0, nslabs - 1)
                    aux_slab = aux_win[(pl.ds((ja % HA) * V, V),) + blanks]
                val = entry.stencil.apply(get, coeffs_of(entry), aux_slab)
                # PE forwarding: with `steps` real iterations this super-step,
                # only entries of the first `steps` program repeats compute
                # (entry i belongs to repeat t = i // S + 1)
                val = jnp.where(i // S + 1 <= steps, val,
                                get((0,) * geom.ndim))
                if i < L - 1:
                    # re-impose the *consumer's* blocked-axis BC on the slab
                    wins[i + 1][(pl.ds((j % Ws[i + 1]) * V, V),) + blanks] = (
                        reclamps[i + 1](val))
                else:
                    oslot = j % 2

                    @pl.when(j >= 2)
                    def _():   # slot reuse: the previous copy must have drained
                        out_copy(j - 2, oslot).wait()

                    out_buf[oslot] = val[(slice(None),)
                                         + tuple(slice(h, h + c) for c in CS)]
                    out_copy(j, oslot).start()
        return 0

    jax.lax.fori_loop(0, nticks, body, 0)

    # drain outstanding output DMAs (last two slabs; nslabs is static)
    if nslabs >= 2:
        out_copy(nslabs - 2, (nslabs - 2) % 2).wait()
    out_copy(nslabs - 1, (nslabs - 1) % 2).wait()


@functools.partial(jax.jit,
                   static_argnames=("stages", "geom", "interpret",
                                    "block_parallel"))
def superstep_chain(stages, geom: BlockGeometry, gp: jnp.ndarray,
                    coeffs_packed: jnp.ndarray, steps: jnp.ndarray,
                    aux_p: Optional[jnp.ndarray] = None,
                    interpret: bool = True,
                    block_parallel: bool = False) -> jnp.ndarray:
    """One super-step (<= ``par_time`` fused program iterations) over the
    padded grid ``gp``, through the ``len(stages) * par_time``-entry PE
    chain.

    ``stages``: static tuple of ``(stencil, bc)`` per program stage (S=1
    recovers the classic single-operator super-step exactly — see
    ``superstep_2d``/``superstep_3d``).  ``gp``/``aux_p`` are BC-padded by
    the wrapper (``kernels/ops``) under stage 0's BC: blocked dims to
    ``bnum*csize + 2*halo``, the stream axis extended ``2*size_halo`` when
    periodic and padded up to a ``par_vec`` multiple.  Returns the padded
    output (only compute columns/rows are meaningful).

    ``block_parallel`` opts the kernel grid into Megacore ("parallel"
    dimension semantics): blocks are independent by construction, so the
    result is bit-identical to the sequential grid.
    """
    nb = geom.ndim - 1
    V = geom.par_vec
    ns = gp.shape[0]
    bc0 = stages[0][1]
    dom = geom.stream_dim + 2 * stream_extension(geom, bc0)
    if ns != geom.stream_slabs(dom) * V:
        raise ValueError(
            f"padded stream extent {ns} != ceil({dom}/{V})*{V} "
            f"= {geom.stream_slabs(dom) * V}: the wrapper must pad the "
            f"stream axis to a slab multiple (kernels/ops._pad_blocked)")
    chain = unroll_chain(stages, geom.par_time)
    Rs, lag = _chain_lags(chain, V)
    has_aux = any(st.has_aux for st, _ in stages)
    HA = lag[-1] + 1
    BS, CS = geom.bsize, geom.csize

    kernel = functools.partial(_chain_kernel, chain=chain, geom=geom,
                               ns=ns, dom=dom)
    # one rolling window per chain entry, sized for that entry's radius
    scratch = [pltpu.VMEM(((2 * r + 1) * V,) + BS, jnp.float32) for r in Rs]
    scratch += [pltpu.VMEM((2, V) + BS, jnp.float32),   # input double buffer
                pltpu.SemaphoreType.DMA((2,))]
    if has_aux:
        scratch += [pltpu.VMEM((HA * V,) + BS, jnp.float32),  # aux window
                    pltpu.VMEM((2, V) + BS, jnp.float32),
                    pltpu.SemaphoreType.DMA((2,))]
    scratch += [pltpu.VMEM((2, V) + CS, jnp.float32),   # output double buffer
                pltpu.SemaphoreType.DMA((2,))]

    n_hbm_in = 2 if has_aux else 1
    operands = (coeffs_packed.reshape(1, -1), gp) + (
        (aux_p,) if has_aux else ())
    steps_arr = jnp.asarray(steps, jnp.int32).reshape(1, 1)
    grid = geom.bnum if nb else (1,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM)]
        + [pl.BlockSpec(memory_space=pl.ANY)] * n_hbm_in,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=scratch,
        out_shape=jax.ShapeDtypeStruct(gp.shape, jnp.float32),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                ("parallel" if block_parallel else "arbitrary",) * len(grid))),
    )(steps_arr, *operands)
