"""Pure-jnp oracle: unblocked iterated stencil (ground truth for everything).

No spatial or temporal blocking — each time-step reads the whole grid and
writes the whole grid, with the paper's clamp boundary condition re-imposed
every step via edge-mode padding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stencils import Stencil


def oracle_step(stencil: Stencil, grid: jnp.ndarray, coeffs: dict,
                aux: jnp.ndarray | None = None) -> jnp.ndarray:
    """One time-step over the full grid (edge-replicated = clamped BC)."""
    r = stencil.radius
    p = jnp.pad(grid, r, mode="edge")

    def get(off):
        idx = tuple(slice(r + o, r + o + n) for o, n in zip(off, grid.shape))
        return p[idx]

    return stencil.apply(get, coeffs, aux)


def oracle_run(stencil: Stencil, grid: jnp.ndarray, coeffs: dict,
               iters: int, aux: jnp.ndarray | None = None) -> jnp.ndarray:
    """``iters`` time-steps (double-buffered in the caller's imagination —
    functionally pure here)."""
    def body(_, g):
        return oracle_step(stencil, g, coeffs, aux)
    return jax.lax.fori_loop(0, iters, body, grid)
