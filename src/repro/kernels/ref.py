"""Pure-jnp oracle: unblocked iterated stencil (ground truth for everything).

No spatial or temporal blocking — each time-step reads the whole grid and
writes the whole grid, with the boundary condition re-imposed every step via
per-axis padding.  The default BC is the paper's clamp (edge replication,
§5.1); any :class:`~repro.core.boundary.BoundaryCondition` is honored by
padding each axis with that axis' kind, which *defines* the mixed-BC corner
semantics every other backend is conformance-tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import boundary, precision
from repro.core.stencils import Stencil


def _padded_getter(grid: jnp.ndarray, r: int, bc=None):
    """Neighbor getter over ``grid`` BC-padded by ``r`` on every axis."""
    if bc is None or bc.is_clamp:
        p = jnp.pad(grid, r, mode="edge")
    else:
        p = grid
        for ax, kind in enumerate(bc.kinds):
            p = boundary.pad_axis(p, ax, r, r, kind, bc.value)

    def get(off):
        idx = tuple(slice(r + o, r + o + n) for o, n in zip(off, grid.shape))
        return p[idx]

    return get


def oracle_step(stencil: Stencil, grid: jnp.ndarray, coeffs: dict,
                aux: jnp.ndarray | None = None, *, bc=None) -> jnp.ndarray:
    """One time-step over the full grid under ``bc`` (default: clamp).

    Storage/accumulation policy (``repro.core.precision``): sub-32-bit
    grids (bf16) widen to f32 for the stage arithmetic and round back to
    storage once per application; f32 passes through apply() untouched."""
    get = _padded_getter(grid, stencil.radius, bc)
    return precision.apply_stage(stencil, get, coeffs, aux, grid.dtype)


def oracle_run(stencil: Stencil, grid: jnp.ndarray, coeffs: dict,
               iters: int, aux: jnp.ndarray | None = None, *,
               bc=None) -> jnp.ndarray:
    """``iters`` time-steps (double-buffered in the caller's imagination —
    functionally pure here)."""
    def body(_, g):
        return oracle_step(stencil, g, coeffs, aux, bc=bc)
    return jax.lax.fori_loop(0, iters, body, grid)


def oracle_program_step(stages, grid: jnp.ndarray, stage_coeffs,
                        aux: jnp.ndarray | None = None) -> jnp.ndarray:
    """One *program iteration*: apply every stage in order, each under its
    own BC.  ``stages`` is ``((stencil, bc), ...)``, ``stage_coeffs`` one
    coefficient dict per stage — the sequential semantics every fused chain
    backend is conformance-tested against."""
    for (st, bc_s), cf in zip(stages, stage_coeffs):
        grid = oracle_step(st, grid, cf, aux if st.has_aux else None,
                           bc=bc_s)
    return grid


def oracle_program_run(stages, grid: jnp.ndarray, stage_coeffs,
                       iters: int, aux: jnp.ndarray | None = None
                       ) -> jnp.ndarray:
    """``iters`` program iterations of the stage chain."""
    def body(_, g):
        return oracle_program_step(stages, g, stage_coeffs, aux)
    return jax.lax.fori_loop(0, iters, body, grid)


def oracle_dag_step(dag, state: jnp.ndarray, stage_coeffs,
                    aux: jnp.ndarray | None = None) -> jnp.ndarray:
    """One *program iteration* of a DAG (:class:`repro.programs.DagSpec`):
    stages evaluated in topological order — each input (field or earlier
    stage) read under the consuming stage's own BC — then every field
    updated simultaneously.  ``state`` is the plain grid for single-field
    programs, else the ``(F, *shape)`` field stack.  This is the sequential
    semantics every fused DAG backend is conformance-tested against."""
    F = dag.n_fields
    fields = [state[k] for k in range(F)] if F > 1 else [state]
    vals: list = [None] * len(dag.stages)
    for si in dag.topo:
        st, bc_s, refs = dag.stages[si]
        ins = [vals[r] if r >= 0 else fields[~r] for r in refs]
        gets = [_padded_getter(x, st.radius, bc_s) for x in ins]
        vals[si] = precision.apply_stage(
            st, tuple(gets) if st.arity > 1 else gets[0],
            stage_coeffs[si], aux if st.has_aux else None, state.dtype)
    new = [vals[u] if u >= 0 else fields[~u] for u in dag.updates]
    return jnp.stack(new) if F > 1 else new[0]


def oracle_dag_run(dag, state: jnp.ndarray, stage_coeffs, iters: int,
                   aux: jnp.ndarray | None = None) -> jnp.ndarray:
    """``iters`` program iterations of the stage DAG."""
    def body(_, s):
        return oracle_dag_step(dag, s, stage_coeffs, aux)
    return jax.lax.fori_loop(0, iters, body, state)
