"""Pure-jnp oracle: unblocked iterated stencil (ground truth for everything).

No spatial or temporal blocking — each time-step reads the whole grid and
writes the whole grid, with the boundary condition re-imposed every step via
per-axis padding.  The default BC is the paper's clamp (edge replication,
§5.1); any :class:`~repro.core.boundary.BoundaryCondition` is honored by
padding each axis with that axis' kind, which *defines* the mixed-BC corner
semantics every other backend is conformance-tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import boundary
from repro.core.stencils import Stencil


def oracle_step(stencil: Stencil, grid: jnp.ndarray, coeffs: dict,
                aux: jnp.ndarray | None = None, *, bc=None) -> jnp.ndarray:
    """One time-step over the full grid under ``bc`` (default: clamp)."""
    r = stencil.radius
    if bc is None or bc.is_clamp:
        p = jnp.pad(grid, r, mode="edge")
    else:
        p = grid
        for ax, kind in enumerate(bc.kinds):
            p = boundary.pad_axis(p, ax, r, r, kind, bc.value)

    def get(off):
        idx = tuple(slice(r + o, r + o + n) for o, n in zip(off, grid.shape))
        return p[idx]

    return stencil.apply(get, coeffs, aux)


def oracle_run(stencil: Stencil, grid: jnp.ndarray, coeffs: dict,
               iters: int, aux: jnp.ndarray | None = None, *,
               bc=None) -> jnp.ndarray:
    """``iters`` time-steps (double-buffered in the caller's imagination —
    functionally pure here)."""
    def body(_, g):
        return oracle_step(stencil, g, coeffs, aux, bc=bc)
    return jax.lax.fori_loop(0, iters, body, grid)


def oracle_program_step(stages, grid: jnp.ndarray, stage_coeffs,
                        aux: jnp.ndarray | None = None) -> jnp.ndarray:
    """One *program iteration*: apply every stage in order, each under its
    own BC.  ``stages`` is ``((stencil, bc), ...)``, ``stage_coeffs`` one
    coefficient dict per stage — the sequential semantics every fused chain
    backend is conformance-tested against."""
    for (st, bc_s), cf in zip(stages, stage_coeffs):
        grid = oracle_step(st, grid, cf, aux if st.has_aux else None,
                           bc=bc_s)
    return grid


def oracle_program_run(stages, grid: jnp.ndarray, stage_coeffs,
                       iters: int, aux: jnp.ndarray | None = None
                       ) -> jnp.ndarray:
    """``iters`` program iterations of the stage chain."""
    def body(_, g):
        return oracle_program_step(stages, g, stage_coeffs, aux)
    return jax.lax.fori_loop(0, iters, body, grid)
