"""Pallas TPU kernel: 3D stencil — 2-D spatial blocking (x,y), z streaming.

The 3D sibling of ``stencil2d.py`` (see that module + DESIGN.md §2 for the
architecture): this is the paper's 3.5D blocking — a ``(bsize_y, bsize_x)``
tile marches along z, with one rolling ``(2*rad+1)``-plane VMEM window per
temporal stage and double-buffered plane DMA.  Kernel grid is
``(bnum_y, bnum_x)``; halo re-clamping applies to both blocked dims.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.core.blocking import BlockGeometry
from repro.core.stencils import Stencil


def _kernel(steps_ref, coeff_ref, gp_ref, aux_ref, out_ref,
            win_ref, in_buf, in_sems, aux_win, aux_buf, aux_sems,
            out_buf, out_sems,
            *, stencil: Stencil, geom: BlockGeometry, nz: int,
            dimy: int, dimx: int, bc=None):
    T, rad = geom.par_time, geom.rad
    S = 2 * rad + 1
    BY, BX = geom.bsize
    CSY, CSX = geom.csize
    h = geom.size_halo
    HA = h + 1
    by, bx = pl.program_id(0), pl.program_id(1)
    ys, xs = by * CSY, bx * CSX
    nticks = nz + h
    steps = steps_ref[0, 0]
    kind_s = "clamp" if bc is None else bc.kinds[0]
    kind_y = "clamp" if bc is None else bc.kinds[1]
    kind_x = "clamp" if bc is None else bc.kinds[2]
    fill = 0.0 if bc is None else bc.value

    coeffs = {name: coeff_ref[0, i]
              for i, name in enumerate(stencil.coeff_names)}

    # --- (y, x) boundary re-imposition: only grid-edge blocks act -----------
    # Per-axis dispatch mirrors stencil2d.reclamp_x: clamp overwrites the
    # out-of-grid band with the edge row/col, reflect with the mirrored one
    # (flip+roll), constant with the fill scalar; periodic skips (wrap-padded
    # halos are exact translated copies, covered by garbage creep).
    lo_y, hi_y = h - ys, (dimy - 1) + h - ys
    lo_x, hi_x = h - xs, (dimx - 1) + h - xs
    iota_y = jax.lax.broadcasted_iota(jnp.int32, (1, BY, BX), 1)
    iota_x = jax.lax.broadcasted_iota(jnp.int32, (1, BY, BX), 2)

    def _reimpose_axis(plane, kind, axis, n, lo, hi, iota):
        if kind == "periodic":
            return plane
        if kind == "constant":
            plane = jnp.where(iota < lo, fill, plane)
            return jnp.where(iota > hi, fill, plane)
        if kind == "reflect":
            flipped = jnp.flip(plane, axis=axis)
            mlo = jnp.roll(flipped, 2 * lo + 1 - n, axis=axis)
            mhi = jnp.roll(flipped, 2 * hi + 1 - n, axis=axis)
            plane = jnp.where(iota < lo, mlo, plane)
            return jnp.where(iota > hi, mhi, plane)
        sizes = (1, 1, BX) if axis == 1 else (1, BY, 1)
        at = lambda p: ((0, p, 0) if axis == 1 else (0, 0, p))  # noqa: E731
        lo_band = jax.lax.dynamic_slice(plane, at(jnp.clip(lo, 0, n - 1)),
                                        sizes)
        hi_band = jax.lax.dynamic_slice(plane, at(jnp.clip(hi, 0, n - 1)),
                                        sizes)
        plane = jnp.where(iota < lo, lo_band, plane)
        return jnp.where(iota > hi, hi_band, plane)

    def reclamp(plane):
        plane = _reimpose_axis(plane, kind_y, 1, BY, lo_y, hi_y, iota_y)
        return _reimpose_axis(plane, kind_x, 2, BX, lo_x, hi_x, iota_x)

    # --- DMA plumbing --------------------------------------------------------
    def in_copy(k, slot):
        src = jnp.clip(k, 0, nz - 1)
        return pltpu.make_async_copy(
            gp_ref.at[pl.ds(src, 1), pl.ds(ys, BY), pl.ds(xs, BX)],
            in_buf.at[slot], in_sems.at[slot])

    def aux_copy(k, slot):
        src = jnp.clip(k, 0, nz - 1)
        return pltpu.make_async_copy(
            aux_ref.at[pl.ds(src, 1), pl.ds(ys, BY), pl.ds(xs, BX)],
            aux_buf.at[slot], aux_sems.at[slot])

    def out_copy(z, slot):
        return pltpu.make_async_copy(
            out_buf.at[slot],
            out_ref.at[pl.ds(z, 1), pl.ds(ys + h, CSY), pl.ds(xs + h, CSX)],
            out_sems.at[slot])

    has_aux = aux_ref is not None
    in_copy(0, 0).start()
    if has_aux:
        aux_copy(0, 0).start()

    def read_win(t, plane_i, newest):
        # stream-axis BC: clamp clips, reflect mirrors (target stays within
        # the S-deep window), constant overrides with the fill; periodic is a
        # stream extension materialized by the wrapper (edge reads here are
        # garbage-tolerant clips).  See stencil2d.read_win.
        if kind_s == "reflect":
            p_ = max(2 * nz - 2, 1)
            m = jnp.mod(plane_i, p_)
            plane_m = jnp.where(m >= nz, p_ - m, m)
        else:
            plane_m = plane_i
        r = jnp.clip(plane_m, 0, jnp.minimum(newest, nz - 1))
        vals = win_ref[t, pl.ds(r % S, 1), :, :]
        if kind_s == "constant":
            vals = jnp.where((plane_i < 0) | (plane_i > nz - 1), fill, vals)
        return vals

    def body(k, _):
        # Planes past nz-1 are never pushed and read_win clamps to the last
        # pushed plane; stop the prefetch (and its matching wait) at the last
        # real plane instead of fetching clamped re-reads out to nticks.
        slot = k % 2

        @pl.when(k <= nz - 1)
        def _():
            in_copy(k, slot).wait()

        @pl.when(k + 1 <= nz - 1)
        def _():
            in_copy(k + 1, (k + 1) % 2).start()

        @pl.when(k <= nz - 1)
        def _():
            win_ref[0, pl.ds(k % S, 1), :, :] = in_buf[slot]

        if has_aux:
            @pl.when(k <= nz - 1)
            def _():
                aux_copy(k, slot).wait()

            @pl.when(k + 1 <= nz - 1)
            def _():
                aux_copy(k + 1, (k + 1) % 2).start()

            @pl.when(k <= nz - 1)
            def _():
                aux_win[pl.ds(k % HA, 1), :, :] = aux_buf[slot]

        for t in range(1, T + 1):
            z = k - t * rad
            newest = k - (t - 1) * rad

            @pl.when((z >= 0) & (z <= nz - 1))
            def _(t=t, z=z, newest=newest):
                planes = {dz: read_win(t - 1, z + dz, newest)
                          for dz in range(-rad, rad + 1)}

                def get(off):
                    dz, dy, dx = off
                    p = planes[dz]
                    if dy:
                        p = jnp.roll(p, -dy, axis=1)
                    if dx:
                        p = jnp.roll(p, -dx, axis=2)
                    return p

                aux_plane = None
                if has_aux:
                    ra = jnp.clip(z, 0, nz - 1)
                    aux_plane = aux_win[pl.ds(ra % HA, 1), :, :]
                val = stencil.apply(get, coeffs, aux_plane)
                val = jnp.where(t <= steps, val, planes[0])  # PE forwarding
                if t < T:
                    win_ref[t, pl.ds(z % S, 1), :, :] = reclamp(val)
                else:
                    oslot = z % 2

                    @pl.when(z >= 2)
                    def _():
                        out_copy(z - 2, oslot).wait()

                    out_buf[oslot] = val[:, h:h + CSY, h:h + CSX]
                    out_copy(z, oslot).start()
        return 0

    jax.lax.fori_loop(0, nticks, body, 0)

    if nz >= 2:
        out_copy(nz - 2, (nz - 2) % 2).wait()
    out_copy(nz - 1, (nz - 1) % 2).wait()


@functools.partial(jax.jit,
                   static_argnames=("stencil", "geom", "interpret", "bc"))
def superstep_3d(stencil: Stencil, geom: BlockGeometry, gp: jnp.ndarray,
                 coeffs_packed: jnp.ndarray, steps: jnp.ndarray,
                 aux_p: Optional[jnp.ndarray] = None,
                 interpret: bool = True, bc=None) -> jnp.ndarray:
    nz, nyp, nxp = gp.shape
    T, rad = geom.par_time, geom.rad
    S = 2 * rad + 1
    BY, BX = geom.bsize
    CSY, CSX = geom.csize
    dimy, dimx = geom.blocked_dims

    kernel = functools.partial(_kernel, stencil=stencil, geom=geom,
                               nz=nz, dimy=dimy, dimx=dimx, bc=bc)
    scratch = [
        pltpu.VMEM((T, S, BY, BX), jnp.float32),
        pltpu.VMEM((2, 1, BY, BX), jnp.float32),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.VMEM((geom.size_halo + 1, BY, BX), jnp.float32) if stencil.has_aux else None,
        pltpu.VMEM((2, 1, BY, BX), jnp.float32) if stencil.has_aux else None,
        pltpu.SemaphoreType.DMA((2,)) if stencil.has_aux else None,
        pltpu.VMEM((2, 1, CSY, CSX), jnp.float32),
        pltpu.SemaphoreType.DMA((2,)),
    ]
    if not stencil.has_aux:
        scratch = [s for s in scratch if s is not None]

        def kernel_noaux(steps_ref, coeff_ref, gp_ref, out_ref,
                         win_ref, in_buf, in_sems, out_buf, out_sems):
            return _kernel(steps_ref, coeff_ref, gp_ref, None, out_ref,
                           win_ref, in_buf, in_sems, None, None, None,
                           out_buf, out_sems, stencil=stencil, geom=geom,
                           nz=nz, dimy=dimy, dimx=dimx, bc=bc)
        kernel = kernel_noaux

    n_hbm_in = 2 if stencil.has_aux else 1
    operands = (coeffs_packed.reshape(1, -1), gp) + (
        (aux_p,) if stencil.has_aux else ())
    steps_arr = jnp.asarray(steps, jnp.int32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(geom.bnum[0], geom.bnum[1]),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM)]
        + [pl.BlockSpec(memory_space=pl.ANY)] * n_hbm_in,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=scratch,
        out_shape=jax.ShapeDtypeStruct((nz, nyp, nxp), jnp.float32),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(steps_arr, *operands)
