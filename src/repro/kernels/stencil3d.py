"""Pallas TPU kernel: 3D stencil — 2-D spatial blocking (x,y), z streaming.

The 3D sibling of ``stencil2d.py`` (see that module + DESIGN.md §2 for the
architecture): this is the paper's 3.5D blocking — a ``(bsize_y, bsize_x)``
tile marches along z, ``par_vec`` planes per tick, with one rolling
``win_slots``-slab VMEM window per temporal stage (a slab is ``par_vec``
planes) and double-buffered slab DMA.  Kernel grid is ``(bnum_y, bnum_x)``;
halo re-clamping applies to both blocked dims.  Stream (z) taps are
BC-mapped per plane and gathered from the window, exactly like the 2D
kernel's per-row maps; the per-stage tap memo computes each distinct ``dz``
window gather and each distinct ``(dz, dy, dx)`` in-plane rotate once per
tick.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.core.blocking import BlockGeometry, stream_extension
from repro.core.stencils import Stencil


def _kernel(steps_ref, coeff_ref, gp_ref, aux_ref, out_ref,
            win_ref, in_buf, in_sems, aux_win, aux_buf, aux_sems,
            out_buf, out_sems,
            *, stencil: Stencil, geom: BlockGeometry, ns: int, dom: int,
            dimy: int, dimx: int, bc=None):
    T, rad, V = geom.par_time, geom.rad, geom.par_vec
    R = geom.slab_lag
    W = geom.win_slots
    BY, BX = geom.bsize
    CSY, CSX = geom.csize
    h = geom.size_halo
    HA = T * R + 1
    nslabs = ns // V
    by, bx = pl.program_id(0), pl.program_id(1)
    ys, xs = by * CSY, bx * CSX
    nticks = nslabs + T * R
    steps = steps_ref[0, 0]
    kind_s = "clamp" if bc is None else bc.kinds[0]
    kind_y = "clamp" if bc is None else bc.kinds[1]
    kind_x = "clamp" if bc is None else bc.kinds[2]
    fill = 0.0 if bc is None else bc.value
    iv = jax.lax.iota(jnp.int32, V)          # plane offsets within a slab

    coeffs = {name: coeff_ref[0, i]
              for i, name in enumerate(stencil.coeff_names)}

    # --- (y, x) boundary re-imposition: only grid-edge blocks act -----------
    # Per-axis dispatch mirrors stencil2d.reclamp_x: clamp overwrites the
    # out-of-grid band with the edge row/col, reflect with the mirrored one
    # (flip+roll), constant with the fill scalar; periodic skips (wrap-padded
    # halos are exact translated copies, covered by garbage creep).
    lo_y, hi_y = h - ys, (dimy - 1) + h - ys
    lo_x, hi_x = h - xs, (dimx - 1) + h - xs
    iota_y = jax.lax.broadcasted_iota(jnp.int32, (V, BY, BX), 1)
    iota_x = jax.lax.broadcasted_iota(jnp.int32, (V, BY, BX), 2)

    def _reimpose_axis(slab, kind, axis, n, lo, hi, iota):
        if kind == "periodic":
            return slab
        if kind == "constant":
            slab = jnp.where(iota < lo, fill, slab)
            return jnp.where(iota > hi, fill, slab)
        if kind == "reflect":
            flipped = jnp.flip(slab, axis=axis)
            mlo = jnp.roll(flipped, 2 * lo + 1 - n, axis=axis)
            mhi = jnp.roll(flipped, 2 * hi + 1 - n, axis=axis)
            slab = jnp.where(iota < lo, mlo, slab)
            return jnp.where(iota > hi, mhi, slab)
        sizes = (V, 1, BX) if axis == 1 else (V, BY, 1)
        at = lambda p: ((0, p, 0) if axis == 1 else (0, 0, p))  # noqa: E731
        lo_band = jax.lax.dynamic_slice(slab, at(jnp.clip(lo, 0, n - 1)),
                                        sizes)
        hi_band = jax.lax.dynamic_slice(slab, at(jnp.clip(hi, 0, n - 1)),
                                        sizes)
        slab = jnp.where(iota < lo, lo_band, slab)
        return jnp.where(iota > hi, hi_band, slab)

    def reclamp(slab):
        slab = _reimpose_axis(slab, kind_y, 1, BY, lo_y, hi_y, iota_y)
        return _reimpose_axis(slab, kind_x, 2, BX, lo_x, hi_x, iota_x)

    # --- DMA plumbing --------------------------------------------------------
    def in_copy(j, slot):
        src = jnp.clip(j, 0, nslabs - 1) * V
        return pltpu.make_async_copy(
            gp_ref.at[pl.ds(src, V), pl.ds(ys, BY), pl.ds(xs, BX)],
            in_buf.at[slot], in_sems.at[slot])

    def aux_copy(j, slot):
        src = jnp.clip(j, 0, nslabs - 1) * V
        return pltpu.make_async_copy(
            aux_ref.at[pl.ds(src, V), pl.ds(ys, BY), pl.ds(xs, BX)],
            aux_buf.at[slot], aux_sems.at[slot])

    def out_copy(j, slot):
        return pltpu.make_async_copy(
            out_buf.at[slot],
            out_ref.at[pl.ds(j * V, V), pl.ds(ys + h, CSY),
                       pl.ds(xs + h, CSX)],
            out_sems.at[slot])

    has_aux = aux_ref is not None
    in_copy(0, 0).start()
    if has_aux:
        aux_copy(0, 0).start()

    def body(k, _):
        # Slabs past nslabs-1 are never pushed and stream taps clamp to the
        # last pushed plane; stop the prefetch (and its matching wait) at the
        # last real slab instead of fetching clamped re-reads out to nticks.
        slot = k % 2

        @pl.when(k <= nslabs - 1)
        def _():
            in_copy(k, slot).wait()

        @pl.when(k + 1 <= nslabs - 1)
        def _():
            in_copy(k + 1, (k + 1) % 2).start()

        @pl.when(k <= nslabs - 1)
        def _():
            win_ref[0, pl.ds((k % W) * V, V), :, :] = in_buf[slot]

        if has_aux:
            @pl.when(k <= nslabs - 1)
            def _():
                aux_copy(k, slot).wait()

            @pl.when(k + 1 <= nslabs - 1)
            def _():
                aux_copy(k + 1, (k + 1) % 2).start()

            @pl.when(k <= nslabs - 1)
            def _():
                aux_win[pl.ds((k % HA) * V, V), :, :] = aux_buf[slot]

        for t in range(1, T + 1):
            j = k - t * R
            newest = k - (t - 1) * R

            @pl.when((j >= 0) & (j <= nslabs - 1))
            def _(t=t, j=j, newest=newest):
                cat = jnp.concatenate(
                    [win_ref[t - 1, pl.ds(((j + o) % W) * V, V), :, :]
                     for o in range(-R, R + 1)], axis=0)
                base = (j - R) * V
                limit = jnp.minimum(newest * V + V - 1, dom - 1)

                def stream_tap(dz):
                    # stream-axis BC, per plane of the slab: clamp clips,
                    # reflect mirrors (target stays within the window),
                    # constant overrides with the fill; periodic is a stream
                    # extension materialized by the wrapper (edge reads here
                    # are garbage-tolerant clips).  See stencil2d.
                    planes = j * V + dz + iv
                    if kind_s == "reflect":
                        p_ = max(2 * dom - 2, 1)
                        m = jnp.mod(planes, p_)
                        planes_m = jnp.where(m >= dom, p_ - m, m)
                    else:
                        planes_m = planes
                    pos = jnp.clip(planes_m, 0, limit) - base
                    vals = jnp.take(cat, pos, axis=0)
                    if kind_s == "constant":
                        oob = (planes < 0) | (planes > dom - 1)
                        vals = jnp.where(oob[:, None, None], fill, vals)
                    return vals

                taps = {}

                def get(off):
                    dz, dy, dx = off
                    tap = taps.get(off)
                    if tap is None:
                        tap = taps.get((dz, 0, 0))
                        if tap is None:
                            tap = taps[(dz, 0, 0)] = stream_tap(dz)
                        if dy:
                            tap = jnp.roll(tap, -dy, axis=1)
                        if dx:
                            tap = jnp.roll(tap, -dx, axis=2)
                        taps[off] = tap
                    return tap

                aux_slab = None
                if has_aux:
                    ja = jnp.clip(j, 0, nslabs - 1)
                    aux_slab = aux_win[pl.ds((ja % HA) * V, V), :, :]
                val = stencil.apply(get, coeffs, aux_slab)
                val = jnp.where(t <= steps, val, get((0, 0, 0)))  # forwarding
                if t < T:
                    win_ref[t, pl.ds((j % W) * V, V), :, :] = reclamp(val)
                else:
                    oslot = j % 2

                    @pl.when(j >= 2)
                    def _():
                        out_copy(j - 2, oslot).wait()

                    out_buf[oslot] = val[:, h:h + CSY, h:h + CSX]
                    out_copy(j, oslot).start()
        return 0

    jax.lax.fori_loop(0, nticks, body, 0)

    if nslabs >= 2:
        out_copy(nslabs - 2, (nslabs - 2) % 2).wait()
    out_copy(nslabs - 1, (nslabs - 1) % 2).wait()


@functools.partial(jax.jit,
                   static_argnames=("stencil", "geom", "interpret", "bc",
                                    "block_parallel"))
def superstep_3d(stencil: Stencil, geom: BlockGeometry, gp: jnp.ndarray,
                 coeffs_packed: jnp.ndarray, steps: jnp.ndarray,
                 aux_p: Optional[jnp.ndarray] = None,
                 interpret: bool = True, bc=None,
                 block_parallel: bool = False) -> jnp.ndarray:
    ns, nyp, nxp = gp.shape
    T, V = geom.par_time, geom.par_vec
    W = geom.win_slots
    HA = T * geom.slab_lag + 1
    BY, BX = geom.bsize
    CSY, CSX = geom.csize
    dimy, dimx = geom.blocked_dims
    dom = geom.stream_dim + 2 * stream_extension(geom, bc)
    if ns != geom.stream_slabs(dom) * V:
        raise ValueError(
            f"padded stream extent {ns} != ceil({dom}/{V})*{V} "
            f"= {geom.stream_slabs(dom) * V}: the wrapper must pad the "
            f"stream axis to a slab multiple (kernels/ops._pad_blocked)")

    kernel = functools.partial(_kernel, stencil=stencil, geom=geom,
                               ns=ns, dom=dom, dimy=dimy, dimx=dimx, bc=bc)
    scratch = [
        pltpu.VMEM((T, W * V, BY, BX), jnp.float32),
        pltpu.VMEM((2, V, BY, BX), jnp.float32),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.VMEM((HA * V, BY, BX), jnp.float32) if stencil.has_aux else None,
        pltpu.VMEM((2, V, BY, BX), jnp.float32) if stencil.has_aux else None,
        pltpu.SemaphoreType.DMA((2,)) if stencil.has_aux else None,
        pltpu.VMEM((2, V, CSY, CSX), jnp.float32),
        pltpu.SemaphoreType.DMA((2,)),
    ]
    if not stencil.has_aux:
        scratch = [s for s in scratch if s is not None]

        def kernel_noaux(steps_ref, coeff_ref, gp_ref, out_ref,
                         win_ref, in_buf, in_sems, out_buf, out_sems):
            return _kernel(steps_ref, coeff_ref, gp_ref, None, out_ref,
                           win_ref, in_buf, in_sems, None, None, None,
                           out_buf, out_sems, stencil=stencil, geom=geom,
                           ns=ns, dom=dom, dimy=dimy, dimx=dimx, bc=bc)
        kernel = kernel_noaux

    n_hbm_in = 2 if stencil.has_aux else 1
    operands = (coeffs_packed.reshape(1, -1), gp) + (
        (aux_p,) if stencil.has_aux else ())
    steps_arr = jnp.asarray(steps, jnp.int32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(geom.bnum[0], geom.bnum[1]),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM)]
        + [pl.BlockSpec(memory_space=pl.ANY)] * n_hbm_in,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=scratch,
        out_shape=jax.ShapeDtypeStruct((ns, nyp, nxp), jnp.float32),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                ("parallel", "parallel") if block_parallel
                else ("arbitrary", "arbitrary"))),
    )(steps_arr, *operands)
