"""3D streaming kernel — compatibility shim over ``kernels.builder``.

The rank-specialized 3D (3.5D-blocking) kernel that used to live here is now
the ``nb=2``, ``S=1`` specialization of the rank- and stage-generic chain
builder (:mod:`repro.kernels.builder`).  ``superstep_3d`` keeps its exact
legacy signature and semantics.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.blocking import BlockGeometry
from repro.core.stencils import Stencil
from repro.kernels.builder import superstep_chain


def superstep_3d(stencil: Stencil, geom: BlockGeometry, gp: jnp.ndarray,
                 coeffs_packed: jnp.ndarray, steps: jnp.ndarray,
                 aux_p: Optional[jnp.ndarray] = None,
                 interpret: bool = True, bc=None,
                 block_parallel: bool = False) -> jnp.ndarray:
    """One super-step (<= par_time fused time-steps) over the padded grid —
    the single-stage 3D chain (see :func:`repro.kernels.builder.superstep_chain`)."""
    return superstep_chain(((stencil, bc),), geom, gp, coeffs_packed, steps,
                           aux_p, interpret=interpret,
                           block_parallel=block_parallel)
