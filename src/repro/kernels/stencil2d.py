"""Pallas TPU kernel: 2D stencil with combined spatial + temporal blocking.

Faithful TPU re-architecture of the paper's accelerator (see DESIGN.md §2):

  * 1-D spatial blocking in x, streaming in y (paper §3.1): kernel grid is
    ``(bnum_x,)``; each program owns one overlapped block of width ``bsize``
    and streams the full y extent row by row.
  * Shift registers → **rolling VMEM windows**: one ``(2*rad+1, bsize)``
    circular row window per temporal stage, indexed mod-S (incrementing the
    start address of the FPGA shift register == bumping the mod-S slot).
  * PE chain → **fused stage loop**: stage ``t`` computes its row ``k - t*rad``
    at stream tick ``k`` — the same ``rad``-row lag the paper gives each PE.
  * read/write kernels + channels → **double-buffered async DMA**
    (``pltpu.make_async_copy``): row ``k+1`` is in flight while row ``k`` is
    consumed; output rows stream back through a 2-deep buffer.
  * Halos are computed redundantly; only the ``csize``-wide compute region is
    DMA'd out (the paper's "control only the flow of writes"). Out-of-bound
    compute lands in padding the wrapper slices off.
  * PE forwarding (paper §3.2): when fewer than ``par_time`` steps remain, the
    trailing stages forward their input row unchanged (runtime ``steps``
    scalar in SMEM).

Boundary handling (DESIGN.md §2.1, generalized by ``core.boundary``): the
streaming-axis BC is exact via BC-mapped window reads (clamp clips, reflect
mirrors — both targets provably live inside the rolling window — constant
overrides with the fill scalar); the blocked-axis BC is re-imposed on every
pushed row (prefix/suffix overwrite from the mapped in-row position — only
the first/last block ever does real work here).  Periodic axes take neither
path: the wrapper materializes the wrap in HBM (wrap-mode padding; for the
streaming axis an explicit 2*halo stream extension, since the rolling window
cannot reach the far end of the stream) and the wrapped halos stay exact up
to the standard garbage creep, exactly like interior block seams.

TPU-shape notes: rows are ``(1, bsize)`` f32 with ``bsize % 128 == 0``;
in-row shifts use ``jnp.roll`` (lane rotate; swap for ``pltpu.roll`` on a
sublane-tiled layout if Mosaic rejects the 1-row form). Mosaic pads the
``(2*rad+1)``-deep windows to 8 sublanes — accounted in the perf model's
VMEM budget via ``BlockGeometry.vmem_bytes``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.core.blocking import BlockGeometry
from repro.core.stencils import Stencil


def _kernel(steps_ref,                      # SMEM (1,1) int32: real steps
            coeff_ref,                      # VMEM (1, n_coeff) f32
            gp_ref,                         # ANY (ny, nxp): padded input
            aux_ref,                        # ANY (ny, nxp) or None
            out_ref,                        # ANY (ny, nxp): padded output
            win_ref,                        # VMEM (T, S, BX): stage windows
            in_buf, in_sems,                # VMEM (2,1,BX) + 2 DMA sems
            aux_win,                        # VMEM (HA, BX) aux window or None
            aux_buf, aux_sems,              # (2,1,BX) + sems, or None
            out_buf, out_sems,              # VMEM (2,1,CS) + 2 DMA sems
            *, stencil: Stencil, geom: BlockGeometry, ny: int, dimx: int,
            bc=None):
    T, rad = geom.par_time, geom.rad
    S = 2 * rad + 1
    BX = geom.bsize[0]
    CS = geom.csize[0]
    h = geom.size_halo
    HA = h + 1
    b = pl.program_id(0)
    xs = b * CS                              # block start col in padded grid
    nticks = ny + h
    steps = steps_ref[0, 0]
    kind_s = "clamp" if bc is None else bc.kinds[0]
    kind_x = "clamp" if bc is None else bc.kinds[1]
    fill = 0.0 if bc is None else bc.value

    coeffs = {name: coeff_ref[0, i]
              for i, name in enumerate(stencil.coeff_names)}

    # --- x boundary re-imposition (blocked dim): only first/last block act --
    lo = h - xs                              # positions j < lo are left of grid
    hi = (dimx - 1) + h - xs                 # positions j > hi are right of grid
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, BX), 1)

    def reclamp_x(row):
        if kind_x == "periodic":
            # wrap-padded halos are exact translated copies: no re-imposition
            # (garbage creep is covered by the halo, as between blocks)
            return row
        if kind_x == "constant":
            row = jnp.where(iota < lo, fill, row)
            return jnp.where(iota > hi, fill, row)
        if kind_x == "reflect":
            # out[j] = row[2*lo - j] for j < lo (mirror about the edge cell);
            # flip+roll keeps the per-position gather Mosaic-friendly
            flipped = jnp.flip(row, axis=1)
            mlo = jnp.roll(flipped, 2 * lo + 1 - BX, axis=1)
            mhi = jnp.roll(flipped, 2 * hi + 1 - BX, axis=1)
            row = jnp.where(iota < lo, mlo, row)
            return jnp.where(iota > hi, mhi, row)
        lo_val = jax.lax.dynamic_slice(row, (0, jnp.clip(lo, 0, BX - 1)), (1, 1))
        hi_val = jax.lax.dynamic_slice(row, (0, jnp.clip(hi, 0, BX - 1)), (1, 1))
        row = jnp.where(iota < lo, lo_val, row)
        return jnp.where(iota > hi, hi_val, row)

    # --- DMA plumbing --------------------------------------------------------
    def in_copy(k, slot):
        src = jnp.clip(k, 0, ny - 1)
        return pltpu.make_async_copy(
            gp_ref.at[pl.ds(src, 1), pl.ds(xs, BX)],
            in_buf.at[slot], in_sems.at[slot])

    def aux_copy(k, slot):
        src = jnp.clip(k, 0, ny - 1)
        return pltpu.make_async_copy(
            aux_ref.at[pl.ds(src, 1), pl.ds(xs, BX)],
            aux_buf.at[slot], aux_sems.at[slot])

    def out_copy(y, slot):
        return pltpu.make_async_copy(
            out_buf.at[slot],
            out_ref.at[pl.ds(y, 1), pl.ds(xs + h, CS)], out_sems.at[slot])

    has_aux = aux_ref is not None
    in_copy(0, 0).start()
    if has_aux:
        aux_copy(0, 0).start()

    def read_win(t, row, newest):
        """Stage-t window row with the stream-axis BC applied (row may be out
        of grid).  clamp clips; reflect mirrors (the mirror target is within
        ``rad`` of the edge, hence provably still in the S-deep window);
        constant reads any in-window row and overrides with the fill;
        periodic was materialized as a stream extension by the wrapper, so
        edge reads here are garbage-tolerant clips.  ``newest`` bounds the
        clip so we never read an unpushed slot."""
        if kind_s == "reflect":
            p_ = max(2 * ny - 2, 1)
            m = jnp.mod(row, p_)
            row_m = jnp.where(m >= ny, p_ - m, m)
        else:
            row_m = row
        r = jnp.clip(row_m, 0, jnp.minimum(newest, ny - 1))
        vals = win_ref[t, pl.ds(r % S, 1), :]
        if kind_s == "constant":
            vals = jnp.where((row < 0) | (row > ny - 1), fill, vals)
        return vals

    def body(k, _):
        # -- wait input row k; prefetch row k+1 into the other buffer --------
        # Rows past ny-1 are never pushed (the window push below is gated at
        # k <= ny-1) and read_win clamps to the last pushed row, so fetching
        # them would be pure waste: stop both the prefetch and its matching
        # wait at the last real row instead of running to nticks.
        slot = k % 2

        @pl.when(k <= ny - 1)
        def _():
            in_copy(k, slot).wait()

        @pl.when(k + 1 <= ny - 1)
        def _():
            in_copy(k + 1, (k + 1) % 2).start()

        @pl.when(k <= ny - 1)
        def _():   # push input row into the stage-0 window (pre-padded => BC-ok)
            win_ref[0, pl.ds(k % S, 1), :] = in_buf[slot]

        if has_aux:
            @pl.when(k <= ny - 1)
            def _():
                aux_copy(k, slot).wait()

            @pl.when(k + 1 <= ny - 1)
            def _():
                aux_copy(k + 1, (k + 1) % 2).start()

            @pl.when(k <= ny - 1)
            def _():
                aux_win[pl.ds(k % HA, 1), :] = aux_buf[slot]

        # -- PE chain: stage t computes row k - t*rad -------------------------
        for t in range(1, T + 1):
            y = k - t * rad
            newest = k - (t - 1) * rad       # newest row stage t-1 can own

            @pl.when((y >= 0) & (y <= ny - 1))
            def _(t=t, y=y, newest=newest):
                rows = {dy: read_win(t - 1, y + dy, newest)
                        for dy in range(-rad, rad + 1)}

                def get(off):
                    dy, dx = off
                    r = rows[dy]
                    return jnp.roll(r, -dx, axis=1) if dx else r

                aux_row = None
                if has_aux:
                    ra = jnp.clip(y, 0, ny - 1)
                    aux_row = aux_win[pl.ds(ra % HA, 1), :]
                val = stencil.apply(get, coeffs, aux_row)
                # PE forwarding: inactive stages copy their input row through.
                val = jnp.where(t <= steps, val, rows[0])
                if t < T:
                    win_ref[t, pl.ds(y % S, 1), :] = reclamp_x(val)
                else:
                    oslot = y % 2

                    @pl.when(y >= 2)
                    def _():   # slot reuse: previous copy must have drained
                        out_copy(y - 2, oslot).wait()

                    out_buf[oslot] = val[:, h:h + CS]
                    out_copy(y, oslot).start()
        return 0

    jax.lax.fori_loop(0, nticks, body, 0)

    # drain outstanding output DMAs (last two rows; ny is static)
    if ny >= 2:
        out_copy(ny - 2, (ny - 2) % 2).wait()
    out_copy(ny - 1, (ny - 1) % 2).wait()


@functools.partial(jax.jit,
                   static_argnames=("stencil", "geom", "interpret", "bc"))
def superstep_2d(stencil: Stencil, geom: BlockGeometry, gp: jnp.ndarray,
                 coeffs_packed: jnp.ndarray, steps: jnp.ndarray,
                 aux_p: Optional[jnp.ndarray] = None,
                 interpret: bool = True, bc=None) -> jnp.ndarray:
    """One super-step (<= par_time fused time-steps) over the padded grid.

    ``gp``/``aux_p``: BC-padded to (ny, bnum*csize + 2*halo) — plus a
    2*halo stream extension when the streaming-axis BC is periodic (the
    wrapper's job; ``ny`` here is whatever streams).  Returns the padded
    output (only compute columns/rows are meaningful).
    """
    ny, nxp = gp.shape
    T, rad = geom.par_time, geom.rad
    S = 2 * rad + 1
    BX = geom.bsize[0]
    CS = geom.csize[0]
    dimx = geom.blocked_dims[0]

    kernel = functools.partial(_kernel, stencil=stencil, geom=geom,
                               ny=ny, dimx=dimx, bc=bc)
    scratch = [
        pltpu.VMEM((T, S, BX), jnp.float32),      # stage windows
        pltpu.VMEM((2, 1, BX), jnp.float32),      # input double buffer
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.VMEM((geom.size_halo + 1, BX), jnp.float32) if stencil.has_aux else None,
        pltpu.VMEM((2, 1, BX), jnp.float32) if stencil.has_aux else None,
        pltpu.SemaphoreType.DMA((2,)) if stencil.has_aux else None,
        pltpu.VMEM((2, 1, CS), jnp.float32),      # output double buffer
        pltpu.SemaphoreType.DMA((2,)),
    ]
    if not stencil.has_aux:
        # drop aux scratch slots entirely (kernel signature shrinks to match)
        scratch = [s for s in scratch if s is not None]

        def kernel_noaux(steps_ref, coeff_ref, gp_ref, out_ref,
                         win_ref, in_buf, in_sems, out_buf, out_sems):
            return _kernel(steps_ref, coeff_ref, gp_ref, None, out_ref,
                           win_ref, in_buf, in_sems, None, None, None,
                           out_buf, out_sems, stencil=stencil, geom=geom,
                           ny=ny, dimx=dimx, bc=bc)
        kernel = kernel_noaux

    n_hbm_in = 2 if stencil.has_aux else 1
    operands = (coeffs_packed.reshape(1, -1), gp) + (
        (aux_p,) if stencil.has_aux else ())
    steps_arr = jnp.asarray(steps, jnp.int32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(geom.bnum[0],),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM)]
        + [pl.BlockSpec(memory_space=pl.ANY)] * n_hbm_in,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=scratch,
        out_shape=jax.ShapeDtypeStruct((ny, nxp), jnp.float32),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
    )(steps_arr, *operands)
