"""Pallas TPU kernel: 2D stencil with combined spatial + temporal blocking.

Faithful TPU re-architecture of the paper's accelerator (see DESIGN.md §2):

  * 1-D spatial blocking in x, streaming in y (paper §3.1): kernel grid is
    ``(bnum_x,)``; each program owns one overlapped block of width ``bsize``
    and streams the full y extent, ``par_vec`` rows per tick.
  * Shift registers → **rolling VMEM windows**: one ``(win_slots*V, bsize)``
    circular slab window per temporal stage (V = ``geom.par_vec`` rows per
    slab, slot ``s`` at rows ``[s*V, s*V + V)``, indexed mod ``win_slots`` —
    incrementing the start address of the FPGA shift register == bumping the
    mod-W slot).  At V=1 this is exactly the classic ``(2*rad+1, bsize)``
    row window.
  * par_vec (paper §3.3) → **sublane vectorization**: every tick advances a
    ``(V, bsize)`` slab, so the 8-sublane f32 tile that Mosaic pads a single
    row out to carries V real rows, per-tick DMAs move V rows at once, and
    the pipeline drains in ``~1/V`` the ticks.  See DESIGN.md §2.2.
  * PE chain → **fused stage loop**: stage ``t`` computes slab ``k - t*R``
    at stream tick ``k`` (``R = slab_lag = ceil(rad/V)``) — the same
    ``rad``-row lag the paper gives each PE, in slab units.
  * read/write kernels + channels → **double-buffered async DMA**
    (``pltpu.make_async_copy``): slab ``k+1`` is in flight while slab ``k``
    is consumed; output slabs stream back through a 2-deep buffer.
  * Halos are computed redundantly; only the ``csize``-wide compute region is
    DMA'd out (the paper's "control only the flow of writes"). Out-of-bound
    compute lands in padding the wrapper slices off.
  * PE forwarding (paper §3.2): when fewer than ``par_time`` steps remain, the
    trailing stages forward their input slab unchanged (runtime ``steps``
    scalar in SMEM).

Boundary handling (DESIGN.md §2.1, generalized by ``core.boundary``): the
streaming-axis BC is exact via BC-mapped window reads, generalized to vector
(per-row) index maps: each of the V rows of a ``dy``-tap slab maps its own
coordinate (clamp clips, reflect mirrors — both targets provably live inside
the rolling window — constant overrides out-of-domain rows with the fill
scalar), then the slab is gathered from the window in one shot.  The
blocked-axis BC is re-imposed on every pushed slab (prefix/suffix overwrite
from the mapped in-row position — only the first/last block ever does real
work here).  Periodic axes take neither path: the wrapper materializes the
wrap in HBM (wrap-mode padding; for the streaming axis an explicit 2*halo
stream extension, since the rolling window cannot reach the far end of the
stream) and the wrapped halos stay exact up to the standard garbage creep,
exactly like interior block seams.  When the stream extent is not a multiple
of V the wrapper pads it up with edge rows; the pad rows are computed (and
discarded) but never tapped — every stream read is BC-mapped into the true
domain ``[0, dom-1]`` first.

Tap micro-optimization: the per-stage neighbor getter memoizes window reads
per ``dy`` and lane rotates per ``(dy, dx)``, so each distinct stream tap
(including its reflect modulus math) and each distinct in-row shift is
computed exactly once per tick per stage, however many offsets share it.

TPU-shape notes: slabs are ``(V, bsize)`` f32 with ``bsize % 128 == 0``;
in-row shifts use ``jnp.roll`` (lane rotate) and stream taps gather along
sublanes (swap for ``pltpu.roll``-based selects if Mosaic rejects the
gather). ``BlockGeometry.vmem_bytes`` accounts the 8-sublane padding of
every buffer.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.core.blocking import BlockGeometry, stream_extension
from repro.core.stencils import Stencil


def _kernel(steps_ref,                      # SMEM (1,1) int32: real steps
            coeff_ref,                      # VMEM (1, n_coeff) f32
            gp_ref,                         # ANY (ns, nxp): padded input
            aux_ref,                        # ANY (ns, nxp) or None
            out_ref,                        # ANY (ns, nxp): padded output
            win_ref,                        # VMEM (T, W*V, BX): stage windows
            in_buf, in_sems,                # VMEM (2,V,BX) + 2 DMA sems
            aux_win,                        # VMEM (HA*V, BX) aux window or None
            aux_buf, aux_sems,              # (2,V,BX) + sems, or None
            out_buf, out_sems,              # VMEM (2,V,CS) + 2 DMA sems
            *, stencil: Stencil, geom: BlockGeometry, ns: int, dom: int,
            dimx: int, bc=None):
    T, rad, V = geom.par_time, geom.rad, geom.par_vec
    R = geom.slab_lag                        # per-stage lag, in slabs
    W = geom.win_slots                       # slab slots per stage window
    BX = geom.bsize[0]
    CS = geom.csize[0]
    h = geom.size_halo
    HA = T * R + 1                           # aux window depth, in slabs
    nslabs = ns // V
    b = pl.program_id(0)
    xs = b * CS                              # block start col in padded grid
    nticks = nslabs + T * R
    steps = steps_ref[0, 0]
    kind_s = "clamp" if bc is None else bc.kinds[0]
    kind_x = "clamp" if bc is None else bc.kinds[1]
    fill = 0.0 if bc is None else bc.value
    iv = jax.lax.iota(jnp.int32, V)          # row offsets within a slab

    coeffs = {name: coeff_ref[0, i]
              for i, name in enumerate(stencil.coeff_names)}

    # --- x boundary re-imposition (blocked dim): only first/last block act --
    lo = h - xs                              # positions j < lo are left of grid
    hi = (dimx - 1) + h - xs                 # positions j > hi are right of grid
    iota = jax.lax.broadcasted_iota(jnp.int32, (V, BX), 1)

    def reclamp_x(slab):
        if kind_x == "periodic":
            # wrap-padded halos are exact translated copies: no re-imposition
            # (garbage creep is covered by the halo, as between blocks)
            return slab
        if kind_x == "constant":
            slab = jnp.where(iota < lo, fill, slab)
            return jnp.where(iota > hi, fill, slab)
        if kind_x == "reflect":
            # out[j] = slab[2*lo - j] for j < lo (mirror about the edge cell);
            # flip+roll keeps the per-position gather Mosaic-friendly
            flipped = jnp.flip(slab, axis=1)
            mlo = jnp.roll(flipped, 2 * lo + 1 - BX, axis=1)
            mhi = jnp.roll(flipped, 2 * hi + 1 - BX, axis=1)
            slab = jnp.where(iota < lo, mlo, slab)
            return jnp.where(iota > hi, mhi, slab)
        lo_val = jax.lax.dynamic_slice(slab, (0, jnp.clip(lo, 0, BX - 1)),
                                       (V, 1))
        hi_val = jax.lax.dynamic_slice(slab, (0, jnp.clip(hi, 0, BX - 1)),
                                       (V, 1))
        slab = jnp.where(iota < lo, lo_val, slab)
        return jnp.where(iota > hi, hi_val, slab)

    # --- DMA plumbing --------------------------------------------------------
    def in_copy(j, slot):
        src = jnp.clip(j, 0, nslabs - 1) * V
        return pltpu.make_async_copy(
            gp_ref.at[pl.ds(src, V), pl.ds(xs, BX)],
            in_buf.at[slot], in_sems.at[slot])

    def aux_copy(j, slot):
        src = jnp.clip(j, 0, nslabs - 1) * V
        return pltpu.make_async_copy(
            aux_ref.at[pl.ds(src, V), pl.ds(xs, BX)],
            aux_buf.at[slot], aux_sems.at[slot])

    def out_copy(j, slot):
        return pltpu.make_async_copy(
            out_buf.at[slot],
            out_ref.at[pl.ds(j * V, V), pl.ds(xs + h, CS)], out_sems.at[slot])

    has_aux = aux_ref is not None
    in_copy(0, 0).start()
    if has_aux:
        aux_copy(0, 0).start()

    def body(k, _):
        # -- wait input slab k; prefetch slab k+1 into the other buffer ------
        # Slabs past nslabs-1 are never pushed (the window push below is
        # gated at k <= nslabs-1) and stream taps clamp to the last pushed
        # row, so fetching them would be pure waste: stop both the prefetch
        # and its matching wait at the last real slab instead of running to
        # nticks.
        slot = k % 2

        @pl.when(k <= nslabs - 1)
        def _():
            in_copy(k, slot).wait()

        @pl.when(k + 1 <= nslabs - 1)
        def _():
            in_copy(k + 1, (k + 1) % 2).start()

        @pl.when(k <= nslabs - 1)
        def _():   # push input slab into the stage-0 window (pre-padded => BC-ok)
            win_ref[0, pl.ds((k % W) * V, V), :] = in_buf[slot]

        if has_aux:
            @pl.when(k <= nslabs - 1)
            def _():
                aux_copy(k, slot).wait()

            @pl.when(k + 1 <= nslabs - 1)
            def _():
                aux_copy(k + 1, (k + 1) % 2).start()

            @pl.when(k <= nslabs - 1)
            def _():
                aux_win[pl.ds((k % HA) * V, V), :] = aux_buf[slot]

        # -- PE chain: stage t computes slab k - t*R --------------------------
        for t in range(1, T + 1):
            j = k - t * R
            newest = k - (t - 1) * R         # newest slab stage t-1 can own

            @pl.when((j >= 0) & (j <= nslabs - 1))
            def _(t=t, j=j, newest=newest):
                # stage-(t-1) slabs j-R..j+R, concatenated in logical order:
                # rows (j-R)*V .. (j+R+1)*V - 1 of the stream
                cat = jnp.concatenate(
                    [win_ref[t - 1, pl.ds(((j + o) % W) * V, V), :]
                     for o in range(-R, R + 1)], axis=0)
                base = (j - R) * V           # logical row of cat[0]
                limit = jnp.minimum(newest * V + V - 1, dom - 1)

                def stream_tap(dy):
                    """(V, BX) slab of rows j*V+dy .. j*V+V-1+dy with the
                    stream-axis BC applied per row (rows may be out of
                    domain).  clamp clips; reflect mirrors (the mirror
                    target is within ``rad`` of the edge, hence provably
                    still in the window); constant reads any in-window row
                    and overrides with the fill; periodic was materialized
                    as a stream extension by the wrapper, so edge reads here
                    are garbage-tolerant clips.  ``limit`` bounds the clip
                    so we never read an unpushed slab."""
                    rows = j * V + dy + iv
                    if kind_s == "reflect":
                        p_ = max(2 * dom - 2, 1)
                        m = jnp.mod(rows, p_)
                        rows_m = jnp.where(m >= dom, p_ - m, m)
                    else:
                        rows_m = rows
                    pos = jnp.clip(rows_m, 0, limit) - base
                    vals = jnp.take(cat, pos, axis=0)
                    if kind_s == "constant":
                        oob = (rows < 0) | (rows > dom - 1)
                        vals = jnp.where(oob[:, None], fill, vals)
                    return vals

                # tap memo: one window gather per distinct dy, one lane
                # rotate per distinct (dy, dx), per stage per tick
                taps = {}

                def get(off):
                    dy, dx = off
                    tap = taps.get((dy, dx))
                    if tap is None:
                        tap = taps.get((dy, 0))
                        if tap is None:
                            tap = taps[(dy, 0)] = stream_tap(dy)
                        if dx:
                            tap = taps[(dy, dx)] = jnp.roll(tap, -dx, axis=1)
                    return tap

                aux_slab = None
                if has_aux:
                    ja = jnp.clip(j, 0, nslabs - 1)
                    aux_slab = aux_win[pl.ds((ja % HA) * V, V), :]
                val = stencil.apply(get, coeffs, aux_slab)
                # PE forwarding: inactive stages copy their input slab through.
                val = jnp.where(t <= steps, val, get((0, 0)))
                if t < T:
                    win_ref[t, pl.ds((j % W) * V, V), :] = reclamp_x(val)
                else:
                    oslot = j % 2

                    @pl.when(j >= 2)
                    def _():   # slot reuse: previous copy must have drained
                        out_copy(j - 2, oslot).wait()

                    out_buf[oslot] = val[:, h:h + CS]
                    out_copy(j, oslot).start()
        return 0

    jax.lax.fori_loop(0, nticks, body, 0)

    # drain outstanding output DMAs (last two slabs; nslabs is static)
    if nslabs >= 2:
        out_copy(nslabs - 2, (nslabs - 2) % 2).wait()
    out_copy(nslabs - 1, (nslabs - 1) % 2).wait()


@functools.partial(jax.jit,
                   static_argnames=("stencil", "geom", "interpret", "bc",
                                    "block_parallel"))
def superstep_2d(stencil: Stencil, geom: BlockGeometry, gp: jnp.ndarray,
                 coeffs_packed: jnp.ndarray, steps: jnp.ndarray,
                 aux_p: Optional[jnp.ndarray] = None,
                 interpret: bool = True, bc=None,
                 block_parallel: bool = False) -> jnp.ndarray:
    """One super-step (<= par_time fused time-steps) over the padded grid.

    ``gp``/``aux_p``: BC-padded to (ns, bnum*csize + 2*halo) — plus a
    2*halo stream extension when the streaming-axis BC is periodic, plus
    edge rows padding the stream extent up to a multiple of ``par_vec``
    (the wrapper's job; ``ns`` here is whatever streams).  Returns the
    padded output (only compute columns/rows are meaningful).

    ``block_parallel`` switches the kernel grid's block dimension from
    ``"arbitrary"`` to ``"parallel"`` semantics (opt-in Megacore): blocks
    are independent by construction — halos are redundantly computed and
    every block writes a disjoint compute region — so Mosaic may split
    them across TensorCores.  Bit-identical to the sequential grid.
    """
    ns, nxp = gp.shape
    T, V = geom.par_time, geom.par_vec
    W = geom.win_slots
    HA = T * geom.slab_lag + 1
    BX = geom.bsize[0]
    CS = geom.csize[0]
    dimx = geom.blocked_dims[0]
    # the BC domain: the true stream extent (plus the materialized periodic
    # wrap), before the par_vec pad — stream taps map into [0, dom-1]
    dom = geom.stream_dim + 2 * stream_extension(geom, bc)
    if ns != geom.stream_slabs(dom) * V:
        raise ValueError(
            f"padded stream extent {ns} != ceil({dom}/{V})*{V} "
            f"= {geom.stream_slabs(dom) * V}: the wrapper must pad the "
            f"stream axis to a slab multiple (kernels/ops._pad_blocked)")

    kernel = functools.partial(_kernel, stencil=stencil, geom=geom,
                               ns=ns, dom=dom, dimx=dimx, bc=bc)
    scratch = [
        pltpu.VMEM((T, W * V, BX), jnp.float32),  # stage slab windows
        pltpu.VMEM((2, V, BX), jnp.float32),      # input double buffer
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.VMEM((HA * V, BX), jnp.float32) if stencil.has_aux else None,
        pltpu.VMEM((2, V, BX), jnp.float32) if stencil.has_aux else None,
        pltpu.SemaphoreType.DMA((2,)) if stencil.has_aux else None,
        pltpu.VMEM((2, V, CS), jnp.float32),      # output double buffer
        pltpu.SemaphoreType.DMA((2,)),
    ]
    if not stencil.has_aux:
        # drop aux scratch slots entirely (kernel signature shrinks to match)
        scratch = [s for s in scratch if s is not None]

        def kernel_noaux(steps_ref, coeff_ref, gp_ref, out_ref,
                         win_ref, in_buf, in_sems, out_buf, out_sems):
            return _kernel(steps_ref, coeff_ref, gp_ref, None, out_ref,
                           win_ref, in_buf, in_sems, None, None, None,
                           out_buf, out_sems, stencil=stencil, geom=geom,
                           ns=ns, dom=dom, dimx=dimx, bc=bc)
        kernel = kernel_noaux

    n_hbm_in = 2 if stencil.has_aux else 1
    operands = (coeffs_packed.reshape(1, -1), gp) + (
        (aux_p,) if stencil.has_aux else ())
    steps_arr = jnp.asarray(steps, jnp.int32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(geom.bnum[0],),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM)]
        + [pl.BlockSpec(memory_space=pl.ANY)] * n_hbm_in,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=scratch,
        out_shape=jax.ShapeDtypeStruct((ns, nxp), jnp.float32),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                ("parallel",) if block_parallel else ("arbitrary",))),
    )(steps_arr, *operands)
