from repro.train.steps import (make_compressed_train_step,
                               make_decode_fn, make_prefill_fn,
                               make_train_step)
from repro.train.loop import TrainLoopConfig, fault_tolerant_train

__all__ = ["make_compressed_train_step", "make_decode_fn",
           "make_prefill_fn", "make_train_step",
           "TrainLoopConfig", "fault_tolerant_train"]
