"""jit-able train / prefill / decode step factories.

``make_train_step`` builds the canonical production step:
  loss (remat'd layer scan) -> grads (microbatch grad-accumulation scan)
  -> global-norm clip -> AdamW -> metrics.
Gradient accumulation runs as a ``lax.scan`` over microbatches with f32
accumulators — the standard activation-memory lever (the per-microbatch
backward overlaps its gradient all-reduce under the XLA latency-hiding
scheduler).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import (ModelConfig, decode_step, lm_loss,
                          make_decode_caches, prefill)
from repro.optim import AdamWState, adamw_update
from repro.optim.adamw import AdamWConfig
from repro.parallel import logical_shard


def _shard_batch_tree(tree, lead=()):
    """Re-impose batch sharding on (micro)batch leaves. Constraint
    propagation dies across the reshape -> scan-slice boundary (XLA then
    replicates activations downstream); stating it explicitly costs nothing
    and anchors the whole layer stack (EXPERIMENTS.md §Perf iteration 1)."""
    return jax.tree.map(
        lambda x: logical_shard(x, *lead, "batch",
                                *([None] * (x.ndim - 1 - len(lead)))), tree)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_fn(params, mb):
        return lm_loss(params, cfg, mb)

    def train_step(params, opt_state: AdamWState, batch: dict):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            resh = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            resh = _shard_batch_tree(resh, lead=(None,))

            def acc(carry, mb):
                l_acc, g_acc = carry
                mb = _shard_batch_tree(mb)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (l_acc + l, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zeros), resh)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params, new_opt, metrics = adamw_update(opt_cfg, params, grads,
                                                    opt_state)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_compressed_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                               microbatches: int = 1,
                               keep_ratio: float = 0.05,
                               quantize: bool = True):
    """Cross-pod variant of ``make_train_step`` with EF-top-k gradient
    compression (DESIGN.md §4: the slow hop at 1000+ nodes is the cross-pod
    DCN all-reduce; EF21-style top-k + int8 bounds its wire bytes while the
    error-feedback residual preserves convergence).

    State is (params, (opt_state, ef_state)); metrics include the wire-byte
    estimate of the compressed message. The fast intra-pod (ICI) reduction
    stays exact — compression applies to the already pod-aggregated grads.
    """
    from repro.optim import ef_compress_update, init_ef_state

    def train_step(params, state, batch):
        opt_state, ef_state = state

        def loss_fn(p, mb):
            return lm_loss(p, cfg, mb)

        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            resh = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            resh = _shard_batch_tree(resh, lead=(None,))

            def acc(carry, mb):
                l_acc, g_acc = carry
                mb = _shard_batch_tree(mb)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (l_acc + l, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zeros), resh)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        grads, ef_state, wire = ef_compress_update(
            grads, ef_state, keep_ratio=keep_ratio, quantize=quantize)
        new_params, new_opt, metrics = adamw_update(opt_cfg, params, grads,
                                                    opt_state)
        metrics["loss"] = loss
        metrics["compressed_wire_bytes"] = wire
        return new_params, (new_opt, ef_state), metrics

    train_step.init_extra = init_ef_state
    return train_step


def make_prefill_fn(cfg: ModelConfig, max_len: int):
    def prefill_fn(params, batch: dict):
        logits, caches, memory = prefill(
            params, cfg, batch["tokens"], max_len,
            embeds=batch.get("embeds"), frames=batch.get("frames"))
        return logits, caches, memory
    return prefill_fn


def make_decode_fn(cfg: ModelConfig):
    def decode_fn(params, tokens, caches, memory=None):
        return decode_step(params, cfg, tokens, caches, memory=memory)
    return decode_fn
