"""Fault-tolerant training loop: checkpoint/restart, straggler detection,
failure retry, elastic re-meshing.

The loop is the piece a 1000-node deployment actually runs:

  * **Restart**: on startup, restore the latest complete checkpoint (params +
    optimizer + step); the data pipeline is stateless-addressable, so the
    stream resumes bit-exactly at that step.
  * **Failure handling**: a step that raises (device loss, preemption —
    simulated in tests via an injection hook) is retried from the last
    snapshot rather than crashing the job; repeated failures back off.
  * **Straggler mitigation**: per-step wall times feed a rolling median; a
    step slower than ``straggler_factor``× median is recorded and (on a real
    multi-host job) would trigger host replacement — here it triggers an
    early checkpoint so a replacement can join with minimal lost work.
  * **Elastic re-mesh**: ``reshard_for_mesh`` maps any checkpoint onto a new
    mesh via the param-spec tree — scale the job up/down between restarts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    straggler_factor: float = 3.0
    straggler_window: int = 20
    max_retries: int = 3


def fault_tolerant_train(loop_cfg: TrainLoopConfig, train_step: Callable,
                         init_state: tuple, batches: Iterator[dict],
                         batch_at: Callable[[int], dict],
                         failure_hook: Optional[Callable[[int], None]] = None,
                         log: Callable[[str], None] = print):
    """Run the loop. ``init_state`` = (params, opt_state). ``batch_at(step)``
    regenerates the batch for any step (restart-safe addressing).

    Returns (params, opt_state, history dict).
    """
    mgr = CheckpointManager(loop_cfg.checkpoint_dir,
                            keep=loop_cfg.keep_checkpoints)
    params, opt_state = init_state
    start_step = 0
    restored, step = mgr.restore_latest({"params": params, "opt": opt_state})
    if restored is not None:
        params, opt_state = restored["params"], restored["opt"]
        start_step = step + 1
        log(f"[restart] resumed from checkpoint step {step}")

    times: list = []
    events = {"stragglers": [], "retries": 0, "losses": []}
    s = start_step
    retries = 0
    while s < loop_cfg.total_steps:
        batch = batch_at(s)
        t0 = time.perf_counter()
        try:
            if failure_hook is not None:
                failure_hook(s)
            params, opt_state, metrics = train_step(params, opt_state, batch)
            loss = float(metrics["loss"])
        except Exception as e:      # noqa: BLE001 — device loss/preemption
            retries += 1
            events["retries"] += 1
            if retries > loop_cfg.max_retries:
                raise
            log(f"[failure] step {s}: {e!r}; restoring last checkpoint "
                f"(retry {retries}/{loop_cfg.max_retries})")
            mgr.wait()
            restored, ck = mgr.restore_latest({"params": params,
                                               "opt": opt_state})
            if restored is not None:
                params, opt_state = restored["params"], restored["opt"]
                s = ck + 1
            continue
        retries = 0
        dt = time.perf_counter() - t0
        times.append(dt)
        events["losses"].append(loss)
        window = times[-loop_cfg.straggler_window:]
        med = float(np.median(window))
        if len(window) >= 5 and dt > loop_cfg.straggler_factor * med:
            events["stragglers"].append((s, dt, med))
            log(f"[straggler] step {s}: {dt:.3f}s vs median {med:.3f}s "
                f"-> early checkpoint")
            mgr.save_async({"params": params, "opt": opt_state}, s)
        if s % loop_cfg.checkpoint_every == 0 or s == loop_cfg.total_steps - 1:
            mgr.save_async({"params": params, "opt": opt_state}, s)
        s += 1
    mgr.wait()
    return params, opt_state, events


def reshard_for_mesh(tree, mesh, spec_tree):
    """Elastic re-mesh: place a host-side pytree onto a (new) mesh using the
    logical spec tree (NamedShardings derived leaf-wise)."""
    from jax.sharding import NamedSharding
    shardings = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), spec_tree,
        is_leaf=lambda x: not isinstance(x, dict))
    return jax.device_put(tree, shardings)
