"""glm4-9b [dense] — 40L d4096 32H (GQA kv=2) ff13696 vocab151552 — RoPE, GQA
[hf:THUDM/glm-4-9b; hf]"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv=2, d_head=128, d_ff=13696, vocab=151552,
    act="swiglu", rope_theta=10000.0, dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=1, d_head=16, d_ff=128,
    vocab=256, attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
    dtype="float32")
