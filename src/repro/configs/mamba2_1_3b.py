"""mamba2-1.3b [ssm] — 48L d2048 (attn-free) vocab50280, ssm_state=128 — SSD
[arXiv:2405.21060; unverified]

d_inner = 2*2048 = 4096; head_dim 64 -> 64 SSD heads; 8 B/C groups.
"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
    vocab=50280, d_state=128, d_conv=4, ssm_head_dim=64, ssm_expand=2,
    ssm_groups=8, ssd_chunk=256, tie_embeddings=True, dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, d_state=16, ssm_head_dim=16,
    ssm_groups=2, ssd_chunk=8, vocab=256, loss_chunk=32, dtype="float32")
