"""qwen3-1.7b [dense] — 28L d2048 16H (GQA kv=8) ff6144 vocab151936 —
qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense", n_layers=28, d_model=2048,
    n_heads=16, n_kv=8, d_head=128, d_ff=6144, vocab=151936,
    act="swiglu", qk_norm=True, rope_theta=1e6, tie_embeddings=True,
    dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16, d_ff=128,
    vocab=256, attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
    dtype="float32")
