"""Config registry: assigned architectures (+ the paper's stencil apps).

``get_config(name)``   — exact published config (dry-run / production).
``smoke_config(name)`` — same family, reduced dims (CPU smoke tests).
``SHAPES``             — the assigned input-shape set (per-arch cells).
``input_specs(...)``   — ShapeDtypeStruct stand-ins for every model input.
"""
from __future__ import annotations

import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import ModelConfig

ARCH_IDS = [
    "granite-3-8b",
    "phi4-mini-3.8b",
    "glm4-9b",
    "qwen3-1.7b",
    "seamless-m4t-large-v2",
    "mamba2-1.3b",
    "qwen3-moe-235b-a22b",
    "qwen3-moe-30b-a3b",
    "zamba2-7b",
    "internvl2-76b",
]

STENCIL_IDS = ["diffusion2d", "diffusion3d", "hotspot2d", "hotspot3d"]

# assigned input-shape set (LM-family): seq_len x global_batch
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def _module(name: str):
    return importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def shape_applicable(cfg: ModelConfig, shape: str) -> Optional[str]:
    """None if runnable; otherwise the skip reason (recorded in DESIGN.md)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: O(L^2) attention at 524k decode "
                "is infeasible by design; no sub-quadratic variant specified "
                "(DESIGN.md §Arch-applicability)")
    return None


def input_specs(cfg: ModelConfig, shape: str, *, mesh=None, rules=None,
                microbatches: int = 1) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the given shape.

    Weak-type-correct, shardable, no device allocation (the dry-run path).
    With ``mesh``+``rules``: structs carry NamedShardings.
    """
    from jax.sharding import NamedSharding
    info = SHAPES[shape]
    S, B = info["seq"], info["batch"]

    def spec(shape_, dtype, *axes):
        sh = None
        if mesh is not None and rules is not None:
            sh = NamedSharding(mesh, rules.spec(axes))
        return jax.ShapeDtypeStruct(shape_, dtype, sharding=sh)

    if info["kind"] == "train":
        out = {"tokens": spec((B, S), jnp.int32, "batch", None),
               "labels": spec((B, S), jnp.int32, "batch", None),
               "loss_mask": spec((B, S), jnp.float32, "batch", None)}
        if cfg.input_mode == "embeds_prefix":
            out["tokens"] = spec((B, S - cfg.prefix_len), jnp.int32,
                                 "batch", None)
            out["labels"] = spec((B, S - cfg.prefix_len), jnp.int32,
                                 "batch", None)
            out["loss_mask"] = spec((B, S - cfg.prefix_len), jnp.float32,
                                    "batch", None)
            out["embeds"] = spec((B, cfg.prefix_len, cfg.d_model),
                                 jnp.float32, "batch", None, None)
        elif cfg.input_mode == "frames":
            out["frames"] = spec((B, S, cfg.d_model), jnp.float32,
                                 "batch", None, None)
        return out
    if info["kind"] == "prefill":
        out = {"tokens": spec((B, S), jnp.int32, "batch", None)}
        if cfg.input_mode == "embeds_prefix":
            out["tokens"] = spec((B, S - cfg.prefix_len), jnp.int32,
                                 "batch", None)
            out["embeds"] = spec((B, cfg.prefix_len, cfg.d_model),
                                 jnp.float32, "batch", None, None)
        elif cfg.input_mode == "frames":
            out["frames"] = spec((B, S, cfg.d_model), jnp.float32,
                                 "batch", None, None)
        return out
    # decode: one new token against a cache of S
    out = {"tokens": spec((B, 1), jnp.int32, "batch", None)}
    if cfg.input_mode == "frames":
        # cross-attention memory: fixed 4096-frame utterance
        out["memory"] = spec((B, 4096, cfg.d_model), jnp.float32,
                             "batch", None, None)
    return out
