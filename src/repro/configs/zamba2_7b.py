"""zamba2-7b [hybrid] — 81L d3584 32H (GQA kv=32) ff14336 vocab32000,
ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242; unverified]

Layout here: 13 groups of [1 shared attn+MLP block + 5 Mamba2 layers] + 3
tail Mamba2 layers = 81 layers, 13 shared-attn applications (one weight set).
The per-application LoRA adapters of the real model are omitted
(DESIGN.md §Arch-applicability).
"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv=32, d_head=112, d_ff=14336, vocab=32000,
    d_state=64, d_conv=4, ssm_head_dim=64, ssm_expand=2, ssm_groups=8,
    ssd_chunk=256, hybrid_group=6, act="swiglu", dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, n_layers=7, hybrid_group=3, d_model=64, n_heads=4, n_kv=4,
    d_head=16, d_ff=128, d_state=16, ssm_head_dim=16, ssm_groups=2,
    ssd_chunk=8, vocab=256, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32")
