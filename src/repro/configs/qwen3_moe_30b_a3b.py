"""qwen3-moe-30b-a3b [moe] — 48L d2048 32H (GQA kv=4) ff_expert=768
vocab151936, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv=4, d_head=128, d_ff=768, vocab=151936,
    n_experts=128, top_k=8, act="swiglu", qk_norm=True, rope_theta=1e6,
    dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16, d_ff=32,
    n_experts=4, top_k=2, vocab=256, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32")
