"""phi4-mini-3.8b [dense] — 32L d3072 24H (GQA kv=8) ff8192 vocab200064 —
RoPE SwiGLU GQA [arXiv:2412.08905; hf]"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv=8, d_head=128, d_ff=8192, vocab=200064,
    act="swiglu", rope_theta=10000.0, tie_embeddings=True, dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv=2, d_head=12, d_ff=96,
    vocab=256, attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
    dtype="float32")
