"""seamless-m4t-large-v2 [audio] — enc-dec, 24L/stack d1024 16H (kv=16 = MHA)
ff8192 vocab256206 [arXiv:2308.11596; hf]

Backbone only: the speech frontend is a stub — input_specs() supplies
precomputed frame embeddings (B, S, d_model). Encoder 24L bidirectional,
decoder 24L causal + cross-attention.
"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24,
    n_enc_layers=24, d_model=1024, n_heads=16, n_kv=16, d_head=64,
    d_ff=8192, vocab=256206, act="gelu", rope_theta=10000.0,
    input_mode="frames", dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_enc_layers=2, d_model=32, n_heads=4, n_kv=4,
    d_head=8, d_ff=64, vocab=256, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32")
