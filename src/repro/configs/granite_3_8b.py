"""granite-3-8b [dense] — 40L d4096 32H (GQA kv=8) ff12800 vocab49155
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv=8, d_head=128, d_ff=12800, vocab=49155,
    act="swiglu", rope_theta=10000.0, dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16, d_ff=128,
    vocab=256, attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
    dtype="float32")
