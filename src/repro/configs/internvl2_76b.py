"""internvl2-76b [vlm] — 80L d8192 64H (GQA kv=8) ff28672 vocab128256 —
InternViT + InternLM2/LLaMA3-70B backbone [arXiv:2404.16821; unverified]

Backbone only: the InternViT frontend is a stub — input_specs() supplies
precomputed patch embeddings occupying the first ``prefix_len`` positions.
"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv=8, d_head=128, d_ff=28672, vocab=128256,
    act="swiglu", rope_theta=5e5, input_mode="embeds_prefix",
    prefix_len=1024, dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16, d_ff=128,
    vocab=256, prefix_len=4, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32")
