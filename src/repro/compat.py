"""Version shims over moving jax APIs.

The repo is written against the current jax surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``pltpu.CompilerParams``); older releases spell
these differently.  Everything that touches one of those names goes through
this module so the rest of the codebase stays on the modern spelling.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        # pre-0.5 jax calls the replication check ``check_rep``
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def axis_size(name) -> int:
    """Static mesh-axis size from inside shard_map (``jax.lax.axis_size``)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)   # static int for a static operand


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported."""
    try:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axes))


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (current) / ``pltpu.TPUCompilerParams`` (old)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
