"""Top-k MoE with expert parallelism.

Three execution paths chosen by context (same math, same params):

  * ``dense``     — no mesh (CPU smoke tests): every expert computed for every
                    token, combined by routing weights. Exact for any top-k.
  * ``ep_a2a``    — training/prefill on a mesh: tokens are sequence-sharded
                    over the EP ('model') axis inside a ``shard_map``; each
                    shard routes its tokens, packs fixed-capacity per-shard
                    send buffers, ``all_to_all``s them to the expert owners,
                    runs a batched per-expert GEMM, and reverses the path.
                    Fixed capacity (the paper's blocking mindset: bounded
                    on-chip working set, slack traded like halo redundancy)
                    keeps every shape static. Expert weights are stored
                    ZeRO-3 style (FSDP over 'data' on the ff dim) and
                    all-gathered per layer inside the shard_map.
  * ``ep_bcast``  — decode (few tokens): tokens replicated over the EP axis;
                    every shard computes its local experts for all tokens,
                    masked by routing, then ``psum`` combines. No dispatch
                    traffic; compute waste bounded by E_local/top_k.

Aux losses (switch-style load balance + router z-loss) are returned alongside.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.layers import _normal
from repro.parallel import current_rules, logical_shard


def init_moe(key, d_model: int, n_experts: int, d_ff: int, act: str,
             dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    mult = 2 if act == "swiglu" else 1
    return {
        "router": _normal(k1, (d_model, n_experts), jnp.float32,
                          d_model ** -0.5),
        "w_in": _normal(k2, (n_experts, d_model, mult * d_ff), dtype,
                        d_model ** -0.5),
        "w_out": _normal(k3, (n_experts, d_ff, d_model), dtype,
                         d_ff ** -0.5),
    }


def moe_axes() -> dict:
    return {"router": (None, None),
            "w_in": ("experts", None, "wt_fsdp"),
            "w_out": ("experts", "wt_fsdp", None)}


def _act(h, act: str, dtype):
    if act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        return jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    return jax.nn.gelu(h.astype(jnp.float32)).astype(dtype)


def _route(x2d, router, top_k: int):
    """x2d (T, D) -> probs/ids (T, k) + aux losses. f32 router math."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)   # norm_topk_prob
    # load-balance aux (Switch): E * sum_e f_e * P_e
    E = router.shape[1]
    f = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    P = probs.mean(axis=0)
    aux = E * jnp.sum(f * P)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return w, ids, aux + 1e-3 * z


def _dense_path(x, p, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    x2 = x.reshape(B * S, D)
    w, ids, aux = _route(x2, p["router"], cfg.top_k)
    E = cfg.n_experts
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)       # (T, k, E)
    comb = jnp.einsum("tk,tke->te", w, onehot).astype(x.dtype)
    h = jnp.einsum("td,edf->tef", x2, p["w_in"])
    h = _act(h, cfg.act, x.dtype)
    y = jnp.einsum("tef,efd->ted", h, p["w_out"])
    out = jnp.einsum("ted,te->td", y, comb)
    return out.reshape(B, S, D), aux


def _fsdp_gather(w, rules, axis: int):
    fs = rules.get("wt_fsdp")
    if not fs:
        return w
    names = tuple(fs) if isinstance(fs, (tuple, list)) else (fs,)
    for name in names:
        w = jax.lax.all_gather(w, name, axis=axis, tiled=True)
    return w


def _ep_a2a_path(x, p, cfg, mesh, rules):
    """Train/prefill EP: sequence-sharded tokens, fixed-capacity all_to_all."""
    ep = rules["experts"]
    dp = rules["batch"]
    dp_t = tuple(dp) if isinstance(dp, (tuple, list)) else (dp,)
    n_ep = mesh.shape[ep]
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // n_ep
    from jax.sharding import PartitionSpec as P
    x_spec = P(dp_t, ep, None)
    w_in_spec = P(ep, None, rules.get("wt_fsdp"))
    w_out_spec = P(ep, rules.get("wt_fsdp"), None)

    def local(x_l, router, w_in_l, w_out_l):
        Bl, Sl, D = x_l.shape
        T = Bl * Sl
        x2 = x_l.reshape(T, D)
        w, ids, aux = _route(x2, router, k)
        aux = jax.lax.pmean(aux, (*dp_t, ep))

        C_s = max(8, -(-T * k * int(8 * cfg.moe_capacity) // (8 * n_ep)))
        C_s = -(-C_s // 8) * 8
        e_f = ids.reshape(-1)                       # (T*k,) global expert ids
        w_f = w.reshape(-1)
        t_f = jnp.arange(T * k) // k
        dest = e_f // E_loc
        order = jnp.argsort(dest * (E + 1) + e_f)   # group by dest, then expert
        dest_s, e_s, t_s, w_s = dest[order], e_f[order], t_f[order], w_f[order]
        seg = jnp.searchsorted(dest_s, jnp.arange(n_ep), side="left")
        pos = jnp.arange(T * k) - seg[dest_s]
        keep = pos < C_s
        send_x = jnp.zeros((n_ep, C_s, D), x_l.dtype).at[
            dest_s, jnp.where(keep, pos, C_s)].set(x2[t_s], mode="drop")
        send_e = jnp.full((n_ep, C_s), -1, jnp.int32).at[
            dest_s, jnp.where(keep, pos, C_s)].set(e_s, mode="drop")

        recv_x = jax.lax.all_to_all(send_x, ep, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e[..., None], ep, 0, 0,
                                    tiled=True)[..., 0]

        my_base = jax.lax.axis_index(ep) * E_loc
        el = jnp.where(recv_e >= 0, recv_e - my_base, E_loc).reshape(-1)
        N = n_ep * C_s
        xr = recv_x.reshape(N, D)
        order2 = jnp.argsort(el)
        el_s = el[order2]
        C_e = max(8, -(-N // E_loc))
        seg2 = jnp.searchsorted(el_s, jnp.arange(E_loc), side="left")
        pos2 = jnp.arange(N) - seg2[jnp.clip(el_s, 0, E_loc - 1)]
        keep2 = (el_s < E_loc) & (pos2 < C_e)
        buf = jnp.zeros((E_loc, C_e, D), x_l.dtype).at[
            jnp.where(keep2, el_s, E_loc),
            jnp.where(keep2, pos2, C_e)].set(xr[order2], mode="drop")

        w_in_f = _fsdp_gather(w_in_l, rules, axis=2)
        w_out_f = _fsdp_gather(w_out_l, rules, axis=1)
        h = jnp.einsum("ecd,edf->ecf", buf, w_in_f)
        h = _act(h, cfg.act, x_l.dtype)
        yb = jnp.einsum("ecf,efd->ecd", h, w_out_f)

        # reverse second dispatch
        y_r = yb[jnp.clip(el_s, 0, E_loc - 1),
                 jnp.clip(pos2, 0, C_e - 1)] * keep2[:, None]
        y_recv = jnp.zeros((N, D), x_l.dtype).at[order2].set(y_r)
        y_send = jax.lax.all_to_all(
            y_recv.reshape(n_ep, C_s, D), ep, 0, 0, tiled=True)
        # combine on the sender
        got = y_send[dest_s, jnp.clip(pos, 0, C_s - 1)] * keep[:, None]
        out = jnp.zeros((T, D), x_l.dtype).at[t_s].add(
            got * w_s[:, None].astype(x_l.dtype))
        return out.reshape(Bl, Sl, D), aux

    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_in_spec, w_out_spec),
        out_specs=(x_spec, P()), check_vma=False)
    return fn(x, p["router"], p["w_in"], p["w_out"])


def _ep_bcast_path(x, p, cfg, mesh, rules):
    """Decode EP: tokens replicated over EP axis; local experts masked+psum."""
    ep = rules["experts"]
    dp = rules["batch"]
    dp_t = tuple(dp) if isinstance(dp, (tuple, list)) else (dp,)
    n_ep = mesh.shape[ep]
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // n_ep
    from jax.sharding import PartitionSpec as P
    x_spec = P(dp_t, None, None)

    def local(x_l, router, w_in_l, w_out_l):
        Bl, Sl, D = x_l.shape
        x2 = x_l.reshape(Bl * Sl, D)
        w, ids, aux = _route(x2, router, k)
        aux = jax.lax.pmean(aux, (*dp_t, ep))
        my_base = jax.lax.axis_index(ep) * E_loc
        onehot = jax.nn.one_hot(ids - my_base, E_loc, dtype=jnp.float32)
        comb = jnp.einsum("tk,tke->te", w, onehot).astype(x_l.dtype)
        w_in_f = _fsdp_gather(w_in_l, rules, axis=2)
        w_out_f = _fsdp_gather(w_out_l, rules, axis=1)
        h = jnp.einsum("td,edf->tef", x2, w_in_f)
        h = _act(h, cfg.act, x_l.dtype)
        y = jnp.einsum("tef,efd->ted", h, w_out_f)
        out = jnp.einsum("ted,te->td", y, comb)
        out = jax.lax.psum(out, ep)
        return out.reshape(Bl, Sl, D), aux

    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(None, None),
                  P(ep, None, rules.get("wt_fsdp")),
                  P(ep, rules.get("wt_fsdp"), None)),
        out_specs=(x_spec, P()), check_vma=False)
    return fn(x, p["router"], p["w_in"], p["w_out"])


def apply_moe(x, p, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (B,S,D), aux_loss scalar)."""
    mesh, rules = current_rules()
    if mesh is None or rules is None or rules.get("experts") is None:
        return _dense_path(x, p, cfg)
    n_ep = mesh.shape[rules["experts"]]
    if cfg.n_experts % n_ep:
        return _dense_path(x, p, cfg)
    S = x.shape[1]
    if S % n_ep == 0 and S >= n_ep:          # train / prefill
        return _ep_a2a_path(x, p, cfg, mesh, rules)
    return _ep_bcast_path(x, p, cfg, mesh, rules)
