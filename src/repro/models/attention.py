"""GQA attention: chunked (flash-style) training/prefill + context-parallel
decode over a sequence-sharded KV cache.

Training/prefill uses an online-softmax kv-chunk scan per q-chunk (bounded
score memory at any sequence length).  Decode computes plain softmax over the
cache with the cache's *sequence* dim sharded over the `model` mesh axis
('kv_seq' logical axis): GSPMD turns the softmax/contraction over the sharded
axis into local partials + tiny all-reduces — the log-sum-exp combine of
flash-decoding, expressed declaratively.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.layers import apply_rope, rms_norm, rope_table, _normal
from repro.parallel import logical_shard

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
                   qk_norm: bool, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": _normal(ks[0], (d_model, n_heads * d_head), dtype,
                      d_model ** -0.5),
        "wk": _normal(ks[1], (d_model, n_kv * d_head), dtype,
                      d_model ** -0.5),
        "wv": _normal(ks[2], (d_model, n_kv * d_head), dtype,
                      d_model ** -0.5),
        "wo": _normal(ks[3], (n_heads * d_head, d_model), dtype,
                      (n_heads * d_head) ** -0.5),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((d_head,), dtype)
        p["k_norm"] = jnp.ones((d_head,), dtype)
    return p


def attention_axes(qk_norm: bool) -> dict:
    p = {"wq": ("wt_fsdp", "heads"), "wk": ("wt_fsdp", "kv_heads"),
         "wv": ("wt_fsdp", "kv_heads"), "wo": ("heads", "wt_fsdp")}
    if qk_norm:
        p["q_norm"] = (None,)
        p["k_norm"] = (None,)
    return p


def _project_qkv(x, p, cfg, positions):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(
        B, S, cfg.n_heads, cfg.d_head)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(
        B, S, cfg.n_kv, cfg.d_head)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(
        B, S, cfg.n_kv, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_table(positions, cfg.d_head, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = logical_shard(q, "batch", "seq", "heads", None)
    k = logical_shard(k, "batch", "seq", "kv_heads", None)
    v = logical_shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _mask_for(qpos, kpos, causal, window, skv_valid):
    mask = qpos[:, None] >= -1   # all-true of the right shape
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    if skv_valid is not None:
        mask &= (kpos < skv_valid)[None, :]
    return mask


def _flash_fwd(q, k, v, causal, q_offset, q_chunk, kv_chunk, window, skv):
    """Scan over q chunks; online-softmax scan over kv chunks inside.
    q (B, nq, Cq, H, D) flat-headed; k/v (B, nk, Ck, H, D) (pre-repeated to
    H = n_q_heads so the 'heads' axis shards cleanly).
    Returns o (B,nq,Cq,H,D) and lse (B,nq,Cq,H)."""
    B, nq, Cq, H, D = q.shape
    nk, Ck = k.shape[1], k.shape[2]
    scale = D ** -0.5

    def one_q(_, inp):
        qc, qi = inp
        qc = logical_shard(qc, "batch", None, "heads", None)
        qpos = q_offset + qi * Cq + jnp.arange(Cq)

        def kv_step(carry, kinp):
            m, l, acc = carry
            kc, vc, kj = kinp
            kpos = kj * Ck + jnp.arange(Ck)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            s = logical_shard(s, "batch", "heads", None, None)
            s = jnp.where(_mask_for(qpos, kpos, causal, window, skv),
                          s, NEG_INF)
            m2 = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc2 = acc * corr[..., None] + pv
            return (m2, l2, acc2), None

        m0 = jnp.full((B, H, Cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, Cq), jnp.float32)
        a0 = jnp.zeros((B, H, Cq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (k.swapaxes(0, 1), v.swapaxes(0, 1), jnp.arange(nk)))
        o_c = (acc / jnp.maximum(l[..., None], 1e-30)).transpose(0, 2, 1, 3)
        lse_c = (m + jnp.log(jnp.maximum(l, 1e-30))).transpose(0, 2, 1)
        return None, (o_c.astype(q.dtype), lse_c)

    _, (o, lse) = jax.lax.scan(one_q, None,
                               (q.swapaxes(0, 1), jnp.arange(nq)))
    return o.swapaxes(0, 1), lse.swapaxes(0, 1)


def _flash_bwd_body(q, k, v, o, lse, do, causal, q_offset, window, skv):
    """Flash backward: recompute p per (q,kv) chunk pair; O(Cq*Ck) live."""
    B, nq, Cq, H, D = q.shape
    nk, Ck = k.shape[1], k.shape[2]
    scale = D ** -0.5
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                      # (B,nq,Cq,H)

    def one_q(carry, inp):
        dk_acc, dv_acc = carry                    # (B,nk,Ck,H,D) f32
        qc, oc, lsec, doc, dltc, qi = inp
        qpos = q_offset + qi * Cq + jnp.arange(Cq)

        def kv_step(inner, kinp):
            dq_c, dk_acc, dv_acc = inner
            kc, vc, kj = kinp
            kpos = kj * Ck + jnp.arange(Ck)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(_mask_for(qpos, kpos, causal, window, skv),
                          s, NEG_INF)
            p = jnp.exp(s - lsec.transpose(0, 2, 1)[..., None])  # (B,H,q,k)
            p = logical_shard(p, "batch", "heads", None, None)
            dv_c = jnp.einsum("bhqk,bqhd->bkhd", p,
                              doc.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhd,bkhd->bhqk", doc, vc,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dltc.transpose(0, 2, 1)[..., None])
            dq_c = dq_c + jnp.einsum("bhqk,bkhd->bqhd", ds, kc,
                                     preferred_element_type=jnp.float32
                                     ) * scale
            dk_c = jnp.einsum("bhqk,bqhd->bkhd", ds,
                              qc.astype(jnp.float32),
                              preferred_element_type=jnp.float32) * scale
            dk_acc = dk_acc.at[:, kj].add(dk_c)
            dv_acc = dv_acc.at[:, kj].add(dv_c)
            return (dq_c, dk_acc, dv_acc), None

        dq0 = jnp.zeros((B, Cq, H, D), jnp.float32)
        (dq_c, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc),
            (k.swapaxes(0, 1), v.swapaxes(0, 1), jnp.arange(nk)))
        return (dk_acc, dv_acc), dq_c

    dk0 = jnp.zeros((B, nk, Ck, H, D), jnp.float32)
    dv0 = jnp.zeros((B, nk, Ck, H, D), jnp.float32)
    (dk, dv), dq = jax.lax.scan(
        one_q, (dk0, dv0),
        (q.swapaxes(0, 1), o.swapaxes(0, 1), lse.swapaxes(0, 1),
         do.swapaxes(0, 1), delta.swapaxes(0, 1), jnp.arange(nq)))
    return dq.swapaxes(0, 1).astype(q.dtype), dk.astype(k.dtype), \
        dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, q_offset, window, skv):
    o, _ = _flash_fwd(q, k, v, causal, q_offset, q.shape[2], k.shape[2],
                      window, skv)
    return o


def _flash_f(q, k, v, causal, q_offset, window, skv):
    o, lse = _flash_fwd(q, k, v, causal, q_offset, q.shape[2], k.shape[2],
                        window, skv)
    return o, (q, k, v, o, lse)


def _flash_b(causal, q_offset, window, skv, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd_body(q, k, v, o, lse, do, causal, q_offset,
                                 window, skv)
    return dq, dk, dv


_flash.defvjp(_flash_f, _flash_b)


def chunked_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      window: Optional[int] = None):
    """Flash attention (online softmax fwd, recompute bwd — custom VJP).

    q (B,Sq,Hq,D); k,v (B,Skv,Hkv,D). GQA is handled by repeating K/V chunks
    to flat Hq heads (cheap: one chunk at a time) so the 'heads' axis shards
    cleanly on the TP mesh axis. Score memory is O(q_chunk × kv_chunk); the
    backward recomputes p instead of saving per-chunk residuals — without
    this, differentiating a kv-chunk scan materializes the full (nq, nk)
    score matrix into while-loop buffers (the paper's lesson, inverted:
    trade recompute for on-chip working set).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq, nk = -(-Sq // q_chunk), -(-Skv // kv_chunk)
    pad_q, pad_k = nq * q_chunk - Sq, nk * kv_chunk - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qs = q.reshape(B, nq, q_chunk, Hq, D)
    ks = k.reshape(B, nk, kv_chunk, Hq, D)
    vs = v.reshape(B, nk, kv_chunk, Hq, D)
    skv = Skv if pad_k else None
    out = _flash(qs, ks, vs, causal, q_offset, window, skv)
    out = out.reshape(B, nq * q_chunk, Hq, D)
    if pad_q:
        out = out[:, :Sq]
    return out.astype(q.dtype)


def _flash_stub_host(q, k, v):
    import numpy as np
    from repro.kernels.flash_attention import ref_attention
    return np.asarray(ref_attention(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), causal=True))


def _flash_stub_bwd_host(q, k, v, do):
    import numpy as np

    def f(q, k, v):
        from repro.kernels.flash_attention import ref_attention
        return ref_attention(q, k, v, causal=True)

    _, vjp = jax.vjp(f, jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    dq, dk, dv = vjp(jnp.asarray(do))
    return np.asarray(dq), np.asarray(dk), np.asarray(dv)


@jax.custom_vjp
def _flash_stub(q, k, v):
    """Custom-call stand-in for the Pallas flash kernel (dry-run billing).

    Lowers to one opaque custom-call with operands (q, k, v) and result o —
    exactly the kernel's HBM DMA footprint (K/V fit VMEM at per-device
    shapes, so each is read once). The HLO analyzer bills callback
    custom-calls operands+result and assigns MXU FLOPs analytically
    (hlo_analysis.attention_stub_flops). Executable too (numpy oracle) so
    smoke tests can run the stub path."""
    return jax.pure_callback(
        _flash_stub_host, jax.ShapeDtypeStruct(q.shape, q.dtype), q, k, v,
        vmap_method="sequential")


def _fs_fwd(q, k, v):
    return _flash_stub(q, k, v), (q, k, v)


def _fs_bwd(res, do):
    q, k, v = res
    return jax.pure_callback(
        _flash_stub_bwd_host,
        (jax.ShapeDtypeStruct(q.shape, q.dtype),
         jax.ShapeDtypeStruct(k.shape, k.dtype),
         jax.ShapeDtypeStruct(v.shape, v.dtype)), q, k, v, do,
        vmap_method="sequential")


_flash_stub.defvjp(_fs_fwd, _fs_bwd)


def _flash_stub_sharded(q, k, v):
    """shard_map wrapper: a bare custom-call is opaque to GSPMD, which would
    replicate q/k/v across the mesh (measured: 8x collective blow-up).
    Mapping it over the ambient mesh keeps operands sharded — each shard's
    custom-call is billed at per-device shapes, which is what the Pallas
    kernel sees on real hardware."""
    from repro.parallel.sharding import current_rules, resolve_spec
    mesh, rules = current_rules()
    if mesh is None or rules is None:
        return _flash_stub(q, k, v)
    qs = resolve_spec(q.shape, ("batch", "seq", "heads", None), mesh, rules)
    ks = resolve_spec(k.shape, ("batch", "seq", "kv_heads", None), mesh,
                      rules)
    fn = compat.shard_map(_flash_stub, mesh=mesh, in_specs=(qs, ks, ks),
                          out_specs=qs, check_vma=False)
    return fn(q, k, v)


def self_attention(x, p, cfg, positions, *, causal: bool = True,
                   return_kv: bool = False):
    """Train/prefill self-attention block core (no residual/norm)."""
    q, k, v = _project_qkv(x, p, cfg, positions)
    if cfg.attn_impl == "pallas" and causal:
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(q, k, v, True, cfg.attn_q_chunk,
                              cfg.attn_kv_chunk,
                              jax.default_backend() != "tpu")
    elif cfg.attn_impl == "stub" and causal:
        out = _flash_stub_sharded(q, k, v)
    else:
        out = chunked_attention(q, k, v, causal=causal,
                                q_chunk=cfg.attn_q_chunk,
                                kv_chunk=cfg.attn_kv_chunk)
    out = logical_shard(out, "batch", "seq", "heads", None)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.d_head)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    if return_kv:
        return out, (k, v)
    return out


def cross_attention(x, memory, p, cfg):
    """Decoder->encoder cross attention (no RoPE on memory side)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(
        B, S, cfg.n_heads, cfg.d_head)
    k = jnp.einsum("bsd,dh->bsh", memory, p["wk"]).reshape(
        B, memory.shape[1], cfg.n_kv, cfg.d_head)
    v = jnp.einsum("bsd,dh->bsh", memory, p["wv"]).reshape(
        B, memory.shape[1], cfg.n_kv, cfg.d_head)
    out = chunked_attention(q, k, v, causal=False,
                            q_chunk=cfg.attn_q_chunk,
                            kv_chunk=cfg.attn_kv_chunk)
    out = out.reshape(B, S, cfg.n_heads * cfg.d_head)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_max, Hkv, D) — 'kv_seq' sharded
    v: jnp.ndarray
    length: jnp.ndarray   # () int32 — tokens already cached


def decode_attention(x, p, cfg, cache: KVCache):
    """One-token decode: attention over the sequence-sharded cache.

    Returns (out (B,1,d_model), new (k,v) for this position).  The softmax
    over the sharded cache axis lowers to local partial max/sum + small
    all-reduces — context-parallel flash-decoding via GSPMD.
    """
    B = x.shape[0]
    pos = cache.length[None].astype(jnp.int32)          # (1,)
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(
        B, 1, cfg.n_heads, cfg.d_head)
    k_new = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(
        B, 1, cfg.n_kv, cfg.d_head)
    v_new = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(
        B, 1, cfg.n_kv, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k_new = rms_norm(k_new, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_table(pos, cfg.d_head, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)

    Skv = cache.k.shape[1]
    G = cfg.n_heads // cfg.n_kv
    qg = q.reshape(B, cfg.n_kv, G, cfg.d_head)
    scale = cfg.d_head ** -0.5
    # scores over the sharded cache + the fresh position appended logically
    s_cache = jnp.einsum("bhgd,bshd->bhgs", qg, cache.k,
                         preferred_element_type=jnp.float32) * scale
    s_cache = logical_shard(s_cache, "batch", "kv_heads", None, "kv_seq")
    valid = jnp.arange(Skv) < cache.length
    s_cache = jnp.where(valid[None, None, None, :], s_cache, NEG_INF)
    s_new = jnp.einsum("bhgd,bshd->bhgs", qg, k_new,
                       preferred_element_type=jnp.float32) * scale
    m = jnp.maximum(s_cache.max(axis=-1), s_new[..., 0])
    p_cache = jnp.exp(s_cache - m[..., None])
    p_new = jnp.exp(s_new[..., 0] - m)
    denom = p_cache.sum(axis=-1) + p_new
    o = jnp.einsum("bhgs,bshd->bhgd", p_cache.astype(cache.v.dtype), cache.v,
                   preferred_element_type=jnp.float32)
    o = (o + p_new[..., None] * v_new[:, 0, :, None, :]) / denom[..., None]
    o = o.reshape(B, 1, cfg.n_heads * cfg.d_head).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, (k_new, v_new)


def update_cache(cache: KVCache, k_new, v_new) -> KVCache:
    """Write this step's K/V at position ``length`` (sharded-dim DUS)."""
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, cache.length, 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, cache.length, 1)
    k = logical_shard(k, "batch", "kv_seq", "kv_heads", None)
    v = logical_shard(v, "batch", "kv_seq", "kv_heads", None)
    return KVCache(k, v, cache.length + 1)


def init_cache(cfg, batch: int, max_len: int, n_layers: int, dtype):
    shape = (n_layers, batch, max_len, cfg.n_kv, cfg.d_head)
    k = jnp.zeros(shape, dtype)
    v = jnp.zeros(shape, dtype)
    return KVCache(k, v, jnp.zeros((), jnp.int32))
