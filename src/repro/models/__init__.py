from repro.models.transformer import (ModelConfig, cache_axes, decode_step,
                                      forward, init_params, lm_loss,
                                      make_decode_caches, param_axes, prefill)

__all__ = ["ModelConfig", "cache_axes", "decode_step", "forward",
           "init_params", "lm_loss", "make_decode_caches", "param_axes",
           "prefill"]
