"""Mamba2 / SSD (state-space duality) layer — chunked scan + decode step.

The SSD chunked scan *is* the paper's temporal blocking applied to a linear
recurrence (DESIGN.md §5): a chunk of Q time-steps is processed per HBM
round-trip (intra-chunk quadratic form), and the only cross-chunk traffic is
the (H, P, N) carried state — the rolling-window analogue. The chunk length
plays ``par_time``; growing it trades on-chip working set (the Q×Q score
tile) for fewer state materializations, exactly the paper's
area-vs-redundancy trade.

Math follows the minimal SSD reference (Mamba2 paper, listing 1), with B/C
group-expanded to flat heads for clean head-sharding ('heads' over the
'model' mesh axis).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _normal, rms_norm
from repro.parallel import logical_shard


def init_ssm(key, cfg, dtype) -> dict:
    D = cfg.d_model
    H, P, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.d_state
    d_inner = H * P
    ks = jax.random.split(key, 8)
    return {
        "w_z": _normal(ks[0], (D, d_inner), dtype, D ** -0.5),
        "w_x": _normal(ks[1], (D, d_inner), dtype, D ** -0.5),
        "w_B": _normal(ks[2], (D, G * N), dtype, D ** -0.5),
        "w_C": _normal(ks[3], (D, G * N), dtype, D ** -0.5),
        "w_dt": _normal(ks[4], (D, H), dtype, D ** -0.5),
        "conv_x": _normal(ks[5], (cfg.d_conv, d_inner), dtype, 0.5),
        "conv_bc": _normal(ks[6], (cfg.d_conv, 2 * G * N), dtype, 0.5),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "w_out": _normal(ks[7], (d_inner, D), dtype, d_inner ** -0.5),
    }


def ssm_axes() -> dict:
    return {"w_z": ("wt_fsdp", "heads"), "w_x": ("wt_fsdp", "heads"),
            "w_B": ("wt_fsdp", None), "w_C": ("wt_fsdp", None),
            "w_dt": ("wt_fsdp", "heads"),
            "conv_x": (None, "heads"), "conv_bc": (None, None),
            "A_log": ("heads",), "D_skip": ("heads",), "dt_bias": ("heads",),
            "norm": ("heads",), "w_out": ("heads", "wt_fsdp")}


def _causal_conv(x, w):
    """Depthwise causal conv via shifted adds (no conv HLO). x (B,S,C)."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    S = x.shape[1]
    out = sum(xp[:, i:i + S, :] * w[i] for i in range(dc))
    return out


def _silu(x):
    return jax.nn.silu(x.astype(jnp.float32)).astype(x.dtype)


def ssd_scan(xh, Bh, Ch, dt, A, chunk: int, init_state=None):
    """Chunked SSD. xh (B,S,H,P); Bh/Ch (B,S,H,N); dt (B,S,H) f32; A (H,) f32.

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bh.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    dtype = xh.dtype

    dA = (dt * A).reshape(Bsz, nc, Q, H)                # (B,nc,Q,H), <= 0
    cs = jnp.cumsum(dA, axis=2)
    xc = xh.reshape(Bsz, nc, Q, H, P)
    Bc = Bh.reshape(Bsz, nc, Q, H, N)
    Cc = Ch.reshape(Bsz, nc, Q, H, N)
    dtc = dt.reshape(Bsz, nc, Q, H)

    # --- intra-chunk (quadratic within the temporal block) ------------------
    scores = jnp.einsum("bcihn,bcjhn->bchij", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # (b,c,i,j,h)
    decay = jnp.transpose(decay, (0, 1, 4, 2, 3))                 # (b,c,h,i,j)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(mask, scores * decay, 0.0)
    M = M * jnp.transpose(dtc, (0, 1, 3, 2))[:, :, :, None, :]    # * dt_j
    M = logical_shard(M, "batch", None, "heads", None, None)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M.astype(dtype), xc)

    # --- per-chunk states (what crosses the temporal block) -----------------
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)                    # (b,c,q,h)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                        Bc.astype(jnp.float32),
                        (decay_end * dtc).astype(jnp.float32),
                        xc.astype(jnp.float32))
    chunk_decay = jnp.exp(cs[:, :, -1, :])                        # (b,c,h)

    def scan_body(carry, inp):
        st, cd = inp
        prev = carry
        new = st + cd[:, :, None, None] * prev
        return new, prev

    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, prevs = jax.lax.scan(
        scan_body, s0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prevs = prevs.swapaxes(0, 1)                                   # (b,c,h,p,n)

    # --- inter-chunk contribution -------------------------------------------
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         Cc.astype(jnp.float32), prevs, jnp.exp(cs))
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(Bsz, S, H, P)
    return y.astype(dtype), final.astype(dtype)


def apply_ssm(x, p, cfg, init_state=None) -> Tuple[jnp.ndarray, tuple]:
    """Train/prefill. x (B,S,D) -> (out, (final ssm state, conv tail)).

    The conv tail is the last ``d_conv-1`` pre-activation conv inputs in the
    decode-cache channel layout (x | B | C) — the prefill→decode handoff.
    """
    H, P, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.d_state
    B_, S, D = x.shape
    rep = H // G
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
    x_pre = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    bc_pre = jnp.concatenate([jnp.einsum("bsd,dg->bsg", x, p["w_B"]),
                              jnp.einsum("bsd,dg->bsg", x, p["w_C"])], axis=-1)
    tail = jnp.concatenate([x_pre, bc_pre], axis=-1)[:, -(cfg.d_conv - 1):, :]
    if S < cfg.d_conv - 1:
        tail = jnp.pad(tail, ((0, 0), (cfg.d_conv - 1 - S, 0), (0, 0)))
    xi = _silu(_causal_conv(x_pre, p["conv_x"]))
    bc = _silu(_causal_conv(bc_pre, p["conv_bc"]))
    Bg, Cg = jnp.split(bc, 2, axis=-1)
    Bh = jnp.repeat(Bg.reshape(B_, S, G, N), rep, axis=2)
    Ch = jnp.repeat(Cg.reshape(B_, S, G, N), rep, axis=2)
    xh = xi.reshape(B_, S, H, P)
    xh = logical_shard(xh, "batch", "seq", "heads", None)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, final = ssd_scan(xh, Bh, Ch, dt, A, cfg.ssd_chunk, init_state)
    y = y + (p["D_skip"].astype(jnp.float32)[:, None]
             * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B_, S, H * P)
    y = rms_norm(y * _silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return logical_shard(out, "batch", "seq", "d_model"), (final, tail)


class SSMCache(NamedTuple):
    conv: jnp.ndarray    # (B, d_conv-1, d_inner + 2*G*N)
    state: jnp.ndarray   # (B, H, P, N)


def init_ssm_cache(cfg, batch: int, dtype) -> SSMCache:
    H, P, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.d_state
    ch = H * P + 2 * G * N
    return SSMCache(jnp.zeros((batch, cfg.d_conv - 1, ch), dtype),
                    jnp.zeros((batch, H, P, N), dtype))


def decode_ssm(x, p, cfg, cache: SSMCache) -> Tuple[jnp.ndarray, SSMCache]:
    """One-token decode. x (B,1,D)."""
    H, P, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.d_state
    B_ = x.shape[0]
    rep = H // G
    d_inner = H * P
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])[:, 0]
    xbc_new = jnp.concatenate(
        [jnp.einsum("bsd,di->bsi", x, p["w_x"])[:, 0],
         jnp.einsum("bsd,dg->bsg", x, p["w_B"])[:, 0],
         jnp.einsum("bsd,dg->bsg", x, p["w_C"])[:, 0]], axis=-1)
    window = jnp.concatenate([cache.conv, xbc_new[:, None, :]], axis=1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=1)
    conv_out = _silu(jnp.einsum("bkc,kc->bc", window, conv_w))
    xi, Bg, Cg = jnp.split(conv_out, [d_inner, d_inner + G * N], axis=-1)
    xh = xi.reshape(B_, H, P)
    Bh = jnp.repeat(Bg.reshape(B_, G, N), rep, axis=1)
    Ch = jnp.repeat(Cg.reshape(B_, G, N), rep, axis=1)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"])[:, 0].astype(jnp.float32)
        + p["dt_bias"])                                        # (B,H)
    A = -jnp.exp(p["A_log"])
    dAe = jnp.exp(dt * A)                                      # (B,H)
    state = cache.state.astype(jnp.float32)
    state = (state * dAe[:, :, None, None]
             + jnp.einsum("bh,bhp,bhn->bhpn", dt,
                          xh.astype(jnp.float32), Bh.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))
    y = y + p["D_skip"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, d_inner).astype(x.dtype)
    y = rms_norm(y * _silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bi,id->bd", y, p["w_out"])[:, None, :]
    new_cache = SSMCache(window[:, 1:].astype(cache.conv.dtype),
                         state.astype(cache.state.dtype))
    return out, new_cache
