"""Shared transformer building blocks: norms, RoPE, MLPs, embeddings.

Everything is a pure function over a params dict; each ``init_*`` has a
matching ``*_axes`` giving the logical sharding axes of every leaf (same
pytree structure) so the launcher can derive NamedShardings mechanically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import logical_shard


def rms_norm(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


def rope_table(positions, d_head: int, theta: float):
    """positions (...,S) -> cos/sin tables (...,S, d_head/2), f32."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x (B,S,H,D); cos/sin (B,S,half) or (S,half). Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin],
                          axis=-1)
    return out.astype(x.dtype)


def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --- MLP ---------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    mult = 2 if act == "swiglu" else 1
    return {
        "w_in": _normal(k1, (d_model, mult * d_ff), dtype, d_model ** -0.5),
        "w_out": _normal(k2, (d_ff, d_model), dtype, d_ff ** -0.5),
    }


def mlp_axes() -> dict:
    return {"w_in": ("wt_fsdp", "ff"), "w_out": ("ff", "wt_fsdp")}


def apply_mlp(x, p, act: str):
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    h = logical_shard(h, "batch", "seq", "ff")
    if act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif act == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(act)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    return logical_shard(out, "batch", "seq", "d_model")


# --- Embedding / head --------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, dtype, tie: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": _normal(k1, (vocab, d_model), dtype, 1.0)}
    if not tie:
        p["head"] = _normal(k2, (d_model, vocab), dtype, d_model ** -0.5)
    return p


def embed_axes(tie: bool) -> dict:
    p = {"tok": ("vocab", "wt_fsdp")}
    if not tie:
        p["head"] = ("wt_fsdp", "vocab")
    return p


def embed_tokens(tokens, p, dtype):
    out = jnp.take(p["tok"], tokens, axis=0).astype(dtype)
    return logical_shard(out, "batch", "seq", "d_model")


def lm_logits(x, p, true_vocab: int | None = None):
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if true_vocab is not None and true_vocab < w.shape[-1]:
        pad_mask = jnp.where(jnp.arange(w.shape[-1]) < true_vocab, 0.0, -1e30)
        logits = logits + pad_mask
    return logits
