"""Model assembly: decoder-only / MoE / SSM / hybrid / encoder-decoder stacks.

One frozen ``ModelConfig`` describes every assigned architecture; params are
plain pytrees with scan-stacked per-layer leaves; ``param_axes(cfg)`` returns
the logical-sharding spec tree with identical structure (the launcher maps it
to NamedShardings).  All forward paths are pure functions usable under jit,
shard_map, and remat.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.attention import (KVCache, attention_axes, cross_attention,
                                    decode_attention, init_attention,
                                    self_attention, update_cache)
from repro.parallel import logical_shard


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv: int = 0
    d_head: int = 128
    d_ff: int = 0
    vocab: int = 32000
    act: str = "swiglu"
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity: float = 1.25
    # SSM
    d_state: int = 0
    d_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 8
    ssd_chunk: int = 256
    # hybrid (Zamba2): groups of [1 shared attn+MLP block, group_size-1 mamba]
    hybrid_group: int = 6
    # enc-dec
    n_enc_layers: int = 0
    # modality stubs
    input_mode: str = "tokens"     # tokens | embeds_prefix | frames
    prefix_len: int = 0            # vlm: patch positions at seq start
    # perf knobs (hillclimbable)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    loss_chunk: int = 1024
    remat_policy: str = "full"     # full | dots | none
    dtype: str = "float32"
    # attention implementation: "xla" (chunked online-softmax, runs
    # anywhere), "pallas" (flash kernel; interpret mode off-TPU), "stub"
    # (custom-call stand-in lowered by the dry-run so the roofline bills the
    # kernel's true DMA traffic — see kernels/flash_attention.py)
    attn_impl: str = "xla"
    # sub-quadratic? (for long_500k eligibility)
    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-style) so the vocab
        dim always divides the 16-way TP axis; padded logits are masked."""
        return -(-self.vocab // 256) * 256

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    @property
    def n_params(self) -> int:
        """Analytic parameter count (for 6ND roofline accounting)."""
        D, V = self.d_model, self.vocab
        emb = V * D * (1 if self.tie_embeddings else 2)
        att = D * (self.n_heads + 2 * self.n_kv) * self.d_head \
            + self.n_heads * self.d_head * D
        mult = 2 if self.act == "swiglu" else 1
        mlp = D * mult * self.d_ff + self.d_ff * D
        moe = (self.n_experts * (D * mult * self.d_ff + self.d_ff * D)
               + D * self.n_experts) if self.family == "moe" else 0
        H, P, G, N = (self.ssm_heads, self.ssm_head_dim, self.ssm_groups,
                      self.d_state)
        di = H * P
        ssm = (2 * D * di + 2 * D * G * N + D * H + di * D
               + self.d_conv * (di + 2 * G * N) + 3 * H + di)
        if self.family == "dense" or self.family == "vlm":
            return emb + self.n_layers * (att + mlp)
        if self.family == "moe":
            return emb + self.n_layers * (att + moe)
        if self.family == "ssm":
            return emb + self.n_layers * ssm
        if self.family == "hybrid":
            n_groups = self.n_layers // self.hybrid_group
            n_mamba = self.n_layers - n_groups
            return emb + n_mamba * ssm + (att + mlp)
        if self.family == "encdec":
            return emb + self.n_enc_layers * (att + mlp) \
                + self.n_layers * (2 * att + mlp)
        raise ValueError(self.family)

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts experts)."""
        if self.family != "moe":
            return self.n_params
        D = self.d_model
        mult = 2 if self.act == "swiglu" else 1
        expert = D * mult * self.d_ff + self.d_ff * D
        inactive = self.n_layers * (self.n_experts - self.top_k) * expert
        return self.n_params - inactive


# --- per-block init / axes ---------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str) -> dict:
    dt = cfg.jdtype
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"ln": jnp.ones((D,), dt), "ssm": SSM.init_ssm(ks[0], cfg, dt)}
    p = {"ln1": jnp.ones((D,), dt),
         "attn": init_attention(ks[0], D, cfg.n_heads, cfg.n_kv, cfg.d_head,
                                cfg.qk_norm, dt),
         "ln2": jnp.ones((D,), dt)}
    if kind == "moe":
        p["moe"] = MOE.init_moe(ks[1], D, cfg.n_experts, cfg.d_ff, cfg.act, dt)
    else:
        p["mlp"] = L.init_mlp(ks[1], D, cfg.d_ff, cfg.act, dt)
    if kind == "dec":
        p["ln_x"] = jnp.ones((D,), dt)
        p["xattn"] = init_attention(ks[2], D, cfg.n_heads, cfg.n_kv,
                                    cfg.d_head, False, dt)
    return p


def _block_axes(cfg: ModelConfig, kind: str) -> dict:
    if kind == "ssm":
        return {"ln": (None,), "ssm": SSM.ssm_axes()}
    p = {"ln1": (None,), "attn": attention_axes(cfg.qk_norm), "ln2": (None,)}
    if kind == "moe":
        p["moe"] = MOE.moe_axes()
    else:
        p["mlp"] = L.mlp_axes()
    if kind == "dec":
        p["ln_x"] = (None,)
        p["xattn"] = attention_axes(False)
    return p


def _stack_init(key, cfg, kind, n):
    return jax.vmap(lambda k: _init_block(k, cfg, kind))(
        jax.random.split(key, n))


def _stack_axes(cfg, kind):
    return jax.tree.map(lambda ax: ("layers", *ax), _block_axes(cfg, kind),
                        is_leaf=lambda x: isinstance(x, tuple))


# --- model init ---------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> dict:
    dt = cfg.jdtype
    ks = jax.random.split(key, 6)
    params: dict = {
        "embed": L.init_embed(ks[0], cfg.padded_vocab, cfg.d_model, dt,
                              cfg.tie_embeddings),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.family in ("dense", "vlm"):
        params["blocks"] = _stack_init(ks[1], cfg, "dense", cfg.n_layers)
    elif cfg.family == "moe":
        params["blocks"] = _stack_init(ks[1], cfg, "moe", cfg.n_layers)
    elif cfg.family == "ssm":
        params["blocks"] = _stack_init(ks[1], cfg, "ssm", cfg.n_layers)
    elif cfg.family == "hybrid":
        g = cfg.hybrid_group
        n_groups = cfg.n_layers // g
        tail = cfg.n_layers - n_groups * g
        per_group = g - 1
        params["shared"] = _init_block(ks[1], cfg, "dense")
        params["groups"] = jax.vmap(
            lambda k: _stack_init(k, cfg, "ssm", per_group))(
                jax.random.split(ks[2], n_groups))
        params["tail"] = _stack_init(ks[3], cfg, "ssm", max(tail, 1)) \
            if tail else None
    elif cfg.family == "encdec":
        params["enc_blocks"] = _stack_init(ks[1], cfg, "dense",
                                           cfg.n_enc_layers)
        params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
        params["blocks"] = _stack_init(ks[2], cfg, "dec", cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    if cfg.family == "hybrid" and params.get("tail") is None:
        params.pop("tail")
    return params


def param_axes(cfg: ModelConfig) -> dict:
    axes: dict = {
        "embed": L.embed_axes(cfg.tie_embeddings),
        "final_norm": (None,),
    }
    if cfg.family in ("dense", "vlm"):
        axes["blocks"] = _stack_axes(cfg, "dense")
    elif cfg.family == "moe":
        axes["blocks"] = _stack_axes(cfg, "moe")
    elif cfg.family == "ssm":
        axes["blocks"] = _stack_axes(cfg, "ssm")
    elif cfg.family == "hybrid":
        axes["shared"] = _block_axes(cfg, "dense")
        axes["groups"] = jax.tree.map(
            lambda ax: ("layers", *ax), _stack_axes(cfg, "ssm"),
            is_leaf=lambda x: isinstance(x, tuple))
        if cfg.n_layers % cfg.hybrid_group:
            axes["tail"] = _stack_axes(cfg, "ssm")
    elif cfg.family == "encdec":
        axes["enc_blocks"] = _stack_axes(cfg, "dense")
        axes["enc_norm"] = (None,)
        axes["blocks"] = _stack_axes(cfg, "dec")
    return axes


# --- forward (train / prefill) ------------------------------------------------

def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)   # full


def _dense_body(cfg, *, causal=True, kind="dense", memory=None,
                collect=False):
    def body(carry, bp):
        x, aux = carry
        pos = jnp.arange(x.shape[1])
        h = self_attention(L.rms_norm(x, bp["ln1"], cfg.norm_eps), bp["attn"],
                           cfg, pos, causal=causal, return_kv=collect)
        kv = None
        if collect:
            h, kv = h
        x = logical_shard(x + h, "batch", "seq", "d_model")
        if kind == "dec":
            h = cross_attention(L.rms_norm(x, bp["ln_x"], cfg.norm_eps),
                                memory, bp["xattn"], cfg)
            x = logical_shard(x + h, "batch", "seq", "d_model")
        xn = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if kind == "moe":
            h, a = MOE.apply_moe(xn, bp["moe"], cfg)
            aux = aux + a
        else:
            h = L.apply_mlp(xn, bp["mlp"], cfg.act)
        return (logical_shard(x + h, "batch", "seq", "d_model"), aux), kv
    return body


def _ssm_body(cfg, collect=False):
    def body(carry, bp):
        x, aux = carry
        h, handoff = SSM.apply_ssm(L.rms_norm(x, bp["ln"], cfg.norm_eps),
                                   bp["ssm"], cfg)
        ys = handoff if collect else None
        return (logical_shard(x + h, "batch", "seq", "d_model"), aux), ys
    return body


def forward(params, cfg: ModelConfig, tokens, *, embeds=None, frames=None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (hidden (B,S,D), aux_loss scalar).

    ``embeds``: (B, prefix, D) precomputed modality embeddings (vlm),
    ``frames``: (B, S_enc, D) encoder-side frame embeddings (encdec stub).
    """
    dt = cfg.jdtype
    x = L.embed_tokens(tokens, params["embed"], dt)
    if cfg.family == "vlm" and embeds is not None:
        x = jnp.concatenate([embeds.astype(dt), x], axis=1)
    aux0 = jnp.zeros((), jnp.float32)
    pol = cfg.remat_policy

    if cfg.family in ("dense", "vlm"):
        body = _remat(_dense_body(cfg), pol)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    elif cfg.family == "moe":
        body = _remat(_dense_body(cfg, kind="moe"), pol)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    elif cfg.family == "ssm":
        body = _remat(_ssm_body(cfg), pol)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    elif cfg.family == "hybrid":
        shared = params["shared"]
        attn_body = _remat(_dense_body(cfg), pol)
        mamba_body = _remat(_ssm_body(cfg), pol)

        def group_body(carry, gp):
            c, _ = attn_body(carry, shared)
            c, _ = jax.lax.scan(mamba_body, c, gp)
            return c, None
        (x, aux), _ = jax.lax.scan(group_body, (x, aux0), params["groups"])
        if "tail" in params:
            (x, aux), _ = jax.lax.scan(mamba_body, (x, aux), params["tail"])
    elif cfg.family == "encdec":
        assert frames is not None, "encdec needs frame embeddings"
        enc_body = _remat(_dense_body(cfg, causal=False), pol)
        (mem, _), _ = jax.lax.scan(enc_body, (frames.astype(dt), aux0),
                                   params["enc_blocks"])
        mem = L.rms_norm(mem, params["enc_norm"], cfg.norm_eps)
        dec_body = _remat(_dense_body(cfg, kind="dec", memory=mem), pol)
        (x, aux), _ = jax.lax.scan(dec_body, (x, aux0), params["blocks"])
    else:
        raise ValueError(cfg.family)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def lm_loss(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    """Chunked softmax cross-entropy (bounded logits memory)."""
    hidden, aux = forward(params, cfg, batch["tokens"],
                          embeds=batch.get("embeds"),
                          frames=batch.get("frames"))
    labels = batch["labels"]
    if cfg.family == "vlm" and batch.get("embeds") is not None:
        # prefix positions carry no LM loss
        hidden = hidden[:, batch["embeds"].shape[1]:]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    B, S, D = hidden.shape
    C = min(cfg.loss_chunk, S)
    nc = S // C
    head = params["embed"].get("head")
    if head is None:
        head = params["embed"]["tok"].T

    pad_mask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, -1e30)

    def chunk_loss(carry, inp):
        h, y, m = inp
        logits = jnp.einsum("bsd,dv->bsv", h, head).astype(jnp.float32)
        logits = logical_shard(logits, "batch", None, "vocab") + pad_mask
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.sum(logits * jax.nn.one_hot(y, cfg.padded_vocab,
                                               dtype=jnp.float32), axis=-1)
        return carry + jnp.sum((lse - gold) * m), None

    hs = hidden[:, :nc * C].reshape(B, nc, C, D).swapaxes(0, 1)
    ys = labels[:, :nc * C].reshape(B, nc, C).swapaxes(0, 1)
    ms = mask[:, :nc * C].reshape(B, nc, C).swapaxes(0, 1)
    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32),
                            (hs, ys, ms))
    loss = total / jnp.maximum(ms.sum(), 1.0)
    return loss + 1e-2 * aux


def prefill(params, cfg: ModelConfig, tokens, max_len: int, *,
            embeds=None, frames=None) -> Tuple[jnp.ndarray, dict, Any]:
    """Process the prompt and build decode caches padded to ``max_len``.

    Returns (last-position logits (B,1,V), caches, memory-or-None).
    """
    dt = cfg.jdtype
    x = L.embed_tokens(tokens, params["embed"], dt)
    if cfg.family == "vlm" and embeds is not None:
        x = jnp.concatenate([embeds.astype(dt), x], axis=1)
    S = x.shape[1]
    aux0 = jnp.zeros((), jnp.float32)
    pol = cfg.remat_policy
    memory = None

    def pad_seq(a):  # (L,B,S,H,D) -> (L,B,max_len,H,D)
        return jnp.pad(a, ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0)))

    if cfg.family in ("dense", "vlm", "moe"):
        kind = "moe" if cfg.family == "moe" else "dense"
        body = _remat(_dense_body(cfg, kind=kind, collect=True), pol)
        (x, _), (ks, vs) = jax.lax.scan(body, (x, aux0), params["blocks"])
        caches = {"k": pad_seq(ks), "v": pad_seq(vs),
                  "length": jnp.asarray(S, jnp.int32)}
    elif cfg.family == "encdec":
        assert frames is not None
        enc_body = _remat(_dense_body(cfg, causal=False), pol)
        (memory, _), _ = jax.lax.scan(enc_body, (frames.astype(dt), aux0),
                                      params["enc_blocks"])
        memory = L.rms_norm(memory, params["enc_norm"], cfg.norm_eps)
        body = _remat(_dense_body(cfg, kind="dec", memory=memory,
                                  collect=True), pol)
        (x, _), (ks, vs) = jax.lax.scan(body, (x, aux0), params["blocks"])
        caches = {"k": pad_seq(ks), "v": pad_seq(vs),
                  "length": jnp.asarray(S, jnp.int32)}
    elif cfg.family == "ssm":
        body = _remat(_ssm_body(cfg, collect=True), pol)
        (x, _), (states, tails) = jax.lax.scan(body, (x, aux0),
                                               params["blocks"])
        caches = {"conv": tails, "state": states,
                  "length": jnp.asarray(S, jnp.int32)}
    elif cfg.family == "hybrid":
        shared = params["shared"]
        attn_body = _remat(_dense_body(cfg, collect=True), pol)
        mamba_body = _remat(_ssm_body(cfg, collect=True), pol)

        def group_body(carry, gp):
            c, kv = attn_body(carry, shared)
            c, (st, tl) = jax.lax.scan(mamba_body, c, gp)
            return c, (kv[0], kv[1], st, tl)
        (x, _), (ks, vs, sts, tls) = jax.lax.scan(group_body, (x, aux0),
                                                  params["groups"])
        caches = {"attn_k": pad_seq(ks), "attn_v": pad_seq(vs),
                  "conv": tls, "state": sts,
                  "length": jnp.asarray(S, jnp.int32)}
        if "tail" in params:
            (x, _), (tst, ttl) = jax.lax.scan(mamba_body, (x, aux0),
                                              params["tail"])
            caches["tail_conv"] = ttl
            caches["tail_state"] = tst
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x, params["embed"], cfg.vocab).astype(jnp.float32)
    return logits, caches, memory


# --- decode -------------------------------------------------------------------

def make_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    dt = cfg.jdtype
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.d_head)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
                "length": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        c = SSM.init_ssm_cache(cfg, batch, dt)
        n = cfg.n_layers
        return {"conv": jnp.broadcast_to(c.conv, (n, *c.conv.shape)),
                "state": jnp.broadcast_to(c.state, (n, *c.state.shape)),
                "length": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        g = cfg.hybrid_group
        n_groups = cfg.n_layers // g
        tail = cfg.n_layers - n_groups * g
        c = SSM.init_ssm_cache(cfg, batch, dt)
        caches = {
            "attn_k": jnp.zeros((n_groups, batch, max_len, cfg.n_kv,
                                 cfg.d_head), dt),
            "attn_v": jnp.zeros((n_groups, batch, max_len, cfg.n_kv,
                                 cfg.d_head), dt),
            "conv": jnp.broadcast_to(c.conv, (n_groups, g - 1, *c.conv.shape)),
            "state": jnp.broadcast_to(c.state,
                                      (n_groups, g - 1, *c.state.shape)),
            "length": jnp.zeros((), jnp.int32),
        }
        if tail:
            caches["tail_conv"] = jnp.broadcast_to(c.conv, (tail, *c.conv.shape))
            caches["tail_state"] = jnp.broadcast_to(c.state,
                                                    (tail, *c.state.shape))
        return caches
    raise ValueError(cfg.family)


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical axes for decode caches ('kv_seq' -> context parallelism)."""
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        return {"k": ("layers", "batch", "kv_seq", "kv_heads", None),
                "v": ("layers", "batch", "kv_seq", "kv_heads", None),
                "length": ()}
    if cfg.family == "ssm":
        return {"conv": ("layers", "batch", None, "heads"),
                "state": ("layers", "batch", "heads", None, None),
                "length": ()}
    ax = {"attn_k": ("layers", "batch", "kv_seq", "kv_heads", None),
          "attn_v": ("layers", "batch", "kv_seq", "kv_heads", None),
          "conv": ("layers", "stage", "batch", None, "heads"),
          "state": ("layers", "stage", "batch", "heads", None, None),
          "length": ()}
    if cfg.n_layers % cfg.hybrid_group:
        ax["tail_conv"] = ("layers", "batch", None, "heads")
        ax["tail_state"] = ("layers", "batch", "heads", None, None)
    return ax


def decode_step(params, cfg: ModelConfig, tokens, caches: dict,
                memory=None) -> Tuple[jnp.ndarray, dict]:
    """One decode step: tokens (B,1) -> (logits (B,1,V), updated caches)."""
    dt = cfg.jdtype
    x = L.embed_tokens(tokens, params["embed"], dt)
    x = logical_shard(x, "batch", None, "d_model")
    length = caches["length"]

    def attn_block(x, bp, k_l, v_l):
        cache = KVCache(k_l, v_l, length)
        h, (kn, vn) = decode_attention(
            L.rms_norm(x, bp["ln1"], cfg.norm_eps), bp["attn"], cfg, cache)
        x = x + h
        if "xattn" in bp:
            h = cross_attention(L.rms_norm(x, bp["ln_x"], cfg.norm_eps),
                                memory, bp["xattn"], cfg)
            x = x + h
        xn = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if "moe" in bp:
            h, _ = MOE.apply_moe(xn, bp["moe"], cfg)
        else:
            h = L.apply_mlp(xn, bp["mlp"], cfg.act)
        upd = update_cache(cache, kn, vn)
        return x + h, upd.k, upd.v

    def ssm_block(x, bp, conv_l, state_l):
        cache = SSM.SSMCache(conv_l, state_l)
        h, new = SSM.decode_ssm(L.rms_norm(x, bp["ln"], cfg.norm_eps),
                                bp["ssm"], cfg, cache)
        return x + h, new.conv, new.state

    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        def body(x, inp):
            bp, k_l, v_l = inp
            x, k2, v2 = attn_block(x, bp, k_l, v_l)
            return x, (k2, v2)
        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["blocks"], caches["k"],
                                    caches["v"]))
        new_caches = {"k": ks, "v": vs, "length": length + 1}
    elif cfg.family == "ssm":
        def body(x, inp):
            bp, c_l, s_l = inp
            x, c2, s2 = ssm_block(x, bp, c_l, s_l)
            return x, (c2, s2)
        x, (cs, ss) = jax.lax.scan(body, x,
                                   (params["blocks"], caches["conv"],
                                    caches["state"]))
        new_caches = {"conv": cs, "state": ss, "length": length + 1}
    elif cfg.family == "hybrid":
        shared = params["shared"]

        def group(x, inp):
            gp, k_l, v_l, conv_g, state_g = inp
            x, k2, v2 = attn_block(x, shared, k_l, v_l)

            def mbody(x, minp):
                bp, c_l, s_l = minp
                x, c2, s2 = ssm_block(x, bp, c_l, s_l)
                return x, (c2, s2)
            x, (cs, ss) = jax.lax.scan(mbody, x, (gp, conv_g, state_g))
            return x, (k2, v2, cs, ss)
        x, (ks, vs, cs, ss) = jax.lax.scan(
            group, x, (params["groups"], caches["attn_k"], caches["attn_v"],
                       caches["conv"], caches["state"]))
        new_caches = {"attn_k": ks, "attn_v": vs, "conv": cs, "state": ss,
                      "length": length + 1}
        if "tail" in params:
            def mbody(x, minp):
                bp, c_l, s_l = minp
                x, c2, s2 = ssm_block(x, bp, c_l, s_l)
                return x, (c2, s2)
            x, (tc, ts) = jax.lax.scan(mbody, x, (params["tail"],
                                                  caches["tail_conv"],
                                                  caches["tail_state"]))
            new_caches["tail_conv"] = tc
            new_caches["tail_state"] = ts
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x, params["embed"], cfg.vocab).astype(jnp.float32)
    return logical_shard(logits, "batch", None, "vocab"), new_caches
