#!/usr/bin/env python
"""Program-fusion micro-benchmark: fused stage chain vs chained plans.

The ``StencilProgram`` subsystem fuses a chain of dependent stencil stages
into one super-step executable: intermediates stay in the rolling VMEM
windows instead of round-tripping HBM, and the whole chain shares one
dispatch per super-step.  This benchmark measures exactly that claim, per
program: one super-step of the fused S-stage plan against the unfused
rendition (S single-stage plans chained step by step), reporting seconds
per super-step, amortized ns per program-iteration cell update, GCell/s,
and the fusion speedup.

Backend: ``pallas_interpret`` by default (the CI-runnable proxy); pass
``--backend pallas`` on a real TPU.

Output: ``results/bench/BENCH_programs.json`` (override with ``--out``).

CI gate (``--baseline``): every measured (program, par_time) row is compared
against the ``program_rows`` section of the committed baseline file; if its
fused per-cell time regresses by more than ``--max-regression`` (default
2x — CI runners are noisy), the process exits non-zero.  Regenerate with::

    python benchmarks/programs.py --smoke --update-baseline results/bench/baseline.json
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from pathlib import Path

import jax

from repro.api import RunConfig, StencilProblem, StencilStage, plan
from repro.core.stencils import make_combine, make_star
from repro.data import make_stencil_inputs
from repro.programs import StencilProgram


def _advect2d():
    return StencilStage(make_star(2, 1),
                        coeffs={"c0": 0.7, "c_0_-1": 0.1, "c_0_1": 0.0,
                                "c_1_-1": 0.2, "c_1_1": 0.0},
                        name="advect")


def _damp(ndim):
    return StencilStage(make_star(ndim, 0), coeffs={"c0": 0.995},
                        name="damp")


#: name -> (stage thunks, dims, par_time, bsize); smoke = CI-sized
SMOKE_CASES = {
    "advect_diffuse2d": ([_advect2d, lambda: StencilStage("diffusion2d")],
                         (96, 256), 2, 256),
    "diffuse_damp2d": ([lambda: StencilStage("diffusion2d"),
                        lambda: _damp(2)], (96, 256), 2, 256),
}
FULL_CASES = {
    "advect_diffuse2d": ([_advect2d, lambda: StencilStage("diffusion2d")],
                         (512, 1024), 4, 512),
    "diffuse_damp2d": ([lambda: StencilStage("diffusion2d"),
                        lambda: _damp(2)], (512, 1024), 4, 512),
    "diffuse3_2d": ([lambda: StencilStage("diffusion2d")] * 3,
                    (512, 1024), 2, 512),
}


def _wave2d_program():
    """Second-order wave equation: the canonical DAG program — two fields
    (``u``, ``u_prev``), a Laplacian stage fanned into a 3-way combine,
    both fields rotated simultaneously each iteration."""
    return StencilProgram(
        (StencilStage(make_star(2, 1), name="lapu", inputs=("u",)),
         StencilStage(make_combine(2, 3), name="unext",
                      inputs=("u", "u_prev", "lapu"),
                      coeffs={"w0": 2.0, "w1": -1.0, "w2": 0.1})),
        fields=("u", "u_prev"),
        updates={"u": "unext", "u_prev": "u"})


def _diamond_program():
    """Fan-out / fan-in: two radius-1 views of ``u`` recombined — exercises
    the per-edge window sizing the DAG unroll prices."""
    s = make_star(2, 1)
    return StencilProgram(
        (StencilStage(s, name="a", inputs=("u",)),
         StencilStage(s, name="b", inputs=("u",),
                      coeffs={"c0": 0.5, "c_0_1": 0.2}),
         StencilStage(make_combine(2, 2), name="m", inputs=("a", "b"),
                      coeffs={"w0": 0.6, "w1": 0.4})))


#: name -> (program thunk, dims, par_time, bsize)
DAG_SMOKE_CASES = {
    "wave2d": (_wave2d_program, (96, 256), 2, 256),
    "diamond2d": (_diamond_program, (96, 256), 2, 256),
}
DAG_FULL_CASES = {
    "wave2d": (_wave2d_program, (512, 1024), 4, 512),
    "diamond2d": (_diamond_program, (512, 1024), 2, 512),
}


def _time_call(fn, warmup, repeats):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def bench_case(backend, name, stages, dims, par_time, bsize, warmup,
               repeats):
    problem = StencilProblem(stages, dims)
    cfg = dict(backend=backend, par_time=par_time, bsize=bsize)
    fused = plan(problem, RunConfig(**cfg))
    # the unfused rendition: one single-stage plan per stage, chained —
    # every stage boundary is an HBM round-trip and a dispatch
    singles = [plan(StencilProblem([s], dims), RunConfig(**cfg))
               for s in problem.stages]
    grid, aux = make_stencil_inputs(jax.random.PRNGKey(0), dims,
                                    problem.needs_aux)

    def run_fused():
        return fused.run(grid, par_time, aux=aux)   # one super-step

    def run_unfused():
        g = grid
        for _ in range(par_time):
            for p in singles:
                g = p.run(g, 1, aux=aux)
        return g

    s_fused = _time_call(run_fused, warmup, repeats)
    s_unfused = _time_call(run_unfused, warmup, repeats)
    cells = math.prod(dims) * par_time          # program iterations
    return {
        "program": name, "n_stages": len(problem.stages),
        "dims": list(dims), "par_time": par_time, "bsize": bsize,
        "s_per_superstep": s_fused,
        "ns_per_cell": s_fused / cells * 1e9,
        "gcells_s": cells / s_fused / 1e9,
        "unfused_s_per_superstep": s_unfused,
        "unfused_gcells_s": cells / s_unfused / 1e9,
        "fusion_speedup": s_unfused / s_fused,
        "intermediate_hbm_bytes_per_superstep":
            fused.traffic_report()["intermediate_hbm_bytes_per_superstep"],
    }


def bench_dag_case(backend, name, build, dims, par_time, bsize, warmup,
                   repeats):
    """One fused super-step of a DAG program (no unfused rendition exists:
    a DAG's intermediates are not expressible as chained single-stage
    plans).  Gated on fused per-cell time alone."""
    problem = StencilProblem(build(), dims)
    fused = plan(problem, RunConfig(backend=backend, par_time=par_time,
                                    bsize=bsize))
    key = jax.random.PRNGKey(0)
    state = jax.random.uniform(key, problem.state_shape, minval=0.5,
                               maxval=2.0)

    def run_fused():
        return fused.run(state, par_time)           # one super-step

    s_fused = _time_call(run_fused, warmup, repeats)
    cells = math.prod(dims) * par_time              # program iterations
    return {
        "program": name, "n_stages": len(problem.stages),
        "n_fields": len(problem.fields),
        "dims": list(dims), "par_time": par_time, "bsize": bsize,
        "s_per_superstep": s_fused,
        "ns_per_cell": s_fused / cells * 1e9,
        "gcells_s": cells / s_fused / 1e9,
    }


def check_regression(rows, baseline_path: Path, max_regression: float,
                     section: str = "program_rows"):
    """Fused per-cell time of every (program, par_time) row vs the
    baseline's ``section``.  Returns failure strings (empty = pass)."""
    try:
        base = json.loads(baseline_path.read_text())
    except (OSError, ValueError) as e:
        return [f"baseline {baseline_path} unreadable: {e}"]
    by_key = {(r["program"], r["par_time"]): r
              for r in base.get(section, [])}
    if not by_key:
        return [f"baseline {baseline_path} has no {section} section — "
                "regenerate it with --update-baseline"]
    failures = []
    for r in rows:
        b = by_key.get((r["program"], r["par_time"]))
        if b is None:
            print(f"  [gate] no program baseline for "
                  f"({r['program']}, T={r['par_time']}) — skipped")
            continue
        ratio = r["ns_per_cell"] / b["ns_per_cell"]
        status = "OK" if ratio <= max_regression else "REGRESSED"
        print(f"  [gate] {r['program']}/T={r['par_time']}: "
              f"{r['ns_per_cell']:.2f} ns/cell vs baseline "
              f"{b['ns_per_cell']:.2f} -> x{ratio:.2f} {status}")
        if ratio > max_regression:
            failures.append(
                f"{r['program']}/T={r['par_time']} fused per-cell time "
                f"regressed x{ratio:.2f} (> x{max_regression:.2f})")
    return failures


def update_baseline(rows, baseline_path: Path, dag_rows=None) -> None:
    """Write/refresh the ``program_rows`` (and ``program_dag_rows``)
    sections, preserving whatever else (kernel/throughput rows) the shared
    baseline file holds."""
    try:
        base = json.loads(baseline_path.read_text())
    except (OSError, ValueError):
        base = {}
    base["program_rows"] = rows
    if dag_rows is not None:
        base["program_dag_rows"] = dag_rows
    baseline_path.parent.mkdir(parents=True, exist_ok=True)
    baseline_path.write_text(json.dumps(base, indent=1, sort_keys=True)
                             + "\n")
    print(f"updated program_rows/program_dag_rows in {baseline_path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized grids (seconds, interpret-friendly)")
    ap.add_argument("--backend", default="pallas_interpret",
                    help="pallas_interpret (CI proxy) or pallas (real TPU)")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="results/bench/BENCH_programs.json")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON to gate against (CI perf-smoke)")
    ap.add_argument("--update-baseline", default=None, metavar="PATH",
                    help="write program_rows into this baseline file & exit")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail if fused ns/cell exceeds baseline by this "
                         "factor")
    args = ap.parse_args(argv)

    cases = SMOKE_CASES if args.smoke else FULL_CASES
    rows = []
    print(f"{'program':18s} {'dims':>12s} {'T':>2s} {'fused ms':>9s} "
          f"{'unfused ms':>10s} {'speedup':>7s} {'GCell/s':>8s}")
    for name, (thunks, dims, par_time, bsize) in cases.items():
        stages = [t() for t in thunks]
        r = bench_case(args.backend, name, stages, dims, par_time, bsize,
                       args.warmup, args.repeats)
        rows.append(r)
        print(f"{r['program']:18s} {str(tuple(r['dims'])):>12s} "
              f"{r['par_time']:2d} {r['s_per_superstep'] * 1e3:9.2f} "
              f"{r['unfused_s_per_superstep'] * 1e3:10.2f} "
              f"x{r['fusion_speedup']:6.2f} {r['gcells_s']:8.4f}")
        assert r["intermediate_hbm_bytes_per_superstep"] == 0

    dag_cases = DAG_SMOKE_CASES if args.smoke else DAG_FULL_CASES
    dag_rows = []
    for name, (build, dims, par_time, bsize) in dag_cases.items():
        r = bench_dag_case(args.backend, name, build, dims, par_time, bsize,
                           args.warmup, args.repeats)
        dag_rows.append(r)
        print(f"{r['program']:18s} {str(tuple(r['dims'])):>12s} "
              f"{r['par_time']:2d} {r['s_per_superstep'] * 1e3:9.2f} "
              f"{'(dag)':>10s} {'':>7s} {r['gcells_s']:8.4f}")

    out = {
        "schema": 1,
        "mode": "smoke" if args.smoke else "full",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "backend": args.backend,
        "rows": rows,
        "dag_rows": dag_rows,
    }
    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(out, indent=1, sort_keys=True) + "\n")
    print(f"wrote {out_path}")

    if args.update_baseline:
        update_baseline(rows, Path(args.update_baseline), dag_rows)
        return 0
    if args.baseline:
        failures = check_regression(rows, Path(args.baseline),
                                    args.max_regression)
        failures += check_regression(dag_rows, Path(args.baseline),
                                     args.max_regression,
                                     section="program_dag_rows")
        if failures:
            print("PERF REGRESSION:\n  " + "\n  ".join(failures),
                  file=sys.stderr)
            return 1
        print("perf gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
