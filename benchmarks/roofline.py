"""Roofline aggregation (deliverable g): read the dry-run cells and emit the
per-(arch x shape x mesh) roofline table.

Terms (per chip, from the compiled single-pod dry-run; DESIGN.md §7):
  compute    = HLO_FLOPs / peak_bf16            (197 TFLOP/s)
  memory     = HLO_bytes / HBM_bw               (819 GB/s)
  collective = collective_bytes / ICI_bw        (~50 GB/s/link)

plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), the useful-compute
ratio MODEL_FLOPS/HLO_FLOPs, and the roofline fraction
(MODEL_FLOPS/peak) / max(term)).

Usage:
  python -m benchmarks.roofline            # table to stdout
  python -m benchmarks.roofline --markdown # EXPERIMENTS.md §Roofline body
  python -m benchmarks.roofline --pick     # hillclimb candidate selection
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_cells(mesh: str = "single", variant: str = "baseline") -> list[dict]:
    cells = []
    for fname in sorted(os.listdir(RESULTS_DIR)):
        if not fname.endswith(f"__{mesh}__{variant}.json"):
            continue
        with open(os.path.join(RESULTS_DIR, fname)) as f:
            cells.append(json.load(f))
    return cells


def rows_for(cells: list[dict]) -> list[dict]:
    rows = []
    for c in cells:
        base = {"arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"]}
        if "skipped" in c:
            rows.append({**base, "skipped": c["skipped"].split(":")[0]})
            continue
        r = c["roofline"]
        t = [r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]]
        row = {
            **base,
            "t_compute_s": r["t_compute_s"],
            "t_memory_s": r["t_memory_s"],
            "t_collective_s": r["t_collective_s"],
            "dominant": r["dominant"],
            "peak_gib": c["memory"]["peak_per_device_gib"],
        }
        if "useful_ratio" in r:
            row["useful_ratio"] = r["useful_ratio"]
            row["roofline_fraction"] = r["roofline_fraction"]
        if "autotuned" in c:
            row["autotuned"] = c["autotuned"]
            # stencil cells: roofline fraction = predicted perf vs dominant
            row["roofline_fraction"] = None
        rows.append(row)
    return rows


def _fmt(x, w=9):
    if x is None:
        return " " * w
    if x >= 100:
        return f"{x:{w}.1f}"
    return f"{x:{w}.3f}"


def print_table(rows, markdown=False):
    if markdown:
        print("| arch | shape | t_compute (s) | t_memory (s) | "
              "t_collective (s) | dominant | useful | roofline frac | "
              "peak GiB/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if "skipped" in r:
                print(f"| {r['arch']} | {r['shape']} | — | — | — | "
                      f"skipped ({r['skipped']}) | — | — | — |")
                continue
            u = r.get("useful_ratio")
            f = r.get("roofline_fraction")
            print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
                  f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
                  f"{r['dominant']} | "
                  f"{u:.3f} |" if u is not None else "— |",
                  f"{f:.4f} |" if f is not None else "— |",
                  f"{r['peak_gib']:.2f} |")
        return
    print(f"{'arch':24s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
          f"{'t_coll':>9s} {'dominant':>10s} {'useful':>7s} {'frac':>8s} "
          f"{'GiB/dev':>8s}")
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']:24s} {r['shape']:12s} "
                  f"{'skipped (' + r['skipped'] + ')':>40s}")
            continue
        u = r.get("useful_ratio")
        f = r.get("roofline_fraction")
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{_fmt(r['t_compute_s'])} {_fmt(r['t_memory_s'])} "
              f"{_fmt(r['t_collective_s'])} {r['dominant']:>10s} "
              f"{u if u is None else round(u, 3)!s:>7s} "
              f"{f if f is None else round(f, 4)!s:>8s} "
              f"{r['peak_gib']:8.2f}")


def pick_hillclimb(rows) -> dict:
    """Choose the three hillclimb cells: worst roofline fraction, most
    collective-bound, most representative of the paper's technique."""
    lm = [r for r in rows if "skipped" not in r
          and r.get("roofline_fraction") is not None]
    worst = min(lm, key=lambda r: r["roofline_fraction"])
    coll = max(lm, key=lambda r: (r["t_collective_s"]
                                  / max(max(r["t_compute_s"],
                                            r["t_memory_s"],
                                            r["t_collective_s"]), 1e-12)))
    # most representative of the paper: the distributed stencil superstep
    stencils = [r for r in rows if r["shape"] == "superstep"]
    rep = stencils[0] if stencils else None
    return {"worst_fraction": worst, "most_collective": coll,
            "paper_representative": rep}


def run() -> list[dict]:
    out = []
    for variant in ("baseline", "optimized"):
        rows = rows_for(load_cells("single", variant))
        for r in rows:
            r["variant"] = variant
        out.extend(rows)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default=None,
                    choices=["baseline", "optimized"],
                    help="default: print both")
    ap.add_argument("--pick", action="store_true")
    args = ap.parse_args()
    variants = [args.variant] if args.variant else ["baseline", "optimized"]
    rows = []
    for v in variants:
        vr = rows_for(load_cells(args.mesh, v))
        if not vr:
            continue
        print(f"\n--- variant: {v} ---")
        print_table(vr, markdown=args.markdown)
        rows = vr   # --pick operates on the last (optimized if present)
    if args.pick:
        picks = pick_hillclimb(rows)
        print("\nhillclimb candidates:")
        for why, r in picks.items():
            if r is None:
                continue
            print(f"  {why}: {r['arch']} x {r['shape']} "
                  f"(dominant={r['dominant']}, "
                  f"frac={r.get('roofline_fraction')})")
    return rows


if __name__ == "__main__":
    main()
