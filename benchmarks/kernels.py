#!/usr/bin/env python
"""Kernel micro-benchmark: GCell/s per super-step, V=1 vs vectorized.

The ``par_vec`` tentpole claims the streaming kernels win by advancing V
rows/planes per pipeline tick (fewer ticks, fatter DMAs, full sublanes —
paper §3.3 / DESIGN.md §2.2).  This benchmark measures exactly that, per
stencil and storage dtype: one super-step of the Pallas kernel at
``par_vec=1`` against the swept vector widths, reporting seconds per
super-step, amortized ns per cell-update, GCell/s, the per-cell DMA bytes
of the kernel's exact schedule, and the best-V speedup over V=1.

The dtype column sweeps the supported storage dtypes (f32 and bf16 —
DESIGN.md §2.2b): bf16 rows must move ~half the per-cell DMA bytes of
their f32 siblings (checked as a hard gate, not just reported); compute
time is an interpret-mode proxy, so only the traffic claim is gated.

Backend: ``pallas_interpret`` by default (the CI-runnable proxy — interpret
mode executes the same tick loop, so the ~V-fold tick reduction shows up in
wall-clock there too); pass ``--backend pallas`` on a real TPU.

Output: ``results/bench/BENCH_kernels.json`` (override with ``--out``).

CI gate (``--baseline``): every measured (stencil, dtype, par_vec) row is
compared against the ``kernel_rows`` section of the committed baseline file
(rows without a ``dtype`` field in older baselines default to f32); if its
amortized per-cell time regresses by more than ``--max-regression`` (default
2x — CI runners are noisy), the process exits non-zero and the perf-smoke
job fails.  Regenerate with::

    python benchmarks/kernels.py --smoke \
        --update-baseline results/bench/baseline.json
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from pathlib import Path

import jax

from repro.api import RunConfig, StencilProblem, plan
from repro.core import STENCILS, default_coeffs
from repro.data import make_stencil_inputs

# (stencil, dims, par_time, bsize): smoke = CI-sized, full = host-benchmark
SMOKE_CASES = [
    ("diffusion2d", (96, 256), 2, 256),     # the 2D star acceptance case
    ("hotspot2d", (96, 256), 2, 256),
]
FULL_CASES = [
    ("diffusion2d", (512, 1024), 4, 512),
    ("hotspot2d", (512, 1024), 4, 512),
    ("diffusion3d", (32, 96, 96), 2, 32),
]
SMOKE_VECS = (1, 4, 8)
FULL_VECS = (1, 2, 4, 8, 16)
#: storage dtypes each case sweeps (f32 accumulation either way)
DTYPES = ("float32", "bfloat16")


def _time_superstep(p, grid, coeffs, aux, iters, warmup, repeats):
    for _ in range(warmup):
        jax.block_until_ready(p.run(grid, iters, coeffs, aux=aux))
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(p.run(grid, iters, coeffs, aux=aux))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_case(backend, name, dims, par_time, bsize, vecs, warmup, repeats,
               dtypes=DTYPES):
    st = STENCILS[name]
    coeffs = default_coeffs(st)
    grid, aux = make_stencil_inputs(jax.random.PRNGKey(0), dims, st.has_aux)
    rows = []
    for dtype in dtypes:
        sd = jax.numpy.dtype(dtype)
        g = grid.astype(sd)
        a = None if aux is None else aux.astype(sd)
        for V in vecs:
            p = plan(StencilProblem(name, dims, dtype=dtype),
                     RunConfig(backend=backend, par_time=par_time,
                               bsize=bsize, par_vec=V))
            # one whole super-step: par_time fused steps, the kernel's unit
            # of work
            s = _time_superstep(p, g, coeffs, a, par_time, warmup, repeats)
            cells = math.prod(dims) * par_time
            dma = p.traffic_report()["kernel_dma_bytes_per_superstep"]
            rows.append({
                "stencil": name, "dims": list(dims), "par_time": par_time,
                "bsize": bsize, "par_vec": V, "dtype": dtype,
                "s_per_superstep": s,
                "ns_per_cell": s / cells * 1e9,
                "gcells_s": cells / s / 1e9,
                "dma_bytes_per_cell": dma / cells,
            })
    return rows


def summarize(rows):
    """Per-(stencil, dtype) V=1 vs best-V table + speedups."""
    out = []
    by_st = {}
    for r in rows:
        by_st.setdefault((r["stencil"], r["dtype"]), []).append(r)
    for (name, dtype), rs in by_st.items():
        v1 = next((r for r in rs if r["par_vec"] == 1), None)
        best = min(rs, key=lambda r: r["s_per_superstep"])
        row = {
            "stencil": name,
            "dtype": dtype,
            "best_par_vec": best["par_vec"],
            "best_gcells_s": best["gcells_s"],
        }
        if v1 is not None:        # --vecs may omit the V=1 anchor
            row["v1_gcells_s"] = v1["gcells_s"]
            row["speedup_vs_v1"] = (v1["s_per_superstep"]
                                    / best["s_per_superstep"])
        out.append(row)
    return out


def check_traffic_halving(rows):
    """bf16 storage must move ~half the per-cell DMA bytes of the f32 row
    with the same (stencil, V) — the whole point of 16-bit streams.  Slab
    padding keeps the ratio from being exactly 0.5; 0.6 is the generous
    ceiling.  Returns failure strings (empty = gate passes)."""
    by_key = {(r["stencil"], r["dtype"], r["par_vec"]): r for r in rows}
    failures = []
    for r in rows:
        if r["dtype"] != "bfloat16":
            continue
        f32 = by_key.get((r["stencil"], "float32", r["par_vec"]))
        if f32 is None:
            continue
        ratio = r["dma_bytes_per_cell"] / f32["dma_bytes_per_cell"]
        status = "OK" if ratio <= 0.6 else "NOT HALVED"
        print(f"  [traffic] {r['stencil']}/V={r['par_vec']}: bf16 moves "
              f"x{ratio:.3f} of f32's DMA bytes/cell {status}")
        if ratio > 0.6:
            failures.append(
                f"{r['stencil']}/V={r['par_vec']}: bf16 DMA bytes/cell is "
                f"x{ratio:.3f} of f32 (expected ~0.5)")
    return failures


def check_regression(rows, baseline_path: Path, max_regression: float):
    """Per-cell time of every (stencil, dtype, par_vec) row vs the
    baseline's ``kernel_rows`` (pre-dtype baseline rows are f32).  Returns
    failure strings (empty = gate passes)."""
    try:
        base = json.loads(baseline_path.read_text())
    except (OSError, ValueError) as e:
        return [f"baseline {baseline_path} unreadable: {e}"]
    by_key = {(r["stencil"], r.get("dtype", "float32"), r["par_vec"]): r
              for r in base.get("kernel_rows", [])}
    if not by_key:
        return [f"baseline {baseline_path} has no kernel_rows section — "
                "regenerate it with --update-baseline"]
    failures = []
    for r in rows:
        b = by_key.get((r["stencil"], r["dtype"], r["par_vec"]))
        if b is None:
            print(f"  [gate] no kernel baseline for "
                  f"({r['stencil']}, {r['dtype']}, V={r['par_vec']}) "
                  "— skipped")
            continue
        ratio = r["ns_per_cell"] / b["ns_per_cell"]
        status = "OK" if ratio <= max_regression else "REGRESSED"
        print(f"  [gate] {r['stencil']}/{r['dtype']}/V={r['par_vec']}: "
              f"{r['ns_per_cell']:.2f} ns/cell vs baseline "
              f"{b['ns_per_cell']:.2f} -> x{ratio:.2f} {status}")
        if ratio > max_regression:
            failures.append(
                f"{r['stencil']}/{r['dtype']}/V={r['par_vec']} per-cell "
                f"time regressed x{ratio:.2f} (> x{max_regression:.2f})")
    return failures


def update_baseline(rows, baseline_path: Path) -> None:
    """Write/refresh the ``kernel_rows`` section, preserving whatever else
    (the throughput rows) the shared baseline file holds."""
    try:
        base = json.loads(baseline_path.read_text())
    except (OSError, ValueError):
        base = {}
    base["kernel_rows"] = rows
    baseline_path.parent.mkdir(parents=True, exist_ok=True)
    baseline_path.write_text(json.dumps(base, indent=1, sort_keys=True)
                             + "\n")
    print(f"updated kernel_rows in {baseline_path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized grids (seconds, interpret-friendly)")
    ap.add_argument("--backend", default="pallas_interpret",
                    help="pallas_interpret (CI proxy) or pallas (real TPU)")
    ap.add_argument("--vecs", default=None,
                    help="comma-separated par_vec sweep (default per mode)")
    ap.add_argument("--dtypes", default=None,
                    help="comma-separated storage dtypes "
                         f"(default {','.join(DTYPES)})")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="results/bench/BENCH_kernels.json")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON to gate against (CI perf-smoke)")
    ap.add_argument("--update-baseline", default=None, metavar="PATH",
                    help="write kernel_rows into this baseline file and exit")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail if ns/cell exceeds baseline by this factor")
    args = ap.parse_args(argv)

    cases = SMOKE_CASES if args.smoke else FULL_CASES
    vecs = (tuple(int(v) for v in args.vecs.split(","))
            if args.vecs else (SMOKE_VECS if args.smoke else FULL_VECS))
    dtypes = (tuple(args.dtypes.split(",")) if args.dtypes else DTYPES)

    rows = []
    print(f"{'stencil':13s} {'dims':>14s} {'dtype':>9s} {'V':>3s} "
          f"{'ms/super':>9s} {'ns/cell':>8s} {'GCell/s':>8s} {'B/cell':>7s}")
    for name, dims, par_time, bsize in cases:
        for r in bench_case(args.backend, name, dims, par_time, bsize, vecs,
                            args.warmup, args.repeats, dtypes):
            rows.append(r)
            print(f"{r['stencil']:13s} {str(tuple(r['dims'])):>14s} "
                  f"{r['dtype']:>9s} "
                  f"{r['par_vec']:3d} {r['s_per_superstep'] * 1e3:9.2f} "
                  f"{r['ns_per_cell']:8.2f} {r['gcells_s']:8.4f} "
                  f"{r['dma_bytes_per_cell']:7.2f}")
    summary = summarize(rows)
    for s in summary:
        vs = (f"x{s['speedup_vs_v1']:.2f} vs V=1"
              if "speedup_vs_v1" in s else "(no V=1 anchor in sweep)")
        print(f"  {s['stencil']}/{s['dtype']}: best V={s['best_par_vec']} "
              f"-> {vs} ({s['best_gcells_s']:.4f} GCell/s)")
    traffic_failures = check_traffic_halving(rows)

    out = {
        "schema": 1,
        "mode": "smoke" if args.smoke else "full",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "backend": args.backend,
        "rows": rows,
        "summary": summary,
    }
    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(out, indent=1, sort_keys=True) + "\n")
    print(f"wrote {out_path}")

    if traffic_failures:
        print("TRAFFIC NOT HALVED:\n  " + "\n  ".join(traffic_failures),
              file=sys.stderr)
        return 1
    if args.update_baseline:
        update_baseline(rows, Path(args.update_baseline))
        return 0
    if args.baseline:
        failures = check_regression(rows, Path(args.baseline),
                                    args.max_regression)
        if failures:
            print("PERF REGRESSION:\n  " + "\n  ".join(failures),
                  file=sys.stderr)
            return 1
        print("perf gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
