#!/usr/bin/env python
"""Serving-throughput benchmark: ``run_batch`` vs. a sequential ``run`` loop.

The ROADMAP's north star is serving heavy traffic, so this benchmark measures
the throughput subsystem end to end, per backend:

  * **sequential** — B independent ``StencilPlan.run()`` calls (the
    pre-``run_batch`` serving pattern: B dispatches, B host round-trips);
  * **batched** — one ``StencilPlan.run_batch()`` over the same B grids
    (one fused executable; see ``repro.api.backends``);

and reports amortized nanoseconds per cell-update and GCell/s for both,
plus the batched/sequential speedup and the executable-cache statistics.

Output: ``results/bench/BENCH_throughput.json`` (override with ``--out``).

CI gate (``--baseline``): every batched row is compared against the matching
row of a committed baseline file; if its amortized per-cell time regresses
by more than ``--max-regression`` (default 2x, loose on purpose — CI runners
are noisy and heterogeneous), the process exits non-zero and the perf-smoke
job fails.  Regenerate the baseline with::

    python benchmarks/throughput.py --smoke --out results/bench/baseline.json

``--smoke`` runs tiny interpret-mode-friendly grids (CI-sized: seconds, not
minutes); the default full mode runs larger grids on every available backend.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.api import RunConfig, StencilProblem, exec_cache_stats, plan
from repro.core import STENCILS, default_coeffs
from repro.data import make_stencil_inputs

# (stencil, dims, par_time, bsize): smoke = CI-sized, full = host-benchmark
SMOKE_CASES = [
    ("diffusion2d", (32, 128), 2, 128),
    ("hotspot2d", (32, 128), 2, 128),
]
FULL_CASES = [
    ("diffusion2d", (512, 512), 4, 256),
    ("hotspot2d", (512, 512), 4, 256),
    ("diffusion3d", (32, 96, 96), 2, 32),
]
SMOKE_BACKENDS = ("reference", "engine", "pallas_interpret")
FULL_BACKENDS = ("reference", "engine", "pallas_interpret")


def _time_best(fn, repeats: int) -> float:
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def bench_case(backend: str, name: str, dims, par_time: int, bsize: int,
               batch: int, iters: int, repeats: int) -> dict:
    st = STENCILS[name]
    p = plan(StencilProblem(name, dims),
             RunConfig(backend=backend, par_time=par_time, bsize=bsize))
    coeffs = default_coeffs(st)
    key = jax.random.PRNGKey(0)
    grid, aux = make_stencil_inputs(key, dims, st.has_aux)
    grids = jnp.stack([grid + 0.01 * b for b in range(batch)])

    def seq():
        return [p.run(grids[b], iters, coeffs, aux=aux)
                for b in range(batch)]

    def bat():
        return p.run_batch(grids, iters, coeffs, aux=aux)

    seq(), bat()                    # warm-up: compile both paths
    seq_s = _time_best(seq, repeats)
    bat_s = _time_best(bat, repeats)
    cell_updates = batch * math.prod(dims) * iters
    return {
        "backend": backend, "stencil": name, "dims": list(dims),
        "par_time": par_time, "bsize": bsize, "batch": batch, "iters": iters,
        "seq_s": seq_s, "batch_s": bat_s,
        "speedup": seq_s / bat_s,
        "seq_ns_per_cell": seq_s / cell_updates * 1e9,
        "batch_ns_per_cell": bat_s / cell_updates * 1e9,
        "batch_gcells_s": cell_updates / bat_s / 1e9,
    }


def check_regression(rows: list, baseline_path: Path,
                     max_regression: float) -> list:
    """Amortized per-cell time of every batched row vs. the baseline row with
    the same (backend, stencil).  Returns a list of failure strings."""
    try:
        base = json.loads(baseline_path.read_text())
    except (OSError, ValueError) as e:
        return [f"baseline {baseline_path} unreadable: {e}"]
    by_key = {(r["backend"], r["stencil"]): r for r in base.get("rows", [])}
    failures = []
    for r in rows:
        b = by_key.get((r["backend"], r["stencil"]))
        if b is None:
            print(f"  [gate] no baseline row for "
                  f"({r['backend']}, {r['stencil']}) — skipped")
            continue
        ratio = r["batch_ns_per_cell"] / b["batch_ns_per_cell"]
        status = "OK" if ratio <= max_regression else "REGRESSED"
        print(f"  [gate] {r['backend']}/{r['stencil']}: "
              f"{r['batch_ns_per_cell']:.2f} ns/cell vs baseline "
              f"{b['batch_ns_per_cell']:.2f} -> x{ratio:.2f} {status}")
        if ratio > max_regression:
            failures.append(
                f"{r['backend']}/{r['stencil']} amortized per-cell time "
                f"regressed x{ratio:.2f} (> x{max_regression:.2f})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized grids (seconds, interpret-friendly)")
    ap.add_argument("--backends", default=None,
                    help="comma-separated backend list (default per mode)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--iters", type=int, default=None,
                    help="time-steps per request (default: 4 smoke, 20 full)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="results/bench/BENCH_throughput.json")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON to gate against (CI perf-smoke)")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail if batched ns/cell exceeds baseline by this "
                         "factor (default 2.0)")
    args = ap.parse_args(argv)

    cases = SMOKE_CASES if args.smoke else FULL_CASES
    if args.iters is None:
        args.iters = 4 if args.smoke else 20
    backends = (tuple(args.backends.split(","))
                if args.backends else
                (SMOKE_BACKENDS if args.smoke else FULL_BACKENDS))

    rows = []
    print(f"{'backend':18s} {'stencil':13s} {'B':>3s} {'seq ms':>9s} "
          f"{'batch ms':>9s} {'speedup':>8s} {'GCell/s':>8s}")
    for backend in backends:
        for name, dims, par_time, bsize in cases:
            r = bench_case(backend, name, dims, par_time, bsize,
                           args.batch, args.iters, args.repeats)
            rows.append(r)
            print(f"{backend:18s} {name:13s} {r['batch']:3d} "
                  f"{r['seq_s'] * 1e3:9.2f} {r['batch_s'] * 1e3:9.2f} "
                  f"{r['speedup']:7.2f}x {r['batch_gcells_s']:8.4f}")

    out = {
        "schema": 1,
        "mode": "smoke" if args.smoke else "full",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "batch": args.batch, "iters": args.iters,
        "exec_cache": exec_cache_stats(),
        "rows": rows,
    }
    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(out, indent=1, sort_keys=True) + "\n")
    print(f"wrote {out_path}")

    if args.baseline:
        failures = check_regression(rows, Path(args.baseline),
                                    args.max_regression)
        if failures:
            print("PERF REGRESSION:\n  " + "\n  ".join(failures),
                  file=sys.stderr)
            return 1
        print("perf gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
