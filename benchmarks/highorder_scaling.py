"""Beyond-paper: high-order stencils under combined blocking (paper §8's
stated future work).

The paper conjectures temporal blocking weakens for high-order stencils:
halo width `rad·par_time` grows with the radius, so redundancy eats the
bandwidth savings sooner. We quantify it with the (traffic-validated)
performance model: for star stencils of radius 1-4, 2D and 3D, report the
autotuned (bsize, par_time), the redundancy, the bound, and the achieved
fraction of the no-temporal-blocking roofline.

Correctness of the high-order engine itself is covered by
tests/test_engine.py::test_high_order_star (radius-2 blocked == oracle).

Expected shape of the result (and what the model shows): optimal par_time
falls roughly as 1/rad in 2D and collapses to 1-4 in 3D, while the
x-over-roofline multiple compresses toward 1 — the paper's temporal-blocking
advantage is a low-order phenomenon unless block sizes grow with rad.
"""
from __future__ import annotations

from repro.core import autotune, make_star
from repro.core.perf_model import TPU_V5E

DIMS = {2: (16384, 16384), 3: (448, 448, 448)}
ITERS = 1000


def run() -> list[dict]:
    rows = []
    for ndim in (2, 3):
        for rad in (1, 2, 3, 4):
            st = make_star(ndim, rad)
            dims = DIMS[ndim]
            best = autotune(st, dims, ITERS)[0]
            roofline = TPU_V5E.mem_bw / st.bytes_pcu * st.flop_pcu
            rows.append({
                "stencil": st.name, "ndim": ndim, "radius": rad,
                "flop_pcu": st.flop_pcu,
                "bsize": best.geom.bsize,
                "par_time": best.geom.par_time,
                "halo": best.geom.size_halo,
                "redundancy": round(best.geom.redundancy, 3),
                "pred_gflops": round(best.gflops / 1e9, 1),
                "bound": best.bound,
                "x_over_roofline": round(best.gflops / roofline, 2),
            })
    return rows


def main():
    rows = run()
    print(f"{'stencil':12s} {'rad':>3s} {'bsize':>12s} {'par_t':>5s} "
          f"{'halo':>4s} {'red.':>6s} {'GFLOP/s':>8s} {'bound':>8s} "
          f"{'x roofline':>10s}")
    for r in rows:
        print(f"{r['stencil']:12s} {r['radius']:3d} {str(r['bsize']):>12s} "
              f"{r['par_time']:5d} {r['halo']:4d} {r['redundancy']:6.2f} "
              f"{r['pred_gflops']:8.1f} {r['bound']:>8s} "
              f"{r['x_over_roofline']:10.2f}")
    # the paper's conjecture, checked: par_time monotonically non-increasing
    # in radius within each dimensionality
    for ndim in (2, 3):
        pts = [r["par_time"] for r in rows if r["ndim"] == ndim]
        assert all(a >= b for a, b in zip(pts, pts[1:])), pts
    return rows


if __name__ == "__main__":
    main()
