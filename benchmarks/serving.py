#!/usr/bin/env python
"""Serving benchmark: the coalescing service vs. a per-request ``run`` loop.

Each scenario boots a one-bucket :class:`repro.serve.StencilService` and
drives it with a **seeded open-loop Poisson arrival process** (arrivals do
not wait for completions — the offered load is set by ``--oversub`` times
the sequential capacity, so coalescing pressure is real and queue-full
backpressure actually triggers).  The same request mix is then replayed as
the pre-serving pattern — a sequential per-request ``plan().run()`` loop —
and the report compares delivered throughput:

  * ``seq_cells_s``    — cell-updates/s of the sequential loop;
  * ``serve_cells_s``  — delivered cell-updates/s of the service (completed
    requests over the submit->last-delivery wall clock);
  * ``speedup``        — serve/seq (the coalescing win);
  * ``p50_ms``/``p99_ms`` — end-to-end request latency percentiles;
  * ``batch_fill``     — mean real/padded launch occupancy;
  * ``rejected``       — queue-full rejections (every one answered with
    ``ServiceOverloaded`` + retry-after; nothing is silently dropped).

Output: ``results/bench/BENCH_serving.json`` (override with ``--out``).

CI gate (``--baseline``): each row's delivered ns/cell is compared against
the committed baseline row with the same (backend, stencil) under
``--max-regression`` (default 2x — CI runners are noisy), and the row must
sustain ``--min-speedup`` (default 1.5x) at ``--min-fill`` (default 0.5)
batch fill.  Regenerate the baseline rows with::

    python benchmarks/serving.py --smoke --out /tmp/serving.json
    # then merge rows into results/bench/baseline.json as "serving_rows"
"""
from __future__ import annotations

import argparse
import asyncio
import json
import math
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.api import RunConfig, StencilProblem, exec_cache_stats, plan
from repro.data import make_stencil_inputs
from repro.serve import (ServiceConfig, ServiceOverloaded, StencilRequest,
                         serve)

# (stencil, dims, par_time, bsize): smoke = CI-sized, full = host-benchmark.
# par_time=4 folds 4 time-steps into one super-step: each request is a
# single fused dispatch, and small grids keep per-request cost dominated by
# marshalling + dispatch — the regime coalescing exists for (large compute-
# bound grids conserve FLOPs either way; FULL_CASES measure that end).
SMOKE_CASES = [
    ("diffusion2d", (16, 64), 4, 64),
    ("hotspot2d", (16, 64), 4, 64),
]
FULL_CASES = [
    ("diffusion2d", (256, 512), 4, 256),
    ("hotspot2d", (256, 512), 4, 256),
]
#: default per-request iteration count: few iterations per request is the
#: regime coalescing exists for (per-request dispatch dominates, so one
#: fused launch amortizes it).  Uniform by default: heterogeneous mixes
#: (``--iters-mix 2,4``) exercise staged advance, but every staged round
#: re-runs the full padded batch, so early-finishing members cost throughput
#: — a policy trade-off the benchmark can measure, not hide.
DEFAULT_ITERS_MIX = (4,)


def make_requests(problem: StencilProblem, n: int, seed: int, iters_mix):
    """The seeded request mix one scenario serves: distinct per-request
    grids (plus shared aux), iteration counts drawn from ``iters_mix``.
    Grids are *host* arrays — requests arrive off the wire as host data,
    which both sides must marshal onto the device."""
    st = problem.stencil
    rng = np.random.default_rng(seed)
    iters = [int(i) for i in rng.choice(iters_mix, n)]
    key = jax.random.PRNGKey(seed)
    grid, aux = make_stencil_inputs(key, problem.shape, st.has_aux)
    base = np.asarray(grid)
    aux = np.asarray(aux) if st.has_aux else None
    reqs = []
    for i in range(n):
        g = base + np.float32(0.01 * i)
        reqs.append(StencilRequest(problem, g, iters[i], aux=aux))
    return reqs


def bench_sequential(problem, run: RunConfig, reqs) -> float:
    """The pre-serving pattern: one ``plan().run()`` per request, in
    arrival order, materializing each result on the host — the same
    per-request deliverable the service hands back (``ServeResult.grid``
    is a host array).  Without the per-request materialization the loop
    would time only async dispatch while XLA computes in the background —
    an idealized baseline no request/response server can match.  Returns
    seconds for the whole mix (after warm-up)."""
    p = plan(problem, run)
    p.prewarm(batch_sizes=(), iters=1)          # compile the single path
    np.asarray(p.run(reqs[0].grid, reqs[0].iters, aux=reqs[0].aux))
    t0 = time.perf_counter()
    for r in reqs:
        np.asarray(p.run(r.grid, r.iters, aux=r.aux))
    return time.perf_counter() - t0


async def bench_serving(problem, run: RunConfig, reqs, *, max_batch: int,
                        max_wait_ms: float, queue_cap: int, gap_s: float,
                        seed: int, concurrent: int) -> dict:
    """Open-loop pass: boot the service (pre-warmed), submit the mix with
    seeded exponential inter-arrival gaps, await every outcome.

    ``concurrent`` > 1 lets the next launch assemble (stack/pad on the
    event loop, thread dispatch) while the previous one computes — the
    coalescing overhead overlaps device time instead of serializing with
    it."""
    svc = await serve(ServiceConfig(
        buckets=[{"problem": problem, "run": run, "max_batch": max_batch,
                  "max_wait_ms": max_wait_ms, "queue_cap": queue_cap}],
        max_concurrent_batches=concurrent))
    # one full + one padded launch through the *service* path (stack, pad,
    # slice, thread pool): plan.prewarm covers the executables, not these
    warm = reqs[:min(max_batch + 1, queue_cap)]
    await asyncio.gather(*[svc.submit_nowait(r) for r in warm])
    svc.metrics.reset()         # measure steady state, not warm-up
    rng = np.random.default_rng(seed + 1)
    gaps = rng.exponential(gap_s, len(reqs))
    futures, rejected = [], 0
    t0 = time.perf_counter()
    # self-correcting pacer: each request has an absolute scheduled time;
    # sleep only the remaining difference.  asyncio.sleep overshoots by
    # ~ms, so per-gap sleeping would silently throttle the offered load —
    # here an overshoot just makes the next submits catch up immediately
    # (bursty arrivals, which open-loop load tolerates).
    sched = 0.0
    for i, (r, gap) in enumerate(zip(reqs, gaps)):
        sched += float(gap)
        delay = t0 + sched - time.perf_counter()
        if delay > 1e-3:
            await asyncio.sleep(delay)
        elif i % 8 == 0:
            await asyncio.sleep(0)      # let the workers run regardless
        try:
            futures.append(svc.submit_nowait(r))
        except ServiceOverloaded:
            rejected += 1
    results = await asyncio.gather(*futures)
    wall_s = time.perf_counter() - t0
    snap = svc.snapshot()
    await svc.stop()
    cells = sum(r.iters for r in results) * math.prod(problem.shape)
    assert snap["submitted"] == snap["completed"] + snap["rejected_total"], \
        "serving accounting leak: a request vanished without an answer"
    return {"wall_s": wall_s, "cells": cells, "snap": snap,
            "rejected": rejected, "completed": len(results)}


def bench_case(backend: str, name: str, dims, par_time: int, bsize: int, *,
               n: int, oversub: float, max_batch: int, max_wait_ms: float,
               queue_cap: int, seed: int, concurrent: int,
               iters_mix, reps: int = 3) -> dict:
    problem = StencilProblem(name, dims)
    run = RunConfig(backend=backend, par_time=par_time, bsize=bsize)
    reqs = make_requests(problem, n, seed, iters_mix)
    total_cells = sum(r.iters for r in reqs) * math.prod(dims)

    # best-of-N on both sides (the suite's _time_best idiom): one-core CI
    # runners jitter either measurement by 2x, and min is the standard
    # noise-robust estimator of the undisturbed run
    seq_s = min(bench_sequential(problem, run, reqs) for _ in range(reps))
    # offered load = oversub x the sequential capacity: batches actually
    # fill, and sustained oversubscription exercises the bounded queue
    gap_s = (seq_s / n) / oversub
    sv = None
    for _ in range(reps):
        cand = asyncio.run(bench_serving(
            problem, run, reqs, max_batch=max_batch,
            max_wait_ms=max_wait_ms, queue_cap=queue_cap, gap_s=gap_s,
            seed=seed, concurrent=concurrent))
        if sv is None or (cand["cells"] / cand["wall_s"]
                          > sv["cells"] / sv["wall_s"]):
            sv = cand

    snap = sv["snap"]
    seq_cells_s = total_cells / seq_s
    serve_cells_s = sv["cells"] / sv["wall_s"] if sv["cells"] else 0.0
    return {
        "backend": backend, "stencil": name, "dims": list(dims),
        "par_time": par_time, "bsize": bsize, "n_requests": n,
        "iters_mix": [int(i) for i in iters_mix], "oversub": oversub,
        "max_batch": max_batch, "max_wait_ms": max_wait_ms,
        "queue_cap": queue_cap, "concurrent": concurrent,
        "seq_s": seq_s, "serve_wall_s": sv["wall_s"],
        "completed": sv["completed"], "rejected": sv["rejected"],
        "batch_fill": snap["batch_fill"],
        "batches": snap["batches"],
        "p50_ms": snap["latency_ms"]["p50"],
        "p99_ms": snap["latency_ms"]["p99"],
        "seq_cells_s": seq_cells_s,
        "serve_cells_s": serve_cells_s,
        "speedup": serve_cells_s / seq_cells_s if seq_cells_s else None,
        "serve_ns_per_cell": (sv["wall_s"] / sv["cells"] * 1e9
                              if sv["cells"] else None),
    }


def check_gate(rows: list, baseline_path: Path, max_regression: float,
               min_speedup: float, min_fill: float) -> list:
    """The serving acceptance gate: delivered ns/cell vs. the committed
    baseline row with the same (backend, stencil), plus the absolute
    speedup/fill floors.  Returns failure strings."""
    failures = []
    base_rows = []
    if baseline_path is not None:
        try:
            base = json.loads(baseline_path.read_text())
            base_rows = base.get("serving_rows", base.get("rows", []))
        except (OSError, ValueError) as e:
            return [f"baseline {baseline_path} unreadable: {e}"]
    by_key = {(r["backend"], r["stencil"]): r for r in base_rows}
    for r in rows:
        tag = f"{r['backend']}/{r['stencil']}"
        b = by_key.get((r["backend"], r["stencil"]))
        if b is None:
            print(f"  [gate] no baseline row for {tag} — skipped")
        else:
            ratio = r["serve_ns_per_cell"] / b["serve_ns_per_cell"]
            status = "OK" if ratio <= max_regression else "REGRESSED"
            print(f"  [gate] {tag}: {r['serve_ns_per_cell']:.2f} ns/cell "
                  f"vs baseline {b['serve_ns_per_cell']:.2f} "
                  f"-> x{ratio:.2f} {status}")
            if ratio > max_regression:
                failures.append(f"{tag} delivered ns/cell regressed "
                                f"x{ratio:.2f} (> x{max_regression:.2f})")
        if r["speedup"] is not None and r["speedup"] < min_speedup:
            failures.append(f"{tag} serve/seq speedup {r['speedup']:.2f} "
                            f"< {min_speedup:.2f}")
        if r["batch_fill"] is not None and r["batch_fill"] < min_fill:
            failures.append(f"{tag} batch fill {r['batch_fill']:.2f} "
                            f"< {min_fill:.2f}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized grids (seconds, not minutes)")
    ap.add_argument("--backends", default="engine",
                    help="comma-separated backend list (default: engine)")
    ap.add_argument("--n", type=int, default=256,
                    help="requests per scenario")
    ap.add_argument("--oversub", type=float, default=2.5,
                    help="offered load as a multiple of sequential capacity")
    ap.add_argument("--iters-mix", default=None,
                    help="comma-separated per-request iteration counts "
                         "(default: uniform 4; a mix exercises staged "
                         "advance at a throughput cost)")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--queue-cap", type=int, default=96)
    ap.add_argument("--concurrent", type=int, default=1,
                    help="max in-flight coalesced launches (>1 overlaps "
                         "launches in threads — pays off only with cores "
                         "to spare; 1 runs compute inline on the loop)")
    ap.add_argument("--reps", type=int, default=3,
                    help="best-of-N repetitions per measurement")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/bench/BENCH_serving.json")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON to gate against (CI perf-smoke)")
    ap.add_argument("--max-regression", type=float, default=2.0)
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="serve/seq throughput floor (acceptance)")
    ap.add_argument("--min-fill", type=float, default=0.5,
                    help="mean batch-fill floor (acceptance)")
    args = ap.parse_args(argv)

    cases = SMOKE_CASES if args.smoke else FULL_CASES
    n = args.n
    iters_mix = (tuple(int(i) for i in args.iters_mix.split(","))
                 if args.iters_mix else DEFAULT_ITERS_MIX)
    backends = tuple(args.backends.split(","))

    rows = []
    print(f"{'backend':10s} {'stencil':13s} {'n':>4s} {'rej':>4s} "
          f"{'fill':>5s} {'p50 ms':>8s} {'p99 ms':>8s} {'speedup':>8s}")
    for backend in backends:
        for name, dims, par_time, bsize in cases:
            r = bench_case(backend, name, dims, par_time, bsize, n=n,
                           oversub=args.oversub, max_batch=args.max_batch,
                           max_wait_ms=args.max_wait_ms,
                           queue_cap=args.queue_cap, seed=args.seed,
                           concurrent=args.concurrent,
                           iters_mix=iters_mix, reps=args.reps)
            rows.append(r)
            print(f"{backend:10s} {name:13s} {r['completed']:4d} "
                  f"{r['rejected']:4d} {r['batch_fill']:5.2f} "
                  f"{r['p50_ms']:8.2f} {r['p99_ms']:8.2f} "
                  f"{r['speedup']:7.2f}x")

    out = {
        "schema": 1,
        "mode": "smoke" if args.smoke else "full",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "n_requests": n, "oversub": args.oversub, "seed": args.seed,
        "exec_cache": exec_cache_stats(),
        "rows": rows,
    }
    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(out, indent=1, sort_keys=True) + "\n")
    print(f"wrote {out_path}")

    if args.baseline:
        failures = check_gate(rows, Path(args.baseline),
                              args.max_regression, args.min_speedup,
                              args.min_fill)
        if failures:
            print("SERVING GATE FAILED:\n  " + "\n  ".join(failures),
                  file=sys.stderr)
            return 1
        print("serving gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
