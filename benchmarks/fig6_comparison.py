"""Paper Fig. 6 analogue: performance vs. the no-temporal-blocking roofline
across devices.

The paper's Fig. 6 compares Diffusion 3D on FPGAs vs GPUs, with each
device's "roofline" = the GFLOP/s achievable at full external-bandwidth
utilization WITHOUT temporal blocking (bytes-PCU-limited). The FPGA beats
its own roofline by several x because temporal blocking trades on-chip
storage for bandwidth — the paper's core argument.

We reproduce that chart's data for the TPU family: per device, the
bandwidth roofline (no temporal blocking), the model-predicted performance
of our combined-blocking accelerator, and the resulting "x over roofline".
Paper-reported device datapoints (Arria 10 measured, P100/V100 from the
paper's Fig. 6) are included as static reference context.
"""
from __future__ import annotations

from repro.core import STENCILS, autotune
from repro.core.perf_model import DEVICES

FULL_DIMS = {2: (16384, 16384), 3: (448, 448, 448)}
ITERS = 1000

# paper Fig. 6 reference points (GFLOP/s, Diffusion 3D, as published)
PAPER_POINTS = {
    "arria10_gx1150 (paper, measured)": dict(mem_bw=34.1e9, gflops=374.7),
    "stratix10_mx2100 (paper, projected)": dict(mem_bw=512e9, gflops=1584.8),
    "tesla_p100 (paper, measured)": dict(mem_bw=720.9e9, gflops=1100.0),
    "tesla_v100 (paper, measured)": dict(mem_bw=900.1e9, gflops=1400.0),
}


def run(benchmark: str = "diffusion3d") -> list[dict]:
    st = STENCILS[benchmark]
    dims = FULL_DIMS[st.ndim]
    rows = []
    for dev_name, dev in DEVICES.items():
        roofline = dev.mem_bw / st.bytes_pcu * st.flop_pcu   # no temp. blocking
        best = autotune(st, dims, ITERS, device=dev)[0]
        rows.append({
            "device": dev_name, "benchmark": benchmark,
            "roofline_gflops": round(roofline / 1e9, 1),
            "predicted_gflops": round(best.gflops / 1e9, 1),
            "x_over_roofline": round(best.gflops / roofline, 2),
            "par_time": best.geom.par_time,
            "bsize": best.geom.bsize,
            "source": "model (this work)",
        })
    for label, p in PAPER_POINTS.items():
        roofline = p["mem_bw"] / st.bytes_pcu * st.flop_pcu
        rows.append({
            "device": label, "benchmark": benchmark,
            "roofline_gflops": round(roofline / 1e9, 1),
            "predicted_gflops": p["gflops"],
            "x_over_roofline": round(p["gflops"] * 1e9 / roofline, 2),
            "source": "paper Fig. 6",
        })
    return rows


def main():
    rows = run()
    print(f"{'device':38s} {'roofline GF/s':>13s} {'achieved GF/s':>13s} "
          f"{'x roofline':>10s}  source")
    for r in rows:
        print(f"{r['device']:38s} {r['roofline_gflops']:13.1f} "
              f"{r['predicted_gflops']:13.1f} {r['x_over_roofline']:10.2f}  "
              f"{r['source']}")
    return rows


if __name__ == "__main__":
    main()
