"""Benchmark orchestrator: one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only table4

Writes machine-readable results to results/bench/<name>.json and prints the
human tables. The roofline section reads the dry-run cells
(results/dryrun/*.json — produced by ``python -m repro.launch.dryrun --all``).
"""
from __future__ import annotations

import argparse
import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")


def _save(name: str, rows):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=2, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="table2|table4|table6|fig6|roofline")
    args = ap.parse_args()

    from benchmarks import (fig6_comparison, highorder_scaling, roofline,
                            table2_characteristics, table4_stencil,
                            table6_projection)
    suites = {
        "table2": ("Paper Table 2: stencil characteristics (verified)",
                   table2_characteristics.main),
        "table4": ("Paper Table 4: tuned configs, predicted perf, "
                   "traffic accuracy", table4_stencil.main),
        "table6": ("Paper Table 6: next-gen device projection (v5p/v6e)",
                   table6_projection.main),
        "fig6": ("Paper Fig. 6: devices vs no-temporal-blocking roofline",
                 fig6_comparison.main),
        "highorder": ("Beyond-paper: high-order stencils (paper §8 future "
                      "work)", highorder_scaling.main),
        "roofline": ("Roofline terms per (arch x shape) from the dry-run",
                     roofline.main),
    }
    failures = []
    for name, (title, fn) in suites.items():
        if args.only and name != args.only:
            continue
        print(f"\n=== {name}: {title} " + "=" * max(0, 40 - len(name)))
        t0 = time.time()
        try:
            rows = fn()
            _save(name, rows)
            print(f"[{name}] ok ({time.time() - t0:.1f}s) -> "
                  f"results/bench/{name}.json")
        except Exception as e:   # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"[{name}] FAILED: {e!r}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
