"""Paper Table 2: benchmark characteristics (FLOP / bytes per cell update).

The static columns come from the stencil zoo; the *verified* FLOP column is
counted from the compiled HLO of one unblocked time-step (XLA cost analysis
divided by grid cells) — the implementation must do exactly the paper's
arithmetic, or the ratio drifts from 1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import STENCILS, default_coeffs
from repro.kernels.ref import oracle_step

GRID2D = (256, 256)
GRID3D = (32, 64, 64)


def run() -> list[dict]:
    rows = []
    for name in ("diffusion2d", "diffusion3d", "hotspot2d", "hotspot3d"):
        st = STENCILS[name]
        dims = GRID2D if st.ndim == 2 else GRID3D
        cells = 1
        for d in dims:
            cells *= d
        coeffs = default_coeffs(st)
        grid = jnp.ones(dims, jnp.float32)
        aux = jnp.ones(dims, jnp.float32) if st.has_aux else None

        compiled = jax.jit(
            lambda g, a: oracle_step(st, g, coeffs, a)).lower(
                grid, aux if aux is not None else grid).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        counted = ca.get("flops", 0.0) / cells

        rows.append({
            "benchmark": st.name,
            "flop_pcu": st.flop_pcu,
            "flop_pcu_counted_hlo": round(counted, 2),
            "bytes_pcu": st.bytes_pcu,
            "bytes_per_flop": round(st.bytes_pcu / st.flop_pcu, 3),
            "num_read": st.num_read,
            "num_write": st.num_write,
            "radius": st.radius,
        })
    return rows


PAPER = {  # paper Table 2 reference values
    "diffusion2d": dict(flop=9, bytes=8, ratio=0.889),
    "diffusion3d": dict(flop=13, bytes=8, ratio=0.615),
    "hotspot2d": dict(flop=15, bytes=12, ratio=0.800),
    "hotspot3d": dict(flop=17, bytes=12, ratio=0.706),
}


def main():
    rows = run()
    hdr = (f"{'benchmark':14s} {'FLOP PCU':>8s} {'HLO-counted':>11s} "
           f"{'Bytes PCU':>9s} {'B/FLOP':>7s} {'paper B/FLOP':>12s}")
    print(hdr)
    for r in rows:
        p = PAPER[r["benchmark"]]
        ok = (r["flop_pcu"] == p["flop"] and r["bytes_pcu"] == p["bytes"]
              and abs(r["bytes_per_flop"] - p["ratio"]) < 5e-3)
        print(f"{r['benchmark']:14s} {r['flop_pcu']:8d} "
              f"{r['flop_pcu_counted_hlo']:11.2f} {r['bytes_pcu']:9d} "
              f"{r['bytes_per_flop']:7.3f} {p['ratio']:12.3f} "
              f"{'ok' if ok else 'MISMATCH'}")
        assert ok, r
    return rows


if __name__ == "__main__":
    main()
