"""Paper Table 6 analogue: performance projection for next-generation
devices via the performance model.

The paper projects its Arria 10 results to the (then-upcoming) Stratix 10
GX 2800 and MX 2100 with a calibration factor derived from measured model
accuracy (80% 2D / 60% 3D). We project the TPU v5e-tuned accelerator to
TPU v5p and v6e the same way: re-run the autotuner with each device's
constants, apply the traffic-accuracy calibration measured in Table 4
(model vs kernel DMA schedule), and report the best configuration.

The paper's headline observation reproduces on TPU: a device's
"memory-bandwidth to compute" ratio decides the bottleneck — v5p's HBM2e
(2.7 TB/s) pushes even 3D stencils fully compute-bound, while v5e leaves
big-par_time 3D configs memory-bound.
"""
from __future__ import annotations

from repro.core import STENCILS, autotune
from repro.core.blocking import superstep_traffic_bytes
from repro.core.perf_model import DEVICES
from repro.kernels.ops import dma_traffic_bytes

FULL_DIMS = {2: (16384, 16384), 3: (448, 448, 448)}
ITERS = 5000   # paper Table 6 uses 5000 iterations


def run(calibration: dict | None = None) -> list[dict]:
    rows = []
    for dev_name in ("tpu_v5e", "tpu_v5p", "tpu_v6e"):
        dev = DEVICES[dev_name]
        for name in ("diffusion2d", "diffusion3d", "hotspot2d", "hotspot3d"):
            st = STENCILS[name]
            dims = FULL_DIMS[st.ndim]
            best = autotune(st, dims, ITERS, device=dev)[0]
            # calibration factor: measured traffic accuracy (Table 4), or
            # the kernel-DMA ratio computed directly for this geometry
            if calibration and name in calibration:
                cal = calibration[name]
            else:
                cal = (superstep_traffic_bytes(best.geom, st.num_read,
                                               st.num_write)
                       / dma_traffic_bytes(st, best.geom))
            rows.append({
                "device": dev_name, "benchmark": name,
                "bsize": best.geom.bsize, "par_time": best.geom.par_time,
                "pred_gflops": round(best.gflops / 1e9, 1),
                "calibration": round(cal, 3),
                "calibrated_gflops": round(best.gflops * cal / 1e9, 1),
                "calibrated_tflops": round(best.gflops * cal / 1e12, 3),
                "bound": best.bound,
                "vmem_mib": round(best.vmem_bytes / 2**20, 2),
                "bw_used_gbs": round(best.gbytes_s / 1e9, 1),
                "bw_util_pct": round(100 * best.gbytes_s / dev.mem_bw, 1),
            })
    return rows


def main():
    rows = run()
    print(f"{'device':9s} {'benchmark':13s} {'bsize':>11s} {'par_t':>5s} "
          f"{'pred GF/s':>10s} {'cal':>6s} {'cal GF/s':>9s} {'bound':>8s} "
          f"{'BW%':>5s}")
    for r in rows:
        print(f"{r['device']:9s} {r['benchmark']:13s} {str(r['bsize']):>11s} "
              f"{r['par_time']:5d} {r['pred_gflops']:10.1f} "
              f"{r['calibration']:6.3f} {r['calibrated_gflops']:9.1f} "
              f"{r['bound']:>8s} {r['bw_util_pct']:5.1f}")
    return rows


if __name__ == "__main__":
    main()
