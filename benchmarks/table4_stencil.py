"""Paper Table 4 analogue: per-stencil tuned configurations and throughput.

The paper reports, per stencil x board: candidate configs (bsize, par_time),
estimated performance from the model, measured performance, and model
accuracy. On this CPU container "the board" is unavailable, so the table
reports, per stencil on TPU v5e constants:

  * top candidate configs from the autotuner (paper §5.3 pruning),
  * predicted GB/s | GFLOP/s | GCell/s for each (paper "Estimated"),
  * **traffic accuracy**: the model's predicted HBM bytes per super-step vs
    the Pallas kernel's exact DMA-schedule bytes (the paper's "model
    accuracy" re-based on what is countable without hardware:
    predicted/actual *traffic* instead of predicted/actual *time*),
  * **engine HLO bytes**: counted fusion-boundary traffic of the pure-JAX
    engine for the same geometry — the ~2-orders-larger number that shows
    why the manual-DMA Pallas kernel is the production path on TPU,
  * **measured tuning** (the paper's Table 4 "Measured" + "Model Accuracy"
    columns): ``repro.api.tune`` times the model's top candidates on the
    blocked engine at reduced, host-measurable dims, reports measured
    GCell/s and model accuracy (estimated/measured time) per stencil, and
    persists the winner in the schedule cache — a second run of this
    benchmark is served from the cache without re-timing.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.api import RunConfig, StencilProblem, plan, tune
from repro.core import STENCILS, autotune
from repro.core.blocking import BlockGeometry
from repro.core.engine import blocked_superstep
from repro.launch import hlo_analysis

# paper-scale dims (>= 1 GB inputs): 16384^2 (2D), 448^3-ish (3D)
FULL_DIMS = {2: (16384, 16384), 3: (448, 448, 448)}
# host-measurable dims
HOST_DIMS = {2: (512, 512), 3: (48, 96, 96)}
ITERS = 1000


def _hlo_traffic(st, geom: BlockGeometry, dims) -> float:
    """Compiled-HLO bytes of one super-step of the pure-JAX engine (CPU
    lowering, no allocation)."""
    coeffs = {k: jax.ShapeDtypeStruct((), jnp.float32)
              for k in st.coeff_names}
    g = jax.ShapeDtypeStruct(dims, jnp.float32)
    aux = jax.ShapeDtypeStruct(dims, jnp.float32) if st.has_aux else None
    fn = jax.jit(lambda gr, cf, ax: blocked_superstep(
        st, geom, gr, cf, geom.par_time, ax))
    compiled = fn.lower(g, coeffs, aux).compile()
    an = hlo_analysis.analyze(compiled.as_text())
    return an.hbm_bytes


def run(n_candidates: int = 3, with_hlo: bool = True,
        cache=None) -> list[dict]:
    """``cache``: passed through to ``RunConfig.cache`` for the measured rows
    (None = default location, False = no persistence, str = explicit path)."""
    rows = []
    for name in ("diffusion2d", "diffusion3d", "hotspot2d", "hotspot3d"):
        st = STENCILS[name]
        dims = FULL_DIMS[st.ndim]
        cands = autotune(st, dims, ITERS)[:n_candidates]
        for rank, p in enumerate(cands):
            row = {
                "benchmark": st.name, "rank": rank,
                "dims": dims, "iters": ITERS,
                "bsize": p.geom.bsize, "par_time": p.geom.par_time,
                "csize": p.geom.csize, "redundancy": round(p.geom.redundancy, 3),
                "pred_gbytes_s": round(p.gbytes_s / 1e9, 1),
                "pred_gflops": round(p.gflops / 1e9, 1),
                "pred_gcells_s": round(p.gcells_s / 1e9, 2),
                "bound": p.bound,
                "vmem_mib": round(p.vmem_bytes / 2**20, 2),
                "run_time_s": round(p.run_time, 4),
            }
            if rank == 0:
                # traffic accuracy via the plan API (model Eq. 7/8 vs. the
                # Pallas kernels' exact DMA schedule)
                tr = plan(StencilProblem(st, dims),
                          RunConfig(backend="engine",
                                    par_time=p.geom.par_time,
                                    bsize=p.geom.bsize)).traffic_report()
                model_bytes = tr["model_bytes_per_superstep"]
                kernel_bytes = tr["kernel_dma_bytes_per_superstep"]
                row["model_bytes_per_super"] = model_bytes
                row["kernel_dma_bytes_per_super"] = kernel_bytes
                row["traffic_accuracy"] = round(tr["traffic_accuracy"], 3)
                if with_hlo:
                    hlo_bytes = _hlo_traffic(st, p.geom, dims)
                    row["engine_hlo_bytes_per_super"] = hlo_bytes
                    row["engine_amplification"] = round(
                        hlo_bytes / kernel_bytes, 1) if kernel_bytes else None
            rows.append(row)

        # measured tuning at host-measurable dims (Table 4 "Measured" +
        # "Model Accuracy" columns): time the model's top candidates on the
        # blocked engine, persist the winner in the schedule cache.
        hdims = HOST_DIMS[st.ndim]
        hplan = tune(StencilProblem(st, hdims),
                     RunConfig(backend="engine", iters_hint=8,
                               tune_top_k=3, tune_warmup=1, tune_repeats=2,
                               cache=cache))
        for rank, c in enumerate(hplan.candidates):
            rows.append({
                "benchmark": st.name, "rank": f"measured-{rank}",
                "dims": hdims, "iters": 8,
                "bsize": c.geom.bsize, "par_time": c.geom.par_time,
                "measured_s_per_super": round(c.measured_s, 6),
                "measured_gcells_s": round(
                    math.prod(hdims) * c.geom.par_time
                    / c.measured_s / 1e9, 4),
                "model_accuracy": c.model_accuracy,
                "from_cache": c.from_cache,
            })
    return rows


def main():
    rows = run()
    print(f"{'benchmark':13s} {'bsize':>12s} {'par_t':>5s} {'red.':>5s} "
          f"{'GB/s':>7s} {'GFLOP/s':>8s} {'GCell/s':>8s} {'bound':>7s} "
          f"{'VMEM MiB':>8s} {'traffic acc':>11s}")
    for r in rows:
        if str(r["rank"]).startswith("measured"):
            src = "cache" if r["from_cache"] else "timed"
            print(f"{r['benchmark']:13s} {str(r['bsize']):>12s} "
                  f"{r['par_time']:5d}   measured ({src}): "
                  f"{r['measured_gcells_s']:.4f} GCell/s @ {r['dims']}, "
                  f"model_accuracy={r['model_accuracy']:.3g}")
            continue
        acc = r.get("traffic_accuracy")
        print(f"{r['benchmark']:13s} {str(r['bsize']):>12s} "
              f"{r['par_time']:5d} {r['redundancy']:5.2f} "
              f"{r['pred_gbytes_s']:7.1f} {r['pred_gflops']:8.1f} "
              f"{r['pred_gcells_s']:8.2f} {r['bound']:>7s} "
              f"{r['vmem_mib']:8.2f} "
              f"{acc if acc is not None else '':>11}")
    return rows


if __name__ == "__main__":
    main()
