"""Batched LM serving driver: prefill + KV-cache decode with a simple
continuous-batching scheduler.

A small request pool arrives with different prompt lengths; the server
prefills each prompt into a padded cache slot, then decodes the whole batch
in lockstep (one token/step for every live slot). Finished slots (EOS or
max-new-tokens) are immediately refilled from the queue — the "continuous
batching" serving pattern, scaled down to a CPU demo.

Demo simplification: the cache ``length`` is shared across slots (the max
over live requests), so a freshly-admitted short prompt also attends over
zero-padded cache positions. Production serving keeps a per-slot length
vector; see ``repro.models.attention.decode_attention`` which already masks
per-position when given one.

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --max-new 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, decode_step, init_params, prefill

CFG = ModelConfig(
    name="demo-serve", family="dense", n_layers=4, d_model=256, n_heads=4,
    n_kv=2, d_head=64, d_ff=1024, vocab=8192, act="swiglu", qk_norm=True,
    tie_embeddings=True, attn_q_chunk=64, attn_kv_chunk=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2, help="decode slots")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=160)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    queue = [rng.integers(1, CFG.vocab, size=rng.integers(8, 64)).tolist()
             for _ in range(args.requests)]
    print(f"serving {len(queue)} requests, {args.batch} decode slots, "
          f"params={CFG.n_params / 1e6:.1f}M")

    params = init_params(jax.random.PRNGKey(0), CFG)
    prefill_1 = jax.jit(
        lambda p, t: prefill(p, CFG, t, args.max_len)[:2])
    decode = jax.jit(lambda p, t, c: decode_step(p, CFG, t, c))

    # slot state: per-slot caches are stacked into one batched cache tree
    def stack(trees):
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=-4)
                            if xs[0].ndim >= 4 else xs[0], *trees)

    completions = {}
    t0 = time.perf_counter()
    slots = []      # (req_id, generated tokens list)
    caches = None
    live_tok = jnp.zeros((args.batch, 1), jnp.int32)
    next_id = 0

    def admit(slot_idx):
        """Prefill the next queued request into a slot."""
        nonlocal caches, live_tok, next_id
        prompt = queue.pop(0)
        logits, c1 = prefill_1(params, jnp.asarray([prompt], jnp.int32))
        first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        if caches is None:
            caches = jax.tree.map(
                lambda x: jnp.repeat(x, args.batch, axis=-4)
                if x.ndim >= 4 else x, c1)
        else:
            # splice this request's cache into the slot (cache layout:
            # (..., B, S, heads, d) with B at axis -4 for k/v leaves)
            caches = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one, slot_idx, axis=-4)
                if full.ndim >= 4 else jnp.maximum(full, one), caches, c1)
        live_tok = live_tok.at[slot_idx, 0].set(first[0])
        slots[slot_idx] = (next_id, [int(first[0])])
        next_id += 1

    for i in range(min(args.batch, len(queue) + 0)):
        slots.append(None)
        admit(i)
    while len(slots) < args.batch:
        slots.append(None)

    steps = 0
    while any(s is not None for s in slots):
        logits, caches = decode(params, live_tok, caches)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        live_tok = nxt[:, None]
        steps += 1
        for i, s in enumerate(slots):
            if s is None:
                continue
            rid, toks = s
            toks.append(int(nxt[i]))
            if len(toks) >= args.max_new:
                completions[rid] = toks
                slots[i] = None
                if queue:
                    admit(i)
    dt = time.perf_counter() - t0

    for rid in sorted(completions):
        print(f"  req {rid}: {len(completions[rid])} tokens "
              f"{completions[rid][:8]}...")
    tput = sum(len(v) for v in completions.values()) / dt
    print(f"{len(completions)} completions in {dt:.2f}s "
          f"({steps} decode steps, {tput:.1f} tok/s on this host)")
    assert len(completions) == args.requests


if __name__ == "__main__":
    main()
