"""End-to-end stencil application driver — the paper's Table 4 workflow.

Picks a stencil, lets ``plan()`` autotune (bsize, par_time) with the
performance model, runs a few hundred iterations through the resulting
``StencilPlan``, and reports measured GCell/s / GFLOP/s / GB/s next to the
model's prediction (paper §6.2 "model accuracy").

    PYTHONPATH=src python examples/stencil_app.py --stencil diffusion2d \
        --dim 1024 --iters 200

On this CPU container the measured numbers reflect the host, not a TPU;
the structure (plan -> run -> model-accuracy) is the deliverable.
"""
import argparse
import math
import time

import jax
import jax.numpy as jnp

from repro.api import RunConfig, StencilProblem, plan
from repro.core import STENCILS
from repro.data import make_stencil_inputs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stencil", default="diffusion2d",
                    choices=sorted(STENCILS))
    ap.add_argument("--dim", type=int, default=1024,
                    help="grid extent per dimension")
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--backend", default="engine",
                    choices=["engine", "pallas_interpret", "reference"])
    ap.add_argument("--par-time", type=int, default=None,
                    help="override autotuned par_time")
    ap.add_argument("--bsize", type=int, default=None,
                    help="override autotuned block size")
    args = ap.parse_args()

    st = STENCILS[args.stencil]
    ndim = st.ndim
    dims = (args.dim,) * ndim if ndim == 2 else \
        (max(64, args.dim // 4),) + (args.dim,) * 2
    grid, aux = make_stencil_inputs(jax.random.PRNGKey(0), dims, st.has_aux)

    # 1. one plan() call: any schedule field left unset is filled by the
    #    perf-model autotuner (paper §5.3)
    p = plan(StencilProblem(st, dims),
             RunConfig(backend=args.backend, par_time=args.par_time,
                       bsize=args.bsize, iters_hint=args.iters))
    pred = p.predicted(args.iters)
    print(p.describe())
    print(f"  predicted run_time on TPU v5e: {pred.run_time * 1e3:.2f} ms "
          f"({pred.n_super} super-steps)")

    # 2. run it (jit warm-up excluded from timing); the plan is reusable
    out = p.run(grid, args.iters, aux=aux)
    out.block_until_ready()
    t0 = time.perf_counter()
    out = p.run(grid, args.iters, aux=aux)
    out.block_until_ready()
    dt = time.perf_counter() - t0

    # 3. measured throughput (paper Table 4 columns) on THIS host
    cells = math.prod(dims) * args.iters
    gcells = cells / dt / 1e9
    gflops = cells * st.flop_pcu / dt / 1e9
    gbytes = cells * st.bytes_pcu / dt / 1e9   # effective, full-locality bytes
    print(f"  measured ({args.backend}, this host): {dt:.3f} s = "
          f"{gcells:.3f} GCell/s, {gflops:.2f} GFLOP/s, {gbytes:.2f} GB/s")
    print(f"  checksum: {float(jnp.sum(out)):.6e}")
    print("  (TPU-projected numbers come from the perf model; see "
          "benchmarks/table4_stencil.py for the model-accuracy table.)")


if __name__ == "__main__":
    main()
