"""Periodic-domain wave transport: a pulse that wraps around the grid.

The paper's fixed clamp boundary (§5.1) kills exactly the workloads the
ROADMAP targets next — periodic physics domains.  This demo runs an
advection-diffusion star stencil (an upwind-biased ``make_star`` — the
explicit-update skeleton of a 2D wave/transport solver) on a torus:
``StencilProblem(boundary="periodic")`` is the *only* change from a clamped
run, and every backend honors it through the same ``plan()`` call.

Two BC effects are checked numerically:
  * transport: the pulse's center of mass drifts through the +x edge and
    re-enters at x=0 (impossible under clamp, where it piles up at the wall);
  * conservation: with convex coefficients a periodic domain conserves total
    mass to float precision, while the clamped run leaks at the boundary.

Per-axis mixing works the same way — e.g. a channel flow periodic in x but
clamped in y is ``boundary=("clamp", "periodic")`` (streaming axis first).

    PYTHONPATH=src python examples/wave2d_periodic.py
"""
import jax.numpy as jnp

from repro.api import RunConfig, StencilProblem, plan
from repro.core import make_star

GRID = (96, 256)
ITERS = 600
DRIFT = 0.35        # upwind bias: cells/step of +x transport


def main():
    # advection-diffusion: diffuse k on every neighbor, bias +x by DRIFT
    st = make_star(2, 1)
    k = 0.1
    coeffs = {name: jnp.float32(k) for name in st.coeff_names}
    coeffs["c0"] = jnp.float32(1.0 - 4 * k)
    # reading the x-1 neighbor with extra weight moves mass +x each step
    coeffs["c_1_-1"] = jnp.float32(k + DRIFT / 2)
    coeffs["c_1_1"] = jnp.float32(k - DRIFT / 2)

    y, x = jnp.meshgrid(jnp.arange(GRID[0]), jnp.arange(GRID[1]),
                        indexing="ij")
    pulse = jnp.exp(-(((y - 48.0) / 10.0) ** 2 + ((x - 64.0) / 10.0) ** 2)
                    ).astype(jnp.float32)

    runs = {}
    for bc in ("periodic", "clamp"):
        p = plan(StencilProblem(st, GRID, boundary=bc),
                 RunConfig(backend="engine", autotune=True, iters_hint=ITERS))
        print(p.describe())
        runs[bc] = p.run(pulse, ITERS, coeffs)

    # transport: after ITERS steps the pulse drifted DRIFT*ITERS cells in +x
    # and must have wrapped around the 256-wide domain under periodic BCs
    expect_x = (64.0 + DRIFT * ITERS) % GRID[1]
    for bc, out in runs.items():
        mass_x = out.sum(axis=0)
        com_phase = jnp.angle(jnp.sum(
            mass_x * jnp.exp(1j * 2 * jnp.pi * jnp.arange(GRID[1])
                             / GRID[1])))  # circular center of mass
        com_x = float(com_phase) % (2 * jnp.pi) / (2 * jnp.pi) * GRID[1]
        drift_err = abs((com_x - expect_x + GRID[1] / 2) % GRID[1]
                        - GRID[1] / 2)
        leak = abs(float(out.sum() - pulse.sum()))
        print(f"{bc:9s} center-of-mass x = {com_x:7.2f} "
              f"(wrap-exact: {expect_x:.2f}, |err| = {drift_err:6.2f}); "
              f"mass leak = {leak:.4f}")
        if bc == "periodic":
            assert drift_err < 2.0, "pulse failed to wrap the torus"
            assert leak < 1e-2, "periodic domain must conserve mass"
    assert abs(float(runs["clamp"].sum() - pulse.sum())) > 1.0, \
        "clamp should visibly leak mass at the +x wall for this drift"
    print("ok: periodic pulse wrapped the torus and conserved mass; "
          "clamp piled up at the wall and leaked")


if __name__ == "__main__":
    main()
