"""Two-stage advection + diffusion as ONE fused StencilProgram.

The classic operator-split transport step — upwind advection followed by
diffusion — is a 2-stage :class:`~repro.programs.StencilProgram`.  Planned
as one problem, both stages run inside every fused super-step: the advected
intermediate field never round-trips HBM (the per-stage traffic breakdown
below shows it billed at zero bytes), while the result stays bit-identical
to running two chained single-stage plans.

    PYTHONPATH=src python examples/advect_diffuse.py
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import RunConfig, StencilProblem, StencilStage, plan
from repro.core.stencils import make_star


def advection_stage(cx: float, cy: float) -> StencilStage:
    """First-order upwind advection (positive velocity): the cell keeps
    ``1-cx-cy`` of itself and takes ``cy``/``cx`` from its upwind neighbors.
    Built on the generic radius-1 star with every other tap zeroed."""
    return StencilStage(
        make_star(2, 1),
        coeffs={"c0": 1.0 - cx - cy,
                "c_0_-1": cy, "c_0_1": 0.0,     # axis 0 (stream/y) taps
                "c_1_-1": cx, "c_1_1": 0.0},    # axis 1 (x) taps
        name="advect")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dim", type=int, default=192)
    ap.add_argument("--iters", type=int, default=24)
    ap.add_argument("--backend", default="pallas_interpret")
    ap.add_argument("--par-time", type=int, default=2)
    ap.add_argument("--bsize", type=int, default=64)
    args = ap.parse_args()

    shape = (args.dim, args.dim)
    advect = advection_stage(cx=0.2, cy=0.1)
    diffuse = StencilStage("diffusion2d")
    cfg = dict(backend=args.backend, par_time=args.par_time,
               bsize=args.bsize)

    fused = plan(StencilProblem([advect, diffuse], shape), RunConfig(**cfg))
    print(fused.describe())

    grid = jax.random.uniform(jax.random.PRNGKey(0), shape, jnp.float32,
                              0.5, 2.0)
    out_fused = fused.run(grid, iters=args.iters)

    # the unfused rendition: two single-stage plans chained step by step
    p_adv = plan(StencilProblem([advect], shape), RunConfig(**cfg))
    p_dif = plan(StencilProblem("diffusion2d", shape), RunConfig(**cfg))
    out_seq = grid
    for _ in range(args.iters):
        out_seq = p_dif.run(p_adv.run(out_seq, iters=1), iters=1)

    assert bool(jnp.all(out_fused == out_seq)), \
        "fused program diverged from the chained single-stage plans"
    print(f"\nfused == chained plans (bit-identical) over {args.iters} iters"
          f"; checksum {float(jnp.sum(out_fused)):.6e}")

    tr = fused.traffic_report()
    print("\nper-stage breakdown (one super-step):")
    for i, s in enumerate(tr["stages"]):
        print(f"  stage {i}: {s['name']:12s} rad={s['radius']} "
              f"flop_pcu={s['flop_pcu']} bc={s['bc']}")
    print(f"  intermediate HBM bytes (fused):    "
          f"{tr['intermediate_hbm_bytes_per_superstep']}")
    print(f"  intermediate HBM bytes (unfused):  "
          f"{tr['unfused_intermediate_bytes_per_superstep']}")
    print(f"  model bytes/super-step:            "
          f"{tr['model_bytes_per_superstep']}")


if __name__ == "__main__":
    main()
