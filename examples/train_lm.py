"""End-to-end LM training driver: data pipeline -> train_step -> fault-
tolerant loop (checkpoint/restart, straggler detection, failure retry).

Defaults to a ~6M-parameter model so it runs on the CPU container in a few
minutes; ``--preset 100m --steps 300`` is the full-size driver on real
hardware. Kill it mid-run and start it again: it restores the latest
checkpoint and the stateless data pipeline resumes bit-exactly.

    PYTHONPATH=src python examples/train_lm.py --steps 30
    PYTHONPATH=src python examples/train_lm.py --steps 30   # -> restarts
    PYTHONPATH=src python examples/train_lm.py --inject-failure 7
"""
import argparse

import jax

from repro.data import DataConfig, SyntheticLMDataset
from repro.models import ModelConfig, init_params
from repro.optim import adamw_init
from repro.optim.adamw import AdamWConfig
from repro.train import TrainLoopConfig, fault_tolerant_train, make_train_step

PRESETS = {
    # ~6M params: CPU-friendly end-to-end demo
    "6m": dict(n_layers=4, d_model=256, n_heads=4, n_kv=2, d_head=64,
               d_ff=1024, vocab=8192, seq=256, batch=8),
    # ~19M params
    "20m": dict(n_layers=6, d_model=384, n_heads=6, n_kv=2, d_head=64,
                d_ff=1536, vocab=16384, seq=512, batch=16),
    # ~100M params: the deliverable-scale driver (run on real hardware)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv=4, d_head=64,
                 d_ff=2048, vocab=32768, seq=1024, batch=32),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="6m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a device loss at this step (recovers "
                    "from checkpoint)")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"demo-{args.preset}", family="dense", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"], n_kv=p["n_kv"],
        d_head=p["d_head"], d_ff=p["d_ff"], vocab=p["vocab"], act="swiglu",
        qk_norm=True, tie_embeddings=True, attn_q_chunk=128,
        attn_kv_chunk=128, loss_chunk=256)
    print(f"model: {cfg.name}  params={cfg.n_params / 1e6:.1f}M  "
          f"seq={p['seq']} batch={p['batch']}")

    data = SyntheticLMDataset(DataConfig(
        vocab=cfg.vocab, seq_len=p["seq"], global_batch=p["batch"]))

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-4, total_steps=args.steps),
        microbatches=args.microbatches), donate_argnums=(0, 1))

    fails = {args.inject_failure} if args.inject_failure is not None else set()

    def failure_hook(s):
        if s in fails:
            fails.remove(s)     # fail once, then recover
            raise RuntimeError(f"injected device loss at step {s}")

    def log(msg):
        print(msg, flush=True)

    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir)
    params, opt_state, events = fault_tolerant_train(
        loop_cfg, step_fn, (params, opt_state), iter(data), data.batch_at,
        failure_hook=failure_hook, log=log)

    losses = events["losses"]
    k = max(1, len(losses) // 10)
    print(f"\nloss: first {sum(losses[:k]) / k:.4f} -> "
          f"last {sum(losses[-k:]) / k:.4f} over {len(losses)} steps")
    print(f"retries={events['retries']} stragglers={len(events['stragglers'])}")
    assert losses and losses[-1] < losses[0], "loss should decrease"
    print("done — checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
