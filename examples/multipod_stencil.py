"""Multi-device stencil: spatial distribution over a mesh (paper §8's stated
future work, implemented).

Forces 8 host-platform devices, builds a (2, 2, 2) pod×data×model mesh, and
runs a Diffusion/Hotspot grid through ``plan()`` with the ``distributed``
backend — the mesh is just config.  Each shard runs the combined
spatial+temporal blocked engine with ``rad*par_time``-wide halo exchange
(ppermute) once per super-step — ``par_time``× fewer exchanges than
step-by-step halo exchange.  Verifies bit-level agreement with the
single-device oracle.

    python examples/multipod_stencil.py          # note: no PYTHONPATH needed
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ruff: noqa: E402
import dataclasses

import jax
import jax.numpy as jnp

from repro.api import RunConfig, StencilProblem, plan
from repro.core import default_coeffs, HOTSPOT2D
from repro.data import make_stencil_inputs

DIMS = (256, 512)
ITERS = 10
PAR_TIME = 4
BSIZE = 64


def main():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    print(f"mesh: {mesh.devices.shape} {mesh.axis_names} "
          f"on {jax.device_count()} devices")

    grid, aux = make_stencil_inputs(jax.random.PRNGKey(0), DIMS, True)
    coeffs = default_coeffs(HOTSPOT2D)
    problem = StencilProblem("hotspot2d", DIMS)

    # grid axis 0 (y) sharded over pod+data, axis 1 (x) over model
    axis_map = (("pod", "data"), ("model",))
    cfg = RunConfig(backend="distributed", par_time=PAR_TIME, bsize=BSIZE,
                    mesh=mesh, axis_map=axis_map)
    dist = plan(problem, cfg)
    print(dist.describe())
    out = dist.run(grid, ITERS, coeffs, aux=aux)

    ref = plan(problem, dataclasses.replace(cfg, backend="reference",
                                            mesh=None, axis_map=None)
               ).run(grid, ITERS, coeffs, aux=aux)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"8-way sharded vs single-device oracle: max|err| = {err:.3e}")
    assert err < 1e-4

    # show the halo-exchange collectives in the compiled HLO
    from repro.core.distributed import build_distributed_fn
    fn = build_distributed_fn(HOTSPOT2D, DIMS, ITERS, PAR_TIME, BSIZE,
                              mesh, axis_map)
    hlo = fn.lower(
        jax.ShapeDtypeStruct(DIMS, jnp.float32),
        jax.ShapeDtypeStruct(DIMS, jnp.float32),
        {k: jax.ShapeDtypeStruct((), jnp.float32) for k in coeffs},
    ).compile().as_text()
    n_perm = hlo.count("collective-permute(") + hlo.count(
        "collective-permute-start(")
    print(f"compiled HLO contains {n_perm} collective-permute site(s) "
          f"(halo exchange, aggregated {PAR_TIME}x by temporal blocking)")
    print("ok")


if __name__ == "__main__":
    main()
