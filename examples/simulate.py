"""Long-running stencil simulation with checkpoint/restart — the paper's
application wired to the fault-tolerance substrate.

The physics is a *program*: the physical operator chained with a pointwise
damping stage (``u *= damp`` — a radius-0 stencil), fused into every
super-step via the ``StencilProgram`` API.  ``--damp 1.0`` degrades the
chain to the bare legacy stencil (the old single-operator path, kept as
the comparison baseline).

Builds one autotuned ``StencilPlan`` and advances it in super-steps of
``par_time`` fused iterations, checkpointing the grid every N super-steps.
Kill it mid-run and start it again: it resumes from the latest snapshot
(integrity-checked, atomic). ``--inject-failure`` simulates a device loss.

    PYTHONPATH=src python examples/simulate.py --iters 400
    PYTHONPATH=src python examples/simulate.py --iters 400  # resumes
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import RunConfig, StencilProblem, StencilStage, plan
from repro.checkpoint import CheckpointManager
from repro.core import STENCILS
from repro.core.stencils import make_star
from repro.data import make_stencil_inputs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stencil", default="diffusion2d",
                    choices=sorted(STENCILS))
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--iters", type=int, default=400)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_simulate")
    ap.add_argument("--ckpt-every", type=int, default=4,
                    help="checkpoint every N super-steps")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="raise at this super-step once (recovers)")
    ap.add_argument("--damp", type=float, default=0.999,
                    help="per-step damping factor chained as a pointwise "
                         "program stage; 1.0 = legacy bare-stencil path")
    args = ap.parse_args()

    st = STENCILS[args.stencil]
    dims = (args.dim,) * 2 if st.ndim == 2 else \
        (max(32, args.dim // 8), args.dim // 2, args.dim // 2)
    if args.damp != 1.0:
        # program path: operator + pointwise damping, fused per super-step
        operator = [StencilStage(st),
                    StencilStage(make_star(st.ndim, 0),
                                 coeffs={"c0": args.damp}, name="damp")]
    else:
        operator = st                # legacy single-operator comparison path
    sim = plan(StencilProblem(operator, dims),
               RunConfig(backend="engine", autotune=True,
                         iters_hint=args.iters))
    pt, bsize = sim.geometry.par_time, sim.geometry.bsize
    n_super = -(-args.iters // pt)
    print(f"{sim.problem.stencil.name} {dims}, {args.iters} iters = "
          f"{n_super} super-steps of par_time={pt}, bsize={bsize}")

    grid, aux = make_stencil_inputs(jax.random.PRNGKey(0), dims, st.has_aux)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    template = {"grid": grid, "super": jnp.zeros((), jnp.int32)}
    restored, _ = mgr.restore_latest(template)
    start = 0
    if restored is not None:
        grid = restored["grid"]
        start = int(restored["super"]) + 1
        print(f"[restart] resumed at super-step {start}")

    fails = ({args.inject_failure} if args.inject_failure is not None
             else set())
    t0 = time.time()
    s = start
    while s < n_super:
        try:
            if s in fails:
                fails.remove(s)
                raise RuntimeError(f"injected failure at super-step {s}")
            steps = min(pt, args.iters - s * pt)
            grid = sim.run(grid, steps, aux=aux)   # one super-step per call
        except RuntimeError as e:
            print(f"[failure] {e}; restoring latest checkpoint")
            restored, _ = mgr.restore_latest(template)
            if restored is not None:
                grid = restored["grid"]
                s = int(restored["super"]) + 1
            else:
                grid, _ = make_stencil_inputs(jax.random.PRNGKey(0), dims,
                                              st.has_aux)
                s = 0
            continue
        if s % args.ckpt_every == 0 or s == n_super - 1:
            mgr.save_async({"grid": grid, "super": jnp.asarray(s, jnp.int32)},
                           s)
        s += 1
    mgr.wait()
    dt = time.time() - t0
    done = n_super - start
    print(f"finished {done} super-steps in {dt:.2f}s; "
          f"checksum {float(jnp.sum(grid)):.6e}")
    print(f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
