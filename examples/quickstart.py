"""Quickstart: the paper's technique in one page.

Describes Diffusion 2D as a ``StencilProblem``, lets ``plan()`` pick
(bsize, par_time) with the performance model (paper §4, §5.3), runs the
combined spatial + temporal blocked backends through the resulting
``StencilPlan``, and checks them against the unblocked oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.api import RunConfig, StencilProblem, plan, tune
from repro.core import DIFFUSION2D, default_coeffs

GRID = (512, 512)
ITERS = 12


def main():
    key = jax.random.PRNGKey(0)
    grid = jax.random.uniform(key, GRID, jnp.float32, 0.5, 2.0)
    coeffs = default_coeffs(DIFFUSION2D)
    problem = StencilProblem("diffusion2d", GRID)

    # 1. Design-space pruning with the performance model (paper §4, §5.3):
    #    plan(autotune=True) enumerates (bsize, par_time), drops configs over
    #    the VMEM budget, and compiles the best one.
    eng = plan(problem, RunConfig(backend="engine", autotune=True,
                                  iters_hint=ITERS))
    print(eng.describe())
    print("runner-up candidates (paper §5.3 pruning):")
    for p in eng.candidates[1:4]:
        print("  ", p.describe())
    bsize, par_time = eng.geometry.bsize, eng.geometry.par_time

    # 2. Run the same schedule through every backend via the one plan() call.
    cfg = RunConfig(par_time=par_time, bsize=bsize)
    ref = plan(problem, dataclasses.replace(cfg, backend="reference")
               ).run(grid, ITERS, coeffs)            # unblocked oracle
    out_eng = eng.run(grid, ITERS, coeffs)           # pure-JAX blocked engine
    out_pal = plan(problem, dataclasses.replace(cfg, backend="pallas_interpret")
                   ).run(grid, ITERS, coeffs)        # Pallas kernel (interpret)

    for name, out in [("engine", out_eng), ("pallas", out_pal)]:
        err = float(jnp.max(jnp.abs(out - ref)))
        print(f"{name:8s} max|err| vs oracle = {err:.3e}")
        assert err < 1e-4, name

    print(f"\nblocked == unblocked for bsize={bsize}, par_time={par_time} "
          f"({ITERS} iters, grid {GRID}).")
    print("model vs kernel DMA traffic:", eng.traffic_report())

    # 3. Measured autotuning (Table 4's "Measured" column): time the model's
    #    top candidates on the real backend and compile the fastest.  With a
    #    cache path (the default), the winner is persisted and later plan()
    #    calls skip the timing entirely; cache=False keeps this demo
    #    filesystem-free.
    meas = tune(problem, RunConfig(backend="engine", iters_hint=ITERS,
                                   tune_top_k=2, tune_repeats=2, cache=False))
    print("\nmeasured autotune (model shortlist, stopwatch winner):")
    for c in meas.candidates:
        print("  ", c.describe())


if __name__ == "__main__":
    main()
