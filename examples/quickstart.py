"""Quickstart: the paper's technique in one page.

Runs Diffusion 2D with combined spatial + temporal blocking (the paper's
accelerator), checks it against the unblocked oracle, and shows the
performance model doing design-space pruning (paper §5.3).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import DIFFUSION2D, autotune, default_coeffs
from repro.kernels.ops import stencil_run

GRID = (512, 512)
ITERS = 12


def main():
    key = jax.random.PRNGKey(0)
    grid = jax.random.uniform(key, GRID, jnp.float32, 0.5, 2.0)
    coeffs = default_coeffs(DIFFUSION2D)

    # 1. Design-space pruning with the performance model (paper §4, §5.3):
    #    enumerate (bsize, par_time), drop configs over the VMEM budget,
    #    rank by predicted runtime.
    candidates = autotune(DIFFUSION2D, GRID, ITERS)
    print("top autotuner candidates (paper §5.3 pruning):")
    for p in candidates[:4]:
        print("  ", p.describe())
    best = candidates[0]
    bsize, par_time = best.geom.bsize, best.geom.par_time

    # 2. Run the combined spatial+temporal blocked implementations.
    ref = stencil_run(DIFFUSION2D, grid, coeffs, ITERS, par_time, bsize,
                      backend="reference")          # unblocked oracle
    eng = stencil_run(DIFFUSION2D, grid, coeffs, ITERS, par_time, bsize,
                      backend="engine")             # pure-JAX blocked engine
    pal = stencil_run(DIFFUSION2D, grid, coeffs, ITERS, par_time, bsize,
                      backend="pallas_interpret")   # Pallas kernel (interpret)

    for name, out in [("engine", eng), ("pallas", pal)]:
        err = float(jnp.max(jnp.abs(out - ref)))
        print(f"{name:8s} max|err| vs oracle = {err:.3e}")
        assert err < 1e-4, name

    print(f"\nblocked == unblocked for bsize={bsize}, par_time={par_time} "
          f"({ITERS} iters, grid {GRID}).")
    print("predicted on TPU v5e:", best.describe())


if __name__ == "__main__":
    main()
