"""Serving throughput: one plan, many requests, one executable.

A stencil-as-a-service process (the ROADMAP's "heavy traffic" north star)
sees a stream of requests against a handful of problem shapes.  The naive
loop — ``plan().run()`` per request — pays a dispatch per request and, before
this subsystem, a re-trace per distinct iteration count.  This example shows
the serving pattern:

  1. ``plan()`` once per problem shape (the executable cache makes repeated
     plans free: same key -> same compiled program, zero re-traces);
  2. ``run_batch()`` over each arriving batch of requests — one fused
     executable advances the whole batch (vmapped super-step loop on the
     engine backend);
  3. ``iters`` is dynamic: requests asking for different iteration counts
     share the same executable.

    PYTHONPATH=src python examples/serve_stencil.py
"""
import time

import jax
import jax.numpy as jnp

from repro.api import (RunConfig, StencilProblem, clear_exec_cache,
                       exec_cache_stats, plan)
from repro.core import HOTSPOT2D, default_coeffs

GRID = (256, 512)
BATCH = 8          # requests per arriving batch
ROUNDS = 4         # batches served
ITERS = (10, 25, 10, 50)   # per-round iteration counts (all share one trace)


def main():
    clear_exec_cache()
    key = jax.random.PRNGKey(0)
    coeffs = default_coeffs(HOTSPOT2D)
    # the chip's power map is server state, shared by every request
    power = jax.random.uniform(jax.random.fold_in(key, 1), GRID,
                               jnp.float32, 0.0, 0.1)
    problem = StencilProblem("hotspot2d", GRID)

    # boot: one plan per served shape (autotuned by the perf model)
    p = plan(problem, RunConfig(backend="engine", autotune=True))
    print(p.describe())
    print("predicted batched throughput:",
          f"{p.predicted(100, batch=BATCH).gcells_s / 1e9:.2f} GCell/s "
          f"(batch={BATCH}, shared power grid loaded once)")

    # serve: batches of requests, varying iteration counts
    for r, iters in zip(range(ROUNDS), ITERS):
        grids = jax.random.uniform(jax.random.fold_in(key, 100 + r),
                                   (BATCH,) + GRID, jnp.float32, 0.5, 2.0)
        t0 = time.perf_counter()
        out = jax.block_until_ready(
            p.run_batch(grids, iters, coeffs, aux=power))
        dt = time.perf_counter() - t0
        print(f"round {r}: B={BATCH} iters={iters:3d} -> {dt * 1e3:7.2f} ms "
              f"({out.shape} out)")

    # a restarted handler re-plans — and hits the executable cache
    p2 = plan(problem, RunConfig(backend="engine", autotune=True))
    p2.run_batch(jnp.ones((BATCH,) + GRID, jnp.float32), 10, coeffs,
                 aux=power)
    stats = exec_cache_stats()
    print(f"\nexecutable cache: {stats['size']} programs, "
          f"{stats['hits']} hits, {stats['misses']} misses, "
          f"traces={stats['traces']}")
    assert stats["hits"] >= 1, "re-plan should reuse the compiled program"


if __name__ == "__main__":
    main()
