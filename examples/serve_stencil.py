"""Stencil-as-a-service: boot, submit, observe — plus the manual pattern.

The ROADMAP's "heavy traffic" north star is a process that sees a stream
of requests against a handful of problem shapes.  ``repro.serve`` packages
the whole serving pattern behind two calls::

    service = await repro.serve.from_config({...})   # booted + pre-warmed
    result  = await service.submit(StencilRequest(problem, grid, iters))

The service buckets requests by (stencil, shape, boundary, dtype),
coalesces each bucket's arrivals into one padded ``run_batch`` launch
under a (max_batch, max_wait_ms) policy, and answers every request —
served, rejected (bounded queue, 429-style retry-after), or expired —
through its future.  Results are bit-identical to a per-request
``plan().run()`` loop: padding replicates along the batch axis only.

Manual mode (the pre-service pattern, still fully supported): call
``plan()`` once per shape and ``run_batch()`` over each arriving batch
yourself — shown at the bottom for when you already hold batches and
want no event loop in the way.

    PYTHONPATH=src python examples/serve_stencil.py
"""
import asyncio
import time

import jax
import jax.numpy as jnp

from repro.api import RunConfig, StencilProblem, plan
from repro.core import HOTSPOT2D, default_coeffs
from repro.serve import StencilRequest, from_config

GRID = (256, 512)
BATCH = 8          # coalescing target: requests per fused launch
ROUNDS = 4         # request waves submitted
ITERS = (10, 25, 10, 50)   # per-wave iteration counts (one shared trace)


async def serve_mode():
    key = jax.random.PRNGKey(0)
    # the chip's power map is server state, shared by every request
    power = jax.random.uniform(jax.random.fold_in(key, 1), GRID,
                               jnp.float32, 0.0, 0.1)
    problem = StencilProblem("hotspot2d", GRID)

    # one JSON-able document boots the whole service: plans built,
    # executables pre-warmed for every batch class, workers running
    service = await from_config({
        "buckets": [{
            "problem": problem,
            "run": {"backend": "engine", "autotune": True},
            "max_batch": BATCH, "max_wait_ms": 2.0, "queue_cap": 64,
        }],
    })
    print("serving buckets:", list(service.buckets))

    async with service:
        for r, iters in zip(range(ROUNDS), ITERS):
            grids = jax.random.uniform(jax.random.fold_in(key, 100 + r),
                                       (BATCH,) + GRID, jnp.float32,
                                       0.5, 2.0)
            t0 = time.perf_counter()
            results = await asyncio.gather(*[
                service.submit(StencilRequest(problem, grids[i], iters,
                                              aux=power))
                for i in range(BATCH)])
            dt = time.perf_counter() - t0
            fills = {f"{res.batch_fill:.2f}" for res in results}
            print(f"wave {r}: B={BATCH} iters={iters:3d} -> "
                  f"{dt * 1e3:7.2f} ms (fill {sorted(fills)})")

        snap = service.snapshot()
        print(f"\nserved {snap['completed']} requests in "
              f"{snap['batches']} coalesced launches; "
              f"p50 {snap['latency_ms']['p50']:.1f} ms, "
              f"p99 {snap['latency_ms']['p99']:.1f} ms, "
              f"mean fill {snap['batch_fill']:.2f}")
        assert snap["completed"] == ROUNDS * BATCH
        assert snap["rejected_total"] == 0


def manual_mode():
    """The pre-service pattern: plan once, run_batch per arriving batch.
    No admission control, no padding, no metrics — but also no loop."""
    key = jax.random.PRNGKey(0)
    coeffs = default_coeffs(HOTSPOT2D)
    power = jax.random.uniform(jax.random.fold_in(key, 1), GRID,
                               jnp.float32, 0.0, 0.1)
    p = plan(StencilProblem("hotspot2d", GRID),
             RunConfig(backend="engine", autotune=True))
    grids = jax.random.uniform(jax.random.fold_in(key, 100), (BATCH,) + GRID,
                               jnp.float32, 0.5, 2.0)
    t0 = time.perf_counter()
    out = jax.block_until_ready(p.run_batch(grids, 10, coeffs, aux=power))
    print(f"\nmanual mode: B={BATCH} iters=10 -> "
          f"{(time.perf_counter() - t0) * 1e3:7.2f} ms ({out.shape} out)")


if __name__ == "__main__":
    asyncio.run(serve_mode())
    manual_mode()
