"""Second-order wave equation as a multi-field DAG StencilProgram.

The leapfrog update

    u_next = 2*u - u_prev + c^2 * lap(u)

is not a chain: it reads TWO state fields (``u``, ``u_prev``), fans the
Laplacian stage and both raw fields into one combine node, and rotates both
fields simultaneously at the end of every iteration.  As a
:class:`~repro.programs.StencilProgram` with ``fields=`` and ``updates=``,
the whole graph runs inside each fused super-step on every backend —
``u_next`` and ``lap(u)`` never round-trip HBM — and the state travels as
one ``(2, ny, nx)`` stack.

    PYTHONPATH=src python examples/wave2d_program.py
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import RunConfig, StencilProblem, StencilStage, plan
from repro.core.stencils import make_combine, make_star
from repro.kernels.ref import oracle_dag_run
from repro.programs import StencilProgram


def wave_program(c: float) -> StencilProgram:
    """lap = 5-point Laplacian of u; unext = 2u - u_prev + c^2*lap."""
    lap = StencilStage(
        make_star(2, 1),
        coeffs={"c0": -4.0, "c_0_-1": 1.0, "c_0_1": 1.0,
                "c_1_-1": 1.0, "c_1_1": 1.0},
        name="lapu", inputs=("u",))
    unext = StencilStage(
        make_combine(2, 3),
        coeffs={"w0": 2.0, "w1": -1.0, "w2": c * c},
        name="unext", inputs=("u", "u_prev", "lapu"))
    return StencilProgram((lap, unext), fields=("u", "u_prev"),
                          updates={"u": "unext", "u_prev": "u"})


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dim", type=int, default=192)
    ap.add_argument("--iters", type=int, default=24)
    ap.add_argument("--wave-speed", type=float, default=0.4)
    ap.add_argument("--backend", default="pallas_interpret")
    ap.add_argument("--par-time", type=int, default=2)
    ap.add_argument("--bsize", type=int, default=64)
    args = ap.parse_args()

    shape = (args.dim, args.dim)
    problem = StencilProblem(wave_program(args.wave_speed), shape,
                             boundary="periodic")
    assert problem.is_dag and problem.state_shape == (2,) + shape
    p = plan(problem, RunConfig(backend=args.backend,
                                par_time=args.par_time, bsize=args.bsize))
    print(p.describe())

    # a Gaussian pulse at rest: u == u_prev
    yy, xx = jnp.meshgrid(*(jnp.arange(d) for d in shape), indexing="ij")
    pulse = jnp.exp(-(((yy - shape[0] / 2) ** 2 + (xx - shape[1] / 2) ** 2)
                      / (2 * (shape[0] / 16) ** 2))).astype(jnp.float32)
    state = jnp.stack([pulse, pulse])

    out = p.run(state, iters=args.iters)
    want = oracle_dag_run(problem.exec_dag, state,
                          problem.resolve_coeffs(dtype=jnp.float32),
                          args.iters, None)
    err = float(jnp.max(jnp.abs(out - want)))
    print(f"\n{args.iters} iters on {args.backend}: "
          f"max |err| vs topological oracle = {err:.2e}")
    assert err < 1e-4

    u, u_prev = out
    print(f"u      checksum {float(jnp.sum(u)):.6e}")
    print(f"u_prev checksum {float(jnp.sum(u_prev)):.6e}")
    energy = float(jnp.sum((u - u_prev) ** 2))
    print(f"kinetic proxy sum((u - u_prev)^2) = {energy:.6e}")


if __name__ == "__main__":
    main()
