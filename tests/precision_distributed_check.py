"""Distributed mixed-precision conformance check (2-device mesh).

Run in a subprocess with 2 fake CPU devices (tests/test_precision.py) so the
main pytest process keeps its single-device view.  bf16 storage must survive
the sharded super-step — halo exchange included — and match the
single-device reference bit for bit (every backend implements the same
round-once-per-stage-application policy of ``repro.core.precision``); f32
must stay bit-identical to the reference as before.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import RunConfig, StencilProblem, plan


def check(dtype, bc, axis_map, dims=(16, 32), par_time=2, bsize=16, iters=5):
    mesh = jax.make_mesh((2,), ("d",))
    g = jax.random.uniform(jax.random.PRNGKey(3), dims, jnp.float32,
                           0.5, 2.0).astype(jnp.dtype(dtype))
    problem = StencilProblem("diffusion2d", dims, dtype=dtype, boundary=bc)
    dist = plan(problem, RunConfig(backend="distributed", mesh=mesh,
                                   axis_map=axis_map, par_time=par_time,
                                   bsize=bsize))
    ref = plan(problem, RunConfig(backend="reference"))
    got = np.asarray(dist.run(g, iters).astype(jnp.float32))
    want = np.asarray(ref.run(g, iters).astype(jnp.float32))
    assert got.dtype == np.float32
    assert dist.run(g, 1).dtype == problem.jnp_dtype
    np.testing.assert_array_equal(
        got, want, err_msg=f"dtype={dtype} bc={bc} map={axis_map}")
    print(f"ok distributed {dtype} bc={problem.bc.token()} map={axis_map}")


def check_batch(dtype):
    mesh = jax.make_mesh((2,), ("d",))
    dims = (16, 32)
    g = jax.random.uniform(jax.random.PRNGKey(5), dims, jnp.float32,
                           0.5, 2.0).astype(jnp.dtype(dtype))
    gs = jnp.stack([g, (g.astype(jnp.float32) * 1.1).astype(g.dtype),
                    (g.astype(jnp.float32) * 0.9).astype(g.dtype)])
    problem = StencilProblem("diffusion2d", dims, dtype=dtype,
                             boundary=("periodic", "reflect"))
    dist = plan(problem, RunConfig(backend="distributed", mesh=mesh,
                                   axis_map=(("d",), None), par_time=2,
                                   bsize=16))
    ref = plan(problem, RunConfig(backend="reference"))
    got = dist.run_batch(gs, 4)
    assert got.dtype == problem.jnp_dtype
    want = jnp.stack([ref.run(gs[i], 4) for i in range(3)])
    np.testing.assert_array_equal(
        np.asarray(got.astype(jnp.float32)),
        np.asarray(want.astype(jnp.float32)),
        err_msg=f"run_batch dtype={dtype}")
    print(f"ok distributed run_batch {dtype}")


if __name__ == "__main__":
    assert len(jax.devices()) == 2, jax.devices()
    for dtype in ("float32", "bfloat16"):
        check(dtype, "clamp", (("d",), None))           # stream-sharded
        check(dtype, ("clamp", "periodic"), (None, ("d",)))  # blocked-sharded
        check(dtype, "reflect", (("d",), None))
        check_batch(dtype)
    print("ALL OK")
