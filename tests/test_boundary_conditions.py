"""Cross-backend boundary-condition conformance suite.

The ``kernels/ref.py`` oracle is the single source of truth for every BC
(clamp / periodic / reflect / constant, per-axis mixes included); this file
locks every backend to it:

  * an independent numpy re-derivation pins the oracle itself,
  * a parametrized matrix checks reference / engine / pallas_interpret for
    2D and 3D stencils at radius 1 and 2 (plus a box stencil, whose corner
    reads exercise the mixed-BC corner semantics),
  * the distributed backend runs the same matrix on a 2-device mesh in a
    subprocess (``bc_distributed_check.py``),
  * ``run_batch`` and both aux (power-grid) modes are covered,
  * the schedule cache and the executable cache must key on the BC — a
    schedule tuned under clamp is never served to a periodic plan,
  * negative paths: unknown kinds, wrong arity, non-scalar constant fills,
    reflect on degenerate axes, periodic vs. mesh divisibility.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (RunConfig, StencilProblem, clear_exec_cache,
                       exec_cache_stats, plan)
from repro.core import STENCILS, default_coeffs, make_box, make_star
from repro.core.boundary import BoundaryCondition
from repro.core.stencils import Stencil
from repro.kernels.ref import oracle_run

BACKENDS = ("reference", "engine", "pallas_interpret")

#: the BC matrix: every kind uniformly, plus per-axis mixes (incl. the
#: ISSUE's periodic-in-x/clamp-in-y example and a constant mix)
BCS_2D = ["clamp", "periodic", "reflect", "constant:0.7",
          ("clamp", "periodic"), ("reflect", "periodic"),
          ("constant:2.0", "reflect")]
BCS_3D = ["periodic", "reflect", "constant:0.3",
          ("clamp", "periodic", "reflect"),
          ("periodic", "constant:1.0", "clamp")]


def _data(st, dims, seed=0):
    k = jax.random.PRNGKey(seed)
    g = jax.random.uniform(k, dims, jnp.float32, 0.5, 2.0)
    aux = (jax.random.uniform(jax.random.fold_in(k, 7), dims,
                              jnp.float32, 0.0, 0.1)
           if st.has_aux else None)
    return g, aux


def _conform(st, dims, bc_spec, backend, par_time=2, bsize=16, iters=5):
    problem = StencilProblem(st, dims, boundary=bc_spec)
    g, aux = _data(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, iters, aux, bc=problem.bc)
    p = plan(problem, RunConfig(backend=backend, par_time=par_time,
                                bsize=bsize))
    got = p.run(g, iters, c, aux=aux)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5,
        err_msg=f"{st.name} {backend} bc={problem.bc.token()}")


# --- the oracle itself, pinned by an independent numpy re-derivation ---------

def _np_oracle_step(st, grid, coeffs, aux, bc):
    """Ground truth for the ground truth: numpy per-axis np.pad."""
    modes = {"clamp": "edge", "periodic": "wrap", "reflect": "reflect"}
    r = st.radius
    p = np.asarray(grid)
    for ax, kind in enumerate(bc.kinds):
        pads = [(0, 0)] * p.ndim
        pads[ax] = (r, r)
        if kind == "constant":
            p = np.pad(p, pads, mode="constant", constant_values=bc.value)
        else:
            p = np.pad(p, pads, mode=modes[kind])

    def get(off):
        idx = tuple(slice(r + o, r + o + n) for o, n in zip(off, grid.shape))
        return jnp.asarray(p[idx])

    return st.apply(get, coeffs, aux)


@pytest.mark.parametrize("bc_spec", BCS_2D)
def test_oracle_matches_numpy_2d(bc_spec):
    st = STENCILS["diffusion2d"]
    bc = BoundaryCondition.make(bc_spec, 2)
    g, _ = _data(st, (9, 13))
    c = default_coeffs(st)
    want = _np_oracle_step(st, np.asarray(g), c, None, bc)
    got = oracle_run(st, g, c, 1, bc=bc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_oracle_matches_numpy_3d_box_corners():
    """A box stencil reads corner neighbors: the mixed-BC corner semantics
    (per-axis rules compose; constant absorbs) must match numpy padding."""
    st = make_box(3, 1)
    bc = BoundaryCondition.make(("periodic", "constant:1.5", "reflect"), 3)
    g, _ = _data(st, (5, 6, 7))
    c = default_coeffs(st)
    want = _np_oracle_step(st, np.asarray(g), c, None, bc)
    got = oracle_run(st, g, c, 1, bc=bc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# --- conformance matrix: BC x backend x {2D,3D} x radius ---------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bc_spec", BCS_2D)
def test_conformance_2d_radius1(bc_spec, backend):
    _conform(STENCILS["diffusion2d"], (23, 49), bc_spec, backend)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bc_spec", ["periodic", ("reflect", "periodic")])
def test_conformance_2d_aux(bc_spec, backend):
    """Hotspot: the aux (power) stream rides through every BC pad path."""
    _conform(STENCILS["hotspot2d"], (17, 33), bc_spec, backend)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bc_spec", BCS_3D)
def test_conformance_3d_radius1(bc_spec, backend):
    _conform(STENCILS["diffusion3d"], (9, 21, 17), bc_spec, backend,
             bsize=(8, 8))


@pytest.mark.parametrize("backend", BACKENDS)
def test_conformance_3d_aux_mix(backend):
    _conform(STENCILS["hotspot3d"], (7, 19, 17),
             ("reflect", "periodic", "constant:1.0"), backend, bsize=(8, 8))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bc_spec", ["periodic", ("reflect", "periodic"),
                                     "constant:0.4"])
def test_conformance_2d_radius2(bc_spec, backend):
    _conform(make_star(2, 2), (21, 41), bc_spec, backend, par_time=2,
             bsize=24)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bc_spec", ["periodic",
                                     ("reflect", "periodic", "periodic")])
def test_conformance_3d_radius2(bc_spec, backend):
    _conform(make_star(3, 2), (9, 25, 25), bc_spec, backend, par_time=1,
             bsize=(12, 12))


@pytest.mark.parametrize("backend", BACKENDS)
def test_conformance_box_corners(backend):
    """Box neighborhoods read diagonal (corner) ghosts — the strictest test
    of mixed-BC corner composition on a real execution path."""
    _conform(make_box(2, 1), (15, 37), ("periodic", "reflect"), backend)


# --- run_batch: the serving path honors the BC too ---------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_run_batch_conformance(backend):
    st = STENCILS["hotspot2d"]
    dims = (16, 32)
    problem = StencilProblem(st, dims, boundary=("periodic", "reflect"))
    g, aux = _data(st, dims)
    gs = jnp.stack([g, g * 1.1, g * 0.9])
    c = default_coeffs(st)
    p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=16))
    want = jnp.stack([oracle_run(st, gs[i], c, 4, aux, bc=problem.bc)
                      for i in range(3)])
    got = p.run_batch(gs, 4, c, aux=aux)             # shared aux
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    auxs = jnp.stack([aux, aux * 2.0, aux * 0.5])    # batched aux
    want_b = jnp.stack([oracle_run(st, gs[i], c, 4, auxs[i], bc=problem.bc)
                        for i in range(3)])
    got_b = p.run_batch(gs, 4, c, aux=auxs)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(want_b),
                               rtol=3e-5, atol=3e-5)


# --- distributed backend: 2-device mesh, in a subprocess ---------------------

@pytest.mark.slow
def test_distributed_conformance_2dev():
    script = os.path.join(os.path.dirname(__file__),
                          "bc_distributed_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL OK" in out.stdout


# --- seam regression: stream-only stencil (radius 0 in the blocked axes) ----

def _stream_only_2d():
    """1D 3-point star embedded in 2D: offsets only along the streaming
    axis, so blocked-dim halos are never read — the zero-coupling seam case
    behind the ``_reclamp_padded`` zero-pad guard."""
    def apply(get, c, aux=None):
        return (c["c0"] * get((0, 0)) + c["cm"] * get((-1, 0))
                + c["cp"] * get((1, 0)))
    return Stencil("stream1d_in2d", 2, 1, 5, 1, 1, False,
                   ("c0", "cm", "cp"), apply,
                   offsets=((0, 0), (-1, 0), (1, 0)))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bc_spec", ["periodic", "constant:0.6",
                                     ("reflect", "periodic")])
def test_stream_only_stencil_seams(bc_spec, backend):
    st = _stream_only_2d()
    c = {"c0": jnp.float32(0.5), "cm": jnp.float32(0.25),
         "cp": jnp.float32(0.25)}
    problem = StencilProblem(st, (19, 33), boundary=bc_spec)
    g, _ = _data(st, (19, 33))
    want = oracle_run(st, g, c, 5, bc=problem.bc)
    p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=16))
    got = p.run(g, 5, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_reclamp_padded_skips_zero_pad_axes():
    """With a zero halo (radius-0 stencil) the padded carry equals the grid:
    the refresh must be an exact no-op — in particular the constant BC must
    NOT treat real edge columns as ghost positions."""
    from repro.core.blocking import BlockGeometry
    from repro.kernels.ops import _reclamp_padded
    st0 = make_star(2, 0)           # pure scaling stencil: radius 0
    geom = BlockGeometry(2, (6, 32), st0.radius, 4, (16,))
    assert geom.size_halo == 0 and geom.padded_dims == (32,)
    gp = jnp.arange(6 * 32, dtype=jnp.float32).reshape(6, 32)
    bc = BoundaryCondition.make("constant:9.0", 2)
    np.testing.assert_array_equal(np.asarray(_reclamp_padded(gp, geom, bc)),
                                  np.asarray(gp))


# --- cache keys: a clamp entry never serves a periodic plan ------------------

def test_schedule_cache_keys_on_bc(tmp_path):
    from repro.api.schedule_cache import schedule_key
    from repro.core.perf_model import TPU_V5E
    cfg = RunConfig(backend="engine", par_time=2, bsize=16)
    keys = {schedule_key(StencilProblem("diffusion2d", (32, 64), boundary=b),
                         cfg, TPU_V5E, 1, None)
            for b in ["clamp", "periodic", "reflect", "constant",
                      "constant:2.0", ("clamp", "periodic")]}
    assert len(keys) == 6   # every BC (incl. the fill value) splits the key


def test_measured_schedule_tuned_under_clamp_not_served_to_periodic(tmp_path):
    cache = str(tmp_path / "schedules.json")
    cfg = RunConfig(backend="engine", autotune="measure", cache=cache,
                    par_time=2, bsize=32, tune_warmup=0, tune_repeats=1)
    p1 = plan(StencilProblem("diffusion2d", (16, 128)), cfg)
    assert not p1.tuned_from_cache          # first tune: measured, cached
    p2 = plan(StencilProblem("diffusion2d", (16, 128)), cfg)
    assert p2.tuned_from_cache              # same key: served from cache
    p3 = plan(StencilProblem("diffusion2d", (16, 128), boundary="periodic"),
              cfg)
    assert not p3.tuned_from_cache          # clamp winner must NOT be served


def test_exec_cache_keys_on_bc():
    clear_exec_cache()
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (16, 32))
    c = default_coeffs(st)
    cfg = RunConfig(backend="engine", par_time=2, bsize=16)
    plan(StencilProblem(st, (16, 32)), cfg).run(g, 2, c)
    plan(StencilProblem(st, (16, 32), boundary="periodic"), cfg).run(g, 2, c)
    stats = exec_cache_stats()
    assert stats["misses"] >= 2 and stats["hits"] == 0, stats
    # and the same BC DOES share the compiled program
    plan(StencilProblem(st, (16, 32), boundary="periodic"), cfg).run(g, 3, c)
    assert exec_cache_stats()["hits"] >= 1


# --- negative paths ----------------------------------------------------------

def test_unknown_bc_name_raises():
    with pytest.raises(ValueError, match="unknown boundary kind"):
        StencilProblem("diffusion2d", (8, 8), boundary="dirichlet-ish")


def test_bc_arity_must_match_grid():
    with pytest.raises(ValueError, match="one per grid axis"):
        StencilProblem("diffusion2d", (8, 8),
                       boundary=("clamp", "periodic", "reflect"))
    with pytest.raises(ValueError, match="2D"):
        BoundaryCondition.make(BoundaryCondition(("clamp",)), 2)


def test_constant_bc_rejects_non_scalar_fill():
    with pytest.raises(ValueError, match="scalar"):
        BoundaryCondition(("constant", "clamp"), value=np.ones(3))
    with pytest.raises(ValueError, match="scalar"):
        BoundaryCondition(("constant", "clamp"), value=[1.0, 2.0])
    with pytest.raises(ValueError, match="conflicting constant fill"):
        BoundaryCondition.make(("constant:1.0", "constant:2.0"), 2)


def test_reflect_needs_two_cells():
    with pytest.raises(ValueError, match="extent >= 2"):
        StencilProblem("diffusion2d", (8, 1), boundary="reflect")
    # clamp on the degenerate axis is fine
    StencilProblem("diffusion2d", (8, 1), boundary=("reflect", "clamp"))


def test_constant_value_suffix_only_for_constant():
    with pytest.raises(ValueError, match="':value' suffix"):
        BoundaryCondition.make("periodic:3.0", 2)
    with pytest.raises(ValueError, match="constant fill must be a number"):
        BoundaryCondition.make("constant:hot", 2)


def test_stream_extension_single_definition():
    """predict(), traffic_report() and the kernels' DMA accounting all bill
    the periodic stream extension through ONE shared helper — and it only
    fires for a periodic *streaming* axis."""
    from repro.core.blocking import (BlockGeometry, extended_geometry,
                                     stream_extension)
    geom = BlockGeometry(2, (16, 64), 1, 2, (16,))
    per = BoundaryCondition.make("periodic", 2)
    assert stream_extension(geom, per) == geom.size_halo == 2
    assert extended_geometry(geom, per).dims == (20, 64)
    for spec in ["clamp", ("reflect", "periodic")]:   # periodic-in-x only
        bc = BoundaryCondition.make(spec, 2)
        assert stream_extension(geom, bc) == 0
        assert extended_geometry(geom, bc) is geom
