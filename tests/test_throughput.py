"""The throughput subsystem: ``run_batch``, the fused donated super-step
loop, and the process-level executable cache.

Acceptance surface of the serving PR:
  * ``run_batch`` is bit-identical to a Python loop of ``run()`` on every
    backend, including aux-stream (Hotspot) stencils with both shared and
    per-batch aux;
  * buffer donation never invalidates caller arrays — plans stay reusable;
  * an executable-cache hit serves a compiled program without re-tracing
    (observable via the trace-counter hook), and dynamic ``iters`` means a
    plan never re-traces for a new iteration count;
  * the Pallas backends reject unsupported dtypes at ``plan()`` time with
    the supported-dtype list (satellite bugfix);
  * ``perf_model.predict(batch=...)`` shares the read-only aux stream
    across the batch.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (BackendProgram, RunConfig, StencilProblem, as_program,
                       clear_exec_cache, exec_cache_stats, plan,
                       register_backend)
from repro.core import STENCILS, default_coeffs
from repro.core.perf_model import TPU_V5E, predict
from repro.kernels.ref import oracle_run

DIMS2 = (12, 20)
DIMS3 = (7, 19, 17)
B = 3


def _data(name, dims, batch=None, seed=0):
    st = STENCILS[name]
    k = jax.random.PRNGKey(seed)
    shape = ((batch,) + dims) if batch else dims
    g = jax.random.uniform(k, shape, jnp.float32, 0.5, 2.0)
    aux = None
    if st.has_aux:
        aux = jax.random.uniform(jax.random.fold_in(k, 1), shape,
                                 jnp.float32, 0.0, 0.1)
    return g, aux


def _cfg(backend, **kw):
    kw.setdefault("par_time", 2)
    kw.setdefault("bsize", 16)
    return RunConfig(backend=backend, **kw)


# --- run_batch == loop of run(), bit-identical, every backend ----------------

@pytest.mark.parametrize("backend", ["reference", "engine",
                                     "pallas_interpret"])
@pytest.mark.parametrize("name,dims", [("diffusion2d", DIMS2),
                                       ("hotspot2d", DIMS2),
                                       ("hotspot3d", DIMS3)])
def test_run_batch_matches_sequential(backend, name, dims):
    st = STENCILS[name]
    gs, auxs = _data(name, dims, batch=B)
    c = default_coeffs(st)
    p = plan(StencilProblem(name, dims),
             _cfg(backend, bsize=16 if len(dims) == 2 else (12, 12)))
    got = p.run_batch(gs, 5, c, aux=auxs)
    want = jnp.stack([p.run(gs[i], 5, c,
                            aux=None if auxs is None else auxs[i])
                      for i in range(B)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_run_batch_shared_aux_matches_sequential():
    st = STENCILS["hotspot2d"]
    gs, _ = _data("hotspot2d", DIMS2, batch=B)
    _, aux = _data("hotspot2d", DIMS2, seed=7)
    c = default_coeffs(st)
    for backend in ("reference", "engine", "pallas_interpret"):
        p = plan(StencilProblem("hotspot2d", DIMS2), _cfg(backend))
        got = p.run_batch(gs, 4, c, aux=aux)           # one aux, whole batch
        want = jnp.stack([p.run(gs[i], 4, c, aux=aux) for i in range(B)])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_run_batch_distributed_matches_engine():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("x",))
    gs, _ = _data("diffusion2d", (24, 40), batch=B)
    c = default_coeffs(STENCILS["diffusion2d"])
    problem = StencilProblem("diffusion2d", (24, 40))
    dist = plan(problem, RunConfig(backend="distributed", par_time=2,
                                   bsize=24, mesh=mesh))
    eng = plan(problem, RunConfig(backend="engine", par_time=2, bsize=24))
    np.testing.assert_allclose(np.asarray(dist.run_batch(gs, 5, c)),
                               np.asarray(eng.run_batch(gs, 5, c)),
                               rtol=2e-5, atol=2e-5)


def test_run_batch_iters_zero_is_identity_and_validates():
    gs, _ = _data("diffusion2d", DIMS2, batch=B)
    p = plan(StencilProblem("diffusion2d", DIMS2), _cfg("engine"))
    np.testing.assert_array_equal(np.asarray(p.run_batch(gs, 0)),
                                  np.asarray(gs))
    with pytest.raises(ValueError, match=r"\(B, \*"):
        p.run_batch(gs[0], 2)                     # missing batch axis
    with pytest.raises(ValueError, match=r"\(B, \*"):
        p.run_batch(gs[:, :-1], 2)                # wrong grid shape
    with pytest.raises(ValueError, match="takes no aux"):
        p.run_batch(gs, 2, aux=gs)
    hs, auxs = _data("hotspot2d", DIMS2, batch=B)
    ph = plan(StencilProblem("hotspot2d", DIMS2), _cfg("engine"))
    with pytest.raises(ValueError, match="needs an aux"):
        ph.run_batch(hs, 2)
    with pytest.raises(ValueError, match="aux shape"):
        ph.run_batch(hs, 2, aux=auxs[:, :-1])


def test_run_batch_fallback_for_unbatched_custom_backend():
    """A factory returning a bare ExecuteFn (no batched entry point) still
    serves run_batch through the per-element fallback loop."""
    calls = []

    def factory(problem, config, geom):
        def execute(grid, coeffs, iters, aux=None):
            calls.append(int(iters))
            return oracle_run(problem.stencil, grid, coeffs, iters, aux)
        return execute

    register_backend("test_unbatched", factory)
    try:
        st = STENCILS["hotspot2d"]
        gs, auxs = _data("hotspot2d", DIMS2, batch=B)
        c = default_coeffs(st)
        p = plan(StencilProblem("hotspot2d", DIMS2), _cfg("test_unbatched"))
        got = p.run_batch(gs, 3, c, aux=auxs)
        assert calls == [3] * B                   # fallback looped
        want = jnp.stack([oracle_run(st, gs[i], c, 3, auxs[i])
                          for i in range(B)])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    finally:
        from repro.api import backends
        backends._REGISTRY.pop("test_unbatched", None)


def test_as_program_normalizes_and_rejects():
    prog = as_program(lambda g, c, i, a: g)
    assert isinstance(prog, BackendProgram) and prog.execute_batch is None
    assert as_program(prog) is prog
    with pytest.raises(TypeError, match="callable or BackendProgram"):
        as_program(42)


# --- donation never poisons caller arrays ------------------------------------

@pytest.mark.parametrize("backend", ["engine", "pallas_interpret"])
def test_donation_does_not_poison_plan_reuse(backend):
    """The fused loop donates only the backend-owned padded carry; the
    caller's grid must survive run()/run_batch() and the plan must stay
    reusable for repeated calls on the same arrays."""
    gs, _ = _data("diffusion2d", DIMS2, batch=B)
    g = gs[0]
    snapshot = np.asarray(g).copy()
    p = plan(StencilProblem("diffusion2d", DIMS2), _cfg(backend))
    out1 = p.run(g, 3)
    out2 = p.run(g, 3)                            # same input array again
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    bat1 = p.run_batch(gs, 3)
    bat2 = p.run_batch(gs, 3)
    np.testing.assert_array_equal(np.asarray(bat1), np.asarray(bat2))
    np.testing.assert_array_equal(np.asarray(g), snapshot)   # never donated


# --- executable cache --------------------------------------------------------

def test_exec_cache_hit_avoids_retrace():
    clear_exec_cache()
    gs, _ = _data("diffusion2d", DIMS2, batch=B)
    cfg = _cfg("engine")
    problem = StencilProblem("diffusion2d", DIMS2)
    p1 = plan(problem, cfg)
    p1.run(gs[0], 2)
    p1.run_batch(gs, 2)
    s1 = exec_cache_stats()
    assert s1["misses"] >= 2 and s1["traces"]["engine"] >= 2
    # a second identical plan reuses both compiled programs: hits, no traces
    p2 = plan(problem, cfg)
    p2.run(gs[0], 2)
    p2.run_batch(gs, 2)
    s2 = exec_cache_stats()
    assert s2["hits"] >= 2
    assert s2["traces"] == s1["traces"]           # nothing re-traced
    assert s2["misses"] == s1["misses"]


def test_dynamic_iters_shares_one_executable():
    """iters is a dynamic scalar: new iteration counts reuse the trace."""
    clear_exec_cache()
    gs, _ = _data("diffusion2d", DIMS2, batch=B)
    p = plan(StencilProblem("diffusion2d", DIMS2), _cfg("engine"))
    p.run(gs[0], 2)
    traces = exec_cache_stats()["traces"].copy()
    for iters in (1, 3, 7, 64):
        p.run(gs[0], iters)
    assert exec_cache_stats()["traces"] == traces


def test_exec_cache_key_separates_geometry():
    clear_exec_cache()
    gs, _ = _data("diffusion2d", DIMS2, batch=B)
    problem = StencilProblem("diffusion2d", DIMS2)
    plan(problem, _cfg("engine")).run(gs[0], 2)
    size1 = exec_cache_stats()["size"]
    plan(problem, RunConfig(backend="engine", par_time=1, bsize=16)
         ).run(gs[0], 2)                          # different schedule
    assert exec_cache_stats()["size"] > size1


def test_exec_cache_opt_out():
    clear_exec_cache()
    gs, _ = _data("diffusion2d", DIMS2, batch=B)
    problem = StencilProblem("diffusion2d", DIMS2)
    cfg = _cfg("engine", exec_cache=False)
    plan(problem, cfg).run(gs[0], 2)
    plan(problem, cfg).run(gs[0], 2)
    s = exec_cache_stats()
    assert s["size"] == 0 and s["hits"] == 0 and s["misses"] == 0
    assert s["traces"]["engine"] == 2             # private executables


def test_exec_cache_opt_out_still_memoizes_within_a_plan():
    """exec_cache=False means *private* programs, not re-trace-per-call: a
    plan must keep its own built executables across run/run_batch calls."""
    clear_exec_cache()
    gs, _ = _data("diffusion2d", DIMS2, batch=B)
    p = plan(StencilProblem("diffusion2d", DIMS2),
             _cfg("engine", exec_cache=False))
    for iters in (2, 5, 2):
        p.run(gs[0], iters)
        p.run_batch(gs, iters)
    traces = exec_cache_stats()["traces"]
    assert traces["engine"] == 2                  # one single + one batched


# --- satellite bugfix: plan-time dtype validation ----------------------------

@pytest.mark.parametrize("backend", ["pallas", "pallas_interpret"])
def test_pallas_rejects_unsupported_dtype_at_plan_time(backend):
    # bf16 joined the supported set (bf16 storage + f32 accumulation); f16
    # remains unsupported and must still fail at plan time, naming what IS
    problem = StencilProblem("diffusion2d", DIMS2, dtype="float16")
    with pytest.raises(ValueError) as ei:
        plan(problem, _cfg(backend))
    msg = str(ei.value)
    assert "float32" in msg and "bfloat16" in msg   # names what IS supported
    assert "float16" in msg


@pytest.mark.parametrize("backend", ["pallas_interpret"])
def test_pallas_accepts_bf16_at_plan_time(backend):
    problem = StencilProblem("diffusion2d", DIMS2, dtype="bfloat16")
    p = plan(problem, _cfg(backend))          # must not raise
    assert p.problem.dtype == "bfloat16"


# --- perf model: batch dimension ---------------------------------------------

def test_predict_batch_shares_aux_stream():
    st = STENCILS["hotspot2d"]
    dims, bsize, pt = (512, 512), (256,), 4
    one = predict(st, dims, 64, bsize, pt, TPU_V5E)
    four = predict(st, dims, 64, bsize, pt, TPU_V5E, batch=4)
    # aux (power) loads are shared: batched bytes < 4x single-problem bytes
    assert one.t_mem * 4 > four.t_mem > one.t_mem
    assert four.t_compute == pytest.approx(4 * one.t_compute)
    assert four.batch == 4
    # a stencil without aux scales memory exactly linearly
    st2 = STENCILS["diffusion2d"]
    one2 = predict(st2, dims, 64, bsize, pt, TPU_V5E)
    four2 = predict(st2, dims, 64, bsize, pt, TPU_V5E, batch=4)
    assert four2.t_mem == pytest.approx(4 * one2.t_mem)
    with pytest.raises(ValueError, match="batch"):
        predict(st, dims, 64, bsize, pt, TPU_V5E, batch=0)


def test_predict_batch_scales_halo_bytes():
    st = STENCILS["diffusion2d"]
    one = predict(st, (100, 512), 64, (256,), 4, TPU_V5E, n_chips=2,
                  chip_grid=(2, 1))
    four = predict(st, (100, 512), 64, (256,), 4, TPU_V5E, n_chips=2,
                   chip_grid=(2, 1), batch=4)
    assert four.t_halo == pytest.approx(4 * one.t_halo)


def test_plan_predicted_accepts_batch():
    p = plan(StencilProblem("diffusion2d", (2048, 2048)),
             RunConfig(backend="engine", autotune=True))
    single = p.predicted(100)
    batched = p.predicted(100, batch=8)
    assert batched.gcells_s >= single.gcells_s    # amortization never hurts
    assert batched.batch == 8
