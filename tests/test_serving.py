"""Serving-subsystem tests: batcher determinism, backpressure, deadlines,
drain, padded-bucket bit-identity, config factory, prewarm, metrics.

The coalescing/backpressure/deadline logic lives in the clock-free
``repro.serve.batcher`` core, so the policy tests drive it with a
hand-rolled clock — no sleeps, no asyncio, no arrays.  The service tests
then exercise the asyncio layer end to end on the engine backend with tiny
grids and pinned schedules (no tuner).
"""
import asyncio
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (RunConfig, StencilProblem, clear_exec_cache,
                       exec_cache_stats, plan)
from repro.serve import (BucketConfig, BucketState, DeadlineExceeded,
                         NoMatchingBucket, PendingRequest, ServiceClosed,
                         ServiceConfig, ServiceOverloaded, StencilRequest,
                         StencilService, bucket_key, coeffs_signature,
                         from_config, percentile, serve)

SHAPE = (12, 32)
RUN = {"backend": "engine", "par_time": 2, "bsize": 16}


def run_async(coro):
    return asyncio.run(coro)


def make_bucket(**kw) -> BucketConfig:
    spec = dict(problem={"stencil": "diffusion2d", "shape": list(SHAPE)},
                run=dict(RUN), max_batch=4, max_wait_ms=5.0, queue_cap=16)
    spec.update(kw)
    return BucketConfig(**spec)


def rec(seq, now=0.0, sig="a", iters=4, expires_at=None) -> PendingRequest:
    return PendingRequest(seq=seq, request=None, submitted_at=now,
                          expires_at=expires_at, coeffs_sig=sig, iters=iters)


def grids_for(n, shape=SHAPE, seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.uniform(jax.random.fold_in(key, i), shape,
                               jnp.float32, 0.5, 2.0) for i in range(n)]


# --- batcher core: deterministic-clock policy tests --------------------------

class TestBucketState:
    def test_window_arms_on_first_admit(self):
        bs = BucketState(make_bucket(max_wait_ms=10.0))
        assert bs.ready_at(now=0.0) is None
        assert bs.admit(rec(1), now=5.0)
        # window = first-admit time + max_wait, regardless of later admits
        assert bs.ready_at(now=5.0) == pytest.approx(5.010)
        assert bs.admit(rec(2), now=5.008)
        assert bs.ready_at(now=5.008) == pytest.approx(5.010)
        assert not bs.ready(now=5.009)
        assert bs.ready(now=5.010)

    def test_full_batch_launches_early(self):
        bs = BucketState(make_bucket(max_batch=3, max_wait_ms=1000.0))
        for i in range(2):
            bs.admit(rec(i), now=0.0)
        assert not bs.ready(now=0.0)          # window far away, batch short
        bs.admit(rec(2), now=0.0)
        assert bs.ready(now=0.0)              # max_batch pending: launch now
        batch, expired = bs.take_batch(now=0.0)
        assert [r.seq for r in batch] == [0, 1, 2] and not expired
        assert bs.ready_at(now=0.0) is None   # queue drained, window unarmed

    def test_draining_ignores_window(self):
        bs = BucketState(make_bucket(max_wait_ms=1000.0))
        bs.admit(rec(1), now=0.0)
        assert not bs.ready(now=0.0)
        assert bs.ready(now=0.0, draining=True)

    def test_queue_cap_backpressure(self):
        bs = BucketState(make_bucket(queue_cap=3, max_batch=8))
        assert all(bs.admit(rec(i), now=0.0) for i in range(3))
        assert not bs.admit(rec(3), now=0.0)   # full: refused, not enqueued
        assert bs.depth() == 3

    def test_coeffs_sig_subgroups(self):
        bs = BucketState(make_bucket(max_batch=8))
        for i, sig in enumerate("aabab"):
            bs.admit(rec(i, sig=sig), now=0.0)
        batch, _ = bs.take_batch(now=7.0)
        # head-of-line group only, FIFO order; 'b' requests stay queued
        assert [r.seq for r in batch] == [0, 1, 3]
        assert [r.seq for r in bs.pending] == [2, 4]
        # the remainder re-arms the window at take time
        assert bs.ready_at(now=7.0) == pytest.approx(7.0 + 5e-3)
        batch2, _ = bs.take_batch(now=7.1)
        assert [r.seq for r in batch2] == [2, 4]

    def test_max_rounds_caps_distinct_iters(self):
        bs = BucketState(make_bucket(max_batch=8, max_rounds=2))
        for i, iters in enumerate([4, 8, 4, 2, 8]):
            bs.admit(rec(i, iters=iters), now=0.0)
        batch, _ = bs.take_batch(now=0.0)
        # iters=2 would be a third distinct value: left for the next launch;
        # repeats of already-admitted values still join
        assert [r.seq for r in batch] == [0, 1, 2, 4]
        assert [r.seq for r in bs.pending] == [3]

    def test_deadline_sweep(self):
        bs = BucketState(make_bucket(max_batch=8))
        bs.admit(rec(0, expires_at=1.0), now=0.0)
        bs.admit(rec(1), now=0.0)
        bs.admit(rec(2, expires_at=9.0), now=0.0)
        batch, expired = bs.take_batch(now=2.0)
        assert [r.seq for r in expired] == [0]
        assert [r.seq for r in batch] == [1, 2]


# --- config factory ----------------------------------------------------------

class TestConfigFactory:
    def test_dict_and_json_forms(self):
        d = {"buckets": [{"problem": {"stencil": "diffusion2d",
                                      "shape": list(SHAPE)},
                          "run": dict(RUN), "max_batch": 4}]}
        for spec in (d, json.dumps(d)):
            cfg = ServiceConfig.make(spec)
            (b,) = cfg.buckets
            assert isinstance(b.problem, StencilProblem)
            assert isinstance(b.run, RunConfig)
            assert b.run.backend == "engine"
            assert b.name == "diffusion2d@12x32"
            assert b.batch_classes == (1, 2, 4)

    def test_bucket_list_form_and_passthrough(self):
        cfg = ServiceConfig.make([make_bucket()])
        assert ServiceConfig.make(cfg) is cfg

    def test_explicit_objects_pass_through(self):
        b = BucketConfig(problem=StencilProblem("diffusion2d", SHAPE),
                         run=RunConfig(**RUN))
        assert b.problem.shape == SHAPE
        assert b.batch_classes == (1, 2, 4, 8)

    def test_batch_classes_validation(self):
        with pytest.raises(ValueError, match="pad up to"):
            make_bucket(max_batch=8, batch_classes=(1, 2, 4))
        b = make_bucket(max_batch=6, batch_classes=(2, 6))
        assert b.pad_to_class(1) == 2 and b.pad_to_class(3) == 6

    def test_duplicate_buckets_rejected(self):
        with pytest.raises(ValueError, match="serve the same"):
            ServiceConfig(buckets=(make_bucket(), make_bucket(max_batch=2)))

    def test_bad_specs_rejected(self):
        with pytest.raises(ValueError, match="unknown stencil"):
            make_bucket(problem={"stencil": "nope", "shape": [8, 8]})
        with pytest.raises(ValueError, match="at least one bucket"):
            ServiceConfig(buckets=())
        with pytest.raises(ValueError, match="max_wait_ms"):
            make_bucket(max_wait_ms=-1)
        with pytest.raises(ValueError, match="queue_cap"):
            make_bucket(queue_cap=0)


# --- request validation ------------------------------------------------------

class TestRequest:
    def test_normalizes_name_to_problem(self):
        g = jnp.zeros(SHAPE)
        r = StencilRequest("diffusion2d", g, iters=3)
        assert isinstance(r.problem, StencilProblem)
        assert r.bucket_key == bucket_key(StencilProblem("diffusion2d",
                                                         SHAPE))

    def test_rejects_bad_fields(self):
        g = jnp.zeros(SHAPE)
        with pytest.raises(ValueError, match="iters"):
            StencilRequest("diffusion2d", g, iters=0)
        with pytest.raises(ValueError, match="deadline_s"):
            StencilRequest("diffusion2d", g, iters=1, deadline_s=0)
        with pytest.raises(ValueError, match="state"):
            StencilRequest(StencilProblem("diffusion2d", (8, 8)), g, iters=1)
        with pytest.raises(ValueError, match="needs an aux"):
            StencilRequest("hotspot2d", g, iters=1)
        with pytest.raises(ValueError, match="takes no aux"):
            StencilRequest("diffusion2d", g, iters=1, aux=g)

    def test_coeffs_signature_groups(self):
        prob = StencilProblem("diffusion2d", SHAPE)
        assert (coeffs_signature(prob, None)
                == coeffs_signature(prob, {}))
        assert (coeffs_signature(prob, {"cc": 0.25})
                != coeffs_signature(prob, None))
        with pytest.raises(ValueError, match="unknown coefficients"):
            coeffs_signature(prob, {"zz": 1.0})

    def test_bc_splits_bucket_key(self):
        a = bucket_key(StencilProblem("diffusion2d", SHAPE))
        b = bucket_key(StencilProblem("diffusion2d", SHAPE,
                                      boundary="periodic"))
        assert a != b


# --- the live service --------------------------------------------------------

class TestService:
    def test_bit_identity_across_bc_mixes(self):
        """Padded-bucket results == per-request plan().run(), bitwise, for
        clamp / periodic-mix / reflect / constant on the engine backend."""
        bcs = ["clamp", ("clamp", "periodic"), "reflect", "constant:1.5"]

        async def main():
            cfg = ServiceConfig(buckets=tuple(
                make_bucket(problem={"stencil": "diffusion2d",
                                     "shape": list(SHAPE), "boundary": bc},
                            max_wait_ms=10.0)
                for bc in bcs))
            svc = await serve(cfg, prewarm=False)
            gs = grids_for(2 * len(bcs))
            reqs = [StencilRequest(
                StencilProblem("diffusion2d", SHAPE, boundary=bcs[i % 4]),
                gs[i], iters=3 + (i % 2)) for i in range(len(gs))]
            futs = [svc.submit_nowait(r) for r in reqs]
            results = await asyncio.gather(*futs)
            await svc.stop()
            return reqs, results

        reqs, results = run_async(main())
        for r, res in zip(reqs, results):
            want = plan(r.problem, RunConfig(**RUN)).run(r.grid, r.iters)
            np.testing.assert_array_equal(np.asarray(res.grid),
                                          np.asarray(want))

    def test_mixed_dtype_admission(self):
        """bf16 and f32 requests for the SAME stencil/shape land in their
        own buckets (a by-name request inherits its grid's dtype), never
        co-batch, and each batch is bit-identical to per-request runs in
        its own storage dtype."""
        async def main():
            cfg = ServiceConfig(buckets=tuple(
                make_bucket(problem={"stencil": "diffusion2d",
                                     "shape": list(SHAPE), "dtype": dt},
                            name=f"diff2d-{dt}", max_wait_ms=10.0)
                for dt in ("float32", "bfloat16")))
            svc = await serve(cfg, prewarm=False)
            gs = grids_for(6)
            grids = [g if i % 2 == 0 else g.astype(jnp.bfloat16)
                     for i, g in enumerate(gs)]
            futs = [svc.submit_nowait(StencilRequest("diffusion2d", g, 3))
                    for g in grids]
            results = await asyncio.gather(*futs)
            snap = svc.snapshot()
            await svc.stop()
            return grids, results, snap

        grids, results, snap = run_async(main())
        plans = {dt: plan(StencilProblem("diffusion2d", SHAPE, dtype=dt),
                          RunConfig(**RUN))
                 for dt in ("float32", "bfloat16")}
        for g, res in zip(grids, results):
            dt = jnp.dtype(g.dtype).name
            assert res.bucket == f"diff2d-{dt}"
            assert res.batch_size == 3     # only same-dtype peers co-batch
            assert res.grid.dtype == g.dtype
            np.testing.assert_array_equal(
                np.asarray(res.grid.astype(jnp.float32)),
                np.asarray(plans[dt].run(g, 3).astype(jnp.float32)))

    def test_unmatched_dtype_rejected(self):
        """An f32-only bucket set must reject a bf16 grid with the typed
        NoMatchingBucket error — never silently serve it as f32."""
        async def main():
            svc = await serve(ServiceConfig(buckets=(make_bucket(),)),
                              prewarm=False)
            g16 = jnp.ones(SHAPE, jnp.bfloat16)
            with pytest.raises(NoMatchingBucket):
                await svc.submit(StencilRequest("diffusion2d", g16, 2))
            await svc.stop()

        run_async(main())

    def test_staged_advance_mixed_iters(self):
        """One launch carries heterogeneous iteration counts: members are
        delivered at their own stop, bit-identical to individual runs."""
        async def main():
            svc = await serve(ServiceConfig(buckets=(
                make_bucket(max_batch=4, max_wait_ms=50.0),)),
                prewarm=False)
            gs = grids_for(4)
            iters = [2, 6, 2, 4]
            futs = [svc.submit_nowait(StencilRequest("diffusion2d", g, it))
                    for g, it in zip(gs, iters)]
            results = await asyncio.gather(*futs)
            snap = svc.snapshot()
            await svc.stop()
            return gs, iters, results, snap

        gs, iters, results, snap = run_async(main())
        assert snap["batches"] == 1 and snap["rounds"] == 3
        p = plan(StencilProblem("diffusion2d", SHAPE), RunConfig(**RUN))
        for g, it, res in zip(gs, iters, results):
            assert res.rounds == 3 and res.batch_size == 4
            np.testing.assert_array_equal(np.asarray(res.grid),
                                          np.asarray(p.run(g, it)))

    def test_batch_padding_to_class_is_exact(self):
        """3 real requests pad to batch class 4 (edge replication): fill is
        reported honestly and results stay bitwise-identical."""
        async def main():
            svc = await serve(ServiceConfig(buckets=(
                make_bucket(max_batch=4, max_wait_ms=20.0),)),
                prewarm=False)
            gs = grids_for(3)
            futs = [svc.submit_nowait(StencilRequest("diffusion2d", g, 4))
                    for g in gs]
            results = await asyncio.gather(*futs)
            await svc.stop()
            return gs, results

        gs, results = run_async(main())
        p = plan(StencilProblem("diffusion2d", SHAPE), RunConfig(**RUN))
        for g, res in zip(gs, results):
            assert res.batch_fill == pytest.approx(3 / 4)
            np.testing.assert_array_equal(np.asarray(res.grid),
                                          np.asarray(p.run(g, 4)))

    def test_aux_and_coeffs_subgrouping(self):
        """Per-request hotspot aux grids batch together; a request with
        different resolved coefficients never shares a launch."""
        async def main():
            svc = await serve(ServiceConfig(buckets=(make_bucket(
                problem={"stencil": "hotspot2d", "shape": list(SHAPE)},
                max_batch=4, max_wait_ms=20.0),)), prewarm=False)
            gs = grids_for(3)
            auxs = grids_for(3, seed=7)
            coeffs = [None, None, {"sdc": 0.5}]
            futs = [svc.submit_nowait(StencilRequest(
                "hotspot2d", g, 3, coeffs=c, aux=a))
                for g, a, c in zip(gs, auxs, coeffs)]
            results = await asyncio.gather(*futs)
            snap = svc.snapshot()
            await svc.stop()
            return gs, auxs, coeffs, results, snap

        gs, auxs, coeffs, results, snap = run_async(main())
        assert snap["batches"] == 2            # the override launched alone
        p = plan(StencilProblem("hotspot2d", SHAPE), RunConfig(**RUN))
        for g, a, c, res in zip(gs, auxs, coeffs, results):
            np.testing.assert_array_equal(
                np.asarray(res.grid), np.asarray(p.run(g, 3, c, aux=a)))

    def test_queue_full_backpressure(self):
        """Admission beyond queue_cap raises ServiceOverloaded with a
        retry-after hint; queued requests still complete on drain."""
        async def main():
            svc = await serve(ServiceConfig(buckets=(
                make_bucket(queue_cap=3, max_batch=8,
                            max_wait_ms=60_000.0),)), prewarm=False)
            gs = grids_for(4)
            futs = [svc.submit_nowait(StencilRequest("diffusion2d", g, 2))
                    for g in gs[:3]]
            with pytest.raises(ServiceOverloaded) as ei:
                svc.submit_nowait(StencilRequest("diffusion2d", gs[3], 2))
            results = None
            stop = asyncio.create_task(svc.stop())   # drain ignores window
            results = await asyncio.gather(*futs)
            await stop
            snap = svc.snapshot()
            return ei.value, results, snap

        err, results, snap = run_async(main())
        assert err.retry_after_s >= 60.0           # >= the coalescing window
        assert len(results) == 3
        assert snap["rejected"]["overload"] == 1
        assert snap["completed"] == 3
        # nothing silently dropped: every submit is accounted for
        assert snap["submitted"] == snap["completed"] \
            + snap["rejected_total"] + snap["failed_total"]
        assert snap["in_flight"] == 0

    def test_metrics_conservation_under_mixed_outcomes(self):
        """The admission ledger balances under every outcome class at once:
        completions, typed rejections (overload + no-bucket), and launch
        failures all sum back to the submitted count, with nothing left
        in flight after drain."""
        from repro.resilience import FaultPlan, FaultSpec

        async def main():
            svc = await serve(ServiceConfig(
                buckets=(make_bucket(queue_cap=2, max_batch=2,
                                     max_wait_ms=60_000.0),),
                retry={"max_attempts": 2, "base_backoff_s": 1e-3},
                breaker=False), prewarm=False)
            gs = grids_for(3)
            outcomes = []
            # a launch that fails persistently (after retries) -> failed
            with FaultPlan([FaultSpec("backend.execute_batch", p=1.0,
                                      max_fires=None)]).active():
                fut = svc.submit_nowait(
                    StencilRequest("diffusion2d", gs[0], 2))
                outcomes.extend(await asyncio.gather(
                    fut, return_exceptions=True))
            # two successes saturating the queue, then an overload rejection
            futs = [svc.submit_nowait(StencilRequest("diffusion2d", g, 2))
                    for g in gs[1:]]
            with pytest.raises(ServiceOverloaded):
                svc.submit_nowait(StencilRequest("diffusion2d", gs[0], 2))
            # a shape no bucket declares -> no-bucket rejection
            with pytest.raises(NoMatchingBucket):
                svc.submit_nowait(StencilRequest(
                    "diffusion2d", jnp.zeros((8, 8), jnp.float32), 2))
            outcomes.extend(await asyncio.gather(
                *futs, return_exceptions=True))
            snap = svc.snapshot()
            await svc.stop()
            return outcomes, snap

        outcomes, snap = run_async(main())
        assert snap["submitted"] == 5
        assert snap["completed"] == 2
        assert snap["rejected"]["overload"] == 1
        assert snap["rejected"]["no_bucket"] == 1
        assert snap["failed"]["launch_failed"] == 1
        assert snap["retries"] >= 1
        # the ledger: submitted == completed + rejected + failed, none lost
        assert snap["submitted"] == snap["completed"] \
            + snap["rejected_total"] + snap["failed_total"]
        assert snap["in_flight"] == 0
        assert len(outcomes) == 3          # every awaited future resolved

    def test_deadline_expiry(self):
        async def main():
            svc = await serve(ServiceConfig(buckets=(
                make_bucket(max_batch=8, max_wait_ms=80.0),)), prewarm=False)
            g = grids_for(1)[0]
            fut = svc.submit_nowait(StencilRequest(
                "diffusion2d", g, 2, deadline_s=1e-3))
            ok = svc.submit_nowait(StencilRequest("diffusion2d", g, 2))
            with pytest.raises(DeadlineExceeded):
                await fut
            res = await ok
            snap = svc.snapshot()
            await svc.stop()
            return res, snap

        res, snap = run_async(main())
        assert res.batch_size == 1                 # the expired one never ran
        assert snap["rejected"]["deadline"] == 1

    def test_drain_on_shutdown_and_closed(self):
        async def main():
            svc = await serve(ServiceConfig(buckets=(
                make_bucket(max_wait_ms=60_000.0),)), prewarm=False)
            gs = grids_for(2)
            futs = [svc.submit_nowait(StencilRequest("diffusion2d", g, 2))
                    for g in gs]
            await svc.stop()                       # graceful: flushes both
            results = [f.result() for f in futs]
            with pytest.raises(ServiceClosed):
                svc.submit_nowait(StencilRequest("diffusion2d", gs[0], 2))
            return results

        results = run_async(main())
        assert len(results) == 2

    def test_no_matching_bucket(self):
        async def main():
            svc = await serve(ServiceConfig(buckets=(make_bucket(),)),
                              prewarm=False)
            with pytest.raises(NoMatchingBucket, match="declared"):
                svc.submit_nowait(StencilRequest(
                    "diffusion2d", jnp.zeros((8, 8), jnp.float32), 2))
            snap = svc.snapshot()
            await svc.stop()
            return snap

        snap = run_async(main())
        assert snap["rejected"]["no_bucket"] == 1

    def test_prewarm_serves_with_zero_new_traces(self):
        """Boot-time prewarm compiles every declared batch class; serving
        traffic then re-traces nothing (the tentpole's cache contract)."""
        clear_exec_cache()

        async def main():
            svc = await from_config({"buckets": [
                {"problem": {"stencil": "diffusion2d", "shape": list(SHAPE)},
                 "run": dict(RUN), "max_batch": 4, "max_wait_ms": 10.0}]})
            warmed = exec_cache_stats()["traces"].copy()
            gs = grids_for(7)
            # two launches: a full class-4 batch and a 3 -> class-4 pad
            futs = [svc.submit_nowait(StencilRequest("diffusion2d", g, 3))
                    for g in gs]
            await asyncio.gather(*futs)
            traced = exec_cache_stats()["traces"]
            snap = svc.snapshot()
            await svc.stop()
            return warmed, traced, snap

        warmed, traced, snap = run_async(main())
        assert snap["completed"] == 7
        assert traced == warmed, "serving must not re-trace after prewarm"
        assert snap["prewarm_s"] and all(
            s > 0 for s in snap["prewarm_s"].values())

    def test_metrics_snapshot_and_json(self, tmp_path):
        async def main():
            svc = await serve(ServiceConfig(buckets=(make_bucket(),)),
                              prewarm=False)
            futs = [svc.submit_nowait(StencilRequest("diffusion2d", g, 2))
                    for g in grids_for(4)]
            await asyncio.gather(*futs)
            path = svc.metrics.write_json(tmp_path / "m" / "snap.json")
            snap = svc.snapshot()
            await svc.stop()
            return path, snap

        path, snap = run_async(main())
        loaded = json.loads(path.read_text())
        for k in ("submitted", "completed", "rejected", "latency_ms",
                  "batch_fill", "cells", "exec_cache", "queue_depth",
                  "failed", "failed_total", "quarantined", "retries",
                  "breaker", "in_flight"):
            assert k in loaded
        assert loaded["latency_ms"]["p50"] <= loaded["latency_ms"]["p99"]
        assert snap["cells"] == 4 * 2 * SHAPE[0] * SHAPE[1]
        b = snap["buckets"]["diffusion2d@12x32"]
        assert b["batch_classes"] == [1, 2, 4] and b["depth"] == 0
        # the per-key breakdown (satellite fix) reaches the snapshot
        assert any(v["misses"] >= 1
                   for v in snap["exec_cache"]["by_key"].values())

    def test_open_loop_seeded_integration(self):
        """Seeded open-loop arrival process on the engine backend: every
        submit resolves (result or typed rejection), served results are
        bit-identical to per-request runs, and overload rejections carry
        retry-after hints."""
        rng = np.random.default_rng(42)
        n = 24
        gaps = rng.exponential(2e-3, n)
        iters = rng.choice([2, 4], n)

        async def main():
            svc = await serve(ServiceConfig(buckets=(
                make_bucket(max_batch=4, max_wait_ms=2.0, queue_cap=6),)),
                prewarm=False)
            gs = grids_for(n)
            outcomes = []

            async def one(i):
                try:
                    fut = svc.submit_nowait(StencilRequest(
                        "diffusion2d", gs[i], int(iters[i])))
                except ServiceOverloaded as e:
                    outcomes.append(("rejected", i, e.retry_after_s))
                    return
                outcomes.append(("served", i, await fut))

            tasks = []
            for i in range(n):
                await asyncio.sleep(float(gaps[i]))
                tasks.append(asyncio.create_task(one(i)))
            await asyncio.gather(*tasks)
            snap = svc.snapshot()
            await svc.stop()
            return gs, outcomes, snap

        gs, outcomes, snap = run_async(main())
        assert len(outcomes) == n
        served = [o for o in outcomes if o[0] == "served"]
        rejected = [o for o in outcomes if o[0] == "rejected"]
        assert snap["completed"] == len(served)
        assert snap["rejected"]["overload"] == len(rejected)
        assert all(r[2] > 0 for r in rejected)
        p = plan(StencilProblem("diffusion2d", SHAPE), RunConfig(**RUN))
        for _, i, res in served[:6]:
            np.testing.assert_array_equal(
                np.asarray(res.grid),
                np.asarray(p.run(gs[i], int(iters[i]))))


# --- plan.prewarm + per-key cache stats (satellites) -------------------------

class TestPrewarmAndStats:
    def test_plan_prewarm_compiles_then_hits(self):
        clear_exec_cache()
        p = plan(StencilProblem("diffusion2d", SHAPE), RunConfig(**RUN))
        t1 = p.prewarm(batch_sizes=(1, 2))
        assert set(t1) == {"single", 1, 2} and all(
            v > 0 for v in t1.values())
        s1 = exec_cache_stats()
        # a same-key plan prewarming again compiles nothing new
        p2 = plan(StencilProblem("diffusion2d", SHAPE), RunConfig(**RUN))
        p2.prewarm(batch_sizes=(1, 2))
        s2 = exec_cache_stats()
        assert s2["size"] == s1["size"]
        assert s2["traces"] == s1["traces"]
        assert s2["hits"] > s1["hits"]

    def test_plan_prewarm_validates(self):
        p = plan(StencilProblem("diffusion2d", SHAPE), RunConfig(**RUN))
        with pytest.raises(ValueError, match="batch sizes"):
            p.prewarm(batch_sizes=(0,))
        with pytest.raises(ValueError, match="iters"):
            p.prewarm(iters=0)

    def test_exec_cache_per_key_breakdown(self):
        clear_exec_cache()
        # plan build resolves the single-run executable: one miss
        p = plan(StencilProblem("diffusion2d", SHAPE), RunConfig(**RUN))
        g = grids_for(1)[0]
        p.run_batch(jnp.stack([g, g]), 2)  # batched key: miss
        p.run_batch(jnp.stack([g, g]), 4)  # dynamic iters: same key, a hit
        plan(StencilProblem("diffusion2d", SHAPE),
             RunConfig(**RUN))             # same-key rebuild: a hit
        stats = exec_cache_stats()
        assert sum(v["misses"] for v in stats["by_key"].values()) \
            == stats["misses"]
        assert sum(v["hits"] for v in stats["by_key"].values()) \
            == stats["hits"]
        assert any(v["hits"] >= 1 for v in stats["by_key"].values())
        assert len(stats["by_key"]) == stats["size"] == 2


def test_percentile_nearest_rank():
    assert percentile([], 50) is None
    assert percentile([3.0], 99) == 3.0
    xs = list(range(1, 101))
    assert percentile(xs, 50) == pytest.approx(50, abs=1)
    assert percentile(xs, 99) == pytest.approx(99, abs=1)
    assert percentile(xs, 0) == 1 and percentile(xs, 100) == 100
