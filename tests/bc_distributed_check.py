"""Distributed boundary-condition conformance check (2-device mesh).

Run in a subprocess with 2 fake CPU devices (tests/test_boundary_conditions.py)
so the main pytest process keeps its single-device view.  Every BC — including
per-axis mixes — through ``plan(backend="distributed")`` must match the
``kernels/ref.py`` oracle, for 2D and 3D, radius 1 and 2, stream-sharded and
blocked-sharded decompositions, plus ``run_batch`` and the aux (power) stream.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import RunConfig, StencilProblem, plan
from repro.core import STENCILS, default_coeffs, make_star
from repro.kernels.ref import oracle_run


def _data(st, dims, seed=0):
    k = jax.random.PRNGKey(seed)
    g = jax.random.uniform(k, dims, jnp.float32, 0.5, 2.0)
    aux = (jax.random.uniform(jax.random.fold_in(k, 1), dims,
                              jnp.float32, 0.0, 0.1)
           if st.has_aux else None)
    return g, aux


def check(st, dims, bc, axis_map, par_time=2, bsize=16, iters=5):
    mesh = jax.make_mesh((2,), ("d",))
    g, aux = _data(st, dims)
    c = default_coeffs(st)
    problem = StencilProblem(st, dims, boundary=bc)
    p = plan(problem, RunConfig(backend="distributed", mesh=mesh,
                                axis_map=axis_map, par_time=par_time,
                                bsize=bsize))
    want = oracle_run(st, g, c, iters, aux, bc=problem.bc)
    got = p.run(g, iters, c, aux=aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5,
                               err_msg=f"{st.name} bc={bc} map={axis_map}")
    print(f"ok {st.name} {dims} bc={problem.bc.token()} map={axis_map}")


def check_batch():
    st = STENCILS["hotspot2d"]
    dims = (16, 32)
    mesh = jax.make_mesh((2,), ("d",))
    g, aux = _data(st, dims)
    gs = jnp.stack([g, g * 1.1, g * 0.9])
    c = default_coeffs(st)
    problem = StencilProblem(st, dims, boundary=("periodic", "reflect"))
    p = plan(problem, RunConfig(backend="distributed", mesh=mesh,
                                axis_map=(("d",), None), par_time=2,
                                bsize=16))
    want = jnp.stack([oracle_run(st, gs[i], c, 4, aux, bc=problem.bc)
                      for i in range(3)])
    got = p.run_batch(gs, 4, c, aux=aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # batched (per-member) aux too
    auxs = jnp.stack([aux, aux * 2.0, aux * 0.5])
    want_b = jnp.stack([oracle_run(st, gs[i], c, 4, auxs[i], bc=problem.bc)
                        for i in range(3)])
    got_b = p.run_batch(gs, 4, c, aux=auxs)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(want_b),
                               rtol=3e-5, atol=3e-5)
    print("ok run_batch distributed periodic/reflect (shared + batched aux)")


def check_indivisible_raises():
    """plan() must reject a periodic grid axis the mesh cannot shard evenly
    at plan time, before any execution."""
    mesh = jax.make_mesh((2,), ("d",))
    problem = StencilProblem("diffusion2d", (17, 32), boundary="periodic")
    try:
        plan(problem, RunConfig(backend="distributed", mesh=mesh,
                                axis_map=(("d",), None), par_time=1,
                                bsize=16))
    except ValueError as e:
        assert "not divisible" in str(e), e
        print(f"ok indivisible periodic raises at plan time: {e}")
        return
    raise AssertionError("plan() accepted an indivisible periodic axis")


if __name__ == "__main__":
    assert len(jax.devices()) == 2, jax.devices()
    d2 = STENCILS["diffusion2d"]
    h2 = STENCILS["hotspot2d"]
    d3 = STENCILS["diffusion3d"]
    for bc in ["clamp", "periodic", "reflect", "constant:0.7",
               ("periodic", "clamp"), ("reflect", "periodic"),
               ("constant:2.0", "periodic")]:
        check(d2, (16, 32), bc, (("d",), None))      # stream-sharded
        check(d2, (16, 32), bc, (None, ("d",)))      # blocked-sharded
    check(h2, (16, 32), "periodic", (("d",), None))
    check(h2, (16, 32), ("reflect", "periodic"), (None, ("d",)))
    for bc in ["periodic", ("clamp", "periodic", "reflect"),
               ("periodic", "constant:1.0", "clamp")]:
        check(d3, (8, 24, 24), bc, (("d",), None, None), bsize=8)
        check(d3, (8, 24, 24), bc, (None, ("d",), None), bsize=8)
    # radius 2 (halo = rad * par_time = 4 wide)
    check(make_star(2, 2), (16, 48), "periodic", (("d",), None), bsize=24)
    check(make_star(2, 2), (16, 48), ("reflect", "periodic"), (None, ("d",)),
          bsize=24)
    check(make_star(3, 2), (8, 24, 24), ("periodic", "reflect", "periodic"),
          (("d",), None, None), par_time=1, bsize=12)
    check_batch()
    check_indivisible_raises()
    print("ALL OK")
