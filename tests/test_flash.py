"""Flash-attention Pallas kernel vs pure-jnp oracle (interpret mode).

Sweeps shapes (incl. GQA head ratios and non-square q/kv), dtypes, causal
flags, and block sizes; asserts fwd and bwd allclose against ref_attention.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import (flash_attention, flash_flops,
                                           flash_traffic_bytes,
                                           ref_attention)


def _mk(B, Sq, Skv, H, Hkv, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), jnp.float32).astype(dtype)
    return q, k, v


SHAPES = [
    # B, Sq, Skv, H, Hkv, D, bq, bkv
    (2, 256, 256, 4, 2, 64, 64, 64),
    (1, 128, 128, 2, 2, 32, 128, 64),
    (2, 256, 256, 8, 2, 128, 128, 128),
    (1, 512, 512, 4, 1, 64, 128, 256),   # MQA
]


@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,D,bq,bkv", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_forward_matches_oracle(B, Sq, Skv, H, Hkv, D, bq, bkv, causal):
    q, k, v = _mk(B, Sq, Skv, H, Hkv, D, jnp.float32)
    o = flash_attention(q, k, v, causal, bq, bkv, True)
    r = ref_attention(q, k, v, causal=causal)
    assert jnp.max(jnp.abs(o - r)) < 1e-4


@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,D,bq,bkv", SHAPES[:2])
def test_backward_matches_oracle(B, Sq, Skv, H, Hkv, D, bq, bkv):
    q, k, v = _mk(B, Sq, Skv, H, Hkv, D, jnp.float32)

    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, bq, bkv, True) ** 2)

    def fr(q, k, v):
        return jnp.sum(ref_attention(q, k, v, causal=True) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        assert jnp.max(jnp.abs(a - b)) < 5e-4


def test_bf16_inputs():
    q, k, v = _mk(1, 128, 128, 2, 2, 64, jnp.bfloat16)
    o = flash_attention(q, k, v, True, 64, 64, True)
    r = ref_attention(q, k, v, causal=True)
    assert o.dtype == jnp.bfloat16
    assert jnp.max(jnp.abs(o.astype(jnp.float32)
                           - r.astype(jnp.float32))) < 3e-2


def test_under_jit_and_remat():
    q, k, v = _mk(1, 128, 128, 2, 2, 32, jnp.float32)

    @jax.jit
    def f(q, k, v):
        g = jax.checkpoint(
            lambda q: jnp.sum(flash_attention(q, k, v, True, 64, 64, True)))
        return jax.grad(g)(q)

    dq = f(q, k, v)
    assert dq.shape == q.shape and not bool(jnp.any(jnp.isnan(dq)))


def test_traffic_and_flops_accounting():
    # analytic accounting sanity: traffic scales linearly in B, flops in S^2
    t1 = flash_traffic_bytes(1, 1024, 1024, 8, 2, 128)
    t2 = flash_traffic_bytes(2, 1024, 1024, 8, 2, 128)
    assert abs(t2 / t1 - 2.0) < 1e-6
    f1 = flash_flops(1, 1024, 1024, 8, 128)
    f2 = flash_flops(1, 2048, 2048, 8, 128)
    assert abs(f2 / f1 - 4.0) < 1e-6
    # kernel beats XLA chunked on traffic by construction: q+k+v+o only
    assert t1 < 20 * 1024 * 1024 * 8 * 2  # well under score materialization


def test_stub_path_matches_oracle():
    """attn_impl='stub' (dry-run billing path) is executable and exact."""
    from repro.models.attention import _flash_stub
    q, k, v = _mk(1, 128, 128, 4, 2, 32, jnp.float32)
    o = _flash_stub(q, k, v)
    r = ref_attention(q, k, v, causal=True)
    assert jnp.max(jnp.abs(o - r)) < 1e-5


# --- property-based sweep (hypothesis is an OPTIONAL dependency) --------------
# Gated so the rest of this module still collects/runs without it; the
# sweep itself reports as skipped via pytest.importorskip.

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 2),                    # B
           st.sampled_from([64, 128]),           # S
           st.sampled_from([(2, 1), (2, 2), (4, 2)]),   # (H, Hkv)
           st.sampled_from([32, 64]),            # D
           st.sampled_from([32, 64]),            # block_q
           st.sampled_from([32, 64]),            # block_kv
           st.booleans())                        # causal
    def test_flash_property_any_geometry(B, S, heads, D, bq, bkv, causal):
        H, Hkv = heads
        q, k, v = _mk(B, S, S, H, Hkv, D, jnp.float32, seed=B * S + H + D)
        o = flash_attention(q, k, v, causal, bq, bkv, True)
        r = ref_attention(q, k, v, causal=causal)
        assert jnp.max(jnp.abs(o - r)) < 1e-4
        # row-stochastic sanity: outputs are convex combos of V rows, so they
        # stay within [min(V), max(V)] per head dim
        assert float(jnp.max(o)) <= float(jnp.max(v)) + 1e-4
        assert float(jnp.min(o)) >= float(jnp.min(v)) - 1e-4
else:
    def test_flash_property_any_geometry():
        pytest.importorskip("hypothesis")
