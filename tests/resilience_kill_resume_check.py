"""Crash-resume check for checkpointed runs (run in a subprocess).

``crash`` mode installs a ``kill`` fault at the ``checkpoint.save`` seam —
the process SIGKILLs itself mid-save (after the shards land in
``step_N.tmp``, before the atomic publish), exactly a crashed host.
``resume`` mode reruns the same call against the same directory: it must
restore the last *complete* step and print the final grid's sha256, which
the parent compares against an uninterrupted run.

Usage: resilience_kill_resume_check.py {crash|resume|fresh} <checkpoint_dir>
"""
import hashlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

import repro.checkpoint  # noqa: F401 — registers the checkpoint.* points
from repro.api import RunConfig, StencilProblem, plan
from repro.resilience import FaultPlan, FaultSpec, run_checkpointed

SHAPE = (16, 24)
ITERS = 8
EVERY = 2          # engine par_time=2 below -> chunk seams at 2, 4, 6, 8
RUN = RunConfig(backend="engine", par_time=2, bsize=16, cache=False)


def make_plan():
    return plan(StencilProblem("diffusion2d", SHAPE), RUN)


def grid():
    return jax.random.uniform(jax.random.PRNGKey(7), SHAPE,
                              jnp.float32, 0.5, 2.0)


def main():
    mode, ckdir = sys.argv[1], sys.argv[2]
    p = make_plan()
    g = grid()
    if mode == "fresh":
        out = p.run(g, ITERS)
        print("sha256:" + hashlib.sha256(
            np.ascontiguousarray(np.asarray(out)).tobytes()).hexdigest())
        return
    if mode == "crash":
        # die inside the SECOND save (step 4): step 2 is already published,
        # step 4 is left as an unpublished .tmp
        FaultPlan([FaultSpec("checkpoint.save", action="kill",
                             nth=2)]).install()
        run_checkpointed(p, g, ITERS, checkpoint_every=EVERY,
                         checkpoint_dir=ckdir)
        raise SystemExit("kill fault did not fire")      # pragma: no cover
    if mode == "resume":
        res = run_checkpointed(p, g, ITERS, checkpoint_every=EVERY,
                               checkpoint_dir=ckdir)
        print(f"resumed_from:{res.resumed_from}")
        print("sha256:" + hashlib.sha256(
            np.ascontiguousarray(
                np.asarray(res.grid)).tobytes()).hexdigest())
        return
    raise SystemExit(f"unknown mode {mode!r}")


if __name__ == "__main__":
    main()
