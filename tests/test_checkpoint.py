"""Checkpoint-substrate robustness: broken-step fallback and elastic
(different-mesh) restore.

``restore_latest_valid`` is the resume path's entry point; these tests
damage the newest step every way a real filesystem does — corrupt
manifest, truncated shard, flipped bytes, missing leaf — and assert the
restore falls back to the previous *complete* step instead of crashing the
restart.  The mesh test saves from a single-device world and restores onto
a 2-device mesh in a subprocess (bit-identically) — the elastic-restart
contract.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint import (complete_steps, restore_latest_valid,
                              save_pytree)


def tree_for(step: int) -> dict:
    rng = np.random.default_rng(step)
    return {"grid": rng.normal(size=(8, 6)).astype(np.float32),
            "t": np.asarray(step, np.int32)}


def step_dir(d, step: int) -> str:
    return os.path.join(d, f"step_{step:08d}")


def save_two(d) -> None:
    save_pytree(tree_for(4), d, 4)
    save_pytree(tree_for(8), d, 8)


def assert_restores(d, want_step: int) -> None:
    with pytest.warns(RuntimeWarning, match="unusable"):
        tree, step = restore_latest_valid(tree_for(0), d)
    assert step == want_step
    want = tree_for(want_step)
    assert (tree["grid"] == want["grid"]).all()
    assert tree["t"] == want["t"]


class TestBrokenStepFallback:
    def test_corrupt_manifest_falls_back(self, tmp_path):
        d = str(tmp_path)
        save_two(d)
        with open(os.path.join(step_dir(d, 8), "MANIFEST.json"), "w") as f:
            f.write("{not json")
        assert_restores(d, 4)

    def test_truncated_shard_falls_back(self, tmp_path):
        d = str(tmp_path)
        save_two(d)
        shard = os.path.join(step_dir(d, 8), "shard_00000.npz")
        with open(shard, "r+b") as f:
            f.truncate(os.path.getsize(shard) // 2)
        assert_restores(d, 4)

    def test_flipped_payload_bytes_fail_checksum(self, tmp_path):
        d = str(tmp_path)
        save_two(d)
        shard = os.path.join(step_dir(d, 8), "shard_00000.npz")
        data = bytearray(open(shard, "rb").read())
        data[-20] ^= 0xFF        # flip a payload byte, keep the zip valid
        open(shard, "wb").write(bytes(data))
        assert_restores(d, 4)

    def test_missing_shard_falls_back(self, tmp_path):
        d = str(tmp_path)
        save_two(d)
        os.unlink(os.path.join(step_dir(d, 8), "shard_00000.npz"))
        assert_restores(d, 4)

    def test_every_step_broken_returns_none(self, tmp_path):
        d = str(tmp_path)
        save_pytree(tree_for(4), d, 4)
        with open(os.path.join(step_dir(d, 4), "MANIFEST.json"), "w") as f:
            f.write("garbage")
        with pytest.warns(RuntimeWarning, match="unusable"):
            tree, step = restore_latest_valid(tree_for(0), d)
        assert tree is None and step is None
        assert restore_latest_valid(tree_for(0), str(tmp_path / "nope")) \
            == (None, None)

    def test_complete_steps_skips_tmp(self, tmp_path):
        d = str(tmp_path)
        save_two(d)
        os.makedirs(os.path.join(d, "step_00000012.tmp"))
        assert complete_steps(d) == [4, 8]


def test_restore_onto_two_device_mesh_is_bit_identical(tmp_path):
    """Save single-device, restore sharded over a 2-fake-device mesh in a
    subprocess (the main process must keep its single-device view)."""
    d = str(tmp_path)
    grid = np.random.default_rng(0).normal(size=(8, 6)).astype(np.float32)
    save_pytree({"grid": grid}, d, 6)
    np.save(os.path.join(d, "expected.npy"), grid)
    script = os.path.join(os.path.dirname(__file__),
                          "checkpoint_mesh_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, script, d, "6"], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL OK" in out.stdout
