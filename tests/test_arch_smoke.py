"""Per-arch smoke tests: reduced config, one forward/train step on CPU;
output shapes + no NaNs. Plus prefill->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, smoke_config
from repro.models import (decode_step, forward, init_params, lm_loss,
                          make_decode_caches, param_axes, prefill)
from repro.optim.adamw import AdamWConfig
from repro.train import make_train_step

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    S_tok = S - cfg.prefix_len if cfg.input_mode == "embeds_prefix" else S
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S_tok), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S_tok), 0, cfg.vocab),
        "loss_mask": jnp.ones((B, S_tok), jnp.float32),
    }
    if cfg.input_mode == "embeds_prefix":
        batch["embeds"] = jax.random.normal(
            ks[2], (B, cfg.prefix_len, cfg.d_model), jnp.float32)
    elif cfg.input_mode == "frames":
        batch["frames"] = jax.random.normal(ks[2], (B, S, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, jax.random.fold_in(key, 1))
    hidden, aux = forward(params, cfg, batch["tokens"],
                          embeds=batch.get("embeds"),
                          frames=batch.get("frames"))
    assert hidden.shape == (B, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(hidden)))
    loss = lm_loss(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"loss={loss}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_axes_structure_matches(arch):
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    axes = param_axes(cfg)
    pt = jax.tree.structure(params)
    at = jax.tree.structure(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert pt == at, f"{pt}\n!=\n{at}"
    # every axes tuple must match its leaf's rank
    leaves = jax.tree.leaves(params)
    axleaves = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    for leaf, ax in zip(leaves, axleaves):
        assert leaf.ndim == len(ax), f"{arch}: {leaf.shape} vs axes {ax}"


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen3-moe-30b-a3b",
                                  "mamba2-1.3b", "zamba2-7b",
                                  "seamless-m4t-large-v2"])
def test_train_step(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    from repro.optim import adamw_init
    opt = adamw_init(params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3, total_steps=10),
                           microbatches=2)
    batch = _batch(cfg, jax.random.fold_in(key, 1))
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     params, new_params)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    """Teacher-forced decode after prefill == full forward logits."""
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, jax.random.fold_in(key, 1))
    tokens = batch["tokens"]
    max_len = S + 8

    hidden, _ = forward(params, cfg, tokens, embeds=batch.get("embeds"),
                        frames=batch.get("frames"))
    from repro.models.layers import lm_logits, rms_norm
    ref_logits = lm_logits(
        rms_norm(hidden[:, -1:], params["final_norm"], cfg.norm_eps),
        params["embed"])

    logits_p, caches, memory = prefill(
        params, cfg, tokens, max_len, embeds=batch.get("embeds"),
        frames=batch.get("frames"))
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)

    # one decode step keeps everything finite and shaped
    nxt = jnp.argmax(logits_p[:, -1], axis=-1).astype(jnp.int32)[:, None]
    logits_d, caches2 = decode_step(params, cfg, nxt, caches, memory=memory)
    assert logits_d.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits_d)))
    # padded vocab positions are masked out of sampling
    assert float(jnp.max(logits_d[..., cfg.vocab:], initial=-1e30)) <= -1e29
    assert int(caches2["length"]) == int(caches["length"]) + 1


def test_decode_consistency_dense():
    """Decode path == forward on the same prefix (position-by-position)."""
    cfg = smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, 8), 0,
                              cfg.vocab)
    # forward logits at last position given first 7 tokens:
    hidden, _ = forward(params, cfg, toks)
    from repro.models.layers import lm_logits, rms_norm
    want = lm_logits(rms_norm(hidden[:, -1:], params["final_norm"],
                              cfg.norm_eps), params["embed"])
    # prefill 7, decode token 8
    _, caches, _ = prefill(params, cfg, toks[:, :7], 16)
    got, _ = decode_step(params, cfg, toks[:, 7:8], caches)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
