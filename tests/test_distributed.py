"""Distributed stencil: run the 8-fake-device check in a subprocess so the
main test process keeps a single-device view (dry-run flags must not leak)."""
import os
import subprocess
import sys

import pytest


def test_multidevice_stencil_matches_oracle():
    script = os.path.join(os.path.dirname(__file__),
                          "multidevice_stencil_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL OK" in out.stdout
