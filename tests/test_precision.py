"""Mixed-precision conformance harness — dtype-aware ulp tolerances.

``repro.core.precision`` is the single owner of the storage/accumulation
policy (bf16 grids widen to f32 per stage application and round back to
storage exactly once); this file locks every backend to it:

  * an independent **f64 numpy oracle** — storage-rounded inputs promoted to
    f64, the stage DAG evaluated in f64 with *no* intermediate rounding,
    coefficients at their f32-resolved values — bounds every backend's error
    under the explicit per-dtype ulp budgets of
    ``precision.ULPS_PER_ITER`` (via ``precision.tolerance``),
  * a parametrized matrix sweeps dtype x BC x backend (incl. a vectorized
    ``par_vec=4`` Pallas column) x rank (1D/2D/3D) x radius (1, 2) x aux,
  * **f32 stays bit-identical to the pre-bf16 code**: golden digests pinned
    per backend,
  * **bf16 is bit-identical across backends** (round-once-per-stage is the
    same computation everywhere), `run_batch` included,
  * multi-stage chains and multi-field DAG programs run the same
    storage/accumulation policy,
  * the schedule cache and the executable cache key on the dtype (a bf16
    executable must never serve an f32 plan, and vice versa),
  * every dtype-spec spelling (``"bf16"``, ``jnp.bfloat16``, ``np.dtype``)
    normalizes to one canonical bucket, and a serving request inherits the
    *grid's* dtype,
  * bf16 extends the ``par_vec`` sweep to V=32 (16-sublane tiles) and
    halves the per-cell traffic/VMEM pricing,
  * the distributed backend runs the same checks on a 2-device mesh in a
    subprocess (``precision_distributed_check.py``).
"""
import hashlib
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (RunConfig, StencilProblem, clear_exec_cache,
                       exec_cache_stats, plan)
from repro.api.schedule_cache import schedule_key
from repro.core import STENCILS, make_star, precision
from repro.core.blocking import BlockGeometry
from repro.core.perf_model import (PAR_VEC_CANDIDATES, autotune,
                                   par_vec_candidates)
from repro.core.stencils import make_combine
from repro.programs import StencilProgram, StencilStage
from repro.serve import StencilRequest

DTYPES = ("float32", "bfloat16")


# --- the f64 numpy oracle ----------------------------------------------------
#
# Promote the storage-rounded initial state to f64 and run the whole program
# in f64 with no intermediate rounding; the difference to a backend's output
# is then exactly the backend's accumulated rounding error, which the
# per-dtype ulp budget must bound.  Stencil ``apply`` bodies are pure
# arithmetic over getter results, so numpy getters + python-float
# coefficients evaluate the same expressions in f64.

_NP_MODES = {"clamp": "edge", "periodic": "wrap", "reflect": "reflect"}


def _np_padded_getter(x, r, bc, sdtype):
    """f64 per-axis BC padding (constant fills pre-rounded through the
    storage dtype, matching the backends)."""
    p = x
    for ax, kind in enumerate(bc.kinds):
        pads = [(0, 0)] * p.ndim
        pads[ax] = (r, r)
        if kind == "constant":
            fill = float(np.asarray(bc.value, sdtype))
            p = np.pad(p, pads, mode="constant", constant_values=fill)
        else:
            p = np.pad(p, pads, mode=_NP_MODES[kind])

    def get(off):
        return p[tuple(slice(r + o, r + o + n)
                       for o, n in zip(off, x.shape))]

    return get


def _f32_resolved_coeffs(problem, coeffs=None):
    """Per-stage coefficient dicts at their f32-resolved values, as exact
    python floats: every backend resolves coefficients in the accumulation
    dtype (f32 for both supported storage dtypes), so the f64 oracle must
    use the f32-rounded values, not the unrounded literals."""
    return tuple({k: float(np.asarray(v, np.float32)) for k, v in cf.items()}
                 for cf in problem.resolve_coeffs(coeffs))


def f64_oracle_run(problem, state, iters, coeffs=None, aux=None):
    """``iters`` program iterations of ``problem``'s stage DAG in f64."""
    dag = problem.exec_dag
    cfs = _f32_resolved_coeffs(problem, coeffs)
    sdtype = problem.jnp_dtype
    s = np.asarray(state).astype(np.float64)
    aux64 = None if aux is None else np.asarray(aux).astype(np.float64)
    F = dag.n_fields
    fields = [s[k] for k in range(F)] if F > 1 else [s]
    for _ in range(iters):
        vals = [None] * len(dag.stages)
        for si in dag.topo:
            st, bc_s, refs = dag.stages[si]
            ins = [vals[r] if r >= 0 else fields[~r] for r in refs]
            gets = [_np_padded_getter(x, st.radius, bc_s, sdtype)
                    for x in ins]
            vals[si] = st.apply(tuple(gets) if st.arity > 1 else gets[0],
                                cfs[si], aux64 if st.has_aux else None)
        fields = [vals[u] if u >= 0 else fields[~u] for u in dag.updates]
    return np.stack(fields) if F > 1 else fields[0]


def _data(problem, seed=3):
    """Initial state + aux in the problem's storage dtype (generated in f32,
    rounded to storage — the storage-rounded values ARE the inputs every
    backend and the f64 oracle start from)."""
    k = jax.random.PRNGKey(seed)
    g = jax.random.uniform(k, problem.state_shape, jnp.float32, 0.5, 2.0)
    aux = (jax.random.uniform(jax.random.fold_in(k, 7), problem.shape,
                              jnp.float32, 0.0, 0.1)
           if problem.needs_aux else None)
    sd = problem.jnp_dtype
    return g.astype(sd), None if aux is None else aux.astype(sd)


# --- the conformance matrix --------------------------------------------------
#
# dtype x BC x backend(+par_vec) x rank x radius x aux, 5 iterations each,
# asserted against the f64 oracle under precision.tolerance's explicit ulp
# budget.  (id, stencil, dims, bc, par_time, bsize)

CASES = [
    ("diff2d-clamp", "diffusion2d", (24, 48), "clamp", 2, 16),
    ("diff2d-per-refl", "diffusion2d", (24, 48),
     ("periodic", "reflect"), 2, 16),
    ("diff2d-const-clamp", "diffusion2d", (24, 48),
     ("constant:0.25", "clamp"), 2, 16),
    ("star2d-r2", make_star(2, 2), (24, 48), ("clamp", "periodic"), 2, 16),
    ("diff3d-mixed", "diffusion3d", (8, 16, 16),
     ("clamp", "periodic", "reflect"), 1, 8),
    ("hotspot2d-aux", "hotspot2d", (24, 48), "clamp", 2, 16),
    ("star1d-r2", "star1d_r2", (64,), "clamp", 2, ()),
]

#: (backend, par_vec) columns — the V=4 column re-checks the matrix through
#: the vectorized kernels (2D cases only; V applies to the stream axis)
BACKEND_COLS = [("reference", 1), ("engine", 1), ("pallas_interpret", 1),
                ("pallas_interpret", 4)]

ITERS = 5


@pytest.mark.parametrize("backend,par_vec", BACKEND_COLS,
                         ids=lambda c: str(c))
@pytest.mark.parametrize("case", CASES, ids=[c[0] for c in CASES])
@pytest.mark.parametrize("dtype", DTYPES)
def test_conformance_matrix(case, backend, par_vec, dtype):
    _, st, dims, bc, par_time, bsize = case
    if par_vec > 1 and len(dims) != 2:
        pytest.skip("V>1 column covers the 2D cases")
    problem = StencilProblem(st, dims, dtype=dtype, boundary=bc)
    g, aux = _data(problem)
    p = plan(problem, RunConfig(backend=backend, par_time=par_time,
                                bsize=bsize,
                                par_vec=par_vec if par_vec > 1 else None))
    got = p.run(g, ITERS, aux=aux)
    assert got.dtype == problem.jnp_dtype
    want = f64_oracle_run(problem, g, ITERS, aux=aux)
    tol = precision.tolerance(dtype, ITERS, problem.n_stages)
    np.testing.assert_allclose(
        np.asarray(got).astype(np.float64), want, **tol,
        err_msg=f"{case[0]} {backend} V={par_vec} {dtype}")


def test_tolerance_budget_shape():
    """The budget is explicit and monotone: more iterations/stages widen it
    linearly, bf16's base rtol is coarser than f32's, and ``scale`` sets
    the absolute floor for far-from-1 fields."""
    t1 = precision.tolerance("float32", 1)
    t5 = precision.tolerance("float32", 5)
    assert t5["rtol"] == pytest.approx(5 * t1["rtol"])
    assert (precision.tolerance("float32", 1, stages=3)["rtol"]
            == pytest.approx(3 * t1["rtol"]))
    assert (precision.tolerance("bfloat16", 1)["rtol"]
            > precision.tolerance("float32", 1)["rtol"])
    t = precision.tolerance("bfloat16", 2, scale=100.0)
    assert t["atol"] == pytest.approx(100.0 * t["rtol"])
    # the documented bases, not fitted fudge factors
    assert precision.tolerance("float32", 1)["rtol"] == 16.0 * 2.0 ** -23
    assert precision.tolerance("bfloat16", 1)["rtol"] == 4.0 * 2.0 ** -8


# --- f32 bit-identity with the pre-bf16 code ---------------------------------
#
# The accumulation casts are emitted ONLY for sub-32-bit storage
# (precision.needs_accum_cast); f32 traces must be byte-for-byte the same
# programs as before this feature.  Digests pinned from the pre-bf16 tree
# (identical across reference/engine/pallas_interpret there and here).

def _digest(a):
    return hashlib.sha256(
        np.asarray(a, np.float32).tobytes()).hexdigest()[:16]


F32_GOLDENS = {
    "diffusion2d": "5e5aa9640930e61c",
    "hotspot2d": "dc2f4f28e1ca0bc7",
    "diffusion3d": "c7d1213aac9ca816",
}


@pytest.mark.parametrize("backend", ("reference", "engine",
                                     "pallas_interpret"))
def test_f32_bit_identical_to_seed(backend):
    key = jax.random.PRNGKey(3)
    g2 = jax.random.uniform(key, (24, 48), jnp.float32)
    aux = jax.random.uniform(jax.random.PRNGKey(4), (24, 48), jnp.float32)
    g3 = jax.random.uniform(key, (8, 16, 16), jnp.float32)
    pv = 4 if backend == "pallas_interpret" else None

    p = plan(StencilProblem("diffusion2d", (24, 48),
                            boundary=("clamp", "periodic")),
             RunConfig(backend=backend, par_time=2, bsize=16, par_vec=pv))
    assert _digest(p.run(g2, 5)) == F32_GOLDENS["diffusion2d"], backend

    p = plan(StencilProblem("hotspot2d", (24, 48),
                            boundary=("clamp", "periodic")),
             RunConfig(backend=backend, par_time=2, bsize=16, par_vec=pv))
    assert _digest(p.run(g2, 5, aux=aux)) == F32_GOLDENS["hotspot2d"], backend

    p = plan(StencilProblem("diffusion3d", (8, 16, 16)),
             RunConfig(backend=backend, par_time=1, bsize=8))
    assert _digest(p.run(g3, 5)) == F32_GOLDENS["diffusion3d"], backend


# --- bf16 is bit-identical ACROSS backends -----------------------------------
#
# Round-once-per-stage-application makes the bf16 computation the *same*
# computation in every backend: the f32 intermediate differences that could
# distinguish them are quashed by the per-stage bf16 rounding.

def test_bf16_bit_identical_across_backends():
    problem = StencilProblem("diffusion2d", (24, 48), dtype="bfloat16",
                             boundary=("clamp", "periodic"))
    g, _ = _data(problem)
    outs = {}
    for backend, pv in BACKEND_COLS:
        p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=16,
                                    par_vec=pv if pv > 1 else None))
        out = p.run(g, ITERS)
        assert out.dtype == jnp.bfloat16
        outs[f"{backend}-V{pv}"] = np.asarray(out.astype(jnp.float32))
    ref = outs["reference-V1"]
    for name, o in outs.items():
        np.testing.assert_array_equal(o, ref, err_msg=name)


@pytest.mark.parametrize("backend", ("engine", "pallas_interpret"))
def test_bf16_run_batch(backend):
    problem = StencilProblem("diffusion2d", (16, 32), dtype="bfloat16",
                             boundary=("clamp", "reflect"))
    g, _ = _data(problem)
    gs = jnp.stack([g, (g.astype(jnp.float32) * 1.1).astype(g.dtype),
                    (g.astype(jnp.float32) * 0.9).astype(g.dtype)])
    p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=16))
    ref = plan(problem, RunConfig(backend="reference"))
    got = p.run_batch(gs, 4)
    assert got.dtype == jnp.bfloat16
    want = jnp.stack([ref.run(gs[i], 4) for i in range(3)])
    np.testing.assert_array_equal(
        np.asarray(got.astype(jnp.float32)),
        np.asarray(want.astype(jnp.float32)), err_msg=backend)


# --- programs: chains and multi-field DAGs under the same policy -------------

def _chain_problem(dims, dtype):
    """Two-stage linear chain: smooth then sharpen-ish recombine."""
    return StencilProblem(
        (StencilStage("diffusion2d"),
         StencilStage(make_star(2, 1), coeffs={"c0": 0.6, "c_0_1": 0.1})),
        dims, dtype=dtype, boundary=("clamp", "periodic"))


def _wave_problem(dims, dtype):
    """Second-order wave equation: two fields, simultaneous rotation."""
    prog = StencilProgram(
        (StencilStage(make_star(2, 1), name="lapu", inputs=("u",)),
         StencilStage(make_combine(2, 3), name="unext",
                      inputs=("u", "u_prev", "lapu"),
                      coeffs={"w0": 2.0, "w1": -1.0, "w2": 0.1})),
        fields=("u", "u_prev"), updates={"u": "unext", "u_prev": "u"})
    return StencilProblem(prog, dims, dtype=dtype, boundary="clamp")


@pytest.mark.parametrize("backend", ("engine", "pallas_interpret"))
@pytest.mark.parametrize("make", (_chain_problem, _wave_problem),
                         ids=("chain", "dag"))
@pytest.mark.parametrize("dtype", DTYPES)
def test_program_conformance(make, backend, dtype):
    problem = make((16, 32), dtype)
    g, _ = _data(problem)
    p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=16))
    ref = plan(problem, RunConfig(backend="reference"))
    got = p.run(g, ITERS)
    assert got.dtype == problem.jnp_dtype
    # ulp-budget conformance against the f64 oracle...
    want = f64_oracle_run(problem, g, ITERS)
    tol = precision.tolerance(dtype, ITERS, problem.n_stages)
    np.testing.assert_allclose(np.asarray(got).astype(np.float64), want,
                               **tol, err_msg=f"{backend} {dtype}")
    # ...and (bf16) bit-identity with the reference backend
    if dtype == "bfloat16":
        np.testing.assert_array_equal(
            np.asarray(got.astype(jnp.float32)),
            np.asarray(ref.run(g, ITERS).astype(jnp.float32)),
            err_msg=backend)


# --- perf model: 16-sublane tiles, V=32 sweep, halved traffic ----------------

def test_sublanes_per_dtype():
    assert precision.sublanes_for(4) == 8
    assert precision.sublanes_for(2) == 16
    assert precision.sublanes_for(1) == 32
    assert precision.sublanes_of("float32") == 8
    assert precision.sublanes_of("bfloat16") == 16


def test_par_vec_candidates_extend_for_16bit():
    assert par_vec_candidates(4) == PAR_VEC_CANDIDATES
    assert 32 not in par_vec_candidates(4)
    assert par_vec_candidates(2) == PAR_VEC_CANDIDATES + (32,)


def test_autotune_sweeps_v32_for_bf16_only():
    st = STENCILS["diffusion2d"]
    f32 = autotune(st, (256, 512), 100, cell_bytes=4)
    b16 = autotune(st, (256, 512), 100, cell_bytes=2)
    assert f32 and b16
    assert not any(p.geom.par_vec == 32 for p in f32)
    assert any(p.geom.par_vec == 32 for p in b16)


def test_plan_autotune_bf16_candidates_include_v32():
    # V is only swept for backends that realize it (the Pallas kernels)
    cfg = RunConfig(backend="pallas_interpret", autotune="model")
    cands = plan(StencilProblem("diffusion2d", (256, 512), dtype="bfloat16"),
                 cfg).candidates
    assert any(p.geom.par_vec == 32 for p in cands)
    cands_f32 = plan(StencilProblem("diffusion2d", (256, 512)),
                     cfg).candidates
    assert cands_f32 and not any(p.geom.par_vec == 32 for p in cands_f32)


def test_bf16_halves_cell_pricing():
    """dtype-derived cell bytes: bf16 halves per-cell HBM traffic and
    shrinks the VMEM footprint; an explicit RunConfig.cell_bytes still
    overrides."""
    cfg = RunConfig()
    assert cfg.resolved_cell_bytes("float32") == 4
    assert cfg.resolved_cell_bytes("bfloat16") == 2
    assert RunConfig(cell_bytes=8).resolved_cell_bytes("bfloat16") == 8
    p32 = plan(StencilProblem("diffusion2d", (128, 256)),
               RunConfig(backend="engine", par_time=2, bsize=32))
    p16 = plan(StencilProblem("diffusion2d", (128, 256), dtype="bfloat16"),
               RunConfig(backend="engine", par_time=2, bsize=32))
    t32 = p32.traffic_report(iters=10)
    t16 = p16.traffic_report(iters=10)
    assert (t16["model_bytes_per_superstep"]
            == pytest.approx(t32["model_bytes_per_superstep"] / 2))
    assert (t16["kernel_dma_bytes_per_superstep"]
            < t32["kernel_dma_bytes_per_superstep"])
    # VMEM: thin V=1 windows pad to 16 sublanes, exactly cancelling the
    # halved cell bytes (equal footprint); once V fills the bf16 tile the
    # footprint genuinely halves
    g1 = BlockGeometry(2, (128, 256), 1, 2, (32,))
    assert g1.vmem_bytes(2, False) == g1.vmem_bytes(4, False)
    g16 = BlockGeometry(2, (128, 256), 1, 2, (32,), par_vec=16)
    assert g16.vmem_bytes(2, False) == g16.vmem_bytes(4, False) // 2


# --- cache splits ------------------------------------------------------------

def test_schedule_cache_keys_on_dtype():
    cfg = RunConfig(backend="engine", par_time=2, bsize=16)
    dev = cfg.resolved_device()
    k32 = schedule_key(StencilProblem("diffusion2d", (24, 48)),
                       cfg, dev, 1, None)
    k16 = schedule_key(StencilProblem("diffusion2d", (24, 48),
                                      dtype="bfloat16"), cfg, dev, 1, None)
    assert k32 != k16
    assert "dtype=float32" in k32 and "cb=4" in k32
    assert "dtype=bfloat16" in k16 and "cb=2" in k16


@pytest.mark.parametrize("make", (
    lambda dt: StencilProblem("diffusion2d", (16, 32), dtype=dt),
    lambda dt: _wave_problem((16, 32), dt),
), ids=("single", "dag"))
def test_exec_cache_splits_on_dtype(make):
    """One executable per dtype — a second same-dtype plan must HIT, a
    same-everything-but-dtype plan must MISS into a new entry (single-stage
    and DAG paths alike)."""
    clear_exec_cache()
    cfg = RunConfig(backend="engine", par_time=2, bsize=16)

    def run(dt):
        problem = make(dt)
        g, _ = _data(problem)
        plan(problem, cfg).run(g, 2)
        return exec_cache_stats()

    s1 = run("float32")
    assert s1["misses"] >= 1 and s1["hits"] == 0, s1
    s2 = run("float32")              # same dtype: shares the executable
    assert s2["hits"] >= 1 and s2["size"] == s1["size"], s2
    s3 = run("bfloat16")             # other dtype: new entry, no hit served
    assert s3["size"] > s2["size"], s3
    assert s3["misses"] > s2["misses"], s3
    clear_exec_cache()


# --- dtype-spec normalization ------------------------------------------------

def test_dtype_spec_normalization():
    specs = ["bfloat16", "bf16", jnp.bfloat16, np.dtype(jnp.bfloat16)]
    assert [precision.normalize_dtype(s) for s in specs] == ["bfloat16"] * 4
    assert precision.normalize_dtype(np.float32) == "float32"
    for s in specs:
        assert StencilProblem("diffusion2d", (8, 8), dtype=s).dtype \
            == "bfloat16"
    assert StencilProblem("diffusion2d", (8, 8),
                          dtype=np.dtype("float32")).dtype == "float32"


def test_request_inherits_grid_dtype():
    """A by-name request lands in the bucket of its *grid's* dtype — a bf16
    grid must never silently inherit the f32 default."""
    g16 = jnp.zeros((8, 8), jnp.bfloat16)
    g32 = jnp.zeros((8, 8), jnp.float32)
    r16 = StencilRequest("diffusion2d", g16, iters=1)
    r32 = StencilRequest("diffusion2d", g32, iters=1)
    assert r16.problem.dtype == "bfloat16"
    assert r32.problem.dtype == "float32"
    assert r16.bucket_key != r32.bucket_key


def test_pallas_supported_dtypes_documented():
    assert precision.SUPPORTED_DTYPES == ("float32", "bfloat16")
    assert precision.accum_dtype("bfloat16") == jnp.float32
    assert precision.accum_dtype("float32") == jnp.dtype("float32")
    assert precision.needs_accum_cast("bfloat16")
    assert not precision.needs_accum_cast("float32")


# --- distributed: the same policy across a 2-device mesh ---------------------

def test_distributed_precision_conformance():
    script = os.path.join(os.path.dirname(__file__),
                          "precision_distributed_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ALL OK" in out.stdout
