"""Multi-device DAG-program correctness check.

Run in a subprocess with 4 fake CPU devices (tests/test_programs.py) so the
main pytest process keeps its single-device view.  The distributed backend
sizes ONE exchange per super-step from the DAG's *critical-path* radius,
the field axis of multi-field state is never sharded, and a single
ppermute ring per sharded axis carries every field's halo at once.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import RunConfig, StencilProblem, StencilStage, plan
from repro.core.stencils import make_combine, make_star
from repro.kernels.ref import oracle_dag_run


def _wave_problem(shape, bc):
    from repro.programs import StencilProgram
    lap = make_star(2, 1)
    comb = make_combine(2, 3)
    prog = StencilProgram(
        (StencilStage(lap, name="lapu", inputs=("u",)),
         StencilStage(comb, name="unext", inputs=("u", "u_prev", "lapu"),
                      coeffs={"w0": 2.0, "w1": -1.0, "w2": 0.1})),
        fields=("u", "u_prev"),
        updates={"u": "unext", "u_prev": "u"})
    return StencilProblem(prog, shape, boundary=bc)


def _diamond_problem(shape, bc):
    from repro.programs import StencilProgram
    s1 = make_star(2, 1)
    comb = make_combine(2, 2)
    prog = StencilProgram(
        (StencilStage(s1, name="a", inputs=("u",)),
         StencilStage(s1, name="b", inputs=("u",)),
         StencilStage(comb, name="m", inputs=("a", "b"),
                      coeffs={"w0": 0.6, "w1": 0.4})))
    return StencilProblem(prog, shape, boundary=bc)


def check_dag(prob, iters, label):
    mesh = jax.make_mesh((4,), ("data",))
    state = jax.random.uniform(jax.random.PRNGKey(0), prob.state_shape,
                               jnp.float32, 0.5, 2.0)
    coeffs = prob.resolve_coeffs(dtype=jnp.float32)
    want = oracle_dag_run(prob.exec_dag, state, coeffs, iters, None)
    p = plan(prob, RunConfig(backend="distributed", mesh=mesh,
                             par_time=2, bsize=12))
    got = p.run(state, iters=iters)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    print(f"{label} ok")

    gs = jnp.stack([state, state * 0.5, state + 0.1])
    outs = p.run_batch(gs, iters=iters)
    wants = jnp.stack([oracle_dag_run(prob.exec_dag, gs[i], coeffs,
                                      iters, None) for i in range(3)])
    np.testing.assert_allclose(np.asarray(outs), np.asarray(wants),
                               rtol=3e-5, atol=3e-5)
    print(f"{label} batch ok")


if __name__ == "__main__":
    assert len(jax.devices()) == 4, jax.devices()
    check_dag(_wave_problem((32, 24), "periodic"), 5, "wave2d")
    check_dag(_wave_problem((32, 24), "clamp"), 4, "wave2d-clamp")
    check_dag(_diamond_problem((32, 24), ("clamp", "reflect")), 5, "diamond")
    print("ALL OK")
