"""Pallas kernels (interpret mode) vs. pure-jnp oracle — shape/param sweeps,
driven through the public ``plan()`` API; exact DMA-traffic accounting; and
end-to-end high-order (radius > 1) star and box neighborhoods."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RunConfig, StencilProblem, plan
from repro.core import STENCILS, default_coeffs, make_box, make_star
from repro.core.blocking import BlockGeometry
from repro.kernels.ops import dma_traffic_bytes
from repro.kernels.ref import oracle_run


def _data(stencil, dims, seed=0):
    k = jax.random.PRNGKey(seed)
    g = jax.random.uniform(k, dims, jnp.float32, 0.5, 2.0)
    aux = None
    if stencil.has_aux:
        aux = jax.random.uniform(jax.random.fold_in(k, 1), dims,
                                 jnp.float32, 0.0, 0.1)
    return g, aux


def _plan_run(st, g, c, iters, par_time, bsize, aux=None,
              backend="pallas_interpret"):
    p = plan(StencilProblem(st, tuple(g.shape)),
             RunConfig(backend=backend, par_time=par_time, bsize=bsize))
    return p.run(g, iters, c, aux=aux)


@pytest.mark.parametrize("name", ["diffusion2d", "hotspot2d"])
@pytest.mark.parametrize("dims,iters,par_time,bsize", [
    ((17, 40), 1, 1, 24),
    ((33, 70), 4, 4, 32),
    ((29, 61), 7, 4, 40),     # remainder -> PE forwarding
    ((12, 130), 6, 2, 128),   # lane-width block
    ((5, 33), 3, 2, 16),      # tiny stream extent
])
def test_pallas2d_matches_oracle(name, dims, iters, par_time, bsize):
    st = STENCILS[name]
    g, aux = _data(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, iters, aux)
    got = _plan_run(st, g, c, iters, par_time, bsize, aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", ["diffusion3d", "hotspot3d"])
@pytest.mark.parametrize("dims,iters,par_time,bsize", [
    ((7, 19, 23), 1, 1, 12),
    ((11, 25, 17), 4, 2, 12),
    ((9, 22, 30), 5, 4, 20),  # remainder
    ((4, 15, 15), 2, 2, 10),
])
def test_pallas3d_matches_oracle(name, dims, iters, par_time, bsize):
    st = STENCILS[name]
    g, aux = _data(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, iters, aux)
    got = _plan_run(st, g, c, iters, par_time, bsize, aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_backends_agree():
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (21, 45))
    c = default_coeffs(st)
    outs = [_plan_run(st, g, c, 5, 2, 24, backend=b)
            for b in ("reference", "engine", "pallas_interpret")]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=2e-5, atol=2e-5)


# --- exact DMA accounting (prefetch stops at the last real row) ---------------

@pytest.mark.parametrize("name,dims,par_time,bsize", [
    ("diffusion2d", (33, 700), 4, (256,)),
    ("hotspot3d", (11, 40, 56), 2, (16, 16)),
])
def test_dma_traffic_counts_stream_not_nticks_rows(name, dims, par_time,
                                                   bsize):
    st = STENCILS[name]
    geom = BlockGeometry(st.ndim, dims, st.radius, par_time, bsize)
    n_streams = 2 if st.has_aux else 1
    got = dma_traffic_bytes(st, geom, 4)
    reads = geom.num_blocks * geom.stream_dim * math.prod(geom.bsize)
    writes = geom.num_blocks * geom.stream_dim * math.prod(geom.csize)
    assert got == (reads * n_streams + writes) * 4
    # vs. the pre-fix schedule (nticks = stream + size_halo input DMAs per
    # block): the saving is exactly one halo's worth of rows per stream
    nticks = geom.stream_dim + geom.size_halo
    prefix_reads = geom.num_blocks * nticks * math.prod(geom.bsize)
    prefix_bytes = (prefix_reads * n_streams + writes) * 4
    assert prefix_bytes - got == (geom.size_halo * math.prod(geom.bsize)
                                  * geom.num_blocks * n_streams * 4)


def test_traffic_report_reflects_lean_schedule():
    p = plan(StencilProblem("diffusion2d", (512, 1024)),
             RunConfig(backend="engine", par_time=4, bsize=512))
    r = p.traffic_report()
    g = p.geometry
    assert r["kernel_dma_bytes_per_superstep"] == dma_traffic_bytes(
        STENCILS["diffusion2d"], g, 4)
    # the model's clipped reads can now exceed the kernel's lean reads only
    # via overlap redundancy, not via phantom drain-tick DMAs
    assert 0 < r["traffic_accuracy"] <= 1.5


@pytest.mark.parametrize("name,dims,par_time,bsize", [
    ("diffusion2d", (17, 40), 2, 24),
    ("diffusion3d", (7, 19, 23), 2, 12),
])
def test_interpret_bit_identical_to_oracle(name, dims, par_time, bsize):
    """The DMA-schedule fix must not perturb values: same arithmetic per
    cell => bit-identical interpret-mode output."""
    st = STENCILS[name]
    g, aux = _data(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, 5, aux)
    got = _plan_run(st, g, c, 5, par_time, bsize, aux)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- high-order (radius > 1) and box neighborhoods end-to-end -----------------

@pytest.mark.parametrize("st,dims,iters,par_time,bsize", [
    (make_star(2, 2), (15, 37), 5, 2, 24),    # r=2: halo 4/side per block
    (make_star(2, 3), (11, 41), 4, 1, 16),    # r=3, superstep remainder
    (make_star(3, 2), (6, 21, 19), 3, 1, 12),
    (make_box(2, 1), (13, 33), 5, 2, 16),     # diagonals exercised
    (make_box(2, 2), (12, 44), 3, 1, 14),
    (make_box(3, 1), (5, 14, 16), 4, 2, 12),
])
def test_highorder_and_box_match_oracle(st, dims, iters, par_time, bsize):
    g, _ = _data(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, iters)
    for backend in ("engine", "pallas_interpret"):
        got = _plan_run(st, g, c, iters, par_time, bsize, backend=backend)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5,
            err_msg=f"{st.name} via {backend}")


def test_box_offsets_include_diagonals():
    st = make_box(2, 1)
    assert (1, 1) in st.offsets and (-1, 1) in st.offsets
    assert len(st.offsets) == 9
    assert len(make_box(3, 1).offsets) == 27
    # star offsets stay axis-aligned, builtins included
    assert set(make_star(2, 2).offsets) == {
        (0, 0), (0, 1), (0, 2), (0, -1), (0, -2),
        (1, 0), (2, 0), (-1, 0), (-2, 0)}
    assert (1, 1) not in STENCILS["diffusion2d"].offsets
    assert len(STENCILS["hotspot3d"].offsets) == 7


def test_offsets_span_must_fit_radius():
    from repro.core.stencils import Stencil
    with pytest.raises(ValueError, match="exceeds radius"):
        Stencil("bad", 2, 1, 1, 1, 1, False, ("c",),
                lambda get, c, aux=None: get((0, 2)),
                offsets=((0, 2),))
