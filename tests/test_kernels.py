"""Pallas kernels (interpret mode) vs. pure-jnp oracle — shape/param sweeps,
driven through the public ``plan()`` API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RunConfig, StencilProblem, plan
from repro.core import STENCILS, default_coeffs
from repro.kernels.ref import oracle_run


def _data(stencil, dims, seed=0):
    k = jax.random.PRNGKey(seed)
    g = jax.random.uniform(k, dims, jnp.float32, 0.5, 2.0)
    aux = None
    if stencil.has_aux:
        aux = jax.random.uniform(jax.random.fold_in(k, 1), dims,
                                 jnp.float32, 0.0, 0.1)
    return g, aux


def _plan_run(st, g, c, iters, par_time, bsize, aux=None,
              backend="pallas_interpret"):
    p = plan(StencilProblem(st, tuple(g.shape)),
             RunConfig(backend=backend, par_time=par_time, bsize=bsize))
    return p.run(g, iters, c, aux=aux)


@pytest.mark.parametrize("name", ["diffusion2d", "hotspot2d"])
@pytest.mark.parametrize("dims,iters,par_time,bsize", [
    ((17, 40), 1, 1, 24),
    ((33, 70), 4, 4, 32),
    ((29, 61), 7, 4, 40),     # remainder -> PE forwarding
    ((12, 130), 6, 2, 128),   # lane-width block
    ((5, 33), 3, 2, 16),      # tiny stream extent
])
def test_pallas2d_matches_oracle(name, dims, iters, par_time, bsize):
    st = STENCILS[name]
    g, aux = _data(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, iters, aux)
    got = _plan_run(st, g, c, iters, par_time, bsize, aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", ["diffusion3d", "hotspot3d"])
@pytest.mark.parametrize("dims,iters,par_time,bsize", [
    ((7, 19, 23), 1, 1, 12),
    ((11, 25, 17), 4, 2, 12),
    ((9, 22, 30), 5, 4, 20),  # remainder
    ((4, 15, 15), 2, 2, 10),
])
def test_pallas3d_matches_oracle(name, dims, iters, par_time, bsize):
    st = STENCILS[name]
    g, aux = _data(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, iters, aux)
    got = _plan_run(st, g, c, iters, par_time, bsize, aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_backends_agree():
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (21, 45))
    c = default_coeffs(st)
    outs = [_plan_run(st, g, c, 5, 2, 24, backend=b)
            for b in ("reference", "engine", "pallas_interpret")]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=2e-5, atol=2e-5)
