"""Multi-device program (stage-chain) correctness check.

Run in a subprocess with 4 fake CPU devices (tests/test_programs.py) so the
main pytest process keeps its single-device view.  The distributed backend
exchanges ONE halo of width ``sum(stage radii) * par_time`` per super-step
for the whole fused chain.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import RunConfig, StencilProblem, StencilStage, plan
from repro.core.stencils import make_star
from repro.kernels.ref import oracle_program_run


def check_program():
    mesh = jax.make_mesh((4,), ("data",))
    shape = (32, 24)
    g = jax.random.uniform(jax.random.PRNGKey(0), shape, jnp.float32,
                           0.5, 2.0)
    prob = StencilProblem(
        [StencilStage(make_star(2, 1)), StencilStage("diffusion2d")],
        shape, boundary=("clamp", "periodic"))
    want = oracle_program_run(prob.exec_stages, g,
                              prob.resolve_coeffs(dtype=jnp.float32), 5)
    p = plan(prob, RunConfig(backend="distributed", mesh=mesh,
                             par_time=2, bsize=12))
    got = p.run(g, iters=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    print("program ok")

    gs = jax.random.uniform(jax.random.PRNGKey(1), (3,) + shape, jnp.float32,
                            0.5, 2.0)
    outs = p.run_batch(gs, iters=4)
    wants = jnp.stack([
        oracle_program_run(prob.exec_stages, gs[i],
                           prob.resolve_coeffs(dtype=jnp.float32), 4)
        for i in range(3)])
    np.testing.assert_allclose(np.asarray(outs), np.asarray(wants),
                               rtol=3e-5, atol=3e-5)
    print("program batch ok")


if __name__ == "__main__":
    assert len(jax.devices()) == 4, jax.devices()
    check_program()
    print("ALL OK")
