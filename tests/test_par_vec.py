"""Stream-axis vectorization (``par_vec``) acceptance surface.

The vectorized kernels must be *observationally invisible*: for every
(BC mix, rank, radius) the ``par_vec > 1`` Pallas output equals the
``par_vec = 1`` output bit for bit and matches the reference oracle — for
divisible and non-divisible stream extents, through ``run`` and
``run_batch`` alike.  The single documented exception: when an axis is
periodic the compiled programs for different V may contract FMAs
differently (XLA codegen, not semantics — the seed kernel already differed
from the engine at the same ±1-ulp level there), so periodic mixes assert
ulp-tight closeness instead of bitwise equality.

Also covered: the executable- and schedule-cache keys split on ``par_vec``
(a V=8 program/winner must never serve a V=1 plan), pre-``par_vec`` cache
entries default to V=1, ``vmem_bytes`` accounts Mosaic's 8-sublane padding
(the satellite undercount fix), the perf model prices and sweeps V, the
exact DMA accounting bills slab padding, and the opt-in Megacore grid
(``RunConfig.block_parallel``) is bit-identical to the sequential grid.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (RunConfig, StencilProblem, clear_exec_cache,
                       exec_cache_stats, plan)
from repro.api import schedule_cache
from repro.core import STENCILS, default_coeffs, make_star
from repro.core.blocking import BlockGeometry, SUBLANE
from repro.core.perf_model import (PAR_VEC_CANDIDATES, TPU_V5E, autotune,
                                   predict)
from repro.kernels.ops import dma_traffic_bytes
from repro.kernels.ref import oracle_run


def _data(stencil, dims, seed=0):
    k = jax.random.PRNGKey(seed)
    g = jax.random.uniform(k, dims, jnp.float32, 0.5, 2.0)
    aux = None
    if stencil.has_aux:
        aux = jax.random.uniform(jax.random.fold_in(k, 1), dims,
                                 jnp.float32, 0.0, 0.1)
    return g, aux


def _run(st, g, c, iters, par_time, bsize, par_vec, aux=None, bc="clamp",
         **cfg):
    p = plan(StencilProblem(st, tuple(g.shape), boundary=bc),
             RunConfig(backend="pallas_interpret", par_time=par_time,
                       bsize=bsize, par_vec=par_vec, **cfg))
    assert p.geometry.par_vec == par_vec
    return p.run(g, iters, c, aux=aux)


def _assert_v_equal(got, want_v1, bc_mix, msg):
    """Bitwise V-identity, except periodic mixes: different-V programs may
    contract FMAs differently there (±1 ulp; pre-existing between the seed
    kernel and the engine too), so assert ulp-tight closeness instead."""
    if "periodic" in bc_mix:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want_v1),
                                   rtol=1e-6, atol=1e-6, err_msg=msg)
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want_v1),
                                      err_msg=msg)


# --- conformance: par_vec x BC x rank x radius (acceptance criterion) --------

CASES = [
    # (stencil, dims, par_time, bsize) — dims deliberately not multiples of
    # any swept V (non-divisible stream extents are the common case)
    ("diffusion2d", (19, 40), 2, 24),
    ("hotspot2d", (13, 33), 2, 16),
    ("diffusion3d", (7, 15, 17), 2, 10),
    ("hotspot3d", (6, 13, 15), 2, 10),
]
BCS = ["clamp", "periodic", "reflect", "constant:0.25"]


@pytest.mark.parametrize("name,dims,par_time,bsize", CASES)
@pytest.mark.parametrize("bc", BCS)
def test_par_vec_matches_v1_and_oracle(name, dims, par_time, bsize, bc):
    st = STENCILS[name]
    g, aux = _data(st, dims)
    c = default_coeffs(st)
    iters = 5
    want = oracle_run(st, g, c, iters, aux,
                      bc=StencilProblem(st, dims, boundary=bc).bc)
    v1 = _run(name, g, c, iters, par_time, bsize, 1, aux, bc)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(want),
                               rtol=3e-5, atol=3e-5, err_msg=f"V=1 bc={bc}")
    for V in (4, 8):
        got = _run(name, g, c, iters, par_time, bsize, V, aux, bc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5,
                                   err_msg=f"V={V} bc={bc} vs oracle")
        _assert_v_equal(got, v1, bc, f"{name} V={V} vs V=1 bc={bc}")


@pytest.mark.parametrize("st,dims,par_time,bsize,bc", [
    (make_star(2, 2), (15, 37), 2, 24, "clamp"),     # rad=2: slab_lag math
    (make_star(2, 2), (15, 37), 2, 24, "reflect"),
    (make_star(3, 2), (6, 17, 15), 1, 12, "clamp"),
    (make_star(3, 2), (6, 17, 15), 1, 12, "periodic"),
])
def test_par_vec_high_order(st, dims, par_time, bsize, bc):
    g, _ = _data(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, 4, bc=StencilProblem(st, dims, boundary=bc).bc)
    v1 = _run(st, g, c, 4, par_time, bsize, 1, bc=bc)
    for V in (3, 4):                      # V > rad and V close to rad
        got = _run(st, g, c, 4, par_time, bsize, V, bc=bc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5,
                                   err_msg=f"{st.name} V={V} bc={bc}")
        _assert_v_equal(got, v1, bc, f"{st.name} V={V} vs V=1 bc={bc}")


def test_par_vec_exceeding_stream_extent():
    """V larger than the whole stream: one slab, mostly pad — still exact."""
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (5, 33))
    c = default_coeffs(st)
    want = oracle_run(st, g, c, 3)
    for V in (8, 16):
        got = _run("diffusion2d", g, c, 3, 2, 16, V)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"V={V} > ny=5")


def test_par_vec_run_batch_matches_sequential():
    st = STENCILS["hotspot2d"]
    g, aux = _data(st, (13, 33))
    c = default_coeffs(st)
    grids = jnp.stack([g + 0.01 * b for b in range(3)])
    p = plan(StencilProblem("hotspot2d", (13, 33)),
             RunConfig(backend="pallas_interpret", par_time=2, bsize=16,
                       par_vec=8))
    got = p.run_batch(grids, 4, c, aux=aux)
    want = jnp.stack([p.run(grids[b], 4, c, aux=aux) for b in range(3)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- cache keys split on par_vec (acceptance criterion) -----------------------

def test_exec_cache_splits_on_par_vec():
    clear_exec_cache()
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (16, 32))
    c = default_coeffs(st)

    def cfg(V):
        return RunConfig(backend="pallas_interpret", par_time=2, bsize=16,
                         par_vec=V)

    plan(StencilProblem(st, (16, 32)), cfg(1)).run(g, 2, c)
    plan(StencilProblem(st, (16, 32)), cfg(8)).run(g, 2, c)
    stats = exec_cache_stats()
    assert stats["misses"] >= 2 and stats["hits"] == 0, stats
    # same V shares the compiled program
    plan(StencilProblem(st, (16, 32)), cfg(8)).run(g, 3, c)
    assert exec_cache_stats()["hits"] >= 1


def test_schedule_cache_key_pins_par_vec():
    problem = StencilProblem("diffusion2d", (64, 512))
    dev = RunConfig().resolved_device()

    def key(V):
        return schedule_cache.schedule_key(
            problem, RunConfig(backend="engine", autotune="measure",
                               par_time=2, bsize=256, par_vec=V),
            dev, 1, None)

    assert key(None) != key(1) != key(8)


def test_measured_winner_roundtrips_par_vec(tmp_path):
    cfg = RunConfig(backend="engine", autotune="measure",
                    cache=str(tmp_path / "s.json"), par_time=2, bsize=256,
                    tune_top_k=2, tune_warmup=0, tune_repeats=1)
    problem = StencilProblem("diffusion2d", (64, 512))
    p1 = plan(problem, cfg)
    assert not p1.tuned_from_cache
    p2 = plan(problem, cfg)
    assert p2.tuned_from_cache
    assert p2.geometry == p1.geometry           # par_vec included
    assert p2.geometry.par_vec == p1.candidates[0].geom.par_vec


def test_pre_par_vec_cache_entry_defaults_to_v1(tmp_path):
    """Entries written before the par_vec field (or hand-edited without it)
    must be served as V=1, not rejected."""
    cfg = RunConfig(backend="engine", autotune="measure",
                    cache=str(tmp_path / "s.json"), par_time=2, bsize=256,
                    tune_top_k=1, tune_warmup=0, tune_repeats=1)
    problem = StencilProblem("diffusion2d", (64, 512))
    cache = schedule_cache.ScheduleCache(str(tmp_path / "s.json"))
    key = schedule_cache.schedule_key(problem, cfg, cfg.resolved_device(),
                                      1, None)
    cache.put(key, {"par_time": 2, "bsize": [256], "measured_s": 0.01,
                    "model_accuracy": 1.0})     # no "par_vec"
    p = plan(problem, cfg)
    assert p.tuned_from_cache
    assert p.geometry.par_vec == 1


# --- satellite: opt-in Megacore grid ------------------------------------------

@pytest.mark.parametrize("name,dims,par_time,bsize", [
    ("diffusion2d", (19, 70), 2, 24),      # several blocks in x
    ("diffusion3d", (7, 19, 21), 2, 10),   # 2-D grid of blocks
])
def test_block_parallel_bit_identical(name, dims, par_time, bsize):
    st = STENCILS[name]
    g, aux = _data(st, dims)
    c = default_coeffs(st)
    outs = {}
    for mc in (False, True):
        p = plan(StencilProblem(name, dims),
                 RunConfig(backend="pallas_interpret", par_time=par_time,
                           bsize=bsize, par_vec=4, block_parallel=mc))
        outs[mc] = p.run(g, 5, c, aux=aux)
    np.testing.assert_array_equal(np.asarray(outs[True]),
                                  np.asarray(outs[False]))


def test_block_parallel_splits_exec_cache():
    clear_exec_cache()
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (16, 32))
    c = default_coeffs(st)
    for mc in (False, True):
        plan(StencilProblem(st, (16, 32)),
             RunConfig(backend="pallas_interpret", par_time=2, bsize=16,
                       block_parallel=mc)).run(g, 2, c)
    stats = exec_cache_stats()
    assert stats["misses"] >= 2 and stats["hits"] == 0, stats


# --- satellite: vmem_bytes accounts Mosaic sublane padding --------------------

def _pad8(n):
    return -(-n // SUBLANE) * SUBLANE


def test_vmem_bytes_accounts_sublane_padding_2d():
    geom = BlockGeometry(2, (64, 512), 1, 2, (256,))    # V=1, W=3 slots
    # window slots, stream buffers and output buffers all round up to 8
    # sublanes: the documented (and previously uncounted) Mosaic padding
    want = 4 * (2 * _pad8(3) * 256          # T * pad8(W*V) * BX
                + 2 * _pad8(1) * 256        # input double buffer
                + 2 * _pad8(1) * 252)       # output double buffer (CS=252)
    assert geom.vmem_bytes(4, False) == want
    # the old unpadded accounting undercounted by >4x here
    naive = 4 * (2 * 3 * 256 + 2 * 256 + 2 * 252)
    assert geom.vmem_bytes(4, False) > 4 * naive
    # V=8 packs the window slots tight: 24 real rows in 24 sublanes
    g8 = BlockGeometry(2, (64, 512), 1, 2, (256,), par_vec=8)
    want8 = 4 * (2 * _pad8(3 * 8) * 256 + 2 * _pad8(8) * 256
                 + 2 * _pad8(8) * 252)
    assert g8.vmem_bytes(4, False) == want8
    # aux = window (slab_lag*T+1 slabs, sublane-padded as one buffer) PLUS
    # its own DMA landing double buffer — the kernels allocate both
    ga = BlockGeometry(2, (64, 512), 1, 2, (256,))
    aux_rows = _pad8((1 * 2 + 1) * 1)
    assert ga.vmem_bytes(4, True) - ga.vmem_bytes(4, False) \
        == 4 * (aux_rows * 256 + 2 * _pad8(1) * 256)


def test_vmem_bytes_accounts_sublane_padding_3d():
    geom = BlockGeometry(3, (16, 40, 40), 1, 2, (10, 12))  # BY=10 -> pad 16
    plane = _pad8(10) * 12
    want = 4 * (2 * 3 * 1 * plane           # T * W * V * pad8(BY) * BX
                + 2 * 1 * plane
                + 2 * 1 * _pad8(6) * 8)     # out: CSY=6 -> 8 sublanes, CSX=8
    assert geom.vmem_bytes(4, False) == want


def test_vmem_feasibility_filter_uses_padded_footprint():
    """A candidate that only fits VMEM when the 8-sublane padding is ignored
    must be filtered out by autotune."""
    st = STENCILS["diffusion2d"]
    geom = BlockGeometry(2, (1 << 14, 1 << 14), 1, 64, (1 << 14,))
    need = geom.vmem_bytes(4, st.has_aux)
    tight = TPU_V5E.scaled(vmem_budget=need - 1)
    cands = autotune(st, (1 << 14, 1 << 14), 64, tight,
                     par_time=64, bsize=(1 << 14,), par_vec=1)
    assert not cands, "padded footprint must trip the feasibility filter"
    roomy = TPU_V5E.scaled(vmem_budget=need)
    ok = autotune(st, (1 << 14, 1 << 14), 64, roomy,
                  par_time=64, bsize=(1 << 14,), par_vec=1)
    assert len(ok) == 1 and ok[0].vmem_bytes == need


# --- perf model: par_vec is priced and swept ----------------------------------

def test_predict_prices_par_vec():
    st = STENCILS["diffusion2d"]
    p1 = predict(st, (2048, 2048), 100, (512,), 4, TPU_V5E)
    p8 = predict(st, (2048, 2048), 100, (512,), 4, TPU_V5E, par_vec=8)
    # V amortizes both the per-descriptor DMA cost and the 2D sublane waste
    assert p8.t_mem < p1.t_mem
    assert p8.t_compute < p1.t_compute
    assert p8.run_time < p1.run_time
    assert "par_vec=8" in p8.describe()
    # idealized bytes are unchanged: the gain is ticks/descriptors, not bytes
    assert p8.geom.par_vec == 8
    # 3D: the sublane dim is bsize_y, so V only moves the DMA term
    st3 = STENCILS["diffusion3d"]
    q1 = predict(st3, (64, 128, 128), 100, (32, 32), 2, TPU_V5E)
    q8 = predict(st3, (64, 128, 128), 100, (32, 32), 2, TPU_V5E, par_vec=8)
    assert q8.t_compute == pytest.approx(q1.t_compute)
    assert q8.t_mem < q1.t_mem


def test_autotune_sweeps_par_vec():
    st = STENCILS["diffusion2d"]
    cands = autotune(st, (2048, 2048), 100)
    assert {c.geom.par_vec for c in cands} >= {1, 8}, \
        "default sweep must cover PAR_VEC_CANDIDATES"
    assert cands[0].geom.par_vec > 1, \
        "the model must prefer a vectorized schedule on a 2D grid"
    pinned = autotune(st, (2048, 2048), 100, par_vec=2)
    assert pinned and all(c.geom.par_vec == 2 for c in pinned)
    assert set(PAR_VEC_CANDIDATES) >= {1, 8}


def test_par_vec_swept_only_for_pallas_backends():
    """Scalar-tick backends (engine/reference/distributed) cannot realize V:
    sweeping it there would distort the (bsize, par_time) ranking and fill
    measured shortlists with V-duplicates — an unpinned V stays 1."""
    prob = StencilProblem("diffusion2d", (2048, 2048))
    eng = plan(prob, RunConfig(backend="engine", autotune=True))
    assert eng.geometry.par_vec == 1
    assert all(c.geom.par_vec == 1 for c in eng.candidates)
    pal = plan(prob, RunConfig(backend="pallas_interpret", autotune=True))
    assert pal.geometry.par_vec > 1


def test_plan_autotune_respects_pinned_par_vec():
    p = plan(StencilProblem("diffusion2d", (2048, 2048)),
             RunConfig(backend="pallas_interpret", autotune=True, par_vec=2))
    assert p.geometry.par_vec == 2
    assert "par_vec=2" in p.describe()
    assert p.traffic_report()["par_vec"] == 2


def test_scalar_backend_rejects_pinned_par_vec():
    """engine/distributed execute scalar ticks: a pinned V>1 would be a
    silently misreported no-op, so plan() refuses it; the reference oracle
    keeps its legacy degrade-to-geometry-less semantics."""
    with pytest.raises(ValueError, match="par_vec"):
        plan(StencilProblem("diffusion2d", (64, 128)),
             RunConfig(backend="engine", par_time=2, bsize=32, par_vec=8))
    p = plan(StencilProblem("diffusion2d", (64, 128)),
             RunConfig(backend="reference", par_time=2, bsize=32, par_vec=8))
    assert p.geometry is None


def test_config_rejects_bad_par_vec():
    with pytest.raises(ValueError, match="par_vec"):
        RunConfig(par_vec=0)
    with pytest.raises(ValueError, match="par_vec"):
        BlockGeometry(2, (16, 32), 1, 1, (16,), par_vec=0)


# --- exact DMA accounting bills the slab pad ----------------------------------

def test_dma_traffic_bills_slab_padding():
    st = STENCILS["diffusion2d"]
    g1 = BlockGeometry(2, (13, 40), 1, 2, (16,), par_vec=1)
    g8 = dataclasses.replace(g1, par_vec=8)
    b1 = dma_traffic_bytes(st, g1, 4)
    b8 = dma_traffic_bytes(st, g8, 4)
    # V=8 streams ceil(13/8)*8 = 16 rows where V=1 streams 13: 3 pad rows
    # billed per block, each bsize wide in and csize wide out
    blocks = g1.num_blocks
    assert b8 - b1 == blocks * 3 * (16 + 12) * 4
    # divisible stream: identical traffic
    gd1 = BlockGeometry(2, (16, 40), 1, 2, (16,), par_vec=1)
    gd8 = dataclasses.replace(gd1, par_vec=8)
    assert dma_traffic_bytes(st, gd1, 4) == dma_traffic_bytes(st, gd8, 4)
