"""The public plan/execute API: StencilProblem -> plan() -> StencilPlan.

Covers the acceptance surface of the API redesign: cross-backend equivalence
through one ``plan()`` call, plan reuse across iteration counts, perf-model
autotuning under the VMEM budget, the ``stencil_run`` deprecation shim, the
backend registry, and the small-grid autotune regression.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (RunConfig, StencilPlan, StencilProblem, get_backend,
                       list_backends, plan, register_backend)
from repro.core import STENCILS, default_coeffs
from repro.core.blocking import bsize_feasible, choose_bsize_candidates
from repro.core.perf_model import TPU_V5E, autotune
from repro.kernels.ref import oracle_run


def _data(stencil, dims, seed=0):
    k = jax.random.PRNGKey(seed)
    g = jax.random.uniform(k, dims, jnp.float32, 0.5, 2.0)
    aux = None
    if stencil.has_aux:
        aux = jax.random.uniform(jax.random.fold_in(k, 1), dims,
                                 jnp.float32, 0.0, 0.1)
    return g, aux


# --- cross-backend equivalence (acceptance criterion) -------------------------

@pytest.mark.parametrize("name,dims,par_time,bsize", [
    ("diffusion2d", (23, 49), 2, 24),
    ("hotspot3d", (7, 19, 17), 2, 12),
])
def test_plan_roundtrip_across_backends(name, dims, par_time, bsize):
    st = STENCILS[name]
    g, aux = _data(st, dims)
    c = default_coeffs(st)
    problem = StencilProblem(name, dims)
    cfg = RunConfig(par_time=par_time, bsize=bsize)
    outs = {}
    for backend in ("reference", "engine", "pallas_interpret"):
        p = plan(problem, dataclasses.replace(cfg, backend=backend))
        assert isinstance(p, StencilPlan)
        outs[backend] = p.run(g, 5, c, aux=aux)
    for backend in ("engine", "pallas_interpret"):
        np.testing.assert_allclose(np.asarray(outs[backend]),
                                   np.asarray(outs["reference"]),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", ["star1d_r1", "star1d_r2"])
@pytest.mark.parametrize("bc", ["clamp", "periodic", "reflect"])
def test_1d_plan_roundtrip_across_backends(name, bc):
    """Satellite: 1D problems (stream axis only, no blocked dims) plan and
    run on every local backend, matching the oracle."""
    st = STENCILS[name]
    dims = (97,)
    g, _ = _data(st, dims)
    problem = StencilProblem(name, dims, boundary=bc)
    want = oracle_run(st, g, default_coeffs(st), 5, bc=problem.bc)
    for backend in ("reference", "engine", "pallas_interpret"):
        p = plan(problem, RunConfig(backend=backend, par_time=2))
        np.testing.assert_allclose(np.asarray(p.run(g, 5)),
                                   np.asarray(want), rtol=2e-5, atol=2e-5)


def test_1d_autotune_and_batch():
    """1D geometry candidates are the trivial `()` bsize; autotune still
    ranks par_time/par_vec and run_batch round-trips."""
    problem = StencilProblem("star1d_r1", (128,))
    assert choose_bsize_candidates(1, problem.shape) == [()]
    p = plan(problem, RunConfig(backend="pallas_interpret", autotune=True))
    assert p.geometry is not None and p.geometry.ndim == 1
    g, _ = _data(STENCILS["star1d_r1"], (128,))
    gs = jnp.stack([g, g * 0.5])
    want = jnp.stack([oracle_run(STENCILS["star1d_r1"], gs[i],
                                 default_coeffs(STENCILS["star1d_r1"]), 3)
                      for i in range(2)])
    np.testing.assert_allclose(np.asarray(p.run_batch(gs, 3)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


def test_distributed_plan_single_device_mesh_matches_engine():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("x",))
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (24, 40))
    c = default_coeffs(st)
    problem = StencilProblem("diffusion2d", (24, 40))
    cfg = RunConfig(backend="distributed", par_time=2, bsize=24, mesh=mesh)
    dist = plan(problem, cfg).run(g, 5, c)
    eng = plan(problem, RunConfig(backend="engine", par_time=2, bsize=24)
               ).run(g, 5, c)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(eng),
                               rtol=2e-5, atol=2e-5)


# --- plan reuse ---------------------------------------------------------------

def test_plan_reuse_across_iters():
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (19, 37))
    c = default_coeffs(st)
    p = plan(StencilProblem("diffusion2d", (19, 37)),
             RunConfig(backend="engine", par_time=2, bsize=24))
    for iters in (1, 3, 4, 9):
        want = oracle_run(st, g, c, iters)
        got = p.run(g, iters, c)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
    # iters=0 is the identity
    np.testing.assert_array_equal(np.asarray(p.run(g, 0, c)), np.asarray(g))


# --- autotune -----------------------------------------------------------------

def test_autotune_selects_vmem_feasible_config():
    p = plan(StencilProblem("diffusion2d", (2048, 2048)),
             RunConfig(backend="engine", autotune=True))
    geom = p.geometry
    assert geom is not None
    assert min(geom.csize) > 0
    st = STENCILS["diffusion2d"]
    assert geom.vmem_bytes(4, st.has_aux) <= TPU_V5E.vmem_budget
    # the plan can introspect itself without running
    pred = p.predicted(100)
    assert pred.run_time > 0
    report = p.traffic_report(iters=100)
    assert report["traffic_accuracy"] > 0
    assert "bsize" in p.describe() or "schedule" in p.describe()


def test_autotune_respects_pinned_par_time():
    p = plan(StencilProblem("diffusion2d", (2048, 2048)),
             RunConfig(backend="engine", par_time=4, autotune=True))
    assert p.geometry.par_time == 4


def test_autotune_exposes_ranked_candidates():
    p = plan(StencilProblem("diffusion2d", (2048, 2048)),
             RunConfig(backend="engine", autotune=True))
    assert len(p.candidates) >= 2
    runtimes = [c.run_time for c in p.candidates]
    assert runtimes == sorted(runtimes)
    assert p.candidates[0].geom.bsize == p.geometry.bsize
    assert p.candidates[0].geom.par_time == p.geometry.par_time
    # pinned schedule -> nothing was swept
    pinned = plan(StencilProblem("diffusion2d", (2048, 2048)),
                  RunConfig(backend="engine", par_time=2, bsize=256))
    assert pinned.candidates == ()


def test_reference_plan_tolerates_unresolvable_schedule():
    """The oracle ignores blocking: an infeasible schedule degrades the plan
    to geometry-less instead of raising (legacy stencil_run semantics)."""
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (32, 48))
    c = default_coeffs(st)
    # par_time=128 on a 48-wide grid: no feasible bsize exists
    p = plan(StencilProblem("diffusion2d", (32, 48)),
             RunConfig(backend="reference", par_time=128))
    assert p.geometry is None
    got = p.run(g, 3, c)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(oracle_run(st, g, c, 3)))
    with pytest.raises(ValueError, match="needs a block geometry"):
        p.predicted()


def test_distributed_axis_map_accepts_bare_string_names():
    """A multi-char axis name given as a bare string is one axis, not a
    sequence of single-character names."""
    cfg = RunConfig(backend="distributed", axis_map=("data", None))
    assert cfg.axis_map == (("data",), None)


class _FakeMesh:
    """Mesh stand-in: plan-time checks only touch axis_names/devices.shape,
    so an indivisible multi-chip layout is testable on one real device."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)


def test_distributed_plan_rejects_indivisible_grid_at_plan_time():
    """Satellite bugfix: the divisibility error must fire at plan() — not
    deep inside build_distributed_fn at the first run()."""
    mesh = _FakeMesh((3,), ("x",))
    with pytest.raises(ValueError, match="not divisible"):
        plan(StencilProblem("diffusion2d", (25, 40)),
             RunConfig(backend="distributed", par_time=2, bsize=24,
                       mesh=mesh))
    # divisible grids still plan fine (execution is deferred)
    p = plan(StencilProblem("diffusion2d", (24, 40)),
             RunConfig(backend="distributed", par_time=2, bsize=24,
                       mesh=mesh))
    assert p.n_chips == 3


def test_predict_halo_follows_chip_grid():
    """Satellite bugfix: t_halo must price the face perpendicular to each
    sharded axis, not always the streaming-axis cross-section."""
    from repro.core.perf_model import TPU_V5E, predict
    st = STENCILS["diffusion2d"]
    dims, bsize, pt = (100, 512), (256,), 4
    h = st.radius * pt
    # shard the *blocked* axis: local dims (100, 256); exchanged strips have
    # cross-section 100 (the streaming extent), width h, both directions
    p = predict(st, dims, 64, bsize, pt, TPU_V5E, 4, n_chips=2,
                chip_grid=(1, 2))
    want = 2 * (h * 100) * 4 * st.num_read / TPU_V5E.ici_bw
    assert p.t_halo == pytest.approx(want)
    # streaming-axis sharding keeps the legacy form: cross-section 512
    p0 = predict(st, dims, 64, bsize, pt, TPU_V5E, 4, n_chips=2,
                 chip_grid=(2, 1))
    want0 = 2 * (h * 512) * 4 * st.num_read / TPU_V5E.ici_bw
    assert p0.t_halo == pytest.approx(want0)
    # a 2x2 grid on a 3D problem sums one face per sharded axis
    st3 = STENCILS["diffusion3d"]
    p3 = predict(st3, (64, 64, 64), 64, (16, 16), 2, TPU_V5E, 4, n_chips=4,
                 chip_grid=(1, 2, 2))
    h3 = st3.radius * 2
    faces = 64 * 32 + 64 * 32          # perp. to y and to x, local (64,32,32)
    assert p3.t_halo == pytest.approx(2 * h3 * faces * 4 * st3.num_read
                                      / TPU_V5E.ici_bw)


# --- deprecation shim ---------------------------------------------------------

def test_stencil_run_shim_warns_and_matches():
    from repro.kernels.ops import stencil_run
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (21, 45))
    c = default_coeffs(st)
    p = plan(StencilProblem("diffusion2d", (21, 45)),
             RunConfig(backend="engine", par_time=2, bsize=24))
    want = p.run(g, 5, c)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got = stencil_run(st, g, c, 5, 2, 24, backend="engine")
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_stencil_run_shim_preserves_dtype():
    """Legacy stencil_run was dtype-generic; the shim must not coerce."""
    from repro.kernels.ops import stencil_run
    st = STENCILS["diffusion2d"]
    g = jnp.ones((12, 20), jnp.bfloat16)
    c = {k: jnp.asarray(v, jnp.bfloat16)
         for k, v in default_coeffs(st).items()}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        got = stencil_run(st, g, c, 2, 1, 8, backend="engine")
    assert got.dtype == jnp.bfloat16


def test_stencil_run_shim_reference_ignores_bad_geometry():
    """Legacy behavior: the oracle path never validated (par_time, bsize)."""
    from repro.kernels.ops import stencil_run
    st = STENCILS["diffusion2d"]
    g, _ = _data(st, (12, 20))
    c = default_coeffs(st)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        got = stencil_run(st, g, c, 3, 16, 8, backend="reference")
    want = oracle_run(st, g, c, 3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- backend registry ---------------------------------------------------------

def test_registry_lists_builtins():
    have = list_backends()
    for name in ("reference", "engine", "pallas", "pallas_interpret",
                 "distributed"):
        assert name in have


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        plan(StencilProblem("diffusion2d", (16, 16)),
             RunConfig(backend="no_such_backend", par_time=1, bsize=8))


def test_register_custom_backend():
    calls = []

    def doubling_oracle(problem, config, geom):
        def execute(grid, coeffs, iters, aux=None):
            calls.append(iters)
            return oracle_run(problem.stencil, grid, coeffs, iters, aux)
        return execute

    register_backend("test_custom", doubling_oracle)
    try:
        assert get_backend("test_custom") is doubling_oracle
        with pytest.raises(ValueError, match="already registered"):
            register_backend("test_custom", doubling_oracle)
        st = STENCILS["diffusion2d"]
        g, _ = _data(st, (11, 17))
        c = default_coeffs(st)
        p = plan(StencilProblem("diffusion2d", (11, 17)),
                 RunConfig(backend="test_custom", par_time=1, bsize=8))
        got = p.run(g, 2, c)
        assert calls == [2]
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(oracle_run(st, g, c, 2)))
    finally:
        from repro.api import backends
        backends._REGISTRY.pop("test_custom", None)


# --- problem/config validation ------------------------------------------------

def test_problem_validation():
    with pytest.raises(ValueError, match="unknown stencil"):
        StencilProblem("nope", (8, 8))
    with pytest.raises(ValueError, match="2D but shape"):
        StencilProblem("diffusion2d", (8, 8, 8))
    with pytest.raises(ValueError, match="boundary"):
        StencilProblem("diffusion2d", (8, 8), boundary="bogus")
    # periodic (and friends) are first-class now — see
    # tests/test_boundary_conditions.py for the conformance matrix
    assert StencilProblem("diffusion2d", (8, 8),
                          boundary="periodic").bc.token() == "periodic"
    with pytest.raises(ValueError, match="aux"):
        StencilProblem("diffusion2d", (8, 8), aux=True)


def test_run_validates_inputs():
    p = plan(StencilProblem("hotspot2d", (16, 24)),
             RunConfig(backend="engine", par_time=1, bsize=8))
    g, aux = _data(STENCILS["hotspot2d"], (16, 24))
    with pytest.raises(ValueError, match="needs an aux"):
        p.run(g, 2)
    with pytest.raises(ValueError, match="grid shape"):
        p.run(g[:-1], 2, aux=aux)
    with pytest.raises(ValueError, match="aux shape"):
        p.run(g, 2, aux=aux[:-1])


# --- small-grid autotune regression (satellite) -------------------------------

def test_candidates_small_grid_high_par_time():
    """256-wide 2D grid at high par_time: infeasible candidates are dropped
    instead of raising inside BlockGeometry (csize would be <= 0)."""
    # the only raw 2D candidate for a 256-wide grid is bsize=(256,)
    assert choose_bsize_candidates(2, (256, 256)) == [(256,)]
    # at par_time=128 its halo (128) swallows the block: csize <= 0
    assert not bsize_feasible(1, 128, (256,))
    assert choose_bsize_candidates(2, (256, 256), rad=1, par_time=128) == []
    # autotune sweeps high par_time without ever building a bad geometry
    cands = autotune(STENCILS["diffusion2d"], (256, 256), 64,
                     par_time_max=512)
    assert cands, "feasible low-par_time configs must survive"
    for pred in cands:
        assert min(pred.geom.csize) > 0
    # and plan(autotune=True) on the small grid picks one of them
    p = plan(StencilProblem("diffusion2d", (256, 256)),
             RunConfig(backend="engine", autotune=True, par_time_max=512))
    assert min(p.geometry.csize) > 0


def test_plan_errors_clearly_when_nothing_feasible():
    with pytest.raises(ValueError, match="no VMEM-feasible"):
        plan(StencilProblem("diffusion2d", (256, 256)),
             RunConfig(backend="engine", autotune=True, par_time=128))
