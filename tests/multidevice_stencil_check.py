"""Multi-device distributed-stencil correctness check.

Run in a subprocess with 8 fake CPU devices (tests/test_distributed.py) so
the main pytest process keeps its single-device view.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import STENCILS, default_coeffs
from repro.core.distributed import distributed_run
from repro.kernels.ref import oracle_run


def check_2d():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    st = STENCILS["diffusion2d"]
    dims = (32, 64)
    g = jax.random.uniform(jax.random.PRNGKey(0), dims, jnp.float32, 0.5, 2.0)
    c = default_coeffs(st)
    for iters, pt in [(1, 1), (4, 2), (5, 2)]:
        want = oracle_run(st, g, c, iters)
        got = distributed_run(st, g, c, iters, pt, (24,), mesh,
                              (("data",), ("model",)))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)
    print("2d ok")


def check_2d_joint_axes():
    """Grid axis sharded over a *tuple* of mesh axes (pod+data pattern)."""
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    st = STENCILS["hotspot2d"]
    dims = (32, 48)
    k = jax.random.PRNGKey(1)
    g = jax.random.uniform(k, dims, jnp.float32, 0.5, 2.0)
    aux = jax.random.uniform(jax.random.fold_in(k, 1), dims, jnp.float32,
                             0.0, 0.1)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, 4, aux)
    got = distributed_run(st, g, c, 4, 2, (16,), mesh,
                          (("pod", "data"), ("model",)), aux=aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    print("2d joint-axes ok")


def check_3d():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    st = STENCILS["diffusion3d"]
    dims = (16, 24, 24)
    g = jax.random.uniform(jax.random.PRNGKey(2), dims, jnp.float32, 0.5, 2.0)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, 4)
    got = distributed_run(st, g, c, 4, 2, (12, 12), mesh,
                          (("pod",), ("data",), ("model",)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    print("3d ok")


if __name__ == "__main__":
    assert len(jax.devices()) == 8, jax.devices()
    check_2d()
    check_2d_joint_axes()
    check_3d()
    print("ALL OK")
