"""Different-mesh restore check (run in a subprocess with 2 fake devices).

The parent process saved a checkpoint from its single-device world; this
process restores it onto a 2-device mesh sharding and asserts the logical
values are bit-identical — the elastic-restart contract: checkpoints are
saved in full and re-shard transparently onto whatever mesh the restart
has.

Usage: checkpoint_mesh_check.py <checkpoint_dir> <step>
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import restore_latest_valid, restore_pytree


def main():
    ckdir, step = sys.argv[1], int(sys.argv[2])
    assert jax.device_count() == 2, jax.devices()
    ref = np.load(os.path.join(ckdir, "expected.npy"))
    template = {"grid": np.zeros_like(ref)}
    mesh = jax.make_mesh((2,), ("d",))
    shardings = {"grid": NamedSharding(mesh, P("d"))}    # shard axis 0

    restored = restore_pytree(template, ckdir, step, shardings=shardings)
    got = restored["grid"]
    assert len(got.sharding.device_set) == 2, got.sharding
    assert np.asarray(got).tobytes() == ref.tobytes(), \
        "restore onto 2-device mesh is not bit-identical"

    # the resume path's entry point re-shards the same way
    latest, got_step = restore_latest_valid(template, ckdir,
                                            shardings=shardings)
    assert got_step == step
    assert np.asarray(latest["grid"]).tobytes() == ref.tobytes()
    print("ALL OK")


if __name__ == "__main__":
    main()
