"""Multi-stage ``StencilProgram`` conformance + cache-key hygiene.

The contract under test: a program (ordered chain of stages, each with its
own coefficients and boundary condition) planned on ANY backend computes
exactly what the sequential per-stage oracle computes — while the fused
backends run the whole chain inside one super-step executable, so stage
intermediates never round-trip through HBM.  Also locks the cache keys:
programs fingerprint by their stage chain (order matters), a plain single
stage normalizes to the legacy problem (identical keys), and dtype splits
both the schedule cache and the executable cache.
"""
import os
import random
import subprocess
import sys

import pytest

try:                                 # the sweep upgrades when available; the
    import hypothesis.strategies as st   # deterministic cases always run
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (RunConfig, StencilProblem, StencilProgram,
                       StencilStage, clear_exec_cache,
                       exec_cache_stats, plan)
from repro.api.backends import _exec_key
from repro.api.schedule_cache import schedule_key, stencil_fingerprint
from repro.core.stencils import STENCILS, make_star
from repro.kernels.ref import oracle_program_run

BACKENDS = ("reference", "engine", "pallas_interpret")


def _inputs(key, shape, needs_aux=False):
    g = jax.random.uniform(key, shape, jnp.float32, 0.5, 2.0)
    aux = (jax.random.uniform(jax.random.fold_in(key, 7), shape,
                              jnp.float32, 0.0, 0.1) if needs_aux else None)
    return g, aux


# --- fused chain == sequential per-stage oracle (acceptance criterion) -------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("shape,bsize", [((20, 17), 12), ((7, 14, 11), (12, 12))])
def test_two_stage_program_matches_oracle(backend, shape, bsize):
    ndim = len(shape)
    prog = [StencilStage(make_star(ndim, 1)),
            StencilStage(f"diffusion{ndim}d")]
    problem = StencilProblem(prog, shape, boundary="clamp")
    g, _ = _inputs(jax.random.PRNGKey(0), shape)
    want = oracle_program_run(problem.exec_stages, g,
                              problem.resolve_coeffs(dtype=jnp.float32), 5)
    p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=bsize,
                                par_vec=1))
    np.testing.assert_allclose(np.asarray(p.run(g, iters=5)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_program_equals_chained_single_stage_plans(backend):
    """The fusion criterion: one 2-stage plan == two chained 1-stage plans,
    and the fused plan's traffic report bills ZERO HBM bytes for the
    intermediate."""
    shape = (24, 18)
    star = make_star(2, 1)
    problem = StencilProblem([StencilStage(star), StencilStage("diffusion2d")],
                             shape)
    g, _ = _inputs(jax.random.PRNGKey(1), shape)
    cfg = dict(par_time=1, bsize=12, par_vec=1)
    fused = plan(problem, RunConfig(backend=backend, **cfg))
    p1 = plan(StencilProblem(star, shape), RunConfig(backend=backend, **cfg))
    p2 = plan(StencilProblem("diffusion2d", shape),
              RunConfig(backend=backend, **cfg))
    seq = g
    for _ in range(4):
        seq = p2.run(p1.run(seq, iters=1), iters=1)
    np.testing.assert_allclose(np.asarray(fused.run(g, iters=4)),
                               np.asarray(seq), rtol=2e-5, atol=2e-5)
    tr = fused.traffic_report()
    assert tr["intermediate_hbm_bytes_per_superstep"] == 0
    assert tr["unfused_intermediate_bytes_per_superstep"] > 0
    assert len(tr["stages"]) == 2


def test_radius_zero_stage():
    """A pointwise (radius-0) stage — e.g. damping/reaction — chains for
    free: it adds no halo and the fused plan still matches the oracle."""
    shape = (18, 15)
    damp = StencilStage(make_star(2, 0), coeffs={"c0": 0.95}, name="damp")
    problem = StencilProblem([StencilStage("diffusion2d"), damp], shape)
    assert problem.stencil.radius == 1          # rad sums; the 0 is free
    g, _ = _inputs(jax.random.PRNGKey(2), shape)
    want = oracle_program_run(problem.exec_stages, g,
                              problem.resolve_coeffs(dtype=jnp.float32), 6)
    for backend in BACKENDS:
        p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=8,
                                    par_vec=1))
        np.testing.assert_allclose(np.asarray(p.run(g, iters=6)),
                                   np.asarray(want), rtol=2e-5, atol=2e-5)


def test_per_stage_coeffs_and_runtime_override():
    """Static stage coeff overrides apply; run-time coeffs are per-stage
    sequences for programs (a bare dict is rejected)."""
    shape = (16, 14)
    star = make_star(2, 1)
    problem = StencilProblem(
        [StencilStage(star, coeffs={"c0": 0.8, "c_0_1": 0.05}),
         StencilStage("diffusion2d")], shape)
    resolved = problem.resolve_coeffs(dtype=jnp.float32)
    assert float(resolved[0]["c0"]) == pytest.approx(0.8)
    assert float(resolved[0]["c_0_1"]) == pytest.approx(0.05)
    p = plan(problem, RunConfig(backend="engine", par_time=1, bsize=8))
    g, _ = _inputs(jax.random.PRNGKey(3), shape)
    override = ({"c0": 0.7}, None)
    want = oracle_program_run(problem.exec_stages, g,
                              problem.resolve_coeffs(override,
                                                     dtype=jnp.float32), 3)
    np.testing.assert_allclose(np.asarray(p.run(g, iters=3, coeffs=override)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)
    with pytest.raises(ValueError, match="sequence of per-stage"):
        p.run(g, iters=1, coeffs={"c0": 0.7})
    with pytest.raises(ValueError, match="unknown coefficients"):
        p.run(g, iters=1, coeffs=({"nope": 1.0}, None))


@pytest.mark.parametrize("backend", BACKENDS)
def test_program_run_batch(backend):
    shape = (18, 16)
    problem = StencilProblem(
        [StencilStage(make_star(2, 1)), StencilStage("diffusion2d")], shape,
        boundary=("clamp", "reflect"))
    p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=12,
                                par_vec=1))
    gs = jax.random.uniform(jax.random.PRNGKey(4), (3,) + shape, jnp.float32,
                            0.5, 2.0)
    cf = problem.resolve_coeffs(dtype=jnp.float32)
    want = jnp.stack([oracle_program_run(problem.exec_stages, gs[i], cf, 4)
                      for i in range(3)])
    np.testing.assert_allclose(np.asarray(p.run_batch(gs, iters=4)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


# --- randomized chain sweep ---------------------------------------------------

def _chain_case(params):
    """ANY 1-3 stage chain (mixed radii incl. pointwise, per-axis BC mixes —
    periodicity uniform across stages, the rest varying per stage — 2D/3D,
    V in {1,4}) == the sequential per-stage oracle."""
    (ndim, n_stages, radii, periodic, kinds, par_time, par_vec, iters,
     backend, seed) = params
    if backend == "engine":
        par_vec = 1                 # a Pallas-only knob (scalar-tick backend)
    radii = radii[:n_stages]
    if ndim == 3:
        radii = [min(r, 1) for r in radii]    # keep 3D halos (and time) small
    cap = 3 if ndim == 2 else 2               # bound the fused halo
    while sum(radii) > cap:
        radii[radii.index(max(radii))] -= 1
    if sum(radii) == 0:
        radii[0] = 1                          # the chain must move data
    rad = sum(radii)
    stages = []
    for s, r in enumerate(radii):
        bc = tuple("periodic" if periodic[ax]
                   else kinds[(s * ndim + ax) % len(kinds)]
                   for ax in range(ndim))
        stages.append(StencilStage(make_star(ndim, r), boundary=bc))
    stream = 3 * rad * par_time + 5
    shape = (stream, 13) if ndim == 2 else (stream, 14, 12)
    bsize = 2 * rad * par_time + 4
    problem = StencilProblem(StencilProgram(tuple(stages)), shape,
                             boundary="clamp")
    g, _ = _inputs(jax.random.PRNGKey(seed), shape)
    want = oracle_program_run(problem.exec_stages, g,
                              problem.resolve_coeffs(dtype=jnp.float32),
                              iters)
    p = plan(problem, RunConfig(backend=backend, par_time=par_time,
                                bsize=bsize, par_vec=par_vec))
    np.testing.assert_allclose(np.asarray(p.run(g, iters=iters)),
                               np.asarray(want), rtol=3e-5, atol=3e-5)


_NONPERIODIC = ["clamp", "reflect", "constant:0.6"]


def _draw_case(rng):
    return (
        rng.choice([2, 3]),                       # ndim
        rng.randint(1, 3),                        # n_stages
        [rng.choice([0, 1, 2]) for _ in range(3)],    # radii
        [rng.random() < 0.3 for _ in range(3)],   # per-axis periodic
        [rng.choice(_NONPERIODIC) for _ in range(9)],  # stage/axis kinds
        rng.randint(1, 2),                        # par_time
        rng.choice([1, 4]),                       # par_vec
        rng.randint(1, 4),                        # iters
        rng.choice(["engine", "pallas_interpret"]),
        rng.randint(0, 10_000),                   # prng seed
    )


_SEEDED_CASES = [_draw_case(random.Random(1000 + i)) for i in range(10)]


@pytest.mark.parametrize("params", _SEEDED_CASES,
                         ids=[f"case{i}" for i in range(len(_SEEDED_CASES))])
def test_chain_matches_oracle_seeded(params):
    _chain_case(params)


if HAVE_HYPOTHESIS:
    _chain_params = st.tuples(
        st.sampled_from([2, 3]),                  # ndim
        st.integers(1, 3),                        # n_stages
        st.lists(st.sampled_from([0, 1, 2]), min_size=3, max_size=3),
        st.lists(st.booleans(), min_size=3, max_size=3),
        st.lists(st.sampled_from(_NONPERIODIC), min_size=9, max_size=9),
        st.integers(1, 2),                        # par_time
        st.sampled_from([1, 4]),                  # par_vec
        st.integers(1, 4),                        # iters
        st.sampled_from(["engine", "pallas_interpret"]),
        st.integers(0, 10_000),                   # prng seed
    )

    @settings(max_examples=20, deadline=None)
    @given(_chain_params)
    def test_random_chain_matches_oracle(params):
        _chain_case(params)


def test_mixed_periodicity_across_stages_rejected():
    with pytest.raises(ValueError, match="periodic"):
        StencilProblem([StencilStage("diffusion2d", boundary="periodic"),
                        StencilStage("diffusion2d", boundary="clamp")],
                       (16, 16))


# --- distributed (subprocess: fake multi-device view) -------------------------

def test_distributed_program_matches_oracle():
    script = os.path.join(os.path.dirname(__file__),
                          "program_distributed_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL OK" in out.stdout


# --- cache-key hygiene --------------------------------------------------------

def test_plain_single_stage_normalizes_to_legacy_problem():
    """One plain stage IS the legacy problem: same `stencil` object class,
    same fingerprint, same schedule/executable keys — nothing in any cache
    splits."""
    legacy = StencilProblem("diffusion2d", (32, 32))
    wrapped = StencilProblem([StencilStage("diffusion2d")], (32, 32))
    assert not wrapped.is_program and wrapped.n_stages == 1
    assert wrapped.stencil is STENCILS["diffusion2d"]
    assert (stencil_fingerprint(wrapped.stencil)
            == stencil_fingerprint(legacy.stencil))
    assert _exec_key("engine", wrapped, None) == _exec_key("engine", legacy,
                                                           None)


def test_program_fingerprint_is_order_and_content_sensitive():
    a, b = StencilStage("diffusion2d"), StencilStage(make_star(2, 1))
    p_ab = StencilProblem([a, b], (24, 24))
    p_ba = StencilProblem([b, a], (24, 24))
    assert (stencil_fingerprint(p_ab.stencil)
            != stencil_fingerprint(p_ba.stencil))
    # static coeff overrides change what the program computes -> new key
    p_cf = StencilProblem([StencilStage("diffusion2d", coeffs={"cc": 0.9}),
                           b], (24, 24))
    assert (stencil_fingerprint(p_ab.stencil)
            != stencil_fingerprint(p_cf.stencil))
    # a per-stage BC override does too
    p_bc = StencilProblem([StencilStage("diffusion2d", boundary="reflect"),
                           b], (24, 24))
    assert (stencil_fingerprint(p_ab.stencil)
            != stencil_fingerprint(p_bc.stencil))


# --- dtype is part of every cache key (satellite regression) ------------------

def _engine_cfg(**kw):
    kw.setdefault("backend", "engine")
    kw.setdefault("par_time", 2)
    kw.setdefault("bsize", 16)
    return RunConfig(**kw)


def test_dtype_splits_schedule_and_exec_keys():
    f32 = StencilProblem("diffusion2d", (48, 48), dtype="float32")
    b16 = StencilProblem("diffusion2d", (48, 48), dtype="bfloat16")
    cfg = _engine_cfg()
    dev = cfg.resolved_device()
    assert (schedule_key(f32, cfg, dev, 1, None, salt="s")
            != schedule_key(b16, cfg, dev, 1, None, salt="s"))
    assert _exec_key("engine", f32, None) != _exec_key("engine", b16, None)


def test_exec_cache_never_serves_across_dtypes():
    """Behavioral half of the key test: running the same problem in a second
    dtype MUST miss the executable cache (a second compile), and each run's
    output keeps its own dtype."""
    clear_exec_cache()
    try:
        shape = (32, 32)
        g32 = jax.random.uniform(jax.random.PRNGKey(5), shape, jnp.float32)
        p32 = plan(StencilProblem("diffusion2d", shape, dtype="float32"),
                   _engine_cfg())
        out32 = p32.run(g32, iters=2)
        misses_after_f32 = exec_cache_stats()["misses"]
        p16 = plan(StencilProblem("diffusion2d", shape, dtype="bfloat16"),
                   _engine_cfg())
        out16 = p16.run(g32.astype(jnp.bfloat16), iters=2)
        stats = exec_cache_stats()
        assert stats["misses"] == misses_after_f32 + 1, \
            "the f32 executable must never serve the bfloat16 plan"
        assert out32.dtype == jnp.float32 and out16.dtype == jnp.bfloat16
    finally:
        clear_exec_cache()


def test_measured_tuning_cache_never_serves_across_dtypes(tmp_path):
    """An f32-tuned schedule-cache entry never serves a different-dtype
    plan: the second dtype re-tunes (tuned_from_cache False) and the file
    ends with two entries."""
    cache = str(tmp_path / "s.json")
    cfg = dict(backend="engine", autotune="measure", iters_hint=4,
               tune_top_k=1, tune_warmup=0, tune_repeats=1, cache=cache)
    p_f32 = plan(StencilProblem("diffusion2d", (32, 96), dtype="float32"),
                 RunConfig(**cfg))
    assert not p_f32.tuned_from_cache
    # same dtype again: served from the persisted winner
    p_again = plan(StencilProblem("diffusion2d", (32, 96), dtype="float32"),
                   RunConfig(**cfg))
    assert p_again.tuned_from_cache
    # different dtype: MUST re-tune, not reuse the f32 winner
    p_b16 = plan(StencilProblem("diffusion2d", (32, 96), dtype="bfloat16"),
                 RunConfig(**cfg))
    assert not p_b16.tuned_from_cache
    import json
    entries = json.load(open(cache))["entries"]
    assert len(entries) == 2


def test_program_splits_exec_cache_from_single_stage():
    """A program and its first stage alone share shape/dtype/BC — the
    executable keys must still differ (different compiled chain)."""
    shape = (24, 24)
    single = StencilProblem("diffusion2d", shape)
    prog = StencilProblem([StencilStage("diffusion2d"),
                           StencilStage(make_star(2, 0))], shape)
    assert (_exec_key("engine", single, None)
            != _exec_key("engine", prog, None))
