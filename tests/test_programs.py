"""Multi-stage ``StencilProgram`` conformance + cache-key hygiene.

The contract under test: a program (ordered chain of stages, each with its
own coefficients and boundary condition) planned on ANY backend computes
exactly what the sequential per-stage oracle computes — while the fused
backends run the whole chain inside one super-step executable, so stage
intermediates never round-trip through HBM.  Also locks the cache keys:
programs fingerprint by their stage chain (order matters), a plain single
stage normalizes to the legacy problem (identical keys), and dtype splits
both the schedule cache and the executable cache.
"""
import os
import random
import subprocess
import sys

import pytest

try:                                 # the sweep upgrades when available; the
    import hypothesis.strategies as st   # deterministic cases always run
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (RunConfig, StencilProblem, StencilProgram,
                       StencilStage, clear_exec_cache,
                       exec_cache_stats, plan)
from repro.api.backends import _exec_key
from repro.api.schedule_cache import schedule_key, stencil_fingerprint
from repro.core.stencils import STENCILS, make_star
from repro.kernels.ref import oracle_program_run

BACKENDS = ("reference", "engine", "pallas_interpret")


def _inputs(key, shape, needs_aux=False):
    g = jax.random.uniform(key, shape, jnp.float32, 0.5, 2.0)
    aux = (jax.random.uniform(jax.random.fold_in(key, 7), shape,
                              jnp.float32, 0.0, 0.1) if needs_aux else None)
    return g, aux


# --- fused chain == sequential per-stage oracle (acceptance criterion) -------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("shape,bsize", [((20, 17), 12), ((7, 14, 11), (12, 12))])
def test_two_stage_program_matches_oracle(backend, shape, bsize):
    ndim = len(shape)
    prog = [StencilStage(make_star(ndim, 1)),
            StencilStage(f"diffusion{ndim}d")]
    problem = StencilProblem(prog, shape, boundary="clamp")
    g, _ = _inputs(jax.random.PRNGKey(0), shape)
    want = oracle_program_run(problem.exec_stages, g,
                              problem.resolve_coeffs(dtype=jnp.float32), 5)
    p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=bsize,
                                par_vec=1))
    np.testing.assert_allclose(np.asarray(p.run(g, iters=5)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_program_equals_chained_single_stage_plans(backend):
    """The fusion criterion: one 2-stage plan == two chained 1-stage plans,
    and the fused plan's traffic report bills ZERO HBM bytes for the
    intermediate."""
    shape = (24, 18)
    star = make_star(2, 1)
    problem = StencilProblem([StencilStage(star), StencilStage("diffusion2d")],
                             shape)
    g, _ = _inputs(jax.random.PRNGKey(1), shape)
    cfg = dict(par_time=1, bsize=12, par_vec=1)
    fused = plan(problem, RunConfig(backend=backend, **cfg))
    p1 = plan(StencilProblem(star, shape), RunConfig(backend=backend, **cfg))
    p2 = plan(StencilProblem("diffusion2d", shape),
              RunConfig(backend=backend, **cfg))
    seq = g
    for _ in range(4):
        seq = p2.run(p1.run(seq, iters=1), iters=1)
    np.testing.assert_allclose(np.asarray(fused.run(g, iters=4)),
                               np.asarray(seq), rtol=2e-5, atol=2e-5)
    tr = fused.traffic_report()
    assert tr["intermediate_hbm_bytes_per_superstep"] == 0
    assert tr["unfused_intermediate_bytes_per_superstep"] > 0
    assert len(tr["stages"]) == 2


def test_radius_zero_stage():
    """A pointwise (radius-0) stage — e.g. damping/reaction — chains for
    free: it adds no halo and the fused plan still matches the oracle."""
    shape = (18, 15)
    damp = StencilStage(make_star(2, 0), coeffs={"c0": 0.95}, name="damp")
    problem = StencilProblem([StencilStage("diffusion2d"), damp], shape)
    assert problem.stencil.radius == 1          # rad sums; the 0 is free
    g, _ = _inputs(jax.random.PRNGKey(2), shape)
    want = oracle_program_run(problem.exec_stages, g,
                              problem.resolve_coeffs(dtype=jnp.float32), 6)
    for backend in BACKENDS:
        p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=8,
                                    par_vec=1))
        np.testing.assert_allclose(np.asarray(p.run(g, iters=6)),
                                   np.asarray(want), rtol=2e-5, atol=2e-5)


def test_per_stage_coeffs_and_runtime_override():
    """Static stage coeff overrides apply; run-time coeffs are per-stage
    sequences for programs (a bare dict is rejected)."""
    shape = (16, 14)
    star = make_star(2, 1)
    problem = StencilProblem(
        [StencilStage(star, coeffs={"c0": 0.8, "c_0_1": 0.05}),
         StencilStage("diffusion2d")], shape)
    resolved = problem.resolve_coeffs(dtype=jnp.float32)
    assert float(resolved[0]["c0"]) == pytest.approx(0.8)
    assert float(resolved[0]["c_0_1"]) == pytest.approx(0.05)
    p = plan(problem, RunConfig(backend="engine", par_time=1, bsize=8))
    g, _ = _inputs(jax.random.PRNGKey(3), shape)
    override = ({"c0": 0.7}, None)
    want = oracle_program_run(problem.exec_stages, g,
                              problem.resolve_coeffs(override,
                                                     dtype=jnp.float32), 3)
    np.testing.assert_allclose(np.asarray(p.run(g, iters=3, coeffs=override)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)
    with pytest.raises(ValueError, match="sequence of per-stage"):
        p.run(g, iters=1, coeffs={"c0": 0.7})
    with pytest.raises(ValueError, match="unknown coefficients"):
        p.run(g, iters=1, coeffs=({"nope": 1.0}, None))


@pytest.mark.parametrize("backend", BACKENDS)
def test_program_run_batch(backend):
    shape = (18, 16)
    problem = StencilProblem(
        [StencilStage(make_star(2, 1)), StencilStage("diffusion2d")], shape,
        boundary=("clamp", "reflect"))
    p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=12,
                                par_vec=1))
    gs = jax.random.uniform(jax.random.PRNGKey(4), (3,) + shape, jnp.float32,
                            0.5, 2.0)
    cf = problem.resolve_coeffs(dtype=jnp.float32)
    want = jnp.stack([oracle_program_run(problem.exec_stages, gs[i], cf, 4)
                      for i in range(3)])
    np.testing.assert_allclose(np.asarray(p.run_batch(gs, iters=4)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


# --- randomized chain sweep ---------------------------------------------------

def _chain_case(params):
    """ANY 1-3 stage chain (mixed radii incl. pointwise, per-axis BC mixes —
    periodicity uniform across stages, the rest varying per stage — 2D/3D,
    V in {1,4}) == the sequential per-stage oracle."""
    (ndim, n_stages, radii, periodic, kinds, par_time, par_vec, iters,
     backend, seed) = params
    if backend == "engine":
        par_vec = 1                 # a Pallas-only knob (scalar-tick backend)
    radii = radii[:n_stages]
    if ndim == 3:
        radii = [min(r, 1) for r in radii]    # keep 3D halos (and time) small
    cap = 3 if ndim == 2 else 2               # bound the fused halo
    while sum(radii) > cap:
        radii[radii.index(max(radii))] -= 1
    if sum(radii) == 0:
        radii[0] = 1                          # the chain must move data
    rad = sum(radii)
    stages = []
    for s, r in enumerate(radii):
        bc = tuple("periodic" if periodic[ax]
                   else kinds[(s * ndim + ax) % len(kinds)]
                   for ax in range(ndim))
        stages.append(StencilStage(make_star(ndim, r), boundary=bc))
    stream = 3 * rad * par_time + 5
    shape = (stream, 13) if ndim == 2 else (stream, 14, 12)
    bsize = 2 * rad * par_time + 4
    problem = StencilProblem(StencilProgram(tuple(stages)), shape,
                             boundary="clamp")
    g, _ = _inputs(jax.random.PRNGKey(seed), shape)
    want = oracle_program_run(problem.exec_stages, g,
                              problem.resolve_coeffs(dtype=jnp.float32),
                              iters)
    p = plan(problem, RunConfig(backend=backend, par_time=par_time,
                                bsize=bsize, par_vec=par_vec))
    np.testing.assert_allclose(np.asarray(p.run(g, iters=iters)),
                               np.asarray(want), rtol=3e-5, atol=3e-5)


_NONPERIODIC = ["clamp", "reflect", "constant:0.6"]


def _draw_case(rng):
    return (
        rng.choice([2, 3]),                       # ndim
        rng.randint(1, 3),                        # n_stages
        [rng.choice([0, 1, 2]) for _ in range(3)],    # radii
        [rng.random() < 0.3 for _ in range(3)],   # per-axis periodic
        [rng.choice(_NONPERIODIC) for _ in range(9)],  # stage/axis kinds
        rng.randint(1, 2),                        # par_time
        rng.choice([1, 4]),                       # par_vec
        rng.randint(1, 4),                        # iters
        rng.choice(["engine", "pallas_interpret"]),
        rng.randint(0, 10_000),                   # prng seed
    )


_SEEDED_CASES = [_draw_case(random.Random(1000 + i)) for i in range(10)]


@pytest.mark.parametrize("params", _SEEDED_CASES,
                         ids=[f"case{i}" for i in range(len(_SEEDED_CASES))])
def test_chain_matches_oracle_seeded(params):
    _chain_case(params)


if HAVE_HYPOTHESIS:
    _chain_params = st.tuples(
        st.sampled_from([2, 3]),                  # ndim
        st.integers(1, 3),                        # n_stages
        st.lists(st.sampled_from([0, 1, 2]), min_size=3, max_size=3),
        st.lists(st.booleans(), min_size=3, max_size=3),
        st.lists(st.sampled_from(_NONPERIODIC), min_size=9, max_size=9),
        st.integers(1, 2),                        # par_time
        st.sampled_from([1, 4]),                  # par_vec
        st.integers(1, 4),                        # iters
        st.sampled_from(["engine", "pallas_interpret"]),
        st.integers(0, 10_000),                   # prng seed
    )

    @settings(max_examples=20, deadline=None)
    @given(_chain_params)
    def test_random_chain_matches_oracle(params):
        _chain_case(params)


def test_mixed_periodicity_across_stages_rejected():
    with pytest.raises(ValueError, match="periodic"):
        StencilProblem([StencilStage("diffusion2d", boundary="periodic"),
                        StencilStage("diffusion2d", boundary="clamp")],
                       (16, 16))


# --- distributed (subprocess: fake multi-device view) -------------------------

def test_distributed_program_matches_oracle():
    script = os.path.join(os.path.dirname(__file__),
                          "program_distributed_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL OK" in out.stdout


# --- cache-key hygiene --------------------------------------------------------

def test_plain_single_stage_normalizes_to_legacy_problem():
    """One plain stage IS the legacy problem: same `stencil` object class,
    same fingerprint, same schedule/executable keys — nothing in any cache
    splits."""
    legacy = StencilProblem("diffusion2d", (32, 32))
    wrapped = StencilProblem([StencilStage("diffusion2d")], (32, 32))
    assert not wrapped.is_program and wrapped.n_stages == 1
    assert wrapped.stencil is STENCILS["diffusion2d"]
    assert (stencil_fingerprint(wrapped.stencil)
            == stencil_fingerprint(legacy.stencil))
    assert _exec_key("engine", wrapped, None) == _exec_key("engine", legacy,
                                                           None)


def test_program_fingerprint_is_order_and_content_sensitive():
    a, b = StencilStage("diffusion2d"), StencilStage(make_star(2, 1))
    p_ab = StencilProblem([a, b], (24, 24))
    p_ba = StencilProblem([b, a], (24, 24))
    assert (stencil_fingerprint(p_ab.stencil)
            != stencil_fingerprint(p_ba.stencil))
    # static coeff overrides change what the program computes -> new key
    p_cf = StencilProblem([StencilStage("diffusion2d", coeffs={"cc": 0.9}),
                           b], (24, 24))
    assert (stencil_fingerprint(p_ab.stencil)
            != stencil_fingerprint(p_cf.stencil))
    # a per-stage BC override does too
    p_bc = StencilProblem([StencilStage("diffusion2d", boundary="reflect"),
                           b], (24, 24))
    assert (stencil_fingerprint(p_ab.stencil)
            != stencil_fingerprint(p_bc.stencil))


# --- dtype is part of every cache key (satellite regression) ------------------

def _engine_cfg(**kw):
    kw.setdefault("backend", "engine")
    kw.setdefault("par_time", 2)
    kw.setdefault("bsize", 16)
    return RunConfig(**kw)


def test_dtype_splits_schedule_and_exec_keys():
    f32 = StencilProblem("diffusion2d", (48, 48), dtype="float32")
    b16 = StencilProblem("diffusion2d", (48, 48), dtype="bfloat16")
    cfg = _engine_cfg()
    dev = cfg.resolved_device()
    assert (schedule_key(f32, cfg, dev, 1, None, salt="s")
            != schedule_key(b16, cfg, dev, 1, None, salt="s"))
    assert _exec_key("engine", f32, None) != _exec_key("engine", b16, None)


def test_exec_cache_never_serves_across_dtypes():
    """Behavioral half of the key test: running the same problem in a second
    dtype MUST miss the executable cache (a second compile), and each run's
    output keeps its own dtype."""
    clear_exec_cache()
    try:
        shape = (32, 32)
        g32 = jax.random.uniform(jax.random.PRNGKey(5), shape, jnp.float32)
        p32 = plan(StencilProblem("diffusion2d", shape, dtype="float32"),
                   _engine_cfg())
        out32 = p32.run(g32, iters=2)
        misses_after_f32 = exec_cache_stats()["misses"]
        p16 = plan(StencilProblem("diffusion2d", shape, dtype="bfloat16"),
                   _engine_cfg())
        out16 = p16.run(g32.astype(jnp.bfloat16), iters=2)
        stats = exec_cache_stats()
        assert stats["misses"] == misses_after_f32 + 1, \
            "the f32 executable must never serve the bfloat16 plan"
        assert out32.dtype == jnp.float32 and out16.dtype == jnp.bfloat16
    finally:
        clear_exec_cache()


def test_measured_tuning_cache_never_serves_across_dtypes(tmp_path):
    """An f32-tuned schedule-cache entry never serves a different-dtype
    plan: the second dtype re-tunes (tuned_from_cache False) and the file
    ends with two entries."""
    cache = str(tmp_path / "s.json")
    cfg = dict(backend="engine", autotune="measure", iters_hint=4,
               tune_top_k=1, tune_warmup=0, tune_repeats=1, cache=cache)
    p_f32 = plan(StencilProblem("diffusion2d", (32, 96), dtype="float32"),
                 RunConfig(**cfg))
    assert not p_f32.tuned_from_cache
    # same dtype again: served from the persisted winner
    p_again = plan(StencilProblem("diffusion2d", (32, 96), dtype="float32"),
                   RunConfig(**cfg))
    assert p_again.tuned_from_cache
    # different dtype: MUST re-tune, not reuse the f32 winner
    p_b16 = plan(StencilProblem("diffusion2d", (32, 96), dtype="bfloat16"),
                 RunConfig(**cfg))
    assert not p_b16.tuned_from_cache
    import json
    entries = json.load(open(cache))["entries"]
    assert len(entries) == 2


def test_program_splits_exec_cache_from_single_stage():
    """A program and its first stage alone share shape/dtype/BC — the
    executable keys must still differ (different compiled chain)."""
    shape = (24, 24)
    single = StencilProblem("diffusion2d", shape)
    prog = StencilProblem([StencilStage("diffusion2d"),
                           StencilStage(make_star(2, 0))], shape)
    assert (_exec_key("engine", single, None)
            != _exec_key("engine", prog, None))

# --- DAG programs: conformance vs an independent topological oracle -----------
#
# The evaluator below shares NOTHING with repro.kernels.ref beyond the stage
# stencils' `apply` (which every backend shares by definition): numpy
# padding, fixpoint scheduling instead of the library's Kahn topo order, a
# plain dict of field arrays instead of DagSpec plumbing.

from repro.core.stencils import make_combine  # noqa: E402

_NP_PAD = {"clamp": "edge", "periodic": "wrap", "reflect": "reflect"}


def _np_get(x, r, bc):
    x = np.asarray(x)
    p = x
    for ax, kind in enumerate(bc.kinds):
        pads = [(0, 0)] * x.ndim
        pads[ax] = (r, r)
        if kind == "constant":
            p = np.pad(p, pads, mode="constant", constant_values=bc.value)
        else:
            p = np.pad(p, pads, mode=_NP_PAD[kind])

    def get(off):
        return p[tuple(slice(r + o, r + o + n)
                       for o, n in zip(off, x.shape))]
    return get


def _np_dag_oracle(problem, state, iters, aux=None):
    """iters program iterations, stages scheduled by *fixpoint* (re-scan
    until every stage has its inputs) — an order-free restatement of the
    topological semantics."""
    prog = problem.program
    coeffs = problem.resolve_coeffs(dtype=jnp.float32)
    F = len(prog.fields)
    state = np.asarray(state, np.float32)
    fields = [state[i] for i in range(F)] if F > 1 else [state]
    S = len(prog.stages)
    for _ in range(iters):
        vals, done = [None] * S, [False] * S
        while not all(done):
            progressed = False
            for i, stage in enumerate(prog.stages):
                if done[i]:
                    continue
                refs = prog.inputs_idx[i]
                if any(r >= 0 and not done[r] for r in refs):
                    continue
                ins = [vals[r] if r >= 0 else fields[~r] for r in refs]
                st = stage.stencil
                gets = [_np_get(x, st.radius, stage.boundary) for x in ins]
                vals[i] = np.asarray(st.apply(
                    tuple(gets) if st.arity > 1 else gets[0], coeffs[i],
                    aux if st.has_aux else None), np.float32)
                done[i] = progressed = True
            assert progressed, "cycle leaked past validation"
        fields = [vals[u] if u >= 0 else fields[~u]
                  for u in prog.updates_idx]
    return np.stack(fields) if F > 1 else fields[0]


def _wave2d_program(c=0.1):
    """Second-order wave equation: two fields, one simultaneous rotation."""
    return StencilProgram(
        (StencilStage(make_star(2, 1), name="lapu", inputs=("u",)),
         StencilStage(make_combine(2, 3), name="unext",
                      inputs=("u", "u_prev", "lapu"),
                      coeffs={"w0": 2.0, "w1": -1.0, "w2": c})),
        fields=("u", "u_prev"),
        updates={"u": "unext", "u_prev": "u"})


def _residual_program():
    """Fan-in from a field: r = u - smooth(u) reads `u` twice (raw + through
    a stage)."""
    return StencilProgram(
        (StencilStage("diffusion2d", name="Au", inputs=("u",)),
         StencilStage(make_combine(2, 2), name="resid", inputs=("u", "Au"),
                      coeffs={"w0": 1.0, "w1": -1.0})))


def _diamond_program():
    """Fan-out then fan-in: two independent views of `u` recombined."""
    s = make_star(2, 1)
    return StencilProgram(
        (StencilStage(s, name="a", inputs=("u",)),
         StencilStage(s, name="b", inputs=("u",),
                      coeffs={"c0": 0.5, "c_0_1": 0.2}),
         StencilStage(make_combine(2, 2), name="m", inputs=("a", "b"),
                      coeffs={"w0": 0.6, "w1": 0.4})))


_DAG_CASES = [
    ("wave2d", _wave2d_program, (22, 19), "periodic", 1, 4),
    ("wave2d", _wave2d_program, (26, 17), ("clamp", "reflect"), 2, 3),
    ("residual", _residual_program, (20, 16), "clamp", 2, 3),
    ("diamond", _diamond_program, (24, 15), ("periodic", "clamp"), 2, 4),
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize(
    "name,build,shape,bc,par_vec,iters", _DAG_CASES,
    ids=[f"{c[0]}-{i}" for i, c in enumerate(_DAG_CASES)])
def test_dag_matches_topological_oracle(backend, name, build, shape, bc,
                                        par_vec, iters):
    if backend == "engine":
        par_vec = 1
    problem = StencilProblem(build(), shape, boundary=bc)
    assert problem.is_dag
    state = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(11), problem.state_shape, jnp.float32, 0.5, 2.0))
    want = _np_dag_oracle(problem, state, iters)
    p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=8,
                                par_vec=par_vec))
    np.testing.assert_allclose(np.asarray(p.run(state, iters=iters)),
                               want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_multi_field_run_batch(backend):
    problem = StencilProblem(_wave2d_program(), (18, 16), boundary="periodic")
    p = plan(problem, RunConfig(backend=backend, par_time=2, bsize=8,
                                par_vec=1))
    base = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(12), problem.state_shape, jnp.float32, 0.5, 2.0))
    batch = np.stack([base, base * 0.5, base + 0.1])
    want = np.stack([_np_dag_oracle(problem, batch[i], 3) for i in range(3)])
    np.testing.assert_allclose(np.asarray(p.run_batch(batch, iters=3)),
                               want, rtol=3e-5, atol=3e-5)
    with pytest.raises(ValueError, match="state"):
        p.run(base[0], iters=1)           # missing field axis


# --- randomized DAG sweep -----------------------------------------------------

def _draw_dag_case(rng):
    """A random valid 2D DAG: every stage feeds something, every field
    updates, periodicity uniform per axis."""
    n_fields = rng.choice([1, 2])
    fields = tuple(f"f{i}" for i in range(n_fields))
    n_inner = rng.randint(1, 3)
    stages, names = [], []
    for i in range(n_inner):
        arity = rng.choice([1, 1, 2])
        pool = list(fields) + names
        if arity == 1:
            r = rng.choice([0, 1, 2])
            stc = make_star(2, r)
            ins = (rng.choice(pool),)
        else:
            stc = make_combine(2, 2)
            ins = (rng.choice(pool), rng.choice(pool))
        names.append(f"s{i}")
        stages.append(StencilStage(stc, name=f"s{i}", inputs=ins))
    # terminal combine consumes every not-yet-consumed stage (+ field 0)
    consumed = {n for s in stages if s.inputs for n in s.inputs}
    tail = [n for n in names if n not in consumed] + [fields[0]]
    if len(tail) == 1:
        stages.append(StencilStage(make_star(2, 1), name="out",
                                   inputs=(tail[0],)))
    else:
        stages.append(StencilStage(make_combine(2, len(tail)), name="out",
                                   inputs=tuple(tail)))
    updates = {fields[0]: "out"}
    for k in range(1, n_fields):
        updates[fields[k]] = fields[k - 1]      # rotate
    prog = StencilProgram(tuple(stages), fields=fields, updates=updates)
    periodic = [rng.random() < 0.3 for _ in range(2)]
    bc = tuple("periodic" if p_ else rng.choice(_NONPERIODIC)
               for p_ in periodic)
    return (prog, bc, rng.randint(1, 2), rng.choice([1, 2]),
            rng.randint(1, 3), rng.choice(["engine", "pallas_interpret"]),
            rng.randint(0, 10_000))


_DAG_SEEDED = [_draw_dag_case(random.Random(2000 + i)) for i in range(8)]


@pytest.mark.parametrize("case", _DAG_SEEDED,
                         ids=[f"dag{i}" for i in range(len(_DAG_SEEDED))])
def test_random_dag_matches_oracle(case):
    prog, bc, par_time, par_vec, iters, backend, seed = case
    if backend == "engine":
        par_vec = 1
    rad = max(1, sum(s.stencil.radius for s in prog.stages))
    stream = 3 * rad * par_time + 5
    problem = StencilProblem(prog, (stream, 13), boundary=bc)
    state = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(seed), problem.state_shape, jnp.float32, 0.5, 2.0))
    want = _np_dag_oracle(problem, state, iters)
    p = plan(problem, RunConfig(backend=backend, par_time=par_time,
                                bsize=2 * rad * par_time + 4,
                                par_vec=par_vec))
    np.testing.assert_allclose(np.asarray(p.run(state, iters=iters)),
                               want, rtol=3e-5, atol=3e-5)


# --- the linear fast path IS the DAG path -------------------------------------

def test_linear_chain_bit_identical_through_dag_executor():
    """A linear program run through the chain wrapper and through the DAG
    wrapper (its chain_dag form) must agree BIT FOR BIT — the acceptance
    criterion that the refactor left PR 6's linear kernels untouched."""
    from repro.core.blocking import BlockGeometry
    from repro.kernels.ops import (pack_dag_coeffs, pack_program_coeffs,
                                   run_pallas_chain, run_pallas_dag)
    from repro.programs import chain_dag
    problem = StencilProblem(
        [StencilStage(make_star(2, 1)), StencilStage("diffusion2d")],
        (24, 18), boundary=("clamp", "reflect"))
    geom = BlockGeometry(2, (24, 18), problem.stencil.radius, 2, (9,),
                         par_vec=1)
    g = jax.random.uniform(jax.random.PRNGKey(13), (24, 18), jnp.float32,
                           0.5, 2.0)
    cf = problem.resolve_coeffs(dtype=jnp.float32)
    dag = chain_dag(problem.exec_stages)
    a = run_pallas_chain(problem.exec_stages, geom, g,
                         pack_program_coeffs(problem.exec_stages, cf), 5,
                         None, interpret=True)
    b = run_pallas_dag(dag, geom, g, pack_dag_coeffs(dag, cf), 5, None,
                       interpret=True)
    assert np.array_equal(np.asarray(a), np.asarray(b)), \
        "chain and DAG executors diverged on a linear program"


# --- DAG validation: every malformed wiring fails at construction -------------

def test_dag_cycle_rejected():
    s = make_star(2, 1)
    with pytest.raises(ValueError, match="[Cc]ycle"):
        StencilProgram((StencilStage(s, name="a", inputs=("b",)),
                        StencilStage(s, name="b", inputs=("a",))))


def test_dag_dangling_input_rejected():
    with pytest.raises(ValueError, match="nope"):
        StencilProgram((StencilStage(make_star(2, 1), name="a",
                                     inputs=("nope",)),))


def test_dag_arity_mismatch_rejected():
    with pytest.raises(ValueError, match="2 .*1|1 .*2|arity|inputs"):
        StencilStage(make_combine(2, 2), name="m", inputs=("u",))


def test_dag_unused_stage_rejected():
    s = make_star(2, 1)
    with pytest.raises(ValueError, match="never consumed"):
        StencilProgram((StencilStage(s, name="dead", inputs=("u",)),
                        StencilStage(s, name="live", inputs=("u",))),
                       updates={"u": "live"})


def test_dag_bad_update_target_rejected():
    with pytest.raises(ValueError):
        StencilProgram((StencilStage(make_star(2, 1), name="a",
                                     inputs=("u",)),),
                       updates={"u": "ghost"})


def test_multi_stage_without_names_needs_explicit_inputs():
    """A multi-input stage downstream of an unnamed fan-out cannot guess its
    wiring — construction must demand explicit inputs."""
    with pytest.raises(ValueError, match="inputs"):
        StencilProgram((StencilStage(make_star(2, 1)),
                        StencilStage(make_combine(2, 2))))


def test_dag_mixed_periodicity_across_branches_rejected():
    """Periodicity is structural (wrap layout, stream extension, the ring):
    two parallel DAG branches cannot disagree on an axis' periodicity."""
    s = make_star(2, 1)
    prog = StencilProgram(
        (StencilStage(s, name="a", inputs=("u",),
                      boundary=("periodic", "clamp")),
         StencilStage(s, name="b", inputs=("u",), boundary="clamp"),
         StencilStage(make_combine(2, 2), name="m", inputs=("a", "b"))))
    with pytest.raises(ValueError, match="periodic"):
        StencilProblem(prog, (16, 16))


# --- cache hygiene for DAG programs -------------------------------------------

def _pr6_fingerprint(prog):
    """The pre-DAG hashing algorithm, verbatim: stage fingerprints + (name,
    coeffs, BC token) only.  Linear programs MUST still hash to this."""
    import hashlib
    h = hashlib.sha1()
    for s in prog.stages:
        btok = (s.boundary.token() if hasattr(s.boundary, "token")
                else repr(s.boundary))
        h.update(stencil_fingerprint(s.stencil).encode())
        h.update(repr((s.name, s.coeffs, btok)).encode())
    return h.hexdigest()[:8]


def test_linear_program_keeps_pre_dag_fingerprint():
    prob = StencilProblem(
        [StencilStage("diffusion2d"), StencilStage(make_star(2, 1))],
        (24, 24), boundary=("clamp", "reflect"))
    assert not prob.is_dag
    assert stencil_fingerprint(prob.stencil) == _pr6_fingerprint(prob.stencil)


def test_dag_wiring_splits_fingerprint():
    shape = (20, 16)
    lin = StencilProblem([StencilStage("diffusion2d"),
                          StencilStage("diffusion2d")], shape)
    dag = StencilProblem(_residual_program(), shape)
    wave = StencilProblem(_wave2d_program(), shape)
    fps = {stencil_fingerprint(p.stencil) for p in (lin, dag, wave)}
    assert len(fps) == 3
    # and the exec keys split too (different compiled graphs)
    assert (_exec_key("engine", lin, None) != _exec_key("engine", dag, None))
    assert (_exec_key("engine", dag, None) != _exec_key("engine", wave, None))


def test_dtype_splits_keys_for_dag_programs():
    """Satellite regression: dtype is part of both cache keys for *program*
    problems, DAG-shaped included."""
    f32 = StencilProblem(_wave2d_program(), (24, 24), dtype="float32")
    b16 = StencilProblem(_wave2d_program(), (24, 24), dtype="bfloat16")
    cfg = _engine_cfg()
    dev = cfg.resolved_device()
    assert (schedule_key(f32, cfg, dev, 1, None, salt="s")
            != schedule_key(b16, cfg, dev, 1, None, salt="s"))
    assert _exec_key("engine", f32, None) != _exec_key("engine", b16, None)


def test_dag_exec_cache_never_serves_across_dtypes():
    clear_exec_cache()
    try:
        problem32 = StencilProblem(_wave2d_program(), (18, 16),
                                   dtype="float32")
        problem16 = StencilProblem(_wave2d_program(), (18, 16),
                                   dtype="bfloat16")
        base = jax.random.uniform(jax.random.PRNGKey(14),
                                  problem32.state_shape, jnp.float32, 0.5, 2.0)
        p32 = plan(problem32, _engine_cfg(bsize=8))
        out32 = p32.run(base, iters=2)
        misses = exec_cache_stats()["misses"]
        p16 = plan(problem16, _engine_cfg(bsize=8))
        out16 = p16.run(base.astype(jnp.bfloat16), iters=2)
        assert exec_cache_stats()["misses"] == misses + 1
        assert out32.dtype == jnp.float32 and out16.dtype == jnp.bfloat16
    finally:
        clear_exec_cache()


# --- distributed DAG (subprocess: fake multi-device view) ---------------------

def test_distributed_dag_matches_oracle():
    script = os.path.join(os.path.dirname(__file__),
                          "dag_distributed_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL OK" in out.stdout
