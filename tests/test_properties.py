"""Hypothesis property tests on the system's invariants (via ``plan()``)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property sweeps need the optional hypothesis dep")
import hypothesis.strategies as st   # noqa: E402
from hypothesis import given, settings  # noqa: E402

import jax                           # noqa: E402
import jax.numpy as jnp              # noqa: E402
import numpy as np                   # noqa: E402

from repro.api import RunConfig, StencilProblem, plan        # noqa: E402
from repro.core import STENCILS, default_coeffs, precision   # noqa: E402
from repro.core.blocking import (BlockGeometry,              # noqa: E402
                                 superstep_traffic_bytes)
from repro.kernels.ref import oracle_run                     # noqa: E402


def _plan_run(stencil, g, c, iters, par_time, bsize, aux=None,
              backend="pallas_interpret", boundary="clamp", par_vec=1,
              dtype="float32"):
    p = plan(StencilProblem(stencil, tuple(g.shape), dtype=dtype,
                            boundary=boundary),
             RunConfig(backend=backend, par_time=par_time, bsize=bsize,
                       par_vec=par_vec))
    return p.run(g, iters, c, aux=aux), p.problem.bc


_bc_kind = st.sampled_from(["clamp", "periodic", "reflect", "constant:0.6"])
_dtype = st.sampled_from(["float32", "bfloat16"])

_geometry2d = st.tuples(
    st.integers(2, 40),            # ny
    st.integers(2, 70),            # nx
    st.integers(1, 6),             # iters
    st.integers(1, 4),             # par_time
    st.sampled_from([16, 24, 32]), # bsize
    st.sampled_from([1, 2, 4, 8]), # par_vec (stream-axis vector width)
    st.sampled_from(["diffusion2d", "hotspot2d"]),
    st.tuples(_bc_kind, _bc_kind), # per-axis BC mix (stream, blocked)
    _dtype,                        # storage dtype (f32 accumulation always)
)


@settings(max_examples=25, deadline=None)
@given(_geometry2d)
def test_pallas_equals_oracle_any_geometry(params):
    """Blocking seams can never leak a wrong halo — for ANY per-axis BC mix
    crossed with ANY (bsize, par_time, par_vec, grid, iters, dtype)
    combination, under the drawn dtype's explicit ulp budget."""
    ny, nx, iters, par_time, bsize, par_vec, name, bc_mix, dtype = params
    stencil = STENCILS[name]
    if bsize <= 2 * stencil.radius * par_time:
        return
    sd = jnp.dtype(dtype)
    key = jax.random.PRNGKey(ny * 1000 + nx)
    g = jax.random.uniform(key, (ny, nx), jnp.float32, 0.5, 2.0).astype(sd)
    aux = (jax.random.uniform(jax.random.fold_in(key, 7), (ny, nx),
                              jnp.float32, 0.0, 0.1).astype(sd)
           if stencil.has_aux else None)
    c = default_coeffs(stencil)
    got, bc = _plan_run(stencil, g, c, iters, par_time, bsize, aux,
                        boundary=bc_mix, par_vec=par_vec, dtype=dtype)
    assert got.dtype == sd
    want = oracle_run(stencil, g, c, iters, aux, bc=bc)
    tol = precision.tolerance(dtype, iters)
    np.testing.assert_allclose(np.asarray(got.astype(jnp.float32)),
                               np.asarray(want.astype(jnp.float32)), **tol,
                               err_msg=f"bc={bc.token()} pt={par_time} "
                                       f"bs={bsize} V={par_vec} {ny}x{nx} "
                                       f"{dtype}")


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 16), st.integers(3, 24), st.integers(3, 20),
       st.integers(1, 4), st.integers(1, 3),
       st.tuples(_bc_kind, _bc_kind, _bc_kind))
def test_engine_3d_equals_oracle_any_bc(nz, ny, nx, iters, par_time, bc_mix):
    """3D sweep through the engine backend: three independent per-axis BC
    draws against random geometry."""
    stencil = STENCILS["diffusion3d"]
    bsize = 8
    if bsize <= 2 * stencil.radius * par_time:
        return
    g = jax.random.uniform(jax.random.PRNGKey(nz * 31 + nx), (nz, ny, nx),
                           jnp.float32, 0.5, 2.0)
    c = default_coeffs(stencil)
    got, bc = _plan_run(stencil, g, c, iters, par_time, (bsize, bsize),
                        backend="engine", boundary=bc_mix)
    want = oracle_run(stencil, g, c, iters, bc=bc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5,
                               err_msg=f"bc={bc.token()} pt={par_time}")


@settings(max_examples=50, deadline=None)
@given(st.integers(10, 100000), st.integers(10, 100000), st.integers(1, 16),
       st.integers(1, 8), st.sampled_from([256, 1024, 4096]))
def test_blocking_geometry_invariants(dimy, dimx, par_time, rad, bsize):
    if bsize <= 2 * rad * par_time:
        return
    geom = BlockGeometry(2, (dimy, dimx), rad, par_time, (bsize,))
    # compute blocks tile at least the whole grid (Eq. 5)
    assert geom.bnum[0] * geom.csize[0] >= dimx
    # ... but never overshoot by a full block
    assert (geom.bnum[0] - 1) * geom.csize[0] < dimx
    # halo identity (Eq. 4): bsize = csize + 2*halo
    assert geom.csize[0] + 2 * geom.size_halo == geom.bsize[0]
    # Eq. (7) traversed extent == padded extent (single definition)
    assert geom.trav == geom.padded_dims
    # redundancy >= 1, monotone in halo
    assert geom.redundancy >= 1.0
    # traffic accounting is positive and >= compulsory traffic
    st_ = STENCILS["diffusion2d"]
    traffic = superstep_traffic_bytes(geom, st_.num_read, st_.num_write)
    assert traffic >= 4 * 2 * dimy * dimx * 0.99  # >= one read + one write


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(2, 40), st.integers(0, 3), _dtype)
def test_diffusion_maximum_principle(ny, nx, seed, dtype):
    """Convex-coefficient diffusion can never exceed initial extrema (up to
    the drawn dtype's per-step output-rounding ulps)."""
    stencil = STENCILS["diffusion2d"]
    g = jax.random.uniform(jax.random.PRNGKey(seed), (ny, nx),
                           jnp.float32, -1.0, 1.0).astype(jnp.dtype(dtype))
    c = default_coeffs(stencil)   # convex: coefficients sum to 1
    out, _ = _plan_run(stencil, g, c, 5, 2, 16, dtype=dtype)
    slack = precision.tolerance(dtype, 5)["atol"]
    assert float(jnp.max(out.astype(jnp.float32))) \
        <= float(jnp.max(g.astype(jnp.float32))) + slack
    assert float(jnp.min(out)) >= float(jnp.min(g)) - 1e-5
    assert not bool(jnp.any(jnp.isnan(out)))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 11))
def test_temporal_blocking_is_iteration_invariant(iters):
    """Result depends only on iteration count, not on par_time factorization.
    A single plan is reused across every par_time's oracle comparison."""
    stencil = STENCILS["diffusion2d"]
    g = jax.random.uniform(jax.random.PRNGKey(0), (19, 37),
                           jnp.float32, 0.5, 2.0)
    c = default_coeffs(stencil)
    ref = oracle_run(stencil, g, c, iters)
    for pt in (1, 2, 4):
        got, _ = _plan_run(stencil, g, c, iters, pt, 24)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)
