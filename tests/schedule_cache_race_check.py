"""Concurrent-writer loop for the schedule-cache race regression test.

Each invocation puts ``count`` distinct entries (``<prefix>-<i>``) into the
shared cache file as fast as it can.  The parent test runs two of these
concurrently and asserts no entry was lost — the read-modify-write in
``ScheduleCache.put`` merges with the on-disk state under an exclusive
lock immediately before its atomic replace, so concurrent writers must
never clobber each other's entries.

Usage: schedule_cache_race_check.py <cache_path> <prefix> <count>
"""
import sys

from repro.api.schedule_cache import ScheduleCache


def main():
    path, prefix, count = sys.argv[1], sys.argv[2], int(sys.argv[3])
    cache = ScheduleCache(path)
    for i in range(count):
        cache.put(f"{prefix}-{i}", {"par_time": i, "writer": prefix})
    print("DONE", prefix)


if __name__ == "__main__":
    main()
