"""HLO analyzer: trip-count-aware FLOPs must match analytic counts."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_multiplied_by_trip_count():
    D, B, L = 128, 32, 8

    def model(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    c = _compile(model,
                 jax.ShapeDtypeStruct((B, D), jnp.float32),
                 jax.ShapeDtypeStruct((L, D, D), jnp.float32))
    an = hlo_analysis.analyze(c.as_text())
    ideal = 2 * B * D * D * L
    assert ideal * 0.9 <= an.flops <= ideal * 1.3, (an.flops, ideal)
    assert any(t == L for t in an.while_trips.values()), an.while_trips


def test_nested_scan_flops():
    D, B, L1, L2 = 64, 16, 3, 5

    def model(x, ws):
        def outer(c, w2):
            def inner(ci, w):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, w2)
            return c, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    c = _compile(model,
                 jax.ShapeDtypeStruct((B, D), jnp.float32),
                 jax.ShapeDtypeStruct((L1, L2, D, D), jnp.float32))
    an = hlo_analysis.analyze(c.as_text())
    ideal = 2 * B * D * D * L1 * L2
    assert ideal * 0.9 <= an.flops <= ideal * 1.3, (an.flops, ideal)


def test_grad_flops_roughly_3x_forward():
    # grad wrt BOTH operands of the matmul: backward needs dx = g @ w.T and
    # dw = x.T @ g on top of the forward x @ w  ->  ~3x forward FLOPs.
    # (grad wrt w alone would be exactly 2x: forward + dw only.)
    D, B = 256, 64

    def f(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    cf = _compile(f, jax.ShapeDtypeStruct((D, D), jnp.float32),
                  jax.ShapeDtypeStruct((B, D), jnp.float32))
    cg = _compile(jax.grad(f, argnums=(0, 1)),
                  jax.ShapeDtypeStruct((D, D), jnp.float32),
                  jax.ShapeDtypeStruct((B, D), jnp.float32))
    ff = hlo_analysis.analyze(cf.as_text()).flops
    fg = hlo_analysis.analyze(cg.as_text()).flops
    assert 2.4 <= fg / ff <= 3.6, (ff, fg)
