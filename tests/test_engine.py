"""Blocked engine == unblocked oracle, for every paper stencil (via ``plan()``),
plus BlockGeometry unit checks against the paper's equations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RunConfig, StencilProblem, plan
from repro.core import STENCILS, default_coeffs, make_star
from repro.core.blocking import BlockGeometry
from repro.kernels.ref import oracle_run

jax.config.update("jax_enable_x64", False)


def _grid(stencil, dims, seed=0):
    k = jax.random.PRNGKey(seed)
    g = jax.random.uniform(k, dims, jnp.float32, 0.5, 2.0)
    aux = None
    if stencil.has_aux:
        aux = jax.random.uniform(jax.random.fold_in(k, 1), dims,
                                 jnp.float32, 0.0, 0.1)
    return g, aux


def _engine_run(st, g, c, iters, par_time, bsize, aux=None):
    p = plan(StencilProblem(st, tuple(g.shape)),
             RunConfig(backend="engine", par_time=par_time, bsize=bsize))
    return p.run(g, iters, c, aux=aux)


@pytest.mark.parametrize("name", ["diffusion2d", "hotspot2d"])
@pytest.mark.parametrize("iters,par_time,bsize", [
    (1, 1, 24), (4, 4, 24), (7, 4, 32), (8, 2, 20), (3, 8, 40),
])
def test_blocked_matches_oracle_2d(name, iters, par_time, bsize):
    st = STENCILS[name]
    dims = (37, 53)   # deliberately not multiples of anything
    g, aux = _grid(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, iters, aux)
    got = _engine_run(st, g, c, iters, par_time, (bsize,), aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", ["diffusion3d", "hotspot3d"])
@pytest.mark.parametrize("iters,par_time,bsize", [
    (1, 1, 12), (4, 2, 12), (5, 4, 16), (2, 2, 10),
])
def test_blocked_matches_oracle_3d(name, iters, par_time, bsize):
    st = STENCILS[name]
    dims = (9, 21, 19)
    g, aux = _grid(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, iters, aux)
    got = _engine_run(st, g, c, iters, par_time, (bsize, bsize), aux)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_high_order_star():
    st = make_star(2, 2)
    dims = (25, 33)
    g, _ = _grid(st, dims)
    c = default_coeffs(st)
    want = oracle_run(st, g, c, 3)
    got = _engine_run(st, g, c, 3, 2, (24,))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_geometry_matches_paper_equations():
    # Paper Table 4 row: Diffusion 2D, A-10: bsize=4096, par_time=36, rad=1.
    geom = BlockGeometry(2, (16096, 16096), 1, 36, (4096,))
    assert geom.size_halo == 36            # Eq. (2)
    assert geom.csize == (4024,)           # Eq. (4)
    assert geom.bnum == (4,)               # Eq. (5): ceil(16096/4024)=4
    assert geom.trav == (4 * 4024 + 72,)   # Eq. (7)
    assert geom.trav == geom.padded_dims   # Eq. (7) == padded extent (alias)
    # dim chosen a multiple of csize -> minimal out-of-bound (paper §5.2)
    assert geom.bnum[0] * geom.csize[0] == 16096


def test_bad_geometry_rejected():
    with pytest.raises(ValueError):
        BlockGeometry(2, (64, 64), 1, 16, (32,))  # bsize <= 2*halo


def test_box_stencil_blocked_matches_oracle():
    """Paper §6.4 portability claim: differently-shaped (box) stencils run
    through the same blocked engine unchanged."""
    from repro.core import make_box
    st = make_box(2, 1)          # 9-point box
    key = jax.random.PRNGKey(3)
    grid = jax.random.uniform(key, (96, 160), jnp.float32, 0.5, 2.0)
    coeffs = default_coeffs(st)
    ref = oracle_run(st, grid, coeffs, 6, None)
    out = _engine_run(st, grid, coeffs, 6, 3, (64,))
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_box3d_stencil_blocked_matches_oracle():
    from repro.core import make_box
    st = make_box(3, 1)          # 27-point box
    key = jax.random.PRNGKey(4)
    grid = jax.random.uniform(key, (24, 48, 48), jnp.float32, 0.5, 2.0)
    coeffs = default_coeffs(st)
    ref = oracle_run(st, grid, coeffs, 4, None)
    out = _engine_run(st, grid, coeffs, 4, 2, (24, 24))
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
