"""Integration tests for the production entry points (subprocess smoke).

These run the actual CLI launchers end-to-end on smoke configs — the same
code path a cluster job executes, minus the mesh size.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}


def _run(args, timeout=500):
    return subprocess.run([sys.executable, "-m", *args], cwd=ROOT, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_launcher_dense(tmp_path):
    r = _run(["repro.launch.train", "--arch", "qwen3-1.7b", "--smoke",
              "--steps", "4", "--batch", "4", "--seq", "32",
              "--ckpt-dir", str(tmp_path)])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss" in r.stdout


@pytest.mark.slow
def test_train_launcher_compressed(tmp_path):
    r = _run(["repro.launch.train", "--arch", "qwen3-1.7b", "--smoke",
              "--steps", "4", "--batch", "4", "--seq", "32",
              "--compress", "0.1", "--ckpt-dir", str(tmp_path)])
    assert r.returncode == 0, r.stderr[-2000:]


@pytest.mark.slow
def test_serve_launcher_moe():
    r = _run(["repro.launch.serve", "--arch", "qwen3-moe-30b-a3b", "--smoke",
              "--batch", "2", "--prompt-len", "8", "--max-new", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decode" in r.stdout


@pytest.mark.slow
def test_dryrun_single_cell_on_tiny_mesh(tmp_path):
    # the dry-run entry point itself (512 placeholder devices) on the
    # fastest cell: proves the XLA_FLAGS bootstrapping works end-to-end
    r = _run(["repro.launch.dryrun", "--arch", "mamba2-1.3b", "--shape",
              "long_500k", "--mesh", "single"], timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "roofline" in r.stdout
